"""End-to-end driver: Mango tunes the LM trainer (the paper's production use).

The objective is a *real training run* of the smollm-135m architecture
(reduced width on this CPU container; pass --full-width on a TPU host) for a
few hundred steps on the synthetic Markov stream; the tuner searches
learning rate, warmup, weight decay, and remat policy — dispatched through
the thread scheduler with a wall-clock deadline per batch, so a diverging or
hung trial is simply dropped (fault-tolerant contract).

Run:  PYTHONPATH=src:. python examples/tune_training.py \
          [--trial-steps 120] [--iterations 5] [--batch 2]
"""
import argparse
import json

from scipy.stats import uniform

from repro.core import Tuner, loguniform
from repro.launch import train as train_mod
from repro.scheduler import ThreadScheduler

ap = argparse.ArgumentParser()
ap.add_argument("--trial-steps", type=int, default=120)
ap.add_argument("--iterations", type=int, default=5)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--full-width", action="store_true")
args = ap.parse_args()


def train_trial(par) -> float:
    targv = [
        "--arch", "smollm-135m",
        "--steps", str(args.trial_steps),
        "--batch", "8", "--seq", "128", "--fp32",
        "--lr", str(par["lr"]),
        "--warmup", str(int(par["warmup"])),
        "--weight-decay", str(par["weight_decay"]),
        "--remat", par["remat"],
    ]
    if not args.full_width:
        targv.append("--reduced")
    targs = train_mod.make_parser().parse_args(targv)
    targs.verbose = False
    out = train_mod.run(targs)
    # objective: negative mean loss over the last 20 steps (stable tail)
    tail = out["losses"][-20:]
    return -sum(tail) / len(tail)


param_space = {
    "lr": loguniform(-3.7, 2.2),        # 10^-3.7 .. 10^-1.5
    "warmup": range(5, 60),
    "weight_decay": uniform(0.0, 0.3),
    "remat": ["none", "full"],          # system knob: memory/compute trade
}

if __name__ == "__main__":
    sched = ThreadScheduler(n_workers=1, timeout=600)
    tuner = Tuner(param_space, sched.make_objective(train_trial),
                  dict(optimizer="bayesian", batch_size=args.batch,
                       num_iteration=args.iterations, initial_random=2,
                       seed=0, mc_samples=2000, fit_steps=15,
                       checkpoint_path="/tmp/tune_training_ckpt.json"))
    res = tuner.maximize()
    print(json.dumps({
        "best_tail_loss": -res.best_objective,
        "best_params": {k: (float(v) if not isinstance(v, str) else v)
                        for k, v in res.best_params.items()},
        "trials": len(res.objective_values),
    }, indent=2))
