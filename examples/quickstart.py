"""Quickstart: tune an RBF-kernel classifier, exactly the paper's Listing 2.

The SVM stand-in is a kernel logistic-regression classifier implemented in
JAX (sklearn is not available offline): hyperparameters C (inverse
regularization) and gamma (RBF width) — the same two-parameter space as the
paper's SVM example.

Uses the unified API: a *per-trial* function plus a scheduler in the config
(``scheduler.make_objective`` wraps it into the paper's batch objective
behind the scenes; passing a batch objective directly still works).

Run:  PYTHONPATH=src:. python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from scipy.stats import uniform

from repro.core import Tuner, loguniform
from repro.scheduler import SerialScheduler


def make_blobs(seed=0, n=240):
    rng = np.random.default_rng(seed)
    centers = np.array([[0, 0], [2.2, 1.2], [0.8, 2.4]])
    X = np.concatenate([rng.normal(c, 0.55, size=(n // 3, 2))
                        for c in centers])
    y = np.repeat(np.arange(3), n // 3)
    p = rng.permutation(n)
    return jnp.asarray(X[p], jnp.float32), jnp.asarray(y[p], jnp.int32)


X, Y = make_blobs()
X_tr, Y_tr, X_te, Y_te = X[:160], Y[:160], X[160:], Y[160:]


def rbf_classifier_accuracy(C: float, gamma: float) -> float:
    """Kernel logistic regression with an RBF gram matrix, trained by GD."""
    d2 = jnp.sum((X_tr[:, None] - X_tr[None]) ** 2, -1)
    K = jnp.exp(-gamma * d2)
    d2_te = jnp.sum((X_te[:, None] - X_tr[None]) ** 2, -1)
    K_te = jnp.exp(-gamma * d2_te)
    Yh = jax.nn.one_hot(Y_tr, 3)

    def loss(a):
        logits = K @ a
        reg = jnp.sum(a * (K @ a)) / (2.0 * C * len(X_tr))
        return -jnp.mean(jnp.sum(Yh * jax.nn.log_softmax(logits), -1)) + reg

    a = jnp.zeros((len(X_tr), 3))
    g = jax.jit(jax.grad(loss))
    for _ in range(300):
        a = a - 0.03 * g(a)  # step bounded by the gram spectral norm
    acc = jnp.mean(jnp.argmax(K_te @ a, -1) == Y_te)
    return float(acc)


# --- the paper's Listing 2 space ------------------------------------------
param_space = {
    "C": uniform(0.1, 10),          # scipy.stats distribution
    "gamma": loguniform(-3, 3),     # Mango's log-uniform: 10^[-3, 0]
}


# --- the paper's Listing 3 trial: one config in, one score out -------------
def trial(par):
    return rbf_classifier_accuracy(par["C"], par["gamma"])


if __name__ == "__main__":
    tuner = Tuner(param_space, trial,
                  dict(scheduler=SerialScheduler(), optimizer="bayesian",
                       batch_size=3, num_iteration=10,
                       initial_random=2, seed=0))
    result = tuner.maximize()
    print(f"best accuracy: {result.best_objective:.4f}")
    print(f"best params:   C={result.best_params['C']:.3f} "
          f"gamma={result.best_params['gamma']:.5f}")
    print(f"evaluations:   {len(result.objective_values)}")
    assert result.best_objective > 0.85
