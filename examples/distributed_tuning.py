"""Distributed tuning with the Celery-style task queue + fault injection.

Mirrors the paper's production deployment (Listing 4 / Kubernetes+Celery):
a task-queue scheduler with a worker pool, per-batch deadline, injected
worker failures and stragglers — the tuner observes only the partial results
that make the deadline, exactly the paper's fault-tolerance contract.

Both tuners drive the same ask/tell core with the same per-trial function;
the sync one takes the scheduler in its config, the async one keeps
``batch_size`` trials continuously in flight (no barrier), waking on the
scheduler's completion condition and checkpointing after every completion.

Run:  PYTHONPATH=src:. python examples/distributed_tuning.py
"""
import tempfile
import time

import numpy as np
from scipy.stats import randint, uniform

from repro.core import AsyncTuner, Tuner
from repro.scheduler import FaultInjection, TaskQueueScheduler


# A KNN-like objective (the paper's KNN_Celery.ipynb example): accuracy of a
# k-nearest-neighbour classifier on a noisy two-moon dataset.
def make_moons(seed=0, n=400):
    rng = np.random.default_rng(seed)
    t = rng.uniform(0, np.pi, n // 2)
    a = np.stack([np.cos(t), np.sin(t)], 1) + rng.normal(0, 0.18, (n // 2, 2))
    b = (np.stack([1 - np.cos(t), 0.5 - np.sin(t)], 1)
         + rng.normal(0, 0.18, (n // 2, 2)))
    X = np.concatenate([a, b])
    y = np.concatenate([np.zeros(n // 2), np.ones(n // 2)]).astype(int)
    p = rng.permutation(n)
    return X[p], y[p]


X, Y = make_moons()
X_tr, Y_tr, X_te, Y_te = X[:300], Y[:300], X[300:], Y[300:]


def knn_accuracy(par):
    time.sleep(0.02)  # pretend this is an expensive remote job
    k = int(par["n_neighbors"])
    w = par["weights"]
    d = np.linalg.norm(X_te[:, None] - X_tr[None], axis=-1)
    idx = np.argsort(d, axis=1)[:, :k]
    if w == "distance":
        wts = 1.0 / (np.take_along_axis(d, idx, 1) + 1e-9)
    else:
        wts = np.ones_like(idx, dtype=float)
    votes = np.zeros((len(X_te), 2))
    for c in (0, 1):
        votes[:, c] = np.where(Y_tr[idx] == c, wts, 0).sum(1)
    return float((votes.argmax(1) == Y_te).mean())


param_space = {
    "n_neighbors": randint(1, 60),
    "weights": ["uniform", "distance"],
    "p_jitter": uniform(0, 1),  # inert param: shows robustness to noise dims
}

if __name__ == "__main__":
    # 20% of workers crash, 10% straggle past the 1s batch deadline
    sched = TaskQueueScheduler(
        n_workers=8, timeout=1.0, max_retries=1,
        faults=FaultInjection(failure_rate=0.2, straggler_rate=0.1,
                              straggler_delay=5.0, seed=1))
    tuner = Tuner(param_space, knn_accuracy,
                  dict(scheduler=sched, optimizer="clustering",
                       batch_size=8, num_iteration=8, seed=0))
    res = tuner.maximize()
    print(f"[sync ] best acc {res.best_objective:.4f} with "
          f"{res.best_params['n_neighbors']} neighbours "
          f"({res.best_params['weights']}); observed "
          f"{len(res.objective_values)} results, "
          f"{res.n_failed} lost to faults/stragglers")
    print(f"[sync ] scheduler stats: {sched.stats}")
    sched.shutdown()

    # async mode: continuous batching — no barrier between batches.  The
    # checkpoint (written after every completion, in-flight trials
    # included) would let a killed run resume to identical proposals.
    sched2 = TaskQueueScheduler(n_workers=8)
    with tempfile.TemporaryDirectory() as td:
        ares = AsyncTuner(param_space, knn_accuracy, sched2, num_evals=40,
                          batch_size=8, seed=0,
                          checkpoint_path=f"{td}/async_ckpt.json"
                          ).maximize()
    print(f"[async] best acc {ares.best_objective:.4f} after "
          f"{len(ares.objective_values)} evals in "
          f"{ares.wall_time_s:.1f}s ({ares.n_failed} failed)")
    sched2.shutdown()
    assert res.best_objective > 0.9
