"""Batched serving example: prefill a request batch, stream greedy decode.

Uses the same prefill/decode steps the production dry-run lowers for the
(16,16) mesh — here executed for a reduced config on CPU.

Run:  PYTHONPATH=src:. python examples/serve_batched.py
"""
from repro.launch import serve

if __name__ == "__main__":
    args = serve.make_parser().parse_args(
        ["--arch", "jamba-v0.1-52b", "--reduced", "--batch", "4",
         "--prompt-len", "32", "--gen", "12", "--fp32"])
    out = serve.run(args)
    print(f"arch={out['arch']} prefill={out['prefill_s']}s "
          f"decode={out['decode_s']}s ({out['decode_tok_s']} tok/s) "
          f"shape={out['generated_shape']}")
    assert out["generated_shape"][1] == 12
