"""Fused device-resident GP-BUCB proposal: parity with the numpy reference
path, incremental-Cholesky observation appends, and checkpoint-resume
determinism."""
import json

import numpy as np
import pytest
from scipy.stats import uniform

from repro.core import Tuner
from repro.core.gp import GaussianProcess
from repro.core.strategies import (FusedHallucinationStrategy,
                                   HallucinationStrategy, STRATEGIES)


def _data(n=20, seed=0, n_cand=600):
    rng = np.random.default_rng(seed)
    X = rng.uniform(size=(n, 2)).astype(np.float32)
    y = -((X[:, 0] - 0.6) ** 2 + (X[:, 1] - 0.4) ** 2)
    C = rng.uniform(size=(n_cand, 2)).astype(np.float32)
    return X, y, C


def test_default_strategy_is_fused():
    assert STRATEGIES["bayesian"] is FusedHallucinationStrategy
    assert STRATEGIES["hallucination_ref"] is HallucinationStrategy


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("batch_size", [1, 4, 8])
def test_fused_matches_python_loop_reference(seed, batch_size):
    """The jit'd fori-loop picks the same candidate indices as the seed
    Python-loop HallucinationStrategy on fixed seeds."""
    X, y, C = _data(seed=seed)
    ref = HallucinationStrategy(2, 1e4, fit_steps=15)
    fused = FusedHallucinationStrategy(2, 1e4, fit_steps=15)
    assert (fused.propose(X, y, C, batch_size)
            == ref.propose(X, y, C, batch_size))


def test_fused_parity_across_incremental_iterations():
    """Parity holds through the incremental observe path too when the fused
    GP re-tunes hypers every iteration (refit_every=1 == reference refit
    schedule)."""
    X, y, C = _data(seed=3)
    ref = HallucinationStrategy(2, 1e4, fit_steps=15)
    fused = FusedHallucinationStrategy(2, 1e4, fit_steps=15, refit_every=1)
    Xl, yl = list(X), list(y)
    for _ in range(3):
        Xa, ya = np.asarray(Xl, np.float32), np.asarray(yl, np.float32)
        picks = fused.propose(Xa, ya, C, batch_size=3)
        assert picks == ref.propose(Xa, ya, C, batch_size=3)
        for i in picks:
            Xl.append(C[i])
            yl.append(-((C[i][0] - 0.6) ** 2 + (C[i][1] - 0.4) ** 2))


def test_incremental_observe_appends_without_refit():
    X, y, C = _data(seed=4)
    gp = GaussianProcess(2, fit_steps=15, refit_every=100)
    gp.observe(X, y)
    hypers0 = (np.asarray(gp.state.ls).copy(), float(gp.state.var))
    # grow past the padded-buffer boundary (n=20 pads to 32)
    rng = np.random.default_rng(0)
    X2 = np.concatenate([X, rng.uniform(size=(20, 2)).astype(np.float32)])
    y2 = np.concatenate([y, rng.normal(size=20).astype(np.float32)])
    st = gp.observe(X2, y2)
    assert st.n == 40 and gp.n_fit == 20          # appended, not refit
    assert np.array_equal(np.asarray(st.ls), hypers0[0])
    # the incremental Cholesky matches a from-scratch factorization
    ref = GaussianProcess(2, fit_steps=15)
    ref.fit(X2, y2)
    mu_inc, sd_inc = gp.predict(C[:50], st)
    # same hypers are required for a meaningful comparison: refit with the
    # frozen hypers by predicting through the appended state vs a fresh
    # Cholesky of the same kernel matrix
    from repro.core.gp import cholesky_masked
    import dataclasses
    import jax.numpy as jnp
    # rebuild standardized y exactly as the incremental state holds it
    L_full = cholesky_masked(jnp.asarray(st.X), jnp.asarray(st.mask),
                             st.ls, st.var, st.noise)
    st_full = dataclasses.replace(st, L=L_full)
    mu_ref, sd_ref = gp.predict(C[:50], st_full)
    np.testing.assert_allclose(mu_inc, mu_ref, atol=5e-3)
    np.testing.assert_allclose(sd_inc, sd_ref, atol=5e-3)


def test_degenerate_standardization_guard_symmetric_on_restore():
    """Constant initial observations leave y_std ~ 1e-6; a later differing
    value must force an immediate re-tune — and a checkpoint-resume replay
    (restore + observe) must reach the same refit decision as the
    uninterrupted incremental run."""
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(6, 2)).astype(np.float32)
    y = np.zeros(6, np.float32)
    X2 = np.concatenate([X, rng.uniform(size=(2, 2)).astype(np.float32)])
    y2 = np.concatenate([y, np.array([0.1, 0.2], np.float32)])

    live = GaussianProcess(2, fit_steps=10, refit_every=100)
    live.observe(X, y)
    assert live.state.y_std < 1e-5
    live.observe(X2, y2)                    # wild rows arrive incrementally
    assert live.n_fit == 8                  # guard fired -> full refit

    resumed = GaussianProcess(2, fit_steps=10, refit_every=100)
    resumed.restore(X2, y2, n_fit=6)        # replay appends the wild rows
    resumed.observe(X2, y2)                 # next propose's observe
    assert resumed.n_fit == 8               # same refit decision
    np.testing.assert_array_equal(np.asarray(resumed.state.ls),
                                  np.asarray(live.state.ls))


def test_observe_refits_on_prefix_change_or_shrink():
    X, y, _ = _data(seed=5)
    gp = GaussianProcess(2, fit_steps=15, refit_every=100)
    gp.observe(X, y)
    assert gp.n_fit == 20
    y_mut = y.copy()
    y_mut[0] += 1.0                      # history rewritten -> full refit
    gp.observe(X, y_mut)
    assert gp.n_fit == 20
    gp.observe(X[:10], y_mut[:10])       # shrink -> full refit
    assert gp.n_fit == 10


def test_fused_pallas_threading():
    """use_pallas routes scoring through the gp_acquisition kernel via the
    shared factor core; the first pick (pure scoring, no hallucination
    yet) matches the chol path and batches stay valid/unique.  (Full-batch
    and noiseless near-tie parity live in test_device_proposal_parity.)"""
    X, y, C = _data(seed=0)
    fused = FusedHallucinationStrategy(2, 1e4, fit_steps=15)
    pallas = FusedHallucinationStrategy(2, 1e4, fit_steps=15,
                                        use_pallas=True)
    assert pallas.propose(X, y, C, 1) == fused.propose(X, y, C, 1)
    picks = pallas.propose(X, y, C, 6)
    assert len(set(picks)) == 6
    assert all(0 <= p < len(C) for p in picks)


SPACE = {"x": uniform(0, 1), "y": uniform(0, 1)}
FAST = dict(mc_samples=1200, fit_steps=15)


def _quad_objective(batch):
    return [-(p["x"] - 0.7) ** 2 - (p["y"] - 0.2) ** 2 for p in batch], \
        list(batch)


def test_checkpoint_resume_reproduces_remaining_proposals(tmp_path):
    """A Tuner resumed from checkpoint_path proposes the same remaining
    configurations as an uninterrupted run (GP fit/append schedule is
    replayed from the checkpointed gp_n_fit)."""
    conf = dict(optimizer="bayesian", num_iteration=6, batch_size=2, seed=7,
                refit_every=4, **FAST)
    full = Tuner(SPACE, _quad_objective, conf).maximize()

    ckpt = tmp_path / "t.json"
    conf_i = {**conf, "checkpoint_path": str(ckpt), "num_iteration": 3}
    Tuner(SPACE, _quad_objective, conf_i).maximize()
    assert json.loads(ckpt.read_text())["iteration"] == 3
    resumed = Tuner(SPACE, _quad_objective,
                    {**conf_i, "num_iteration": 6}).maximize()
    assert resumed.iterations == 6
    full_xy = [(p["x"], p["y"]) for p in full.params_tried]
    res_xy = [(p["x"], p["y"]) for p in resumed.params_tried]
    assert res_xy == full_xy
    assert resumed.objective_values == full.objective_values
