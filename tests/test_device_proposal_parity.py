"""Parity suite for the on-device proposal stack (ISSUE 3 tentpole,
hardened by the ISSUE 5 shared scoring core).

Covers the paths that used to fall off the single-program fast path:

  * the factor-core scorer with pending trials —
    ``fused_propose_pallas_pending`` absorbs the in-flight set with
    hardened (L, L^{-1}) factor appends *inside* the program; picks must
    match the host ``_absorb_pending`` loop + the fused pick, and the
    numpy reference strategy, on fixed seeds;
  * the clustering strategy — ``fused_cluster_propose`` runs acquisition,
    top-k, weighted k-means and the per-cluster argmax on-device through
    the same shared scoring core; picks must match the host reference
    pipeline (``propose_host``);
  * noiseless near-tie surfaces — the ROADMAP PR-3 pick-flip case.  Before
    ISSUE 5 these tests needed a noise floor on y because the float32
    K^{-1} quadratic form flipped near-tied argmaxes once the fitted noise
    collapsed; the hardened core (sum-of-squares variance against the
    triangular inverse factor + refined Schur solves) must pick identically
    to the Cholesky path with NO noise on the objective.
"""
import numpy as np
import pytest

from repro.core.strategies import (ClusteringStrategy,
                                   FusedHallucinationStrategy,
                                   HallucinationStrategy)


def _data(seed=0, n=20, n_cand=300, d=2, n_pend=3):
    rng = np.random.default_rng(seed)
    X = rng.uniform(size=(n, d)).astype(np.float32)
    y = (-np.sum((X - 0.6) ** 2, -1)
         + 0.05 * rng.normal(size=n)).astype(np.float32)
    C = rng.uniform(size=(n_cand, d)).astype(np.float32)
    P = rng.uniform(size=(n_pend, d)).astype(np.float32)
    return X, y, C, P


def _data_noiseless(seed=0, n=20, n_cand=300, d=2, n_pend=3):
    """The ROADMAP-documented pick-flip surface: a noiseless quadratic
    drives the fitted GP noise to its floor, K goes ill-conditioned, and
    near-tied UCB scores probe the scorer's float32 conditioning."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(size=(n, d)).astype(np.float32)
    y = (-np.sum((X - 0.6) ** 2, -1)).astype(np.float32)
    C = rng.uniform(size=(n_cand, d)).astype(np.float32)
    P = rng.uniform(size=(n_pend, d)).astype(np.float32)
    return X, y, C, P


# ------------------------------------------------- pallas pending absorb
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n_cand", [300, 600])
def test_pallas_pending_parity_three_way(seed, n_cand):
    """fused in-program absorb == host absorb loop == numpy reference."""
    X, y, C, P = _data(seed=seed, n_cand=n_cand)

    fused = FusedHallucinationStrategy(2, 1e4, fit_steps=15,
                                       use_pallas=True)
    picks = fused.propose(X, y, C, 4, pending=P)

    host = FusedHallucinationStrategy(2, 1e4, fit_steps=15, use_pallas=True)
    st = host.gp.observe(X, y)
    st = host.gp.ensure_capacity(st, len(P) + 4)
    st = host._absorb_pending(st, P)
    assert picks == host.pick_from_state(st, C, 4)

    ref = HallucinationStrategy(2, 1e4, fit_steps=15)
    assert picks == ref.propose(X, y, C, 4, pending=P)


def test_pallas_pending_batch_valid_and_unique():
    X, y, C, P = _data(seed=5, n_cand=600, n_pend=5)
    s = FusedHallucinationStrategy(2, 1e4, fit_steps=15, use_pallas=True)
    picks = s.propose(X, y, C, 6, pending=P)
    assert len(set(picks)) == 6
    assert all(0 <= p < len(C) for p in picks)


def test_pallas_downdate_matches_full_rescore_path():
    """The O(n S) in-kernel variance downdate must pick what the plain
    fused (Cholesky) path picks — the downdate is the extended system's
    exact block-inverse variance, not an approximation."""
    for seed in range(3):
        X, y, C, _ = _data(seed=seed)
        pal = FusedHallucinationStrategy(2, 1e4, fit_steps=15,
                                         use_pallas=True)
        chol = FusedHallucinationStrategy(2, 1e4, fit_steps=15)
        assert pal.propose(X, y, C, 4) == chol.propose(X, y, C, 4)


# ------------------------------------- conditioning (noiseless near-ties)
@pytest.mark.parametrize("seed", range(8))
def test_noiseless_near_tie_parity_three_way(seed):
    """Cholesky / K⁻¹-jit / K⁻¹-Pallas pick identically on noiseless
    surfaces — the ROADMAP PR-3 pick-flip case, now a hard parity claim
    instead of a noise-floored workaround (4 of these 8 seeds flipped on
    the pre-hardening K⁻¹ quadratic-form scorer)."""
    X, y, C, P = _data_noiseless(seed=seed)
    chol = FusedHallucinationStrategy(2, 1e4, fit_steps=15)
    kjit = FusedHallucinationStrategy(2, 1e4, fit_steps=15,
                                      scorer="kinv_jnp")
    kpal = FusedHallucinationStrategy(2, 1e4, fit_steps=15,
                                      use_pallas=True)
    picks = chol.propose(X, y, C, 4, pending=P)
    assert kjit.propose(X, y, C, 4, pending=P) == picks
    assert kpal.propose(X, y, C, 4, pending=P) == picks


def test_noiseless_near_tie_parity_no_pending():
    """Same claim without the absorb phase (isolates the scoring pass and
    the per-slot downdates)."""
    for seed in range(4):
        X, y, C, _ = _data_noiseless(seed=seed, n_cand=600)
        chol = FusedHallucinationStrategy(2, 1e4, fit_steps=15)
        kpal = FusedHallucinationStrategy(2, 1e4, fit_steps=15,
                                          use_pallas=True)
        assert kpal.propose(X, y, C, 5) == chol.propose(X, y, C, 5)


def test_cond_proxy_surfaced_to_host():
    """Every GP propose refreshes the conditioning diagnostic."""
    X, y, C, _ = _data_noiseless(seed=0)
    s = FusedHallucinationStrategy(2, 1e4, fit_steps=15, use_pallas=True)
    assert s.last_cond_proxy is None
    s.propose(X, y, C, 2)
    assert s.last_cond_proxy is not None and s.last_cond_proxy >= 1.0
    c = ClusteringStrategy(2, 1e4, fit_steps=15)
    c.propose(X, y, C, 3)
    assert c.last_cond_proxy is not None and c.last_cond_proxy >= 1.0


@pytest.mark.parametrize("noise", [1e-1, 1e-3, 1e-5])
def test_cond_estimate_within_2x_of_true(noise):
    """The power-iteration estimate (``scoring.cond_estimate``, the value
    behind ``last_cond_proxy`` and the bank factor stage) lands within 2x
    of ``np.linalg.cond`` on masked identity-padded RBF kernels — the old
    diagonal bound sat 20-50x low."""
    import jax.numpy as jnp

    from repro.core import scoring

    rng = np.random.default_rng(0)
    for na, n in [(32, 20), (64, 49)]:
        X = rng.uniform(size=(n, 3)).astype(np.float32)
        d2 = ((X[:, None, :] - X[None, :, :]) ** 2).sum(-1)
        K = (np.exp(-0.5 * d2)
             + np.eye(n) * noise).astype(np.float32)
        true = np.linalg.cond(K.astype(np.float64))
        Kp = np.eye(na, dtype=np.float32)
        Kp[:n, :n] = K
        L = np.linalg.cholesky(Kp.astype(np.float64)).astype(np.float32)
        mask = np.zeros(na, np.float32)
        mask[:n] = 1.0
        est = float(scoring.cond_estimate(jnp.asarray(L),
                                          jnp.asarray(mask)))
        assert true / 2.0 <= est <= true * 2.0, (na, n, est, true)


# --------------------------------------------- one shared scoring backend
def test_single_scoring_backend_dispatch(monkeypatch):
    """``fused_propose_pallas_pending`` and ``fused_cluster_propose`` must
    both score through ``scoring.posterior_scores`` — the one-scoring-
    backend contract of the shared core.  Fresh (odd) candidate counts
    force retraces so the spy sees the trace-time calls."""
    import jax

    from repro.core import scoring

    calls = []
    orig = scoring.posterior_scores

    def spy(*args, **kwargs):
        calls.append(kwargs.get("use_pallas"))
        return orig(*args, **kwargs)

    monkeypatch.setattr(scoring, "posterior_scores", spy)
    jax.clear_caches()

    X, y, C, P = _data(seed=9, n_cand=317)   # unique shape -> retrace
    fused = FusedHallucinationStrategy(2, 1e4, fit_steps=15,
                                       use_pallas=True)
    fused.propose(X, y, C, 3, pending=P)
    assert calls == [True]                   # scored via the shared core

    clust = ClusteringStrategy(2, 1e4, fit_steps=15)
    clust.propose(X, y, C, 3, pending=P)
    assert len(calls) == 2                   # same entry point, jnp twin
    assert calls[1] is False

    clust_pal = ClusteringStrategy(2, 1e4, fit_steps=15, use_pallas=True)
    clust_pal.propose(X, y, C, 3, pending=P)
    assert calls == [True, False, True]


# ------------------------------------------------------ device clustering
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_clustering_device_matches_host_reference(seed):
    X, y, C, _ = _data(seed=seed, n_cand=600)
    dev = ClusteringStrategy(2, 1e4, fit_steps=15)
    host = ClusteringStrategy(2, 1e4, fit_steps=15)
    assert (dev.propose(X, y, C, 5, seed=seed)
            == host.propose_host(X, y, C, 5, seed=seed))


@pytest.mark.parametrize("seed", [0, 1])
def test_clustering_device_matches_host_with_pending(seed):
    X, y, C, P = _data(seed=seed, n_cand=300)
    dev = ClusteringStrategy(2, 1e4, fit_steps=15)
    host = ClusteringStrategy(2, 1e4, fit_steps=15)
    assert (dev.propose(X, y, C, 4, seed=seed, pending=P)
            == host.propose_host(X, y, C, 4, seed=seed, pending=P))


def test_clustering_device_batch1_is_ucb_argmax():
    X, y, C, _ = _data(seed=2)
    dev = ClusteringStrategy(2, 1e4, fit_steps=15)
    h = HallucinationStrategy(2, 1e4, fit_steps=15)
    assert dev.propose(X, y, C, 1)[0] == h.propose(X, y, C, 1)[0]


# --------------------------------------------------------- device-resident TPE
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n_cand", [300, 600])
def test_tpe_pick_parity_three_way(seed, n_cand):
    """host numpy oracle == jit'd jnp path == Pallas-interpret path: the
    fused split -> l/g scoring -> top_k program must select the host's
    candidates on noise-floored surfaces."""
    from repro.core.tpe import TPEStrategy

    X, y, C, _ = _data(seed=seed, n_cand=n_cand)
    picks = TPEStrategy(2, 1e4).propose_host(X, y, C, 4)
    assert TPEStrategy(2, 1e4).propose(X, y, C, 4) == picks
    assert TPEStrategy(2, 1e4, use_pallas=True).propose(X, y, C, 4) == picks


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_tpe_pending_penalty_parity_three_way(seed):
    """With the opt-in pending penalty, the in-flight rows join the
    bad-split KDE in-program; all three paths must still agree."""
    from repro.core.tpe import TPEStrategy

    X, y, C, P = _data(seed=seed, n_cand=300)
    kw = dict(pending_penalty=True)
    picks = TPEStrategy(2, 1e4, **kw).propose_host(X, y, C, 4, pending=P)
    assert TPEStrategy(2, 1e4, **kw).propose(X, y, C, 4, pending=P) == picks
    assert TPEStrategy(2, 1e4, use_pallas=True,
                       **kw).propose(X, y, C, 4, pending=P) == picks


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_tpe_per_dim_bandwidth_parity_anisotropic(seed):
    """Per-dimension bandwidths (Scott base * clipped per-dim spread):
    on anisotropic data — a near-binary one-hot-style dim next to a
    concentrated low-variance dim and a wide uniform one — the device
    per-dim moment computation must still pick exactly what the host
    oracle picks, and the scale vector must actually differ across dims
    (a d-global bandwidth would collapse it)."""
    from repro.core.tpe import TPEStrategy

    rng = np.random.default_rng(seed)
    n, S = 24, 300
    X = np.stack([rng.uniform(size=n),                       # wide uniform
                  (rng.uniform(size=n) < 0.3).astype(float),  # one-hot
                  0.5 + 0.02 * rng.normal(size=n)], 1)       # concentrated
    X = X.astype(np.float32)
    y = (-(X[:, 0] - 0.6) ** 2 - 0.3 * X[:, 1]
         + 0.05 * rng.normal(size=n)).astype(np.float32)
    C = np.stack([rng.uniform(size=S),
                  (rng.uniform(size=S) < 0.5).astype(float),
                  0.5 + 0.02 * rng.normal(size=S)], 1).astype(np.float32)
    picks = TPEStrategy(3, 1e4).propose_host(X, y, C, 4)
    assert TPEStrategy(3, 1e4).propose(X, y, C, 4) == picks
    assert TPEStrategy(3, 1e4, use_pallas=True).propose(X, y, C, 4) == picks
    scale = TPEStrategy._dim_scale(X)
    assert scale[2] == np.float32(0.1)                  # clip floor binds
    assert scale[0] > np.float32(0.1) and scale[1] > np.float32(0.1)


def test_tpe_naive_parallelism_ignores_pending():
    """Default (Hyperopt) semantics: pending trials must not change the
    picks — the documented naive-parallelism baseline behavior."""
    from repro.core.tpe import TPEStrategy

    X, y, C, P = _data(seed=1, n_cand=400)
    s = TPEStrategy(2, 1e4)
    assert s.propose(X, y, C, 4) == s.propose(X, y, C, 4, pending=P)


def test_tpe_pending_penalty_breaks_topb_duplication():
    """An async replacement pick with the previous pick still in flight:
    naive TPE re-proposes the same candidate (top-b duplication); with
    ``pending_penalty`` the bad-split KDE rises around the pending point
    and the replacement pick moves elsewhere."""
    from repro.core.tpe import TPEStrategy

    X, y, C, _ = _data(seed=1, n_cand=400)
    naive = TPEStrategy(2, 1e4)
    first = naive.propose(X, y, C, 1)
    assert naive.propose(X, y, C, 1, pending=C[first]) == first
    pen = TPEStrategy(2, 1e4, pending_penalty=True)
    second = pen.propose(X, y, C, 1, pending=C[first])
    assert second != first


def test_tpe_batch_valid_unique_and_clamped():
    from repro.core.tpe import TPEStrategy

    X, y, C, _ = _data(seed=5, n_cand=300)
    s = TPEStrategy(2, 1e4)
    picks = s.propose(X, y, C, 6)
    assert len(set(picks)) == 6
    assert all(0 <= p < len(C) for p in picks)
    # batch_size > n_candidates degrades to the whole candidate set
    tiny = C[:3]
    assert sorted(s.propose(X, y, tiny, 8)) == [0, 1, 2] == \
        sorted(s.propose_host(X, y, tiny, 8))
