"""Durable tuning service: WAL framing, journal-then-apply recovery,
exactly-once-effect dedup, degradation, HTTP layer, and the subprocess
chaos kill/restart harness."""
import json
import os
import threading
import time

import numpy as np
import pytest
from scipy import stats

from repro.service.chaos import run as chaos_run
from repro.service.client import (RemoteOptimizer, ServiceClient,
                                  ServiceError)
from repro.service.recovery import WAL_FILE, wal_suffix
from repro.service.server import CrashPoints, TuningService, serve
from repro.service.wal import (WriteAheadLog, encode_frame, read_records,
                               truncate_to)

CFG = {"space": {"x": {"uniform": [-1.0, 2.0]},
                 "lr": {"loguniform": [1e-4, 1e-1]}},
       "max_studies": 4, "optimizer": "bayesian", "seed": 0,
       "mc_samples": 32, "fit_steps": 4}


def _svc(tmp_path, name="svc", **over):
    cfg = {**CFG, **over}
    return TuningService(tmp_path / name, config=cfg,
                         crash=CrashPoints(""))


# --------------------------------------------------------------------------- #
# WAL unit suite
# --------------------------------------------------------------------------- #
def test_wal_roundtrip(tmp_path):
    p = tmp_path / "w.log"
    wal = WriteAheadLog(p)
    recs = [{"seq": i, "op": "tell", "study": 0, "trial_id": i,
             "value": 0.1 * i} for i in range(5)]
    for r in recs:
        wal.append(r)
    wal.close()
    out, good, total = read_records(p)
    assert out == recs
    assert good == total == os.path.getsize(p)


def test_wal_crc_corruption_stops_scan(tmp_path):
    p = tmp_path / "w.log"
    wal = WriteAheadLog(p)
    for i in range(4):
        wal.append({"seq": i, "op": "trace", "study": 0})
    wal.close()
    # flip one payload byte inside the THIRD frame: frames 0-1 stay valid,
    # everything from the corrupted frame on is discarded
    frame = len(encode_frame({"seq": 0, "op": "trace", "study": 0}))
    raw = bytearray(p.read_bytes())
    raw[2 * frame + 14] ^= 0xFF
    p.write_bytes(bytes(raw))
    out, good, total = read_records(p)
    assert [r["seq"] for r in out] == [0, 1]
    assert good == 2 * frame and total == 4 * frame


def test_wal_torn_tail_truncated_and_appendable(tmp_path):
    p = tmp_path / "w.log"
    wal = WriteAheadLog(p)
    for i in range(3):
        wal.append({"seq": i, "op": "trace", "study": 0})
    wal.close()
    whole = p.read_bytes()
    p.write_bytes(whole[:-7])    # crash mid-write of the last frame
    out, good, total = read_records(p)
    assert [r["seq"] for r in out] == [0, 1]
    assert good < total
    truncate_to(p, good)
    # the truncated log extends cleanly
    wal2 = WriteAheadLog(p)
    wal2.append({"seq": 2, "op": "trace", "study": 0})
    wal2.close()
    out2, good2, total2 = read_records(p)
    assert [r["seq"] for r in out2] == [0, 1, 2]
    assert good2 == total2


def test_wal_mid_hook_leaves_torn_frame(tmp_path):
    """The chaos harness's mid-write kill point: the hook fires after a
    flushed partial frame, so the on-disk state is a genuine torn tail."""
    p = tmp_path / "w.log"
    wal = WriteAheadLog(p)
    wal.append({"seq": 1, "op": "trace", "study": 0})

    class Die(Exception):
        pass

    def hook():
        raise Die()     # stands in for SIGKILL

    with pytest.raises(Die):
        wal.append({"seq": 2, "op": "trace", "study": 0}, mid_hook=hook)
    wal.close()
    out, good, total = read_records(p)
    assert [r["seq"] for r in out] == [1]
    assert good < total     # the partial frame is on disk, and invalid


# --------------------------------------------------------------------------- #
# service core: dedup, replay, compaction boundary
# --------------------------------------------------------------------------- #
def test_tell_dedup_and_ask_req_id_cache(tmp_path):
    svc = _svc(tmp_path)
    svc.create_study("a")
    r = svc.ask("a", 3, req_id="r1")
    ids = [t["id"] for t in r["trials"]]
    # retried ask: same trials, no new journal record
    n_wal = len(wal_suffix(svc.data_dir))
    r2 = svc.ask("a", 3, req_id="r1")
    assert r2["cached"] and r2["trials"] == r["trials"]
    assert len(wal_suffix(svc.data_dir)) == n_wal
    # duplicate tell: applied exactly once, repeat doesn't journal
    assert svc.tell("a", ids[0], 1.5)["applied"]
    n_wal = len(wal_suffix(svc.data_dir))
    dup = svc.tell("a", ids[0], 99.0)
    assert not dup["applied"] and dup["value"] == 1.5
    assert len(wal_suffix(svc.data_dir)) == n_wal
    assert not svc.tell_failed("a", ids[0])["applied"]
    with pytest.raises(ServiceError) as ei:
        svc.tell("a", 999, 0.0)
    assert ei.value.status == 404
    svc.close()


def test_recovery_replays_interrupted_ask_bitwise(tmp_path):
    """Kill after the ask was journaled but before the reply: restart must
    re-serve the SAME trial ids and configurations (the WAL replay re-runs
    view.ask against bit-identical RNG/GP state)."""
    svc = _svc(tmp_path)
    svc.create_study("a")
    r1 = svc.ask("a", 2, req_id="q1")
    svc.tell("a", 0, 0.7)
    svc.tell("a", 1, -0.2)
    r2 = svc.ask("a", 2, req_id="q2")   # response "lost" to the crash
    svc.close()                          # no compaction: pure WAL replay
    svc2 = _svc(tmp_path)                # same dir, config already on disk
    assert svc2.recovery.replayed > 0 and not svc2.recovery.snapshot_loaded
    again = svc2.ask("a", 2, req_id="q2")
    assert again["cached"] and again["trials"] == r2["trials"]
    # q1's trials were told since; the re-served reply carries the same
    # ids/params with their *current* status
    q1 = svc2.ask("a", 2, req_id="q1")["trials"]
    assert [(t["id"], t["params"]) for t in q1] \
        == [(t["id"], t["params"]) for t in r1["trials"]]
    assert [t["status"] for t in q1] == ["observed", "observed"]
    svc2.close()


def test_compaction_boundary_replay(tmp_path):
    """A WAL overlapping the snapshot (crash between snapshot replace and
    log truncate) replays without double-applying anything: records with
    seq <= snapshot op_seq are skipped."""
    svc = _svc(tmp_path)
    svc.create_study("a")
    svc.ask("a", 2, req_id="r")
    svc.tell("a", 0, 1.0)
    wal_path = os.path.join(svc.data_dir, WAL_FILE)
    pre_compact_wal = open(wal_path, "rb").read()
    svc.compact()
    svc.tell("a", 1, 2.0)
    post = svc.ask("a", 1, req_id="r2")
    suffix_wal = open(wal_path, "rb").read()
    svc.close()
    # reconstruct the crash: snapshot written, but the old WAL was never
    # truncated — full history + suffix both on disk
    with open(wal_path, "wb") as fh:
        fh.write(pre_compact_wal + suffix_wal)
    svc2 = _svc(tmp_path)
    assert svc2.recovery.snapshot_loaded
    assert svc2.recovery.skipped > 0          # the overlapped prefix
    view = svc2.bank.studies[0]
    obs = [(t.id, t.value) for t in view.observed_trials()]
    assert obs == [(0, 1.0), (1, 2.0)]        # told once each
    assert svc2.ask("a", 1, req_id="r2")["trials"] == post["trials"]
    svc2.close()


def test_recovery_matches_uninterrupted_oracle(tmp_path):
    """Snapshot + WAL-suffix recovery reproduces the exact optimizer
    state: the next proposals equal an uninterrupted run's, bitwise."""
    def drive(svc):
        svc.create_study("a", sign=-1.0)
        for rnd in range(4):
            ids = [t["id"] for t in
                   svc.ask("a", 2, req_id=f"r{rnd}")["trials"]]
            svc.tell("a", ids[0], float(np.sin(rnd)))
            svc.tell_failed("a", ids[1])
            if rnd == 1:
                svc.compact()

    svc = _svc(tmp_path, name="crashy")
    drive(svc)
    svc.close()
    svc2 = TuningService(tmp_path / "crashy", crash=CrashPoints(""))
    oracle = _svc(tmp_path, name="oracle")
    drive(oracle)
    a = svc2.ask("a", 4, req_id="final")
    b = oracle.ask("a", 4, req_id="final")
    assert a["trials"] == b["trials"]
    assert svc2.bank.op_seq == oracle.bank.op_seq
    svc2.close()
    oracle.close()


def test_invalid_ops_rejected_before_journal(tmp_path):
    """Journal-then-apply requires apply to be infallible once journaled:
    a malformed op (ask n<1, observe params that don't encode) must be
    rejected BEFORE the WAL append, or the fsync'd poison frame would
    re-raise on every restart and wedge the service."""
    svc = _svc(tmp_path)
    svc.create_study("a")
    svc.ask("a", 1, req_id="r")
    n_wal = len(wal_suffix(svc.data_dir))
    seq = svc.bank.op_seq
    with pytest.raises(ValueError, match="n >= 1"):
        svc.ask("a", 0, req_id="bad")
    with pytest.raises(KeyError):
        svc.observe("a", {"bogus": 1.0}, 0.5)
    # nothing journaled, no seq burned: the next valid op extends cleanly
    assert len(wal_suffix(svc.data_dir)) == n_wal
    assert svc.bank.op_seq == seq
    svc.tell("a", 0, 1.0)
    svc.close()
    svc2 = _svc(tmp_path)            # restart replays without error
    assert svc2.recovery.poisoned == 0
    assert svc2.bank.op_seq == seq + 1
    svc2.close()


def test_poison_wal_record_skipped_on_recovery(tmp_path):
    """Defense in depth: should a journaled record still fail to apply
    (version skew, hand-edited log), its seq is consumed, recovery skips
    the poison frame, and the service starts with no seq collision."""
    svc = _svc(tmp_path)
    svc.create_study("a")
    svc.ask("a", 1, req_id="r")
    seq = svc.bank.op_seq
    data_dir = svc.data_dir
    svc.close()
    wal = WriteAheadLog(os.path.join(data_dir, WAL_FILE))
    wal.append({"seq": seq + 1, "op": "frobnicate", "study": 0})
    wal.close()
    svc2 = _svc(tmp_path)
    assert svc2.recovery.poisoned == 1
    assert svc2.bank.op_seq == seq + 1       # the poison seq is consumed
    svc2.tell("a", 0, 1.0)                   # fresh ops get fresh seqs
    assert wal_suffix(data_dir)[-1]["seq"] == seq + 2
    svc2.close()
    # a seq GAP is a structural journal error, not a poison record:
    # recovery must refuse rather than silently drop the suffix
    wal = WriteAheadLog(os.path.join(data_dir, WAL_FILE))
    wal.append({"seq": seq + 10, "op": "trace", "study": 0})
    wal.close()
    with pytest.raises(ValueError, match="does not extend"):
        _svc(tmp_path)


def test_observe_trace_req_id_dedup(tmp_path):
    """observe/trace retries land exactly once: same req_id replies from
    the cache without journaling, and the cache is rebuilt by WAL replay
    so a retry crossing a crash still dedups."""
    svc = _svc(tmp_path)
    svc.create_study("a")
    r1 = svc.observe("a", {"x": 0.5, "lr": 1e-2}, 1.0, req_id="o1")
    n_wal = len(wal_suffix(svc.data_dir))
    r2 = svc.observe("a", {"x": 0.5, "lr": 1e-2}, 1.0, req_id="o1")
    assert r2["cached"] and r2["id"] == r1["id"]
    assert len(wal_suffix(svc.data_dir)) == n_wal
    assert svc.best("a")["n_observed"] == 1
    assert svc.trace("a", req_id="t1") == {"ok": True, "cached": False}
    n_wal = len(wal_suffix(svc.data_dir))
    assert svc.trace("a", req_id="t1")["cached"]
    assert len(wal_suffix(svc.data_dir)) == n_wal
    assert svc.bank.studies[0]._best_trace == [1.0]
    svc.close()
    svc2 = _svc(tmp_path)
    assert svc2.observe("a", {"x": 0.5, "lr": 1e-2}, 1.0,
                        req_id="o1")["cached"]
    assert svc2.trace("a", req_id="t1")["cached"]
    assert svc2.best("a")["n_observed"] == 1
    assert svc2.bank.studies[0]._best_trace == [1.0]
    svc2.close()


def test_wal_failure_degrades_to_read_only(tmp_path):
    svc = _svc(tmp_path)
    svc.create_study("a")
    ids = [t["id"] for t in svc.ask("a", 2, req_id="r")["trials"]]
    svc.tell("a", ids[0], 1.0)

    def broken_append(record, mid_hook=None):
        raise OSError(28, "No space left on device")

    svc.wal.append = broken_append
    with pytest.raises(ServiceError) as ei:
        svc.tell("a", ids[1], 2.0)
    assert ei.value.status == 503
    assert svc.health()["status"] == "degraded"
    # reads keep serving
    assert svc.best("a")["best_objective"] == 1.0
    assert svc.studies()["studies"][0]["name"] == "a"
    # every mutation path refuses
    for call in (lambda: svc.ask("a", 1, req_id="x"),
                 lambda: svc.create_study("b"),
                 lambda: svc.compact()):
        with pytest.raises(ServiceError) as ei:
            call()
        assert ei.value.status == 503
    svc.close()


def test_create_study_idempotent_and_capacity(tmp_path):
    svc = _svc(tmp_path, max_studies=2)
    assert svc.create_study("a", sign=1.0)["created"]
    assert not svc.create_study("a", sign=1.0)["created"]
    svc.ask("a", 1, req_id="r")
    with pytest.raises(ServiceError) as ei:
        svc.create_study("a", sign=-1.0)   # direction flip with trials
    assert ei.value.status == 409
    svc.create_study("b")
    with pytest.raises(ServiceError) as ei:
        svc.create_study("c")
    assert ei.value.status == 507
    svc.close()


def test_create_study_optimizer_idempotent_and_conflict(tmp_path):
    svc = _svc(tmp_path)
    r = svc.create_study("a", optimizer="tpe")
    assert r["created"] and r["optimizer"] == "tpe"
    r = svc.create_study("a", optimizer="tpe")     # exact re-create
    assert not r["created"] and r["optimizer"] == "tpe"
    # optimizer omitted matches whatever the study already runs
    assert not svc.create_study("a")["created"]
    # trial-free strategy switch re-journals the create
    r = svc.create_study("a", optimizer="clustering")
    assert r["created"] and r["optimizer"] == "clustering"
    assert svc.bank.strategy_names[0] == "clustering"
    svc.ask("a", 1, req_id="r")
    with pytest.raises(ServiceError) as ei:
        svc.create_study("a", optimizer="bayesian")   # flip with trials
    assert ei.value.status == 409 and "clustering" in str(ei.value)
    svc.close()


@pytest.mark.parametrize("compact_mid", [False, True])
def test_mixed_strategy_recovery_matches_oracle(tmp_path, compact_mid):
    """Kill->resume with a heterogeneous fleet: per-study strategies are
    journaled on the create ops (and carried by the snapshot's strategy
    column), so recovery rebuilds the family routing and every family's
    next proposals are bit-equal to an uninterrupted oracle — via pure
    WAL replay and via snapshot + WAL suffix."""
    studies = [("g", "bayesian"), ("t", "tpe"), ("c", "clustering")]

    def drive(svc):
        for name, strat in studies:
            assert svc.create_study(name, optimizer=strat)["optimizer"] \
                == strat
        for rnd in range(3):
            for name, _ in studies:
                ids = [t["id"] for t in
                       svc.ask(name, 2, req_id=f"{name}{rnd}")["trials"]]
                svc.tell(name, ids[0], float(np.cos(rnd)))
                svc.tell_failed(name, ids[1])
            if compact_mid and rnd == 1:
                svc.compact()

    svc = _svc(tmp_path, name="crashy")
    drive(svc)
    svc.close()
    svc2 = TuningService(tmp_path / "crashy", crash=CrashPoints(""))
    assert svc2.recovery.snapshot_loaded == compact_mid
    assert [svc2.bank.strategy_names[svc2._names[n]]
            for n, _ in studies] == [s for _, s in studies]
    oracle = _svc(tmp_path, name="oracle")
    drive(oracle)
    for name, _ in studies:
        a = svc2.ask(name, 2, req_id=f"fin{name}")
        b = oracle.ask(name, 2, req_id=f"fin{name}")
        assert a["trials"] == b["trials"], name
    assert svc2.bank.op_seq == oracle.bank.op_seq
    svc2.close()
    oracle.close()


def test_background_compaction_drains_and_shutdown_joins(tmp_path):
    """Past the op threshold the request only wakes the compactor; the
    daemon thread takes the snapshot shortly after, off the request path.
    ``shutdown(timeout=)`` stops and joins it, and a restart recovers
    from the background-written snapshot."""
    # the op threshold wakes the daemon mid-burst; the interval timer
    # drains whatever tail stays below the threshold afterwards
    svc = _svc(tmp_path, compact_every_ops=4, compact_interval_s=0.05)
    assert svc._compact_thread is not None and svc._compact_thread.is_alive()
    svc.create_study("a")
    for i in range(8):
        tid = svc.ask("a", 1, req_id=f"r{i}")["trials"][0]["id"]
        svc.tell("a", tid, float(i))
    deadline = time.time() + 10.0
    while time.time() < deadline and svc._ops_since_snapshot:
        time.sleep(0.01)
    assert svc._ops_since_snapshot == 0      # the daemon drained the WAL
    op_seq = svc.bank.op_seq
    svc.shutdown(timeout=5.0)
    assert svc._compact_thread is None
    svc2 = _svc(tmp_path)
    assert svc2.recovery.snapshot_loaded
    assert svc2.bank.op_seq == op_seq
    svc2.close()


# --------------------------------------------------------------------------- #
# HTTP layer + drivers
# --------------------------------------------------------------------------- #
@pytest.fixture()
def http_service(tmp_path):
    httpd, svc = serve(tmp_path / "http", port=0, config=CFG)
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    base = f"http://127.0.0.1:{httpd.server_address[1]}"
    yield base, svc
    httpd.shutdown()
    svc.close()


def test_http_end_to_end(http_service):
    base, _ = http_service
    cl = ServiceClient(base)
    assert cl.health()["status"] == "ok"
    cl.create_study("web", sign=1.0)
    r = cl.ask("web", n=2, req_id="h1")
    ids = [t["id"] for t in r["trials"]]
    assert cl.ask("web", n=2, req_id="h1")["trials"] == r["trials"]
    assert cl.tell("web", ids[0], 0.5)["applied"]
    assert not cl.tell("web", ids[0], 0.5)["applied"]
    cl.tell_failed("web", ids[1])
    cl.trace("web")
    best = cl.best("web")
    assert best["best_objective"] == 0.5 and best["n_failed"] == 1
    res = cl.results("web")
    assert res["objective_values"] == [0.5]
    assert cl.compact()["op_seq"] == cl.health()["op_seq"]
    with pytest.raises(ServiceError) as ei:
        cl.tell("nope", 0, 1.0)
    assert ei.value.status == 404
    with pytest.raises(ServiceError) as ei:
        cl._request("POST", "/no/such/route", {})
    assert ei.value.status == 404


def test_remote_optimizer_matches_local_bank(http_service):
    """Proposals served over HTTP are bit-equal to the same bank row
    driven in-process: JSON floats round-trip exactly."""
    from repro.core.studybank import StudyBank
    from repro.service.server import space_from_spec
    base, svc = http_service
    ro = RemoteOptimizer(ServiceClient(base), "par")
    ro.sign = 1.0
    local = StudyBank(space_from_spec(CFG["space"]),
                      n_studies=CFG["max_studies"],
                      optimizer=CFG["optimizer"], seed=CFG["seed"],
                      mc_samples=CFG["mc_samples"],
                      fit_steps=CFG["fit_steps"])
    lview = local.studies[svc._names["par"]]
    for rnd in range(3):
        remote = ro.ask(2)
        mine = lview.ask(2)
        assert [t.id for t in remote] == [t.id for t in mine]
        assert [t.params for t in remote] == [t.params for t in mine]
        ro.tell(remote[0].id, float(rnd))
        lview.tell(mine[0].id, float(rnd))
        ro.tell_failed(remote[1].id)
        lview.tell_failed(mine[1].id)
    assert ro.n_observed == lview.n_observed == 3
    assert ro.n_failed == lview.n_failed == 3


def test_tuner_against_service(http_service):
    from repro.core import Tuner
    from repro.scheduler import ServiceScheduler

    base, svc = http_service
    sched = ServiceScheduler(base, study="tuned")
    t = Tuner({"x": stats.uniform(-1, 2), "lr": stats.loguniform(1e-4, 1e-1)},
              lambda p: -(p["x"] - 0.5) ** 2,
              {"num_iteration": 4, "batch_size": 2, "scheduler": sched})
    res = t.maximize()
    assert res.best_objective <= 0.0
    # initial random batch + num_iteration batches, all told remotely
    assert len(res.objective_values) == 10
    # state lives server-side
    assert svc.best("tuned")["n_observed"] == 10


def test_async_tuner_against_service(http_service):
    from repro.core.async_tuner import AsyncTuner
    from repro.scheduler import ServiceScheduler, TaskQueueScheduler

    base, svc = http_service
    inner = TaskQueueScheduler(n_workers=2)
    sched = ServiceScheduler(base, study="atuned", inner=inner)
    at = AsyncTuner({"x": stats.uniform(-1, 2),
                     "lr": stats.loguniform(1e-4, 1e-1)},
                    lambda p: -(p["x"] - 0.5) ** 2, sched,
                    num_evals=6, batch_size=2)
    res = at.maximize()
    assert len(res.objective_values) == 6
    assert svc.best("atuned")["n_observed"] == 6
    assert inner.shutdown(timeout=5.0)


# --------------------------------------------------------------------------- #
# chaos: subprocess SIGKILL/restart, deterministic kill points
# --------------------------------------------------------------------------- #
def test_chaos_kill_restart_quick(tmp_path):
    """Two seeded SIGKILLs mid-workload; the recovered service's ledger,
    op_seq and next proposals must be bit-equal to the uninterrupted
    oracle.  (CI runs the full 5-kill grid via repro.service.chaos.)"""
    report = chaos_run(str(tmp_path / "chaos"), kills=2, seed=1,
                       studies=2, rounds=3, verbose=False)
    assert report["failures"] == []
    assert report["kills_fired"] == 2
