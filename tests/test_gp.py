import numpy as np
import pytest

from repro.core.gp import GaussianProcess


@pytest.fixture(scope="module")
def fitted():
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(24, 2)).astype(np.float32)
    y = np.sin(3 * X[:, 0]) + 0.5 * X[:, 1]
    gp = GaussianProcess(dim=2)
    st = gp.fit(X, y)
    return gp, st, X, y


def test_posterior_interpolates(fitted):
    gp, st, X, y = fitted
    mu, sd = gp.predict(X)
    assert np.abs(mu - y).max() < 0.25
    # uncertainty grows away from data
    far = np.full((4, 2), 5.0, np.float32)
    _, sd_far = gp.predict(far)
    assert sd_far.mean() > sd.mean()


def test_hallucination_mean_fixed_variance_contracts(fitted):
    gp, st, X, y = fitted
    probe = np.array([[0.5, 0.5], [0.9, 0.1]], np.float32)
    x_new = np.array([0.52, 0.48], np.float32)
    mu0, sd0 = gp.predict(probe, st)
    st2 = gp.hallucinate(st, x_new)
    mu1, sd1 = gp.predict(probe, st2)
    # GP-BUCB invariant: the phantom obs at mu leaves the mean field intact
    np.testing.assert_allclose(mu0, mu1, atol=2e-3)
    # ... but shrinks the variance near the hallucinated point
    assert sd1[0] < sd0[0] - 1e-4
    assert st2.n == st.n + 1


def test_hallucinate_buffer_growth():
    rng = np.random.default_rng(1)
    X = rng.uniform(size=(15, 1)).astype(np.float32)  # pads to 16
    y = rng.normal(size=15).astype(np.float32)
    gp = GaussianProcess(dim=1)
    st = gp.fit(X, y)
    for i in range(4):  # crosses the 16 -> 32 growth boundary
        st = gp.hallucinate(st, rng.uniform(size=1).astype(np.float32))
    assert st.n == 19
    mu, sd = gp.predict(np.array([[0.5]], np.float32), st)
    assert np.isfinite(mu).all() and np.isfinite(sd).all()


def test_fit_recovers_signal_scale():
    rng = np.random.default_rng(2)
    X = rng.uniform(size=(48, 1)).astype(np.float32)
    y = 3.0 * np.sin(8 * X[:, 0])
    gp = GaussianProcess(dim=1)
    st = gp.fit(X, y)
    grid = np.linspace(0, 1, 50, dtype=np.float32)[:, None]
    mu, _ = gp.predict(grid)
    ref = 3.0 * np.sin(8 * grid[:, 0])
    assert np.abs(mu - ref).mean() < 0.5
