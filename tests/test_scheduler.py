import time

import numpy as np
import pytest
from scipy.stats import uniform

from repro.core import Tuner
from repro.core.async_tuner import AsyncTuner
from repro.scheduler import (FaultInjection, SerialScheduler,
                             TaskQueueScheduler, ThreadScheduler)

SPACE = {"x": uniform(0, 1)}


def trial(p):
    return -(p["x"] - 0.5) ** 2


def test_serial_scheduler_drops_failures():
    def flaky(p):
        if p["x"] > 0.8:
            raise RuntimeError("boom")
        return trial(p)

    obj = SerialScheduler().make_objective(flaky)
    batch = [{"x": v} for v in (0.1, 0.9, 0.5, 0.95)]
    evals, params = obj(batch)
    assert len(evals) == 2
    assert all(p["x"] <= 0.8 for p in params)


def test_thread_scheduler_straggler_deadline():
    def slow(p):
        if p["x"] > 0.5:
            time.sleep(5.0)  # straggler
        return trial(p)

    obj = ThreadScheduler(n_workers=4, timeout=0.5).make_objective(slow)
    t0 = time.time()
    evals, params = obj([{"x": v} for v in (0.1, 0.2, 0.9, 0.8)])
    assert time.time() - t0 < 2.0  # did not wait for stragglers
    assert len(evals) == 2


def test_taskqueue_fault_injection_and_retry():
    sched = TaskQueueScheduler(
        n_workers=4, timeout=2.0, max_retries=2,
        faults=FaultInjection(failure_rate=0.5, seed=7))
    obj = sched.make_objective(trial)
    evals, params = obj([{"x": v} for v in np.linspace(0, 1, 12)])
    # with 2 retries at 50% failure, nearly all should eventually land
    assert len(evals) >= 8
    assert sched.stats["retried"] > 0
    sched.shutdown()


def test_taskqueue_no_faults_full_batch():
    sched = TaskQueueScheduler(n_workers=2)
    evals, params = sched.make_objective(trial)(
        [{"x": v} for v in (0.1, 0.5, 0.9)])
    assert len(evals) == 3
    sched.shutdown()


def test_end_to_end_tuning_under_faults():
    sched = TaskQueueScheduler(
        n_workers=4, timeout=1.0, max_retries=1,
        faults=FaultInjection(failure_rate=0.25, straggler_rate=0.15,
                              straggler_delay=3.0, seed=11))
    res = Tuner(SPACE, sched.make_objective(trial),
                dict(optimizer="bayesian", batch_size=4, num_iteration=6,
                     seed=0, mc_samples=1000, fit_steps=10)).maximize()
    assert res.best_objective > -0.01
    assert res.n_failed > 0  # faults actually happened
    sched.shutdown()


def test_async_tuner_continuous_batching():
    sched = TaskQueueScheduler(n_workers=4)
    res = AsyncTuner(SPACE, trial, sched, num_evals=12, batch_size=4,
                     seed=0, mc_samples=800).maximize()
    assert len(res["objective_values"]) == 12
    assert res["best_objective"] > -0.05
    sched.shutdown()
