import gc
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import numpy as np
import pytest
from scipy.stats import uniform

from repro.core import Tuner
from repro.core.async_tuner import AsyncTuner
from repro.scheduler import (BatchToAsyncAdapter, FaultInjection,
                             SerialScheduler, TaskQueueScheduler,
                             ThreadScheduler)

SPACE = {"x": uniform(0, 1)}
SRC = str(Path(__file__).resolve().parents[1] / "src")


def trial(p):
    return -(p["x"] - 0.5) ** 2


def test_serial_scheduler_drops_failures():
    def flaky(p):
        if p["x"] > 0.8:
            raise RuntimeError("boom")
        return trial(p)

    obj = SerialScheduler().make_objective(flaky)
    batch = [{"x": v} for v in (0.1, 0.9, 0.5, 0.95)]
    evals, params = obj(batch)
    assert len(evals) == 2
    assert all(p["x"] <= 0.8 for p in params)


def test_thread_scheduler_straggler_deadline():
    def slow(p):
        if p["x"] > 0.5:
            time.sleep(5.0)  # straggler
        return trial(p)

    obj = ThreadScheduler(n_workers=4, timeout=0.5).make_objective(slow)
    t0 = time.time()
    evals, params = obj([{"x": v} for v in (0.1, 0.2, 0.9, 0.8)])
    assert time.time() - t0 < 2.0  # did not wait for stragglers
    assert len(evals) == 2


def test_thread_scheduler_straggler_does_not_block_exit():
    """Fault-semantics contract: a deadline-exceeding trial is *abandoned*.
    The seed implementation used ThreadPoolExecutor, whose non-daemon
    workers are joined at interpreter exit — a straggler held the whole
    process hostage for as long as it kept running."""
    code = """
        import sys, time
        sys.path.insert(0, %r)
        from repro.scheduler.local import ThreadScheduler

        def slow_or_fast(p):
            if p["slow"]:
                time.sleep(60.0)   # would block exit if joined
            return 1.0

        obj = ThreadScheduler(n_workers=2, timeout=0.3).make_objective(
            slow_or_fast)
        evals, params = obj([{"slow": True}, {"slow": False}])
        print("DONE", len(evals))
    """ % SRC
    t0 = time.monotonic()
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=30)
    elapsed = time.monotonic() - t0
    assert out.returncode == 0, out.stderr
    assert "DONE 1" in out.stdout
    # the straggler sleeps 60s; a joined (non-daemon) thread would hold the
    # subprocess far past this bound
    assert elapsed < 15.0


def test_adapter_objective_cache_is_weak_and_per_object():
    """The adapter's objective cache must key on the fn *object*, not
    ``id(fn)``: ids are recycled after GC, so a fresh fn could silently
    inherit a stale objective, and id-keyed entries leak forever."""
    class CountingScheduler(SerialScheduler):
        def __init__(self):
            self.built = []

        def make_objective(self, trial_fn):
            self.built.append(trial_fn)
            return super().make_objective(trial_fn)

    sched = CountingScheduler()
    adapter = BatchToAsyncAdapter(sched)

    def make_fn(c):
        def fn(p):
            return c
        return fn

    f1 = make_fn(1.0)
    obj1 = adapter._objective_for(f1)[0]
    assert adapter._objective_for(f1)[0] is obj1   # cached per object
    assert len(sched.built) == 1
    del f1
    gc.collect()
    assert len(adapter._objectives) == 0           # no leak after GC
    # a new fn (possibly allocated at the recycled id) gets a *fresh*
    # objective, never the stale one
    f2 = make_fn(2.0)
    obj2 = adapter._objective_for(f2)[0]
    assert obj2 is not obj1
    assert len(sched.built) == 2
    assert obj2([{"x": 0.0}])[0] == [2.0]
    # unhashable callables fall back to per-call objectives, uncached
    class UnhashableFn:
        __hash__ = None

        def __call__(self, p):
            return 3.0

    u = UnhashableFn()
    assert adapter._objective_for(u)[0]([{"x": 0.0}])[0] == [3.0]
    assert len(adapter._objectives) == 1           # only f2 cached


def test_adapter_pins_wrapped_fn_for_equal_bound_methods():
    """Bound methods are equal-but-distinct objects per access: a cache hit
    wraps the *first* object, so the caller must pin that one — otherwise
    it can be GC'd while the reusing trial is still in flight and the
    trial spuriously fails."""
    class Trialer:
        def trial(self, p):
            return float(p["x"])

    t = Trialer()
    adapter = BatchToAsyncAdapter(SerialScheduler())
    m1 = t.trial
    obj1, pin1 = adapter._objective_for(m1)
    m2 = t.trial
    assert m2 is not m1 and m2 == m1
    obj2, pin2 = adapter._objective_for(m2)
    assert obj2 is obj1          # equality hit reuses the objective...
    assert pin2 is m1            # ...and pins the object it actually wraps
    # end-to-end: churning bound methods across submits never goes stale
    handles = [adapter.submit(t.trial, {"x": float(i)}) for i in range(4)]
    gc.collect()
    while not all(h.done.is_set() for h in handles):
        adapter.wait_any(handles, timeout=5.0)
    assert [h.error for h in handles] == [None] * 4
    assert sorted(h.result for h in handles) == [0.0, 1.0, 2.0, 3.0]


def test_thread_scheduler_deadline_cancels_unstarted_trials():
    """Trials still queued behind the worker gate when the deadline fires
    must never start (the old executor cancelled its unstarted futures;
    the daemon rewrite must not regress into running the whole backlog on
    abandoned threads)."""
    import threading as th

    started = []
    lock = th.Lock()

    def slow(p):
        with lock:
            started.append(p["i"])
        time.sleep(0.8)
        return 1.0

    obj = ThreadScheduler(n_workers=2, timeout=0.3).make_objective(slow)
    evals, _ = obj([{"i": k} for k in range(12)])
    assert evals == []            # nothing finishes inside the deadline
    time.sleep(1.5)               # give any buggy backlog time to run
    with lock:
        assert len(started) <= 4  # only in-flight waves, never the backlog


def test_taskqueue_submit_after_shutdown_raises():
    """submit() after shutdown() used to enqueue into a dead queue (start()
    no-ops once _started is set) and wait_any hung until timeout."""
    sched = TaskQueueScheduler(n_workers=2)
    h = sched.submit(trial, {"x": 0.4})
    assert sched.wait_any([h], timeout=5.0) == [h]
    sched.shutdown()
    with pytest.raises(RuntimeError, match="shutdown"):
        sched.submit(trial, {"x": 0.5})


def test_taskqueue_stats_consistent_under_worker_races():
    """Counter increments run under the scheduler lock: completed+failed
    must exactly equal the number of finished tasks."""
    sched = TaskQueueScheduler(
        n_workers=8, max_retries=1,
        faults=FaultInjection(failure_rate=0.3, seed=3))
    tasks = [sched.submit(trial, {"x": v})
             for v in np.linspace(0, 1, 64)]
    evals, _ = sched.gather(tasks, timeout=30.0)
    assert all(t.done.is_set() for t in tasks)
    assert sched.stats["completed"] + sched.stats["failed"] == 64
    assert sched.stats["completed"] == len(evals)
    sched.shutdown()


def test_taskqueue_fault_injection_and_retry():
    sched = TaskQueueScheduler(
        n_workers=4, timeout=2.0, max_retries=2,
        faults=FaultInjection(failure_rate=0.5, seed=7))
    obj = sched.make_objective(trial)
    evals, params = obj([{"x": v} for v in np.linspace(0, 1, 12)])
    # with 2 retries at 50% failure, nearly all should eventually land
    assert len(evals) >= 8
    assert sched.stats["retried"] > 0
    sched.shutdown()


@pytest.mark.parametrize("straggler_rate", [0.0, 0.4])
def test_taskqueue_fault_injection_is_deterministic(straggler_rate):
    """Injected failures are a pure function of (faults.seed, submit
    order): two runs at failure_rate=0.5 must drop *identical* task sets
    even though the queue races tasks across 8 worker threads (the old
    shared ``random.Random`` let thread scheduling decide which tasks
    died)."""
    def run():
        sched = TaskQueueScheduler(
            n_workers=8,
            faults=FaultInjection(failure_rate=0.5, seed=13,
                                  straggler_rate=straggler_rate,
                                  straggler_delay=0.01))
        batch = [{"x": round(v, 6)} for v in np.linspace(0, 1, 40)]
        tasks = [sched.submit(trial, p) for p in batch]
        sched.gather(tasks, timeout=30.0)
        dropped = frozenset(t.params["x"] for t in tasks
                            if t.error is not None)
        sched.shutdown()
        return dropped

    first = run()
    assert 0 < len(first) < 40        # the injection actually fired
    for _ in range(2):
        assert run() == first


def test_taskqueue_fault_determinism_unaffected_by_retry_races():
    """Retries draw from the failed task's own RNG stream, so the final
    survivor set stays deterministic under max_retries too."""
    def run():
        sched = TaskQueueScheduler(
            n_workers=6, max_retries=1,
            faults=FaultInjection(failure_rate=0.5, seed=5))
        tasks = [sched.submit(trial, {"x": round(v, 6)})
                 for v in np.linspace(0, 1, 32)]
        sched.gather(tasks, timeout=30.0)
        dropped = frozenset(t.params["x"] for t in tasks
                            if t.error is not None)
        sched.shutdown()
        return dropped

    assert run() == run()


def test_taskqueue_no_faults_full_batch():
    sched = TaskQueueScheduler(n_workers=2)
    evals, params = sched.make_objective(trial)(
        [{"x": v} for v in (0.1, 0.5, 0.9)])
    assert len(evals) == 3
    sched.shutdown()


def test_end_to_end_tuning_under_faults():
    sched = TaskQueueScheduler(
        n_workers=4, timeout=1.0, max_retries=1,
        faults=FaultInjection(failure_rate=0.25, straggler_rate=0.15,
                              straggler_delay=3.0, seed=11))
    res = Tuner(SPACE, sched.make_objective(trial),
                dict(optimizer="bayesian", batch_size=4, num_iteration=6,
                     seed=0, mc_samples=1000, fit_steps=10)).maximize()
    assert res.best_objective > -0.01
    assert res.n_failed > 0  # faults actually happened
    sched.shutdown()


def test_async_tuner_continuous_batching():
    sched = TaskQueueScheduler(n_workers=4)
    res = AsyncTuner(SPACE, trial, sched, num_evals=12, batch_size=4,
                     seed=0, mc_samples=800).maximize()
    assert len(res["objective_values"]) == 12
    assert res["best_objective"] > -0.05
    sched.shutdown()


# --------------------------------------------------------------------------- #
# Coalescing adapter: per-batch setup cost amortization
# --------------------------------------------------------------------------- #
class CountingScheduler(SerialScheduler):
    """ProcessScheduler-shaped: every objective call pays one 'pool setup'
    (here just counted), so dispatch count == setup count."""

    def __init__(self):
        import threading
        self.dispatches = []
        self.entered = threading.Event()
        self.release = threading.Event()

    def make_objective(self, trial_fn):
        inner = super().make_objective(trial_fn)

        def objective(params_list):
            self.dispatches.append(len(params_list))
            if len(self.dispatches) == 1:
                self.entered.set()
                self.release.wait(10)
            return inner(params_list)

        return objective


def test_batch_to_async_adapter_coalesces_queued_submits():
    """Submits queued while a dispatch is in flight ride ONE later
    objective call: 8 single-trial submits cost 2 scheduler dispatches
    (1 + the 7 that queued behind it), amortizing per-batch setup cost."""
    sched = CountingScheduler()
    adapter = sched.as_async(coalesce=True)
    h0 = adapter.submit(trial, {"x": 0.125})
    assert sched.entered.wait(10)
    later = [adapter.submit(trial, {"x": i / 16.0}) for i in range(1, 8)]
    sched.release.set()
    for h in [h0] + later:
        assert h.done.wait(10)
        assert h.error is None
        assert h.result == pytest.approx(trial(h.params))
    assert sched.dispatches == [1, 7]


def test_batch_to_async_adapter_default_stays_per_trial():
    sched = CountingScheduler()
    sched.release.set()   # don't block the first dispatch
    adapter = sched.as_async()
    handles = [adapter.submit(trial, {"x": i / 8.0}) for i in range(4)]
    for h in handles:
        assert h.done.wait(10)
    assert sorted(sched.dispatches) == [1, 1, 1, 1]


def test_coalescing_adapter_keeps_fault_semantics():
    """A trial dropped inside a coalesced batch surfaces as a failed
    handle; its batchmates still complete."""
    import threading

    class HalfDrop(SerialScheduler):
        def __init__(self):
            self.entered = threading.Event()
            self.release = threading.Event()
            self.calls = 0

        def make_objective(self, trial_fn):
            inner = super().make_objective(trial_fn)

            def objective(params_list):
                self.calls += 1
                if self.calls == 1:
                    self.entered.set()
                    self.release.wait(10)
                return inner(params_list)

            return objective

    def flaky(p):
        if p["x"] > 0.5:
            raise RuntimeError("boom")
        return trial(p)

    sched = HalfDrop()
    adapter = sched.as_async(coalesce=True)
    first = adapter.submit(flaky, {"x": 0.1})
    assert sched.entered.wait(10)
    ok = adapter.submit(flaky, {"x": 0.2})
    bad = adapter.submit(flaky, {"x": 0.9})
    sched.release.set()
    for h in (first, ok, bad):
        assert h.done.wait(10)
    assert first.error is None and ok.error is None
    assert bad.result is None and isinstance(bad.error, RuntimeError)


# --------------------------------------------------------------------------- #
# graceful drain shutdown
# --------------------------------------------------------------------------- #
def test_task_queue_shutdown_drains_in_flight():
    """shutdown(timeout=) lets queued work finish before stopping the
    workers, and refuses new submits while draining."""
    sched = TaskQueueScheduler(n_workers=2)
    release = __import__("threading").Event()

    def slowish(p):
        release.wait(10)
        return trial(p)

    handles = [sched.submit(slowish, {"x": 0.1 * i}) for i in range(4)]
    drainer = {}

    def do_drain():
        drainer["drained"] = sched.shutdown(timeout=10.0)

    t = __import__("threading").Thread(target=do_drain)
    t.start()
    time.sleep(0.05)          # drain has started: submits must be refused
    with pytest.raises(RuntimeError, match="drain"):
        sched.submit(slowish, {"x": 0.9})
    release.set()
    t.join(10)
    assert drainer["drained"] is True
    assert all(h.done.is_set() and h.error is None for h in handles)


def test_task_queue_shutdown_timeout_reports_undrained():
    sched = TaskQueueScheduler(n_workers=1)
    sched.submit(lambda p: time.sleep(5) or 0.0, {"x": 0.5})
    assert sched.shutdown(timeout=0.1) is False


def test_batch_adapter_shutdown_drains_and_refuses_submits():
    release = __import__("threading").Event()

    def gated(p):
        release.wait(10)
        return trial(p)

    adapter = BatchToAsyncAdapter(SerialScheduler())
    handles = [adapter.submit(gated, {"x": 0.2}) for _ in range(3)]
    out = {}
    t = __import__("threading").Thread(
        target=lambda: out.update(d=adapter.shutdown(timeout=10.0)))
    t.start()
    time.sleep(0.05)
    with pytest.raises(RuntimeError, match="shutdown"):
        adapter.submit(gated, {"x": 0.3})      # submit-during-drain
    release.set()
    t.join(10)
    assert out["d"] is True
    assert all(h.done.is_set() for h in handles)
    # already-drained second call is a cheap no-op
    assert adapter.shutdown() is True


def test_batch_adapter_submit_shutdown_race_cannot_orphan():
    """submit's closed-check and outstanding-increment are one critical
    section under the adapter lock: a submit racing shutdown(timeout) is
    either counted by the drain or refused.  drained=True therefore
    guarantees every accepted trial completed — the contract the durable
    service snapshots on."""
    import threading
    for _ in range(25):
        adapter = BatchToAsyncAdapter(SerialScheduler())
        accepted = []
        barrier = threading.Barrier(2)

        def spam(adapter=adapter, accepted=accepted, barrier=barrier):
            barrier.wait()
            for i in range(100):
                try:
                    accepted.append(adapter.submit(trial, {"x": 0.01 * i}))
                except RuntimeError:
                    return

        t = threading.Thread(target=spam)
        t.start()
        barrier.wait()
        assert adapter.shutdown(timeout=10.0) is True
        t.join(10)
        assert all(h.done.is_set() for h in accepted)


def test_task_queue_submit_shutdown_race_cannot_orphan():
    """Same contract for TaskQueueScheduler: the drain check and the
    outstanding increment share the completion cv, so a drained=True
    can't leave a racing submit's task in the queue."""
    import threading
    for _ in range(10):
        sched = TaskQueueScheduler(n_workers=2)
        accepted = []
        barrier = threading.Barrier(2)

        def spam(sched=sched, accepted=accepted, barrier=barrier):
            barrier.wait()
            for i in range(100):
                try:
                    accepted.append(sched.submit(trial, {"x": 0.01 * i}))
                except RuntimeError:
                    return

        t = threading.Thread(target=spam)
        t.start()
        barrier.wait()
        assert sched.shutdown(timeout=10.0) is True
        t.join(10)
        assert all(h.done.is_set() for h in accepted)
