"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gp_acquisition.gp_acquisition import (score_cov_pallas,
                                                         var_downdate_pallas)
from repro.kernels.gp_acquisition.ops import score_cov
from repro.kernels.gp_acquisition.ref import (matern52, score_cov_ref,
                                              var_downdate_ref)
from repro.kernels.mlstm_chunk.mlstm_chunk import mlstm_chunk
from repro.kernels.mlstm_chunk.ref import mlstm_ref
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ssm_scan.ssm_scan import ssm_scan
from repro.kernels.tpe_kde.ops import parzen_logdens
from repro.kernels.tpe_kde.ref import tpe_scores_ref
from repro.kernels.tpe_kde.tpe_kde import tpe_scores_pallas

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,KV,S,hd,causal,dtype", [
    (2, 4, 2, 256, 64, True, jnp.float32),
    (1, 8, 8, 128, 128, True, jnp.float32),
    (2, 6, 2, 256, 64, False, jnp.float32),
    (1, 9, 3, 128, 64, True, jnp.float32),
    (1, 4, 1, 128, 64, True, jnp.bfloat16),   # MQA + bf16
    (2, 2, 2, 64, 32, True, jnp.float32),
])
def test_flash_attention(B, H, KV, S, hd, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,di,N,bd,ck", [
    (2, 128, 64, 8, 32, 32),
    (1, 64, 128, 16, 64, 16),
    (1, 96, 32, 4, 32, 32),
])
def test_ssm_scan(B, S, di, N, bd, ck):
    ks = jax.random.split(KEY, 3)
    A = jax.random.uniform(ks[0], (B, S, di, N), jnp.float32, 0.5, 0.999)
    Bx = jax.random.normal(ks[1], (B, S, di, N), jnp.float32) * 0.1
    C = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    out = ssm_scan(A, Bx, C, block_d=bd, chunk=ck)
    ref = ssm_scan_ref(A, Bx, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("B,NH,S,dh,L", [
    (2, 2, 128, 64, 32),
    (1, 4, 64, 128, 16),
    (1, 1, 64, 32, 64),   # single chunk == whole sequence
])
def test_mlstm_chunk(B, NH, S, dh, L):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, NH, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, NH, S, dh), jnp.float32) * (dh ** -0.5)
    v = jax.random.normal(ks[2], (B, NH, S, dh), jnp.float32)
    li = jax.random.normal(ks[3], (B, NH, S), jnp.float32)
    lf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, NH, S)) - 1.0)
    out = mlstm_chunk(q, k, v, li, lf, chunk=L)
    ref = mlstm_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4)


@pytest.mark.parametrize("n,d,S", [(64, 5, 500), (32, 3, 300), (128, 11, 257)])
def test_gp_acquisition(n, d, S):
    """The public scoring wrapper (``ops.score_cov``: S padded to a block
    multiple, d to a lane multiple) matches the unpadded factor oracle."""
    import scipy.linalg as sla

    rng = np.random.default_rng(0)
    X = rng.uniform(size=(n, d)).astype(np.float32)
    mask = np.ones(n, np.float32)
    mask[n - n // 4:] = 0.0
    ls = np.full(d, 0.5, np.float32)
    var, noise = 1.3, 0.01
    K = np.asarray(matern52(jnp.asarray(X / ls), jnp.asarray(X / ls),
                            1.0, var))
    K = K * mask[:, None] * mask[None, :]
    K[np.diag_indices(n)] = np.where(mask > 0, var + noise + 1e-6, 1.0)
    L = np.linalg.cholesky(K)
    Linv = sla.solve_triangular(L, np.eye(n), lower=True).astype(np.float32)
    y = (rng.normal(size=n) * mask).astype(np.float32)
    alpha = (Linv.T @ (Linv @ y)).astype(np.float32)
    C = rng.uniform(size=(S, d)).astype(np.float32)
    mu, sig2 = score_cov(C, X, mask, Linv, alpha, ls, var, noise)
    ref_mu, ref_sig2, _ = score_cov_ref(
        jnp.asarray(C / ls), jnp.asarray(X / ls), jnp.asarray(mask),
        jnp.asarray(Linv), jnp.asarray(alpha), 1.0, var, noise)
    np.testing.assert_allclose(mu, np.asarray(ref_mu), atol=1e-4)
    np.testing.assert_allclose(sig2, np.asarray(ref_sig2), atol=1e-4)


def _gp_system(n=64, d=5, S=512, seed=0):
    import scipy.linalg as sla

    rng = np.random.default_rng(seed)
    X = rng.uniform(size=(n, d)).astype(np.float32)
    mask = np.ones(n, np.float32)
    mask[n - n // 4:] = 0.0
    ls = np.full(d, 0.5, np.float32)
    var, noise = 1.3, 0.01
    K = np.asarray(matern52(jnp.asarray(X / ls), jnp.asarray(X / ls),
                            1.0, var))
    K = K * mask[:, None] * mask[None, :]
    K[np.diag_indices(n)] = np.where(mask > 0, var + noise + 1e-6, 1.0)
    # the scoring kernel consumes the triangular inverse factor L^{-1}
    # (ISSUE 5); K and K^{-1} stay around for the from-scratch checks
    L = np.linalg.cholesky(K).astype(np.float32)
    Linv = sla.solve_triangular(L, np.eye(n, dtype=np.float32),
                                lower=True).astype(np.float32)
    y = (rng.normal(size=n) * mask).astype(np.float32)
    C = rng.uniform(size=(S, d)).astype(np.float32)
    # pre-scaled, lane-padded coords (what the fused proposal feeds in)
    dp = 8
    Cs = np.zeros((S, dp), np.float32)
    Cs[:, :d] = C / ls
    Xs = np.zeros((n, dp), np.float32)
    Xs[:, :d] = X / ls
    return Xs, Cs, mask, K, Linv, y, var, noise


def test_gp_score_cov_kernel():
    """score+cross-covariance kernel vs the jnp oracle (mu, sig2, block)."""
    Xs, Cs, mask, _, Linv, y, var, noise = _gp_system()
    alpha = Linv.T @ (Linv @ y)
    mu, sig2, Kc = score_cov_pallas(
        jnp.asarray(Cs), jnp.asarray(Xs), jnp.asarray(mask),
        jnp.asarray(Linv), jnp.asarray(alpha), jnp.float32(var),
        jnp.float32(noise))
    mu_r, sig2_r, Kc_r = score_cov_ref(
        jnp.asarray(Cs), jnp.asarray(Xs), jnp.asarray(mask),
        jnp.asarray(Linv), jnp.asarray(alpha), 1.0, var, noise)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sig2), np.asarray(sig2_r),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(Kc), np.asarray(Kc_r), atol=1e-5)


def test_gp_score_cov_sumsq_matches_direct_posterior():
    """The factor sum-of-squares variance equals the from-scratch posterior
    ``var + noise − k K^{-1} kᵀ`` computed in float64 — the conditioning
    contract of the hardened scorer."""
    Xs, Cs, mask, K, Linv, y, var, noise = _gp_system()
    alpha = Linv.T @ (Linv @ y)
    mu, sig2, Kc = score_cov_pallas(
        jnp.asarray(Cs), jnp.asarray(Xs), jnp.asarray(mask),
        jnp.asarray(Linv), jnp.asarray(alpha), jnp.float32(var),
        jnp.float32(noise))
    kC = np.asarray(Kc, np.float64)
    q = np.sum((kC @ np.linalg.inv(K.astype(np.float64))) * kC, -1)
    sig2_direct = np.maximum(var + noise - q, 1e-10)
    np.testing.assert_allclose(np.asarray(sig2), sig2_direct, atol=2e-5)
    mu_direct = kC @ np.linalg.solve(K.astype(np.float64),
                                     y.astype(np.float64))
    np.testing.assert_allclose(np.asarray(mu), mu_direct, atol=2e-4)


def test_gp_var_downdate_kernel_matches_extended_system():
    """The rank-1 downdate kernel equals (a) the jnp oracle and (b) the
    from-scratch variance of the system extended by the absorbed point."""
    Xs, Cs, mask, K, Linv, y, var, noise = _gp_system()
    alpha = Linv.T @ (Linv @ y)
    _, sig2, Kc = score_cov_pallas(
        jnp.asarray(Cs), jnp.asarray(Xs), jnp.asarray(mask),
        jnp.asarray(Linv), jnp.asarray(alpha), jnp.float32(var),
        jnp.float32(noise))
    star = 17                        # absorb candidate 17
    x_star = Cs[star]
    k_star = np.asarray(Kc)[star]    # masked cross-covariance row
    u = np.linalg.solve(K, k_star).astype(np.float32)
    schur = float(var + noise + 1e-6 - k_star @ u)
    sig2_dd, k_new = var_downdate_pallas(
        jnp.asarray(Cs), jnp.asarray(x_star), Kc, jnp.asarray(u),
        jnp.float32(schur), sig2, jnp.float32(var))
    sig2_ref, k_new_ref = var_downdate_ref(
        jnp.asarray(Cs), jnp.asarray(x_star), Kc, jnp.asarray(u),
        schur, sig2, 1.0, var)
    np.testing.assert_allclose(np.asarray(sig2_dd), np.asarray(sig2_ref),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(k_new), np.asarray(k_new_ref),
                               atol=1e-5)
    # downdates can only shrink the variance
    assert np.all(np.asarray(sig2_dd) <= np.asarray(sig2) + 1e-7)
    # (b) from-scratch: append x* to K and recompute candidate variances —
    # the downdate is the extended system's exact variance, not an
    # approximation
    n = Xs.shape[0]
    K_ext = np.zeros((n + 1, n + 1), np.float32)
    K_ext[:n, :n] = K
    K_ext[:n, n] = K_ext[n, :n] = k_star
    K_ext[n, n] = var + noise + 1e-6
    kC_ext = np.concatenate([np.asarray(Kc),
                             np.asarray(k_new)[:, None]], 1)     # (S, n+1)
    t = kC_ext @ np.linalg.inv(K_ext)
    sig2_scratch = np.maximum(var + noise - np.sum(t * kC_ext, -1), 1e-10)
    np.testing.assert_allclose(np.asarray(sig2_dd), sig2_scratch, atol=2e-3)


@pytest.mark.parametrize("m,n,d", [(500, 20, 2), (300, 64, 5), (257, 33, 11)])
def test_tpe_parzen_logdens_matches_host_oracle(m, n, d):
    """The padded Pallas product-Parzen log-density == TPEStrategy's numpy
    ``_log_kde`` (same Scott bandwidth, same eps floor)."""
    from repro.core.tpe import TPEStrategy

    rng = np.random.default_rng(0)
    pts = rng.uniform(size=(n, d)).astype(np.float32)
    cands = rng.uniform(size=(m, d)).astype(np.float32)
    out = parzen_logdens(cands, pts)
    host = TPEStrategy._log_kde(pts, cands)
    np.testing.assert_allclose(out, host, atol=1e-4)


@pytest.mark.parametrize("S,n,d_true", [(512, 64, 4), (256, 24, 8)])
def test_tpe_score_kernel_matches_ref(S, n, d_true):
    """Fused two-split score kernel == the pure-jnp oracle on padded
    buffers with masked-out rows in both splits."""
    rng = np.random.default_rng(3)
    dp = 8 if d_true <= 8 else 16
    C = np.zeros((S, dp), np.float32)
    C[:, :d_true] = rng.uniform(size=(S, d_true))
    X = np.zeros((n, dp), np.float32)
    X[: n - 4, :d_true] = rng.uniform(size=(n - 4, d_true))  # 4 padded rows
    wg = np.zeros(n, np.float32)
    wb = np.zeros(n, np.float32)
    wg[: (n - 4) // 4] = 1.0
    wb[(n - 4) // 4: n - 4] = 1.0
    # per-row per-DIM scale: distinct values along dims so a kernel that
    # flattened the dim axis would fail parity
    a = np.where(wg[:, None] > 0, np.float32(3.1), np.float32(5.7)) \
        * np.linspace(0.5, 1.5, dp, dtype=np.float32)[None, :]
    scal = np.array([[1.0 / wg.sum(), 1.0 / wb.sum(), 0.0, 0.0]],
                    np.float32)
    out = tpe_scores_pallas(jnp.asarray(C), jnp.asarray(X),
                            jnp.asarray(a), jnp.asarray(wg),
                            jnp.asarray(wb), jnp.asarray(scal),
                            d_true=d_true, block_s=256)
    ref = tpe_scores_ref(jnp.asarray(C), jnp.asarray(X),
                         jnp.asarray(a), jnp.asarray(wg),
                         jnp.asarray(wb), jnp.asarray(scal), d_true=d_true)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
