"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gp_acquisition.ops import ucb_scores
from repro.kernels.gp_acquisition.ref import matern52, ucb_scores_ref
from repro.kernels.mlstm_chunk.mlstm_chunk import mlstm_chunk
from repro.kernels.mlstm_chunk.ref import mlstm_ref
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ssm_scan.ssm_scan import ssm_scan

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,KV,S,hd,causal,dtype", [
    (2, 4, 2, 256, 64, True, jnp.float32),
    (1, 8, 8, 128, 128, True, jnp.float32),
    (2, 6, 2, 256, 64, False, jnp.float32),
    (1, 9, 3, 128, 64, True, jnp.float32),
    (1, 4, 1, 128, 64, True, jnp.bfloat16),   # MQA + bf16
    (2, 2, 2, 64, 32, True, jnp.float32),
])
def test_flash_attention(B, H, KV, S, hd, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,di,N,bd,ck", [
    (2, 128, 64, 8, 32, 32),
    (1, 64, 128, 16, 64, 16),
    (1, 96, 32, 4, 32, 32),
])
def test_ssm_scan(B, S, di, N, bd, ck):
    ks = jax.random.split(KEY, 3)
    A = jax.random.uniform(ks[0], (B, S, di, N), jnp.float32, 0.5, 0.999)
    Bx = jax.random.normal(ks[1], (B, S, di, N), jnp.float32) * 0.1
    C = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    out = ssm_scan(A, Bx, C, block_d=bd, chunk=ck)
    ref = ssm_scan_ref(A, Bx, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("B,NH,S,dh,L", [
    (2, 2, 128, 64, 32),
    (1, 4, 64, 128, 16),
    (1, 1, 64, 32, 64),   # single chunk == whole sequence
])
def test_mlstm_chunk(B, NH, S, dh, L):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, NH, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, NH, S, dh), jnp.float32) * (dh ** -0.5)
    v = jax.random.normal(ks[2], (B, NH, S, dh), jnp.float32)
    li = jax.random.normal(ks[3], (B, NH, S), jnp.float32)
    lf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, NH, S)) - 1.0)
    out = mlstm_chunk(q, k, v, li, lf, chunk=L)
    ref = mlstm_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4)


@pytest.mark.parametrize("n,d,S", [(64, 5, 500), (32, 3, 300), (128, 11, 257)])
def test_gp_acquisition(n, d, S):
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(n, d)).astype(np.float32)
    mask = np.ones(n, np.float32)
    mask[n - n // 4:] = 0.0
    ls = np.full(d, 0.5, np.float32)
    var, noise, beta = 1.3, 0.01, 4.0
    K = np.asarray(matern52(jnp.asarray(X / ls), jnp.asarray(X / ls),
                            1.0, var))
    K = K * mask[:, None] * mask[None, :]
    K[np.diag_indices(n)] = np.where(mask > 0, var + noise + 1e-6, 1.0)
    Kinv = np.linalg.inv(K).astype(np.float32)
    y = (rng.normal(size=n) * mask).astype(np.float32)
    alpha = Kinv @ y
    C = rng.uniform(size=(S, d)).astype(np.float32)
    out = ucb_scores(C, X, mask, Kinv, alpha, ls, var, noise, beta)
    ref = np.asarray(ucb_scores_ref(
        jnp.asarray(C / ls), jnp.asarray(X / ls), jnp.asarray(mask),
        jnp.asarray(Kinv), jnp.asarray(alpha), 1.0, var, noise, beta))
    np.testing.assert_allclose(out, ref, atol=1e-4)
