"""The loop-aware HLO analyzer against a program with known FLOPs."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_scan_flops_counted_with_trip_multiplier():
    code = """
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hlo_cost
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh((2, 4), ("data", "model"))
        def f(a, b):
            def body(c, _):
                return c @ b, None
            out, _ = jax.lax.scan(body, a, None, length=5)
            return out
        A = jax.ShapeDtypeStruct((1024, 2048), jnp.bfloat16)
        B = jax.ShapeDtypeStruct((2048, 2048), jnp.bfloat16)
        sa = NamedSharding(mesh, P("data", None))
        sb = NamedSharding(mesh, P(None, "model"))
        comp = jax.jit(f, in_shardings=(sa, sb)).lower(A, B).compile()
        res = hlo_cost.analyze_module(comp.as_text(), 8)
        print(json.dumps({"flops": res["flops"],
                          "ag": res["coll"]["all-gather"]["count"]}))
    """
    # JAX_PLATFORMS=cpu: see tests/test_sharding.py — a stripped env lets
    # the TPU PJRT plugin probe GCP metadata and hang past the timeout.
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # per-device: 5 iterations x 2 * (1024/2) * 2048 * (2048/4)
    expected = 5 * 2 * 512 * 2048 * 512
    assert abs(res["flops"] - expected) / expected < 0.05
    assert res["ag"] >= 5  # the FSDP-style gather runs every iteration
