"""The loop-aware HLO analyzer against a program with known FLOPs."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_scan_flops_counted_with_trip_multiplier():
    code = """
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hlo_cost
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh((2, 4), ("data", "model"))
        def f(a, b):
            def body(c, _):
                return c @ b, None
            out, _ = jax.lax.scan(body, a, None, length=5)
            return out
        A = jax.ShapeDtypeStruct((1024, 2048), jnp.bfloat16)
        B = jax.ShapeDtypeStruct((2048, 2048), jnp.bfloat16)
        sa = NamedSharding(mesh, P("data", None))
        sb = NamedSharding(mesh, P(None, "model"))
        comp = jax.jit(f, in_shardings=(sa, sb)).lower(A, B).compile()
        res = hlo_cost.analyze_module(comp.as_text(), 8)
        print(json.dumps({"flops": res["flops"],
                          "ag": res["coll"]["all-gather"]["count"]}))
    """
    # JAX_PLATFORMS=cpu: see tests/test_sharding.py — a stripped env lets
    # the TPU PJRT plugin probe GCP metadata and hang past the timeout.
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # per-device: 5 iterations x 2 * (1024/2) * 2048 * (2048/4)
    expected = 5 * 2 * 512 * 2048 * 512
    assert abs(res["flops"] - expected) / expected < 0.05
    assert res["ag"] >= 5  # the FSDP-style gather runs every iteration


# ---- estimate_plan: the analytic roofline behind the autotune scenario ----
# In-process (no subprocess): the estimator never touches jax/XLA, which is
# the whole point — microseconds per call so it can serve as a constraint
# predicate and CI objective.

def _plan_env():
    from repro.configs import get_config, get_shape
    return get_config("yi-34b"), get_shape("train_4k")


def test_estimate_plan_returns_finite_roofline():
    from repro.launch.hlo_cost import estimate_plan
    cfg, shape = _plan_env()
    est = estimate_plan(cfg, shape, {"tp": 4, "zero": "zero3",
                                     "remat": "dots", "micro": 2}, 256)
    assert est["feasible"] and est["t_step_s"] > 0
    assert est["t_step_s"] >= max(est["t_compute_s"], est["t_memory_s"])
    assert est["dominant"] in ("t_compute_s", "t_memory_s")
    assert est["hbm_gb"] > 0


def test_estimate_plan_tp_must_divide_devices():
    from repro.launch.hlo_cost import estimate_plan
    cfg, shape = _plan_env()
    est = estimate_plan(cfg, shape, {"tp": 7}, 256)
    assert not est["feasible"] and est["t_step_s"] == float("inf")
    assert not est["fits"]


def test_estimate_plan_remat_trades_flops_for_hbm():
    from repro.launch.hlo_cost import estimate_plan
    cfg, shape = _plan_env()
    plans = {r: estimate_plan(cfg, shape, {"tp": 8, "remat": r}, 256)
             for r in ("none", "dots", "full")}
    # more recompute -> more flops, less stored activation memory
    assert plans["none"]["t_compute_s"] < plans["dots"]["t_compute_s"] \
        < plans["full"]["t_compute_s"]
    assert plans["none"]["hbm_gb"] > plans["dots"]["hbm_gb"] \
        > plans["full"]["hbm_gb"]


def test_estimate_plan_zero3_shards_params_for_wire_time():
    from repro.launch.hlo_cost import estimate_plan
    cfg, shape = _plan_env()
    z1 = estimate_plan(cfg, shape, {"zero": "zero1", "micro": 4}, 256)
    z3 = estimate_plan(cfg, shape, {"zero": "zero3", "micro": 4}, 256)
    # zero3 regathers params per microbatch (more wire) but shards the
    # resident optimizer+param state (less HBM)
    assert z3["t_collective_s"] > z1["t_collective_s"]
    assert z3["hbm_gb"] < z1["hbm_gb"]


def test_estimate_plan_ep_costs_wire_only_on_moe():
    from repro.configs import get_config, get_shape
    from repro.launch.hlo_cost import estimate_plan
    moe, shape = get_config("qwen2-moe-a2.7b"), get_shape("train_4k")
    base = estimate_plan(moe, shape, {"tp": 1}, 256)
    ep = estimate_plan(moe, shape, {"tp": 1, "ep": True}, 256)
    assert ep["t_collective_s"] > base["t_collective_s"]  # all-to-all
    dense = get_config("yi-34b")
    d0 = estimate_plan(dense, shape, {"tp": 1}, 256)
    d1 = estimate_plan(dense, shape, {"tp": 1, "ep": True}, 256)
    assert d1["t_collective_s"] == d0["t_collective_s"]  # no experts


def test_estimate_plan_deterministic():
    from repro.launch.hlo_cost import estimate_plan
    cfg, shape = _plan_env()
    plan = {"tp": 4, "zero": "zero3", "remat": "full",
            "micro": 8, "seq_parallel": True}
    assert estimate_plan(cfg, shape, plan, 256) == \
        estimate_plan(cfg, shape, plan, 256)
