"""The ask/tell core: ledger invariants, fused pending-trial hallucination
(single device program per pick), sync<->async parity, and kill/resume
determinism in both execution modes."""
import json
import threading

import numpy as np
import pytest
from scipy.stats import uniform

import repro.core.gp as gp_mod
from repro.core import AskTellOptimizer, AsyncTuner, Tuner, TunerResults
from repro.core.strategies import (FusedHallucinationStrategy,
                                   HallucinationStrategy)
from repro.scheduler.base import TaskHandle

SPACE = {"x": uniform(0, 1), "y": uniform(0, 1)}
FAST = dict(mc_samples=500, fit_steps=10)


def quad(p):
    return -(p["x"] - 0.7) ** 2 - (p["y"] - 0.2) ** 2


class InlineScheduler:
    """Deterministic async scheduler: trials complete synchronously inside
    ``submit``, and ``wait_any`` hands back one completion at a time in
    dispatch order — the async loop becomes a reproducible sequence."""

    def submit(self, fn, params):
        h = TaskHandle(params)
        try:
            h.result = float(fn(params))
        except Exception as e:  # noqa: BLE001
            h.error = e
        h.done.set()
        return h

    def wait_any(self, handles, timeout=None):
        done = [h for h in handles if h.done.is_set()]
        return done[:1]


# --------------------------------------------------------------------- ledger
def test_ask_ids_unique_and_monotonic():
    opt = AskTellOptimizer(SPACE, seed=0, **FAST)
    ids = [t.id for t in opt.ask(3)] + [t.id for t in opt.ask(2)]
    assert len(set(ids)) == 5
    assert ids == sorted(ids)


def test_tell_before_ask_rejected():
    opt = AskTellOptimizer(SPACE, seed=0, **FAST)
    with pytest.raises(KeyError):
        opt.tell(0, 1.0)
    with pytest.raises(KeyError):
        opt.tell_failed(17)


def test_double_tell_rejected():
    opt = AskTellOptimizer(SPACE, seed=0, **FAST)
    (t,) = opt.ask(1)
    opt.tell(t.id, 0.5)
    with pytest.raises(ValueError):
        opt.tell(t.id, 0.5)
    with pytest.raises(ValueError):
        opt.tell_failed(t.id)


def test_observe_params_invalid_leaves_state_untouched():
    """A failing observe must be a no-op: no phantom trial, no burned id.
    The durable service journals observes before applying them, so live
    state diverging from what replay reconstructs would break bit-exact
    recovery."""
    opt = AskTellOptimizer(SPACE, seed=0, **FAST)
    with pytest.raises(KeyError):
        opt.observe_params({"bogus": 1.0}, 0.5)      # not in the space
    with pytest.raises(TypeError):
        opt.observe_params({"x": 0.5, "y": 0.5}, None)
    assert opt.num_trials == 0 and opt.n_observed == 0
    t = opt.observe_params({"x": 0.5, "y": 0.5}, 0.5)
    assert t.id == 0 and t.status == "observed"


def test_failed_and_nonfinite_trials_never_observed():
    opt = AskTellOptimizer(SPACE, seed=0, **FAST)
    a, b, c = opt.ask(3)
    opt.tell(a.id, 1.0)
    opt.tell_failed(b.id)
    opt.tell(c.id, float("nan"))   # non-finite counts as a failure
    assert opt.n_observed == 1
    assert opt.n_failed == 2
    res = opt.results()
    assert res.objective_values == [1.0]
    assert res.n_failed == 2
    # the GP only ever sees the observed row
    assert [t.id for t in opt.observed_trials()] == [a.id]


def test_minimize_sign_handling():
    opt = AskTellOptimizer(SPACE, seed=0, sign=-1.0, **FAST)
    a, b = opt.ask(2)
    opt.tell(a.id, 3.0)
    opt.tell(b.id, 1.0)
    res = opt.results()
    assert res.best_objective == 1.0   # smaller raw value wins


# ---------------------------------------------- fused pending hallucination
def test_pending_absorbed_inside_fused_program():
    """Pending trials hallucinated in-program pick the same candidates as
    the host-loop hallucinate + fused pick (the seed AsyncTuner path)."""
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(20, 2)).astype(np.float32)
    y = -((X[:, 0] - 0.6) ** 2 + (X[:, 1] - 0.4) ** 2)
    C = rng.uniform(size=(600, 2)).astype(np.float32)
    P = rng.uniform(size=(3, 2)).astype(np.float32)

    fused = FusedHallucinationStrategy(2, 1e4, fit_steps=15)
    picks = fused.propose(X, y, C, 4, pending=P)

    host = FusedHallucinationStrategy(2, 1e4, fit_steps=15)
    st = host.gp.observe(X, y)
    st = host.gp.ensure_capacity(st, len(P) + 4)
    for p in P:
        st = host.gp.hallucinate(st, p)
    assert picks == host.pick_from_state(st, C, 4)

    ref = HallucinationStrategy(2, 1e4, fit_steps=15)
    assert picks == ref.propose(X, y, C, 4, pending=P)


def test_async_pick_is_single_gp_program(monkeypatch):
    """A replacement pick with k pending trials must dispatch the staged
    bank pipeline exactly once — one ``bank_absorb`` + one ``bank_pick``
    per ask, never one posterior+append program per pending trial (the
    seed's host loop).  Single-study asks route through the bank-of-one
    engine, so the retired monolithic ``fused_propose*`` ask-path entry
    points must never run."""
    calls = {"bank_pick": 0, "bank_absorb": 0, "host_hallucinate": 0}
    orig_pick = gp_mod.bank_pick
    orig_absorb = gp_mod.bank_absorb
    orig_hall = gp_mod.GaussianProcess.hallucinate

    def count(key, orig):
        def wrapper(*a, **k):
            calls[key] += 1
            return orig(*a, **k)
        return wrapper

    def boom(*a, **k):
        raise AssertionError("retired monolithic ask path was used")

    monkeypatch.setattr(gp_mod, "bank_pick", count("bank_pick", orig_pick))
    monkeypatch.setattr(gp_mod, "bank_absorb",
                        count("bank_absorb", orig_absorb))
    monkeypatch.setattr(gp_mod.GaussianProcess, "hallucinate",
                        count("host_hallucinate", orig_hall))
    for name in ("fused_propose", "fused_propose_pending",
                 "fused_propose_pallas", "fused_propose_pallas_pending"):
        monkeypatch.setattr(gp_mod, name, boom)

    opt = AskTellOptimizer(SPACE, seed=0, **FAST)
    for t in opt.ask(4):               # random phase (no GP yet)
        opt.tell(t.id, quad(t.params))
    opt.ask(3)                         # no pending -> pick only, no absorb
    assert calls["bank_pick"] == 1 and calls["bank_absorb"] == 0
    opt.ask(2)                         # 3 pending -> ONE absorb + ONE pick
    assert calls["bank_pick"] == 2 and calls["bank_absorb"] == 1
    assert calls["host_hallucinate"] == 0


# ----------------------------------------------------- sync <-> async parity
def test_sync_async_pick_parity_on_fixed_seed():
    """With a strictly sequential schedule (batch_size=1, deterministic
    inline completion) the async event loop proposes exactly the sync batch
    loop's configurations: one shared core, no duplicated propose logic."""
    conf = dict(optimizer="bayesian", num_iteration=6, batch_size=1,
                initial_random=2, seed=11, **FAST)
    sync = Tuner(SPACE, lambda b: ([quad(p) for p in b], list(b)),
                 conf).maximize()
    anc = AsyncTuner(SPACE, quad, InlineScheduler(), num_evals=8,
                     batch_size=1, initial_random=2, seed=11,
                     **FAST).maximize()
    assert isinstance(anc, TunerResults)
    sync_xy = [(p["x"], p["y"]) for p in sync.params_tried]
    async_xy = [(p["x"], p["y"]) for p in anc.params_tried]
    assert async_xy == sync_xy
    assert anc.objective_values == sync.objective_values


# -------------------------------------------------------- kill/resume replay
def test_state_dict_roundtrip_mid_flight_pending():
    """Killing with trials in flight: the JSON state_dict carries the
    pending ledger, and the restored core replays the remaining proposals
    exactly (same RNG stream, same GP fit/append schedule)."""
    opt1 = AskTellOptimizer(SPACE, seed=3, **FAST)
    for t in opt1.ask(3):
        opt1.tell(t.id, quad(t.params))
    batch = opt1.ask(2)                       # leave 2 pending
    sd = json.loads(json.dumps(opt1.state_dict()))

    opt2 = AskTellOptimizer(SPACE, seed=999, **FAST)  # seed overwritten
    opt2.load_state_dict(sd)
    restored = opt2.pending_trials()
    assert [t.id for t in restored] == [t.id for t in batch]
    assert [(t.params["x"], t.params["y"]) for t in restored] == \
        [(t.params["x"], t.params["y"]) for t in batch]

    for opt, pend in ((opt1, batch), (opt2, restored)):
        for t in pend:
            opt.tell(t.id, quad(t.params))
    nxt1 = [(t.params["x"], t.params["y"]) for t in opt1.ask(2)]
    nxt2 = [(t.params["x"], t.params["y"]) for t in opt2.ask(2)]
    assert nxt1 == nxt2


def test_async_kill_resume_reproduces_remaining_proposals(tmp_path):
    """An async run stopped mid-flight resumes from its checkpoint to the
    exact proposals of an uninterrupted run — in-flight trials are
    re-dispatched from the serialized ledger."""
    kw = dict(num_evals=10, batch_size=2, initial_random=2, seed=7, **FAST)
    full = AsyncTuner(SPACE, quad, InlineScheduler(), **kw).maximize()

    ckpt = tmp_path / "async.json"
    # "kill" after 5 completions: early_stopping exits the loop leaving
    # in-flight trials pending in the checkpointed ledger
    stopped = AsyncTuner(SPACE, quad, InlineScheduler(),
                         checkpoint_path=str(ckpt),
                         early_stopping=lambda r: r.iterations >= 5,
                         **kw).maximize()
    assert stopped.iterations == 5
    state = json.loads(ckpt.read_text())
    assert any(t["status"] == "pending"
               for t in state["optimizer"]["trials"])

    resumed = AsyncTuner(SPACE, quad, InlineScheduler(),
                         checkpoint_path=str(ckpt), **kw).maximize()
    full_xy = [(p["x"], p["y"]) for p in full.params_tried]
    res_xy = [(p["x"], p["y"]) for p in resumed.params_tried]
    assert res_xy == full_xy
    assert resumed.objective_values == full.objective_values


def test_sync_kill_resume_via_state_dict(tmp_path):
    """Same guarantee through the sync driver's checkpoint file (which is
    now just iteration + the core's state_dict)."""
    conf = dict(optimizer="bayesian", num_iteration=6, batch_size=2,
                seed=5, refit_every=4, **FAST)
    objective = lambda b: ([quad(p) for p in b], list(b))  # noqa: E731
    full = Tuner(SPACE, objective, conf).maximize()

    ckpt = tmp_path / "sync.json"
    conf_i = {**conf, "checkpoint_path": str(ckpt), "num_iteration": 3}
    Tuner(SPACE, objective, conf_i).maximize()
    assert json.loads(ckpt.read_text())["iteration"] == 3
    resumed = Tuner(SPACE, objective,
                    {**conf_i, "num_iteration": 6}).maximize()
    assert [(p["x"], p["y"]) for p in resumed.params_tried] == \
        [(p["x"], p["y"]) for p in full.params_tried]


# ------------------- checkpoint round-trips through the hardened core
@pytest.mark.parametrize("use_pallas", [False, True])
def test_sync_kill_resume_with_hardened_scorer(tmp_path, use_pallas):
    """Kill/resume replay reproduces identical picks when proposals run
    through the unified factor-scoring core (ISSUE 5): the checkpoint's GP
    fit schedule must replay the hardened (L, L^{-1}) append chain
    bit-for-bit in the sync driver.  ``use_pallas=False`` additionally
    covers the clustering strategy, which now also scores through the
    shared core."""
    conf = dict(optimizer="bayesian", num_iteration=5, batch_size=2,
                seed=11, refit_every=4, use_pallas=use_pallas, **FAST)
    if not use_pallas:
        conf["optimizer"] = "clustering"
    objective = lambda b: ([quad(p) for p in b], list(b))  # noqa: E731
    full = Tuner(SPACE, objective, conf).maximize()

    ckpt = tmp_path / "hardened.json"
    conf_i = {**conf, "checkpoint_path": str(ckpt), "num_iteration": 2}
    Tuner(SPACE, objective, conf_i).maximize()
    resumed = Tuner(SPACE, objective,
                    {**conf_i, "num_iteration": 5}).maximize()
    assert [(p["x"], p["y"]) for p in resumed.params_tried] == \
        [(p["x"], p["y"]) for p in full.params_tried]
    assert resumed.objective_values == full.objective_values


def test_async_kill_resume_with_hardened_scorer(tmp_path):
    """Async kill/resume through the Pallas factor core: in-flight trials
    re-dispatch from the ledger and the replacement picks (which absorb
    pending rows via the hardened ``scoring.absorb_pending`` loop inside
    the device program) replay identically."""
    kw = dict(num_evals=8, batch_size=2, initial_random=2, seed=21,
              use_pallas=True, **FAST)
    full = AsyncTuner(SPACE, quad, InlineScheduler(), **kw).maximize()

    ckpt = tmp_path / "hardened_async.json"
    stopped = AsyncTuner(SPACE, quad, InlineScheduler(),
                         checkpoint_path=str(ckpt),
                         early_stopping=lambda r: r.iterations >= 4,
                         **kw).maximize()
    assert stopped.iterations == 4
    resumed = AsyncTuner(SPACE, quad, InlineScheduler(),
                         checkpoint_path=str(ckpt), **kw).maximize()
    assert [(p["x"], p["y"]) for p in resumed.params_tried] == \
        [(p["x"], p["y"]) for p in full.params_tried]
    assert resumed.objective_values == full.objective_values


def test_state_dict_format_unchanged_by_scoring_core():
    """The unified core must not change the serialized format: version
    stays 1, the key set is stable, and the GP snapshot still carries only
    the fit schedule (n_fit + raw log-params) — the tracked factor is a
    pure function of those, so no migration shim is needed."""
    opt = AskTellOptimizer(SPACE, seed=0, use_pallas=True, **FAST)
    for t in opt.ask(3):
        opt.tell(t.id, quad(t.params))
    opt.ask(1)
    sd = json.loads(json.dumps(opt.state_dict()))
    assert sd["version"] == 1
    assert set(sd) == {"version", "next_id", "ask_count", "n_failed",
                       "sign", "best_trace", "trials", "rng_state", "gp"}
    assert set(sd["gp"]) == {"n_fit", "log_params"}
    assert set(sd["gp"]["log_params"]) == {"log_ls", "log_var", "log_noise"}


# ------------------------------------------------------------ driver surface
def test_async_tuner_returns_tuner_results_with_trace():
    res = AsyncTuner(SPACE, quad, InlineScheduler(), num_evals=6,
                     batch_size=2, initial_random=2, seed=1,
                     **FAST).maximize()
    assert isinstance(res, TunerResults)
    assert len(res.objective_values) == 6
    assert len(res.best_trace) == 6          # one snapshot per completion
    assert res.best_trace == sorted(res.best_trace)  # maximizing
    # legacy dict-style access still works
    assert res["best_objective"] == res.best_objective


def test_tuner_accepts_scheduler_config_key():
    from repro.scheduler import SerialScheduler
    res = Tuner(SPACE, quad,
                dict(scheduler=SerialScheduler(), optimizer="bayesian",
                     num_iteration=4, batch_size=2, seed=2,
                     **FAST)).maximize()
    assert res.best_objective > -0.2
    assert len(res.objective_values) == 2 + 4 * 2


def test_out_of_order_tells_keep_incremental_gp_path(monkeypatch):
    """Async completions land out of ask order; the GP history must stay
    append-only (tell order) so incremental Cholesky appends survive and
    full refits only happen on the refit_every schedule."""
    fits = {"n": 0}
    orig_fit = gp_mod.GaussianProcess.fit

    def counting_fit(self, X, y):
        fits["n"] += 1
        return orig_fit(self, X, y)

    monkeypatch.setattr(gp_mod.GaussianProcess, "fit", counting_fit)
    rng = np.random.default_rng(0)
    opt = AskTellOptimizer(SPACE, seed=0, mc_samples=400, fit_steps=10)
    inflight = list(opt.ask(4))
    n_done = 0
    while n_done < 40:
        t = inflight.pop(rng.integers(len(inflight)))  # random completion
        opt.tell(t.id, quad(t.params))
        n_done += 1
        if n_done + len(inflight) < 40:
            inflight.extend(opt.ask(1))
    # refit_every=8 over 40 observations -> ~5 scheduled refits; prefix
    # instability would push this to ~19
    assert fits["n"] <= 7


def test_objective_may_return_transformed_params():
    """Legacy contract: the objective may return *transformed* configs;
    they count as observations (not failures) and the returned params are
    authoritative in the results."""
    def transforming(batch):
        return ([quad(p) for p in batch],
                [dict(p, fold=1) for p in batch])

    res = Tuner(SPACE, transforming,
                dict(optimizer="bayesian", num_iteration=4, batch_size=2,
                     initial_random=2, seed=0, **FAST)).maximize()
    assert res.n_failed == 0
    assert len(res.objective_values) == 2 + 4 * 2
    assert all(p.get("fold") == 1 for p in res.params_tried)


def test_condition_wait_wakes_on_completion():
    """wait_any blocks on the scheduler's condition variable and returns as
    soon as a trial lands — not after a poll interval."""
    from repro.scheduler import TaskQueueScheduler
    sched = TaskQueueScheduler(n_workers=1)
    release = threading.Event()

    def gated(p):
        release.wait(5.0)
        return 1.0

    h = sched.submit(gated, {"x": 0.5})
    assert sched.wait_any([h], timeout=0.05) == []   # still blocked
    release.set()
    done = sched.wait_any([h], timeout=5.0)
    assert done == [h] and h.result == 1.0
    sched.shutdown()


# --------------------------------------------------- TPE through the core
def test_tpe_sync_kill_resume_replays_proposals(tmp_path):
    """TPE (no GP: ledger + RNG only) through the sync driver's checkpoint:
    a run stopped at iteration 3 resumes to the exact proposals of an
    uninterrupted one."""
    conf = dict(optimizer="tpe", num_iteration=6, batch_size=2, seed=5,
                **FAST)
    objective = lambda b: ([quad(p) for p in b], list(b))  # noqa: E731
    full = Tuner(SPACE, objective, conf).maximize()

    ckpt = tmp_path / "tpe_sync.json"
    conf_i = {**conf, "checkpoint_path": str(ckpt), "num_iteration": 3}
    Tuner(SPACE, objective, conf_i).maximize()
    resumed = Tuner(SPACE, objective,
                    {**conf_i, "num_iteration": 6}).maximize()
    assert [(p["x"], p["y"]) for p in resumed.params_tried] == \
        [(p["x"], p["y"]) for p in full.params_tried]


def test_tpe_async_kill_resume_replays_proposals(tmp_path):
    """Same guarantee through the async driver, with in-flight TPE trials
    serialized in the ledger and re-dispatched on resume."""
    kw = dict(optimizer="tpe", num_evals=10, batch_size=2,
              initial_random=2, seed=7, **FAST)
    full = AsyncTuner(SPACE, quad, InlineScheduler(), **kw).maximize()

    ckpt = tmp_path / "tpe_async.json"
    stopped = AsyncTuner(SPACE, quad, InlineScheduler(),
                         checkpoint_path=str(ckpt),
                         early_stopping=lambda r: r.iterations >= 5,
                         **kw).maximize()
    assert stopped.iterations == 5
    resumed = AsyncTuner(SPACE, quad, InlineScheduler(),
                         checkpoint_path=str(ckpt), **kw).maximize()
    assert [(p["x"], p["y"]) for p in resumed.params_tried] == \
        [(p["x"], p["y"]) for p in full.params_tried]
    assert resumed.objective_values == full.objective_values


def test_tpe_ask_is_single_device_program(monkeypatch):
    """Every TPE ask — pending trials included — must dispatch exactly one
    bank-serving fused device program (``fused_tpe_propose_bank``, which
    vmaps the per-row kernel over the study axis) and never fall back to
    the host numpy KDE."""
    import repro.core.tpe as tpe_mod

    calls = {"fused": 0}
    orig = tpe_mod.fused_tpe_propose_bank

    def counting(*a, **k):
        calls["fused"] += 1
        return orig(*a, **k)

    def boom(*a, **k):
        raise AssertionError("host numpy KDE path was used")

    monkeypatch.setattr(tpe_mod, "fused_tpe_propose_bank", counting)
    monkeypatch.setattr(tpe_mod.TPEStrategy, "_log_kde", boom)
    monkeypatch.setattr(tpe_mod.TPEStrategy, "propose_host", boom)

    opt = AskTellOptimizer(
        SPACE, optimizer="tpe", seed=0,
        strategy_kwargs={"pending_penalty": True}, **FAST)
    for t in opt.ask(4):               # random phase (no model yet)
        opt.tell(t.id, quad(t.params))
    assert calls["fused"] == 0
    opt.ask(3)                         # no pending
    assert calls["fused"] == 1
    opt.ask(2)                         # 3 pending, absorbed in-program
    assert calls["fused"] == 2


def test_strategy_kwargs_forwarded_and_validated():
    """The core forwards strategy_kwargs verbatim; unknown keys surface as
    TypeError at first ask (the old TPEStrategy silently swallowed them)."""
    opt = AskTellOptimizer(SPACE, optimizer="tpe", seed=0,
                           strategy_kwargs={"gamma": 0.5}, **FAST)
    for t in opt.ask(2):
        opt.tell(t.id, quad(t.params))
    opt.ask(1)
    assert opt._strat.gamma == 0.5
    assert opt._strat.domain_size == opt.domain_size   # no longer dropped

    bad = AskTellOptimizer(SPACE, optimizer="tpe", seed=0,
                           strategy_kwargs={"gamme": 0.5}, **FAST)
    with pytest.raises(TypeError):   # strategy built on the first ask
        bad.ask(1)


def test_tpe_gamma_validation():
    from repro.core.tpe import TPEStrategy
    with pytest.raises(ValueError):
        TPEStrategy(2, 1e4, gamma=0.0)
    with pytest.raises(ValueError):
        TPEStrategy(2, 1e4, gamma=0.6)   # good quantile capped at 0.5:
    with pytest.raises(ValueError):      # disjoint splits -> one exp/row
        TPEStrategy(2, 1e4, gamma=1.0)
    with pytest.raises(ValueError):
        TPEStrategy(0, 1e4)
    TPEStrategy(2, 1e4, gamma=0.5)       # boundary is valid
