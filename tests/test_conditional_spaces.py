"""Conditional / structured spaces (Choice, Int, LogInt, constraints):
masked encode/decode round-trips, scalar-vs-columnar bitwise parity, and
kill->resume replay of a conditional-space study through the sync, async,
service (WAL), and StudyBank drivers."""
import json

import numpy as np
import pytest
from scipy.stats import uniform

from repro.core import (AskTellOptimizer, AsyncTuner, StudyBank, Tuner,
                        CHOICE_KEY, Choice, Int, LogInt, ParamSpace)
from repro.core.spaces import IMPUTED
from repro.scheduler.base import TaskHandle

FAST = dict(mc_samples=500, fit_steps=10)

CSPACE = {
    "algo": Choice({
        "sgd": {"momentum": uniform(0, 1)},
        "adam": {"beta2": [0.99, 0.999], "eps_exp": Int(-9, -6)},
    }),
    "lr_exp": uniform(-4, 3),
    "tile": LogInt(16, 512),
}


def cond_obj(p):
    a = p["algo"]
    base = -(p["lr_exp"] + 2.0) ** 2 - (np.log2(p["tile"]) - 7.0) ** 2
    if a[CHOICE_KEY] == "sgd":
        return float(base - (a["momentum"] - 0.9) ** 2)
    return float(base - 100 * (a["beta2"] - 0.999) ** 2
                 - 0.1 * (a["eps_exp"] + 8) ** 2)


class InlineScheduler:
    """Deterministic async scheduler (see test_optimizer)."""

    def submit(self, fn, params):
        h = TaskHandle(params)
        try:
            h.result = float(fn(params))
        except Exception as e:  # noqa: BLE001
            h.error = e
        h.done.set()
        return h

    def wait_any(self, handles, timeout=None):
        done = [h for h in handles if h.done.is_set()]
        return done[:1]


# --------------------------------------------------------------------- shape
def test_int_logint_bounds_and_encoding():
    ps = ParamSpace({"a": Int(3, 9), "b": LogInt(16, 512)})
    rng = np.random.default_rng(0)
    rows = ps.sample(500, rng)
    assert all(3 <= r["a"] <= 9 for r in rows)
    assert all(16 <= r["b"] <= 512 for r in rows)
    E = ps.encode(rows)
    assert E.shape == (500, 2)
    assert E.min() >= 0.0 and E.max() <= 1.0
    # log-scale encoding: 128 lands midway between 16 and 512 (x32 each way)
    mid = ps.encode([{"a": 6, "b": 91}])   # sqrt(16*512) ~ 90.5
    assert abs(mid[0, 1] - 0.5) < 0.01
    # LogInt skews small: the median draw is far below the midpoint 264
    assert np.median([r["b"] for r in rows]) < 150
    with pytest.raises(ValueError):
        Int(5, 4)
    with pytest.raises(ValueError):
        LogInt(0, 8)


def test_choice_validation():
    with pytest.raises(ValueError):
        Choice({})
    with pytest.raises(ValueError):
        Choice({"a": {"x": [1]}, "b": {CHOICE_KEY: [1]}})
    with pytest.raises(ValueError):
        Choice({"a": {"inner": Choice({"b": {}})}})   # no nesting


def test_choice_samples_carry_only_active_children():
    ps = ParamSpace(CSPACE)
    rows = ps.sample(200, np.random.default_rng(1))
    for r in rows:
        a = r["algo"]
        if a[CHOICE_KEY] == "sgd":
            assert set(a) == {CHOICE_KEY, "momentum"}
            assert 0.0 <= a["momentum"] <= 1.0
        else:
            assert set(a) == {CHOICE_KEY, "beta2", "eps_exp"}
            assert a["beta2"] in (0.99, 0.999)
            assert -9 <= a["eps_exp"] <= -6
        # JSON-clean: nested values are Python scalars
        json.dumps(r)


def test_masked_encoding_imputes_inactive_dims():
    ps = ParamSpace(CSPACE)
    rows = ps.sample(64, np.random.default_rng(2))
    E = ps.encode(rows)
    # layout: [sgd_oh, adam_oh | momentum | beta2, eps_exp | lr_exp | tile]
    assert E.shape == (64, ps.dim) and ps.dim == 7
    for i, r in enumerate(rows):
        if r["algo"][CHOICE_KEY] == "sgd":
            assert E[i, 0] == 1.0 and E[i, 1] == 0.0
            assert E[i, 3] == IMPUTED and E[i, 4] == IMPUTED
            assert E[i, 2] != IMPUTED or r["algo"]["momentum"] == IMPUTED
        else:
            assert E[i, 0] == 0.0 and E[i, 1] == 1.0
            assert E[i, 2] == IMPUTED


def test_encode_decode_round_trip():
    ps = ParamSpace(CSPACE)
    rows = ps.sample(128, np.random.default_rng(3))
    dec = ps.decode(ps.encode(rows))
    for r, d in zip(rows, dec):
        assert d["algo"][CHOICE_KEY] == r["algo"][CHOICE_KEY]
        if r["algo"][CHOICE_KEY] == "sgd":
            assert abs(d["algo"]["momentum"] - r["algo"]["momentum"]) < 1e-9
        else:
            assert d["algo"]["beta2"] == r["algo"]["beta2"]
            assert d["algo"]["eps_exp"] == r["algo"]["eps_exp"]
        assert abs(d["lr_exp"] - r["lr_exp"]) < 1e-9
        assert d["tile"] == r["tile"]


def test_decode_inverts_flat_spaces_too():
    ps = ParamSpace({"x": uniform(2, 6), "k": ["a", "b", "c"],
                     "d": range(1, 10), "c": 42})
    rows = ps.sample(50, np.random.default_rng(4))
    dec = ps.decode(ps.encode(rows))
    for r, d in zip(rows, dec):
        assert abs(d["x"] - r["x"]) < 1e-9
        assert d["k"] == r["k"] and d["d"] == r["d"] and d["c"] == 42


def test_domain_size_sums_branch_products():
    ps = ParamSpace({"a": Choice({"p": {"x": [1, 2, 3]},
                                  "q": {"y": [4, 5], "z": range(2)}})})
    assert ps.domain_size == 3 + 2 * 2
    assert ParamSpace({"t": Int(1, 16)}).domain_size == 16


# ----------------------------------------------------------------- parity
def test_columnar_scalar_bitwise_parity_conditional():
    """sample_columns consumes the identical RNG stream as sample and
    yields bitwise-identical configs — the StudyBank contract, extended
    to conditional spaces."""
    ps = ParamSpace(CSPACE)
    r1, r2 = np.random.default_rng(7), np.random.default_rng(7)
    rows = ps.sample(256, r1)
    cols = ps.sample_columns(256, r2)
    assert r1.bit_generator.state == r2.bit_generator.state
    for i, row in enumerate(rows):
        assert row == ps.config_at(cols, i)
    got = ps.configs_at(cols, np.arange(0, 256, 17))
    assert got == [rows[i] for i in range(0, 256, 17)]
    # encode_columns == encode on the same draws
    np.testing.assert_array_equal(ps.encode_columns(cols, 256),
                                  ps.encode(rows))


def test_columnar_scalar_bitwise_parity_constrained():
    ps = ParamSpace({"a": Int(1, 10), "b": Int(1, 10)},
                    constraints=[lambda c: c["a"] + c["b"] <= 10])
    r1, r2 = np.random.default_rng(9), np.random.default_rng(9)
    rows = ps.sample(100, r1)
    cols = ps.sample_columns(100, r2)
    assert r1.bit_generator.state == r2.bit_generator.state
    assert all(r["a"] + r["b"] <= 10 for r in rows)
    for i, row in enumerate(rows):
        assert row == ps.config_at(cols, i)


def test_infeasible_constraints_raise():
    ps = ParamSpace({"a": Int(1, 4)}, constraints=[lambda c: c["a"] > 99])
    with pytest.raises(RuntimeError, match="feasible region"):
        ps.sample(4, np.random.default_rng(0))


def test_flat_spaces_bit_identical_with_and_without_extension_args():
    flat = {"x": uniform(0, 1), "k": ["a", "b"], "n": range(4)}
    a, b = ParamSpace(flat), ParamSpace(flat, constraints=None)
    assert not a.is_conditional
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    assert a.sample(64, r1) == b.sample(64, r2)
    assert r1.bit_generator.state == r2.bit_generator.state


# ------------------------------------------------------- driver replay
def test_sync_kill_resume_conditional(tmp_path):
    conf = dict(optimizer="bayesian", num_iteration=6, batch_size=2,
                seed=5, refit_every=4, **FAST)
    objective = lambda b: ([cond_obj(p) for p in b], list(b))  # noqa: E731
    full = Tuner(CSPACE, objective, conf).maximize()
    assert any(p["algo"][CHOICE_KEY] == "adam" for p in full.params_tried)

    ckpt = tmp_path / "sync.json"
    conf_i = {**conf, "checkpoint_path": str(ckpt), "num_iteration": 3}
    Tuner(CSPACE, objective, conf_i).maximize()
    resumed = Tuner(CSPACE, objective,
                    {**conf_i, "num_iteration": 6}).maximize()
    assert resumed.params_tried == full.params_tried
    assert resumed.objective_values == full.objective_values


def test_async_kill_resume_conditional(tmp_path):
    kw = dict(num_evals=10, batch_size=2, initial_random=2, seed=7, **FAST)
    full = AsyncTuner(CSPACE, cond_obj, InlineScheduler(), **kw).maximize()

    ckpt = tmp_path / "async.json"
    stopped = AsyncTuner(CSPACE, cond_obj, InlineScheduler(),
                         checkpoint_path=str(ckpt),
                         early_stopping=lambda r: r.iterations >= 5,
                         **kw).maximize()
    assert stopped.iterations == 5
    resumed = AsyncTuner(CSPACE, cond_obj, InlineScheduler(),
                         checkpoint_path=str(ckpt), **kw).maximize()
    assert resumed.params_tried == full.params_tried
    assert resumed.objective_values == full.objective_values


def test_state_dict_replays_conditional_params_bitwise():
    """Nested Choice params survive the JSON checkpoint round trip and
    re-encode to the exact GP inputs on load (the tell-replay contract)."""
    opt = AskTellOptimizer(CSPACE, seed=3, **FAST)
    for t in opt.ask(4):
        opt.tell(t.id, cond_obj(t.params))
    sd = json.loads(json.dumps(opt.state_dict()))
    opt2 = AskTellOptimizer(CSPACE, seed=99, **FAST)
    opt2.load_state_dict(sd)
    assert opt2.state_dict() == sd
    a = [(t.id, t.params) for t in opt.ask(3)]
    b = [(t.id, t.params) for t in opt2.ask(3)]
    assert a == b


# ----------------------------------------------------------- StudyBank
def test_bank_of_one_parity_conditional():
    """A 1-study bank over a conditional space round-trips its study entry
    through a stand-alone AskTellOptimizer (the v1 snapshot contract)."""
    bank = StudyBank(CSPACE, 1, seed=5, mc_samples=32)
    for _ in range(4):
        (trials,) = bank.ask_all(1)
        for t in trials:
            bank.tell(0, t.id, cond_obj(t.params))
    entry = bank.state_dict()["studies"][0]
    solo = AskTellOptimizer(CSPACE, seed=0)
    solo.load_state_dict(entry)
    assert solo.state_dict() == entry
    assert [dict(t.params) for t in solo.observed_trials()] == \
        [dict(t.params) for t in bank.study(0).observed_trials()]


def test_bank_kill_resume_conditional(tmp_path):
    kw = dict(optimizer="bayesian", seed=11, mc_samples=32)

    def drive(bank, steps):
        hist = []
        for _ in range(steps):
            for b, ts in enumerate(bank.ask_all(1)):
                for t in ts:
                    hist.append((b, t.id, dict(t.params)))
                    bank.tell(b, t.id, cond_obj(t.params))
        return hist

    ref = StudyBank(CSPACE, 4, **kw)
    h_ref = drive(ref, 3) + drive(ref, 2)
    a = StudyBank(CSPACE, 4, **kw)
    drive(a, 3)
    b = StudyBank(CSPACE, 4, **kw)
    b.load_state_dict(json.loads(json.dumps(a.state_dict())))
    h_resumed = drive(b, 2)
    assert h_resumed == h_ref[len(h_ref) - len(h_resumed):]


# ------------------------------------------------------------- service
SVC_CFG = {"space": {
    "algo": {"cond": {
        "sgd": {"momentum": {"uniform": [0.0, 1.0]}},
        "adam": {"beta2": {"choice": [0.99, 0.999]},
                 "eps_exp": {"int": [-9, -6]}},
    }},
    "lr_exp": {"uniform": [-4.0, 3.0]},
    "tile": {"logint": [16, 512]},
}, "max_studies": 2, "optimizer": "bayesian", "seed": 0,
    "mc_samples": 32, "fit_steps": 4}


def _svc(tmp_path, name="svc"):
    from repro.service.server import CrashPoints, TuningService
    return TuningService(tmp_path / name, config=SVC_CFG,
                         crash=CrashPoints(""))


def test_service_space_spec_cond_kinds(tmp_path):
    svc = _svc(tmp_path)
    svc.create_study("a")
    trials = svc.ask("a", 4, req_id="r0")["trials"]
    for t in trials:
        a = t["params"]["algo"]
        assert a[CHOICE_KEY] in ("sgd", "adam")
        assert 16 <= t["params"]["tile"] <= 512
    svc.close()


def test_service_wal_recovery_conditional_matches_oracle(tmp_path):
    """Kill->restart recovery of a conditional-space study replays to the
    oracle's exact state: same next proposals (nested params included),
    same op_seq — the WAL journal carries Choice configs verbatim."""
    from repro.service.server import CrashPoints, TuningService

    def drive(svc):
        svc.create_study("a")
        for rnd in range(3):
            trials = svc.ask("a", 2, req_id=f"r{rnd}")["trials"]
            svc.tell("a", trials[0]["id"], cond_obj(trials[0]["params"]))
            svc.tell_failed("a", trials[1]["id"])
            if rnd == 1:
                svc.compact()

    svc = _svc(tmp_path, name="crashy")
    drive(svc)
    svc.close()   # "crash": recovery rebuilds from snapshot + WAL suffix
    svc2 = TuningService(tmp_path / "crashy", crash=CrashPoints(""))
    oracle = _svc(tmp_path, name="oracle")
    drive(oracle)
    a = svc2.ask("a", 4, req_id="final")
    b = oracle.ask("a", 4, req_id="final")
    assert a["trials"] == b["trials"]
    assert svc2.bank.op_seq == oracle.bank.op_seq
    svc2.close()
    oracle.close()
