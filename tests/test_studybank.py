"""StudyBank: fleet serialization, kill->resume replay, bucket-boundary
parity of the vmap'd bank ask against unpadded single-study oracles."""
import json
import os

import numpy as np
import pytest
from scipy import stats

from repro.core import AskTellOptimizer, StudyBank, StudyLedger
from repro.core.studybank import pack_rng_state, unpack_rng_state

SPACE = {"x": stats.uniform(0, 1), "y": stats.uniform(-1, 2)}
STRATS = ["bayesian", "tpe", "clustering"]


def _objective(p):
    return -(p["x"] - 0.3) ** 2 - (p["y"] - 0.5) ** 2


def _run(bank, steps, leave_pending=False):
    """Drive every study; returns the full proposal history.  With
    ``leave_pending`` every third ask stays in flight (async mode)."""
    hist = []
    for s in range(steps):
        trials = bank.ask_all(1)
        for b, ts in enumerate(trials):
            for t in ts:
                hist.append((b, t.id, dict(t.params)))
                if not (leave_pending and s % 3 == 2):
                    bank.tell(b, t.id, _objective(t.params))
    return hist


# --------------------------------------------------------------------------- #
# serialization
# --------------------------------------------------------------------------- #
def test_rng_state_pack_roundtrip():
    rng = np.random.default_rng(1234)
    rng.uniform(size=7)
    rng.integers(0, 10)  # leaves a cached uint32 in the bit generator
    clone = unpack_rng_state(pack_rng_state(rng))
    assert list(clone.uniform(size=5)) == list(rng.uniform(size=5))
    assert clone.bit_generator.state == rng.bit_generator.state


def test_fleet_state_dict_roundtrip_json():
    bank = StudyBank(SPACE, 4, seed=5, mc_samples=32)
    _run(bank, 4, leave_pending=True)
    sd = json.loads(json.dumps(bank.state_dict()))
    bank2 = StudyBank(SPACE, 4, seed=99, mc_samples=32)
    bank2.load_state_dict(sd)
    assert bank2.state_dict() == sd


def test_single_study_view_matches_v1_snapshot_format():
    """A bank study's snapshot entry IS the v1 single-study format: same
    keys, and byte-identical to an AskTellOptimizer replaying the same
    study stand-alone."""
    bank = StudyBank(SPACE, 3, seed=5, mc_samples=32)
    _run(bank, 3)
    entry = bank.state_dict()["studies"][1]
    assert set(entry) == {"version", "next_id", "ask_count", "n_failed",
                          "sign", "best_trace", "trials", "rng_state", "gp"}
    assert entry["version"] == 1
    # a stand-alone (bank-of-one) optimizer loads it and round-trips it
    solo = AskTellOptimizer(SPACE, seed=0)
    solo.load_state_dict(entry)
    assert solo.state_dict() == entry
    assert solo.n_observed == bank.study(1).n_observed
    assert [t.id for t in solo.observed_trials()] == \
        [t.id for t in bank.study(1).observed_trials()]


def test_npz_checkpoint_single_write(tmp_path):
    bank = StudyBank(SPACE, 4, seed=2, mc_samples=32)
    _run(bank, 4, leave_pending=True)
    path = tmp_path / "fleet.npz"
    bank.save(path, iteration=4)
    assert path.exists() and not (tmp_path / "fleet.tmp").exists()
    bank2 = StudyBank(SPACE, 4, seed=77, mc_samples=32)
    assert bank2.load(path) == 4
    assert bank2.state_dict() == bank.state_dict()
    for name in StudyLedger.ARRAY_FIELDS:
        np.testing.assert_array_equal(getattr(bank2.ledger, name),
                                      getattr(bank.ledger, name))


def test_checkpoint_study_count_mismatch_raises(tmp_path):
    bank = StudyBank(SPACE, 3, seed=2, mc_samples=32)
    path = tmp_path / "fleet.npz"
    bank.save(path)
    other = StudyBank(SPACE, 4, seed=2, mc_samples=32)
    with pytest.raises(ValueError):
        other.load(path)
    with pytest.raises(ValueError):
        other.load_state_dict(bank.state_dict())


# --------------------------------------------------------------------------- #
# kill -> resume replay (16-study bank, mid-flight)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("opt", STRATS)
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_bank_kill_resume_replay(opt, mode, tmp_path):
    """A 16-study bank killed mid-flight resumes to the exact proposals of
    an uninterrupted run — sync (every trial told before the next ask) and
    async (a third of the asks still in flight at the kill point)."""
    pending = mode == "async"
    kw = dict(optimizer=opt, seed=11, mc_samples=32)
    ref = StudyBank(SPACE, 16, **kw)
    h_ref = _run(ref, 4, pending) + _run(ref, 3, pending)

    # kill via the one-write npz checkpoint ...
    a = StudyBank(SPACE, 16, **kw)
    _run(a, 4, pending)
    path = tmp_path / f"{opt}-{mode}.npz"
    a.save(path)
    b = StudyBank(SPACE, 16, **kw)
    b.load(path)
    h_npz = _run(b, 3, pending)
    assert h_npz == h_ref[len(h_ref) - len(h_npz):]

    # ... and via the JSON fleet state dict
    c = StudyBank(SPACE, 16, **kw)
    c.load_state_dict(json.loads(json.dumps(a.state_dict())))
    h_json = _run(c, 3, pending)
    assert h_json == h_ref[len(h_ref) - len(h_json):]


# --------------------------------------------------------------------------- #
# bucket-boundary parity vs unpadded oracles
# --------------------------------------------------------------------------- #
EDGE = 28  # bank bucket jumps 32 -> 64 here (n_obs + pend_cap(4) + n(1))


def _seeded_bank(opt, n_obs_list, seed=31):
    """A bank with one study per requested observation count, frozen
    hypers (no fit runs during the ask under test), noise-floored values
    so the acquisition surfaces have no ties."""
    rng = np.random.default_rng(seed)
    bank = StudyBank(SPACE, len(n_obs_list), optimizer=opt, seed=seed,
                     mc_samples=64)
    led = bank.ledger
    for b, k in enumerate(n_obs_list):
        v = bank.study(b)
        for _ in range(k):
            p = {"x": float(rng.uniform(0, 1)),
                 "y": float(rng.uniform(-1, 1))}
            v.observe_params(p, float(rng.normal()))
        led.have_fit[b] = 1
        led.n_fit[b] = k
        led.log_ls[b] = np.log(0.5)
        led.log_var[b] = 0.1
        led.log_noise[b] = np.log(1e-2)
        led.y_mean[b] = 0.0
        led.y_std[b] = 1.0
    return bank


def _bank_ask_rows(bank, n):
    """Run one bank ask; returns per-study encoded pick rows plus the
    candidate matrix each study saw (replayed from the bank RNG)."""
    state = bank._rng.bit_generator.state
    out = bank.ask_all(n)
    B = bank.n_studies
    n_mc = bank.mc_samples
    replay = np.random.default_rng()
    replay.bit_generator.state = state
    cols = bank.space.sample_columns(B * n_mc, replay)
    C = bank.space.encode_columns(cols, B * n_mc).reshape(B, n_mc, -1)
    rows = [bank.space.encode([t.params for t in ts]) for ts in out]
    return rows, C


@pytest.mark.parametrize("n_obs", [EDGE - 1, EDGE, EDGE + 1])
def test_bucket_boundary_parity_bayesian(n_obs):
    import jax.numpy as jnp

    from repro.core import gp as gp_lib
    from repro.core import scoring

    n = 2
    bank = _seeded_bank("bayesian", [n_obs])
    led = bank.ledger
    ids = led.obs_ids(0)
    X = led.X[0, ids].astype(np.float32)              # unpadded (n_obs, d)
    z = (led.y[0, ids].astype(np.float32) - led.y_mean[0]) / led.y_std[0]
    rows, C = _bank_ask_rows(bank, n)
    ls = np.exp(led.log_ls[0]).astype(np.float32)
    var = np.float32(np.exp(led.log_var[0]))
    noise = np.float32(np.exp(led.log_noise[0]) + 1e-5)
    mask = np.ones(n_obs, np.float32)
    L = gp_lib.cholesky_masked(X, mask, ls, var, noise)
    Linv = scoring.linv_from_chol(L)
    idx = gp_lib.fused_propose_pallas_pending(
        X, z, mask, L, Linv, np.zeros((4, X.shape[1]), np.float32),
        jnp.float32(0.0), C[0].astype(np.float32), ls, var, noise,
        jnp.float32(n_obs), jnp.float32(bank.study(0).domain_size), n, 4,
        use_pallas=False)
    oracle = C[0][np.asarray(idx)]
    np.testing.assert_array_equal(np.asarray(rows[0], np.float32),
                                  oracle.astype(np.float32))


@pytest.mark.parametrize("n_obs", [EDGE - 1, EDGE, EDGE + 1])
def test_bucket_boundary_parity_tpe(n_obs):
    from repro.core.tpe import fused_tpe_propose
    from repro.kernels.tpe_kde.ops import pad_dims

    n = 2
    bank = _seeded_bank("tpe", [n_obs])
    led = bank.ledger
    ids = led.obs_ids(0)
    d = led.dim
    rows, C = _bank_ask_rows(bank, n)
    dp = pad_dims(d)
    Xb = np.zeros((n_obs, dp), np.float32)            # unpadded rows
    Xb[:, :d] = led.X[0, ids]
    yb = led.y[0, ids].astype(np.float32)             # sign=+1
    Cb = np.zeros((C.shape[1], dp), np.float32)
    Cb[:, :d] = C[0]
    meta = np.array([n_obs, 0, C.shape[1], 0.25], np.float32)
    idx = fused_tpe_propose(Xb, yb, Cb, meta, batch_size=n, d_true=d)
    oracle = C[0][np.asarray(idx)]
    np.testing.assert_array_equal(np.asarray(rows[0], np.float32),
                                  oracle.astype(np.float32))


@pytest.mark.parametrize("n_obs", [EDGE - 1, EDGE, EDGE + 1])
def test_bucket_boundary_parity_clustering(n_obs):
    import jax
    import jax.numpy as jnp

    from repro.core import gp as gp_lib
    from repro.core import scoring
    from repro.core.acquisition import fused_cluster_propose
    from repro.core.strategies import n_top_candidates

    n = 2
    bank = _seeded_bank("clustering", [n_obs])
    led = bank.ledger
    ask_count_before = int(led.ask_count[0])
    ids = led.obs_ids(0)
    X = led.X[0, ids].astype(np.float32)
    z = (led.y[0, ids].astype(np.float32) - led.y_mean[0]) / led.y_std[0]
    rows, C = _bank_ask_rows(bank, n)
    ls = np.exp(led.log_ls[0]).astype(np.float32)
    var = np.float32(np.exp(led.log_var[0]))
    noise = np.float32(np.exp(led.log_noise[0]) + 1e-5)
    mask = np.ones(n_obs, np.float32)
    L = gp_lib.cholesky_masked(X, mask, ls, var, noise)
    Linv = scoring.linv_from_chol(L)
    idx = fused_cluster_propose(
        X, z, mask, L, Linv, np.zeros((4, X.shape[1]), np.float32),
        jnp.float32(0.0), C[0].astype(np.float32), ls, var, noise,
        jnp.float32(n_obs), jnp.float32(bank.study(0).domain_size),
        jax.random.PRNGKey(ask_count_before), n,
        n_top_candidates(C.shape[1], n, 0.2), 4, use_pallas=False)
    oracle = C[0][np.asarray(idx)]
    np.testing.assert_array_equal(np.asarray(rows[0], np.float32),
                                  oracle.astype(np.float32))


def test_mixed_bank_parity_with_homogeneous_banks():
    """One bank holding GP + TPE + clustering studies picks bit-equal to
    three homogeneous banks: the per-family sub-batching inside a single
    ``ask_all`` changes the dispatch grouping, never the math — every row
    of a vmap'd stage is independent of its neighbors, and all four banks
    draw the identical flat candidate stream from the same bank seed."""
    B = 9
    strats = STRATS * 3

    def build(opt):
        bank = StudyBank(SPACE, B, optimizer=opt, seed=11, mc_samples=48,
                         fit_steps=8)
        rng = np.random.default_rng(2)
        for b in range(B):
            for _ in range(8):
                p = {"x": float(rng.uniform(0, 1)),
                     "y": float(rng.uniform(-1, 1))}
                bank.study(b).observe_params(p, _objective(p))
        return bank

    mixed = build(strats)
    assert mixed.optimizer == "mixed"
    homos = {s: build(s) for s in STRATS}
    for rnd in range(3):
        got = mixed.ask_all(2)
        want = {s: homos[s].ask_all(2) for s in STRATS}
        for b in range(B):
            s = strats[b]
            assert [t.params for t in got[b]] \
                == [t.params for t in want[s][b]], (rnd, b, s)
            for tm, th in zip(got[b], want[s][b]):
                # identical params -> identical objective fed to both
                mixed.tell(b, tm.id, _objective(tm.params))
                homos[s].tell(b, th.id, _objective(th.params))


def test_bucket_shapes_shared_across_bank():
    """Studies of different sizes share one bucket: the bank ask pads every
    study to the same power-of-2 capacity, and the ledger factor buffers
    grow to hold it."""
    bank = _seeded_bank("bayesian", [EDGE - 1, EDGE, EDGE + 1])
    bank.ask_all(1)
    # all three studies proposed through one program at one bucket shape
    assert bank.ledger.gp_capacity >= 64
    for b in range(3):
        assert len(bank.study(b).pending_trials()) == 1


# --------------------------------------------------------------------------- #
# rng kind tag
# --------------------------------------------------------------------------- #
def test_pack_rng_state_rejects_non_pcg64():
    rng = np.random.Generator(np.random.MT19937(0))
    with pytest.raises(ValueError, match="PCG64"):
        pack_rng_state(rng)


def test_checkpoint_rng_kind_tag_validated(tmp_path):
    """Checkpoints carry the bit-generator kind; load refuses a mismatch
    (the 6-word packed rng rows are PCG64-specific) and treats legacy
    checkpoints without the tag as PCG64."""
    bank = StudyBank(SPACE, 2, seed=3, mc_samples=32)
    _run(bank, 2)
    path = tmp_path / "fleet.npz"
    bank.save(path, iteration=4)
    with np.load(path) as z:
        meta = json.loads(bytes(z["meta"]).decode())
        arrays = {k: z[k] for k in z.files if k != "meta"}
    assert meta["rng_kind"] == "PCG64"

    def rewrite(meta_dict, to):
        np.savez(to, meta=np.frombuffer(
            json.dumps(meta_dict).encode(), dtype=np.uint8), **arrays)

    bad = tmp_path / "bad.npz"
    rewrite({**meta, "rng_kind": "MT19937"}, bad)
    fresh = StudyBank(SPACE, 2, seed=3, mc_samples=32)
    with pytest.raises(ValueError, match="MT19937"):
        fresh.load(bad)
    # legacy (pre-tag) checkpoint: still loads as PCG64
    legacy_meta = {k: v for k, v in meta.items() if k != "rng_kind"}
    legacy = tmp_path / "legacy.npz"
    rewrite(legacy_meta, legacy)
    assert fresh.load(legacy) == 4
