import json

import numpy as np
import pytest
from scipy.stats import uniform

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # deterministic tests below still run
    HAVE_HYPOTHESIS = False

from repro.core import Tuner


def quad(p):
    return -(p["x"] - 0.7) ** 2 - (p["y"] - 0.2) ** 2


def serial_objective(batch):
    return [quad(p) for p in batch], list(batch)


SPACE = {"x": uniform(0, 1), "y": uniform(0, 1)}
FAST = dict(mc_samples=1500, fit_steps=15)


def test_maximize_beats_random_seeded():
    conf = dict(optimizer="bayesian", num_iteration=10, batch_size=3,
                seed=0, **FAST)
    res_b = Tuner(SPACE, serial_objective, conf).maximize()
    res_r = Tuner(SPACE, serial_objective,
                  {**conf, "optimizer": "random"}).maximize()
    assert res_b.best_objective >= res_r.best_objective - 1e-3
    assert res_b.best_objective > -0.01


def test_minimize():
    res = Tuner(SPACE, lambda b: ([-quad(p) for p in b], list(b)),
                dict(optimizer="clustering", num_iteration=8, batch_size=3,
                     seed=1, **FAST)).minimize()
    assert res.best_objective < 0.01  # minimizing the positive quadratic


def test_partial_results_and_reordering():
    """Paper §2.4: objective may return any subset in any order."""
    rng = np.random.default_rng(0)

    def flaky(batch):
        pairs = [(quad(p), p) for p in batch]
        rng.shuffle(pairs)
        keep = pairs[:max(1, len(pairs) - 2)]  # drop up to 2 per batch
        return [v for v, _ in keep], [p for _, p in keep]

    res = Tuner(SPACE, flaky, dict(optimizer="bayesian", num_iteration=8,
                                   batch_size=4, seed=2, **FAST)).maximize()
    assert res.n_failed > 0
    assert res.best_objective > -0.05
    assert len(res.objective_values) == len(res.params_tried)


def test_nan_and_exception_eval_dropped():
    def sometimes_nan(batch):
        out = []
        for i, p in enumerate(batch):
            out.append(float("nan") if i % 2 == 0 else quad(p))
        return out, list(batch)

    res = Tuner(SPACE, sometimes_nan,
                dict(optimizer="bayesian", num_iteration=5, batch_size=4,
                     seed=3, **FAST)).maximize()
    assert all(np.isfinite(v) for v in res.objective_values)
    assert res.n_failed >= 10


def test_empty_batches_survive():
    calls = {"n": 0}

    def dead_then_alive(batch):
        calls["n"] += 1
        if calls["n"] <= 2:
            return [], []  # total worker outage for 2 rounds
        return serial_objective(batch)

    res = Tuner(SPACE, dead_then_alive,
                dict(optimizer="bayesian", num_iteration=6, batch_size=2,
                     seed=4, **FAST)).maximize()
    assert res.best_objective > -0.2


def test_checkpoint_resume(tmp_path):
    ckpt = tmp_path / "tuner.json"
    conf = dict(optimizer="bayesian", num_iteration=6, batch_size=2, seed=5,
                checkpoint_path=str(ckpt), **FAST)
    full = Tuner(SPACE, serial_objective, conf).maximize()

    # restart from scratch with the same config: first tuner runs 3 iters
    ckpt2 = tmp_path / "tuner2.json"
    conf2 = {**conf, "checkpoint_path": str(ckpt2), "num_iteration": 3}
    Tuner(SPACE, serial_objective, conf2).maximize()
    state = json.loads(ckpt2.read_text())
    assert state["iteration"] == 3
    # resume to 6
    conf3 = {**conf2, "num_iteration": 6}
    resumed = Tuner(SPACE, serial_objective, conf3).maximize()
    assert resumed.iterations == 6
    assert len(resumed.objective_values) == len(full.objective_values)


def test_config_validation():
    with pytest.raises(ValueError):
        Tuner(SPACE, serial_objective, dict(optimizer="sgd"))
    with pytest.raises(ValueError):
        Tuner(SPACE, serial_objective, dict(nonsense=1))
    with pytest.raises(ValueError):
        bad = lambda b: ([1.0], [])  # mismatched lengths
        Tuner(SPACE, bad, dict(num_iteration=1)).maximize()


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.permutations(list(range(6))), st.integers(0, 1000))
    def test_observation_order_invariance(perm, seed):
        """The tuner's observed set is invariant to result ordering."""
        def permuting(batch):
            idx = [i for i in perm if i < len(batch)]
            return [quad(batch[i]) for i in idx], [batch[i] for i in idx]

        res = Tuner(SPACE, permuting,
                    dict(optimizer="random", num_iteration=3, batch_size=6,
                         seed=seed, mc_samples=500)).maximize()
        for v, p in zip(res.objective_values, res.params_tried):
            assert abs(v - quad(p)) < 1e-9
else:
    def test_observation_order_invariance():
        pytest.importorskip("hypothesis")
