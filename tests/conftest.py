import os
import sys
from pathlib import Path

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only the dry-run (and subprocess sharding tests)
# force 512/8 host devices.
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402

from repro.models.common import Runtime  # noqa: E402


@pytest.fixture(scope="session")
def rt32():
    """fp32 runtime with small chunks for reduced-config tests."""
    return Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32,
                   ce_chunk=16, ssm_chunk=8, attn_q_chunk=8,
                   attn_dense_threshold=4096)
