"""Runtime sanitizers: retrace audits, transfer guards, lock assertions,
and the steady-state serving contract they gate end to end."""
import importlib.util
import sys
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy import stats

from repro.analysis.sanitizers import (RetraceError, assert_holds,
                                       debug_locks_enabled, no_retrace,
                                       no_transfer, set_debug_locks)
from repro.core import StudyBank

SPACE = {"x": stats.uniform(0, 1), "y": stats.uniform(-1, 2)}


def _objective(p):
    return -(p["x"] - 0.3) ** 2 - (p["y"] - 0.5) ** 2


def _drive(bank, rounds):
    for _ in range(rounds):
        for b, ts in enumerate(bank.ask_all(1)):
            for t in ts:
                bank.tell(b, t.id, _objective(t.params))


# --------------------------------------------------------------------------- #
# no_retrace
# --------------------------------------------------------------------------- #
def test_no_retrace_clean_on_cache_hits():
    f = jax.jit(lambda x: x + 1)
    f(jnp.ones(4))  # warm
    with no_retrace({"f": f}) as rep:
        f(jnp.ones(4))
        f(jnp.ones(4))
    assert rep.violations == 0
    assert rep.deltas == {"f": 0}
    assert rep.detail() == ""


def test_no_retrace_raises_on_new_shape():
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones(4))
    with pytest.raises(RetraceError, match="bad_entry=1/0"):
        with no_retrace({"bad_entry": f}):
            f(jnp.ones(8))  # new shape -> new compile


def test_no_retrace_expected_budget_allows_known_compiles():
    f = jax.jit(lambda x: x - 1)
    f(jnp.ones(4))
    with no_retrace({"f": f}, expected={"f": 1}) as rep:
        f(jnp.ones(8))
        f(jnp.ones(8))  # second call is a hit
    assert rep.violations == 0
    assert rep.deltas == {"f": 1}


def test_no_retrace_report_mode_fills_expected_late():
    """The benchmark idiom: audit with raise_on_violation=False, assign
    rep.expected once the sweep knows its bucket count."""
    f = jax.jit(lambda x: x / 2)
    f(jnp.ones(4))
    with no_retrace({"f": f}, raise_on_violation=False) as rep:
        f(jnp.ones(16))
        rep.expected = {"f": 1}
    assert rep.violations == 0
    with no_retrace({"f": f}, raise_on_violation=False) as rep:
        f(jnp.ones(32))
    assert rep.violations == 1
    assert rep.detail() == "f=1/0"


# --------------------------------------------------------------------------- #
# no_transfer
# --------------------------------------------------------------------------- #
def test_no_transfer_implicit_h2d_raises_explicit_allowed():
    x = np.ones(3, np.float32)
    with no_transfer(device_to_host=None, host_to_device="disallow"):
        jnp.asarray(x)  # explicit upload: always sanctioned
        with pytest.raises(Exception, match="[Dd]isallow"):
            jnp.sin(x)  # implicit operand upload


def test_no_transfer_default_keeps_device_get_and_uploads_open():
    y = jax.jit(lambda v: v + 1)(jnp.ones(3))
    jax.block_until_ready(y)
    with no_transfer():
        jnp.asarray(np.ones(3, np.float32))    # designed h2d traffic
        out = jax.device_get(y)                # the sanctioned exit
    np.testing.assert_allclose(out, 2.0)


# --------------------------------------------------------------------------- #
# assert_holds
# --------------------------------------------------------------------------- #
def test_assert_holds_noop_when_disabled():
    prev = set_debug_locks(False)
    try:
        assert_holds(threading.RLock())  # not held: still no raise
    finally:
        set_debug_locks(prev)


def test_assert_holds_checks_ownership_when_enabled():
    prev = set_debug_locks(True)
    try:
        assert debug_locks_enabled()
        rlock = threading.RLock()
        with pytest.raises(AssertionError, match="not held"):
            assert_holds(rlock)
        with rlock:
            assert_holds(rlock)
        cv = threading.Condition()
        with pytest.raises(AssertionError):
            assert_holds(cv)
        with cv:
            assert_holds(cv)
        plain = threading.Lock()
        with pytest.raises(AssertionError):
            assert_holds(plain)
        with plain:
            assert_holds(plain)
    finally:
        set_debug_locks(prev)


def test_scheduler_drain_contracts_pass_under_debug_locks():
    """The adopted assert_holds sites (shutdown drain predicates) hold
    their declared locks on the real paths."""
    from repro.scheduler import SerialScheduler
    from repro.scheduler.base import BatchToAsyncAdapter
    from repro.scheduler.distributed import TaskQueueScheduler

    prev = set_debug_locks(True)
    try:
        adapter = BatchToAsyncAdapter(SerialScheduler())
        h = adapter.submit(lambda p: p["x"], {"x": 1.5})
        adapter.wait_any([h], timeout=10.0)
        assert adapter.shutdown(timeout=10.0)

        q = TaskQueueScheduler(n_workers=2)
        hs = [q.submit(lambda p: p["x"], {"x": i}) for i in range(3)]
        q.wait_any(hs, timeout=10.0)
        assert q.shutdown(timeout=10.0)
    finally:
        set_debug_locks(prev)


# --------------------------------------------------------------------------- #
# steady-state serving under both sanitizers (the PR 4/6 contract)
# --------------------------------------------------------------------------- #
def test_steady_state_bank_serving_is_sanitizer_clean():
    """Warm StudyBank ask_all/tell rounds inside one shape bucket: not a
    single jit compile of any BANK_JITS entry point and no implicit
    transfers, with real tells (growing n_obs) in the loop."""
    bank = StudyBank(SPACE, 4, optimizer="bayesian", seed=0, mc_samples=32)
    _drive(bank, 3)  # warmup: GP pipeline + first hyper fit compile here
    with no_transfer(), no_retrace() as rep:
        _drive(bank, 5)
    assert rep.violations == 0, rep.detail()


def test_smoke_module_passes():
    from repro.analysis import smoke
    assert smoke.run(rounds=4, verbose=False) == 0


class _FreshJit:
    """Deliberately broken jit wrapper: re-jits the wrapped function on
    every call, so each invocation is a fresh compile."""

    def __init__(self, jitted):
        self._inner = jitted.__wrapped__
        self._jits = []

    def __call__(self, *args, **kwargs):
        j = jax.jit(self._inner)
        self._jits.append(j)
        return j(*args, **kwargs)

    def _cache_size(self):
        return sum(j._cache_size() for j in self._jits)


def test_injected_retrace_trips_the_gate(monkeypatch):
    """Negative control: break bank_exp's caching and the zero-retrace
    audit must report violations (the bench gate then exits 1)."""
    from repro.core import gp as gp_lib

    bank = StudyBank(SPACE, 2, optimizer="bayesian", seed=3, mc_samples=32)
    _drive(bank, 3)  # warm with the intact pipeline
    fresh = _FreshJit(gp_lib.bank_exp)
    monkeypatch.setattr(gp_lib, "bank_exp", fresh)
    monkeypatch.setitem(gp_lib.BANK_JITS, "bank_exp", fresh)
    with no_retrace(raise_on_violation=False) as rep:
        _drive(bank, 2)
    assert rep.violations >= 2  # one fresh compile per audited ask
    assert "bank_exp" in rep.detail()


# --------------------------------------------------------------------------- #
# the benchmark gate plumbing
# --------------------------------------------------------------------------- #
def _load_multi_study():
    path = Path(__file__).resolve().parents[1] / "benchmarks" \
        / "multi_study.py"
    spec = importlib.util.spec_from_file_location("_multi_study_bench",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_multi_study_main_exits_nonzero_on_retraces(monkeypatch):
    mod = _load_multi_study()
    monkeypatch.setattr(mod, "run_throughput", lambda **kw: [])
    monkeypatch.setattr(mod, "run_retrace_sweep", lambda **kw: 3)
    monkeypatch.setattr(sys, "argv", ["multi_study.py", "--quick"])
    with pytest.raises(SystemExit) as exc:
        mod.main()
    assert exc.value.code == 1
    monkeypatch.setattr(mod, "run_retrace_sweep", lambda **kw: 0)
    mod.main()  # zero retraces: returns without SystemExit
