"""Sharded lowering + elastic-restore + compressed-psum tests.

These need >1 device, so each spawns a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count set BEFORE jax imports
(the main test process must keep seeing 1 device).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, n_dev: int = 8) -> str:
    # JAX_PLATFORMS=cpu: these are forced-host-device simulations; without
    # it a stripped env lets the TPU PJRT plugin probe GCP instance metadata
    # (30 retries per variable) and the subprocess blows its timeout.
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}",
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_reduced_arch_lowers_on_mesh():
    """jit(train_step) with full sharding rules compiles on a (2,4) mesh
    and the loop-aware HLO analyzer sees its collectives."""
    out = _run("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh, make_shard_ctx
        from repro.launch.sharding import param_specs, batch_specs, to_shardings
        from repro.launch import hlo_cost
        from repro.models.common import Runtime
        from repro.train.step import TrainHyper, init_train_state, make_train_step
        import dataclasses

        mesh = make_test_mesh((2, 4), ("data", "model"))
        cfg = get_config("smollm-135m", reduced=True)
        cfg = dataclasses.replace(cfg, n_heads=4, n_kv_heads=4, d_model=64,
                                  d_ff=128, vocab_size=512)
        rt = Runtime(sc=make_shard_ctx(mesh), ce_chunk=16)
        state = jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg, rt))
        ps = to_shardings(param_specs(state["params"], cfg, rt.sc), mesh)
        sh = {"params": ps, "opt": {"m": ps, "v": ps, "step": NamedSharding(mesh, P())}}
        B, S = 8, 32
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        bs = to_shardings(batch_specs(batch, rt.sc, B), mesh)
        step = make_train_step(cfg, rt, TrainHyper(), 2)
        lowered = jax.jit(step, in_shardings=(sh, bs), donate_argnums=0).lower(state, batch)
        compiled = lowered.compile()
        res = hlo_cost.analyze_module(compiled.as_text(), 8)
        coll = {k: v["count"] for k, v in res["coll"].items() if v["count"]}
        print(json.dumps({"flops": res["flops"], "coll": coll}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["flops"] > 0
    assert sum(res["coll"].values()) > 0  # TP/FSDP produced collectives


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint saved while sharded on (4,2) restores onto (2,2,2)."""
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import Checkpointer
        from repro.launch.mesh import make_test_mesh

        mesh1 = make_test_mesh((4, 2), ("data", "model"))
        state = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        sh1 = {{"w": NamedSharding(mesh1, P("data", "model"))}}
        state = jax.device_put(state, sh1)
        ck = Checkpointer({str(tmp_path)!r}, async_save=False)
        ck.save(1, state)

        mesh2 = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
        sh2 = {{"w": NamedSharding(mesh2, P(("pod", "data"), "model"))}}
        restored, _ = ck.restore(None, state, shardings=sh2)
        assert restored["w"].sharding == sh2["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64).reshape(8, 8))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_compressed_psum_shard_map():
    """int8 EF all-reduce over a manual 'pod' axis matches fp32 psum."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.compat import shard_map
        from repro.launch.mesh import make_test_mesh
        from repro.optim.compression import compressed_psum
        from jax.sharding import PartitionSpec as P

        mesh = make_test_mesh((4,), ("pod",))
        x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                        jnp.float32)

        @partial(shard_map, mesh=mesh, in_specs=P("pod"),
                 out_specs=P("pod"))
        def f(xs):
            return compressed_psum(xs[0], "pod")[None]

        got = np.asarray(f(x))[0]
        want = np.asarray(x.sum(0))
        err = np.abs(got - want).max() / np.abs(want).max()
        assert err < 0.02, err
        print("PSUM_OK", err)
    """, n_dev=4)
    assert "PSUM_OK" in out


def test_production_mesh_shapes():
    out = _run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.devices.size == 256 and m1.axis_names == ("data", "model")
        assert m2.devices.size == 512 and m2.axis_names == ("pod", "data", "model")
        print("MESH_OK")
    """, n_dev=512)
    assert "MESH_OK" in out
