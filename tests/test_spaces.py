import numpy as np
import pytest
from scipy.stats import expon, norm, randint, uniform

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # deterministic tests below still run
    HAVE_HYPOTHESIS = False

from repro.core.spaces import ParamSpace, loguniform


def test_listing2_svm_space():
    """The paper's Listing 2 space (SVM: C, gamma, kernel)."""
    space = ParamSpace({
        "C": uniform(0.1, 10),
        "gamma": loguniform(-3, 3),
        "kernel": ["rbf", "sigmoid", "poly"],
    })
    rng = np.random.default_rng(0)
    samples = space.sample(100, rng)
    assert len(samples) == 100
    for s in samples:
        assert 0.1 <= s["C"] <= 10.1
        assert 10 ** -3 <= s["gamma"] <= 10 ** 0
        assert s["kernel"] in ("rbf", "sigmoid", "poly")
    enc = space.encode(samples)
    assert enc.shape == (100, 1 + 1 + 3)  # one-hot categorical
    assert (enc >= 0).all() and (enc <= 1).all()


def test_listing1_xgboost_space():
    """The paper's Listing 1 space (XGBoost)."""
    space = ParamSpace({
        "learning_rate": uniform(0, 1),
        "gamma": uniform(0, 5),
        "max_depth": range(1, 10),
        "n_estimators": range(1, 300),
        "booster": ["gbtree", "gblinear", "dart"],
    })
    rng = np.random.default_rng(1)
    s = space.sample(50, rng)
    assert all(1 <= x["max_depth"] <= 9 for x in s)
    assert all(1 <= x["n_estimators"] <= 299 for x in s)
    assert space.domain_size > 1e5  # ~10^6 per the paper


def test_scipy_distribution_breadth():
    space = ParamSpace({"a": norm(0, 1), "b": expon(), "c": randint(2, 30)})
    rng = np.random.default_rng(2)
    samples = space.sample(64, rng)
    enc = space.encode(samples)
    assert enc.shape == (64, 3)
    assert np.isfinite(enc).all()


def test_constants_and_numeric_lists():
    space = ParamSpace({"const": 7, "sizes": [16, 32, 64, 128]})
    rng = np.random.default_rng(3)
    s = space.sample(10, rng)
    assert all(x["const"] == 7 for x in s)
    assert all(x["sizes"] in (16, 32, 64, 128) for x in s)
    assert space.encode(s).shape == (10, 1)  # numeric list is ordinal


def test_errors():
    with pytest.raises(ValueError):
        ParamSpace({})
    with pytest.raises(ValueError):
        ParamSpace({"x": []})
    with pytest.raises(ValueError):
        ParamSpace({"x": range(5, 5)})


def test_mc_samples_heuristic_scales():
    small = ParamSpace({"x": uniform(0, 1)})
    big = ParamSpace({f"x{i}": uniform(0, 1) for i in range(8)})
    assert small.mc_samples() < big.mc_samples()
    assert 2000 <= small.mc_samples() <= 32768
    assert big.mc_samples(batch_size=8) <= 32768


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 5), st.integers(1, 60), st.integers(0, 2 ** 31 - 1))
    def test_encode_in_unit_cube_property(n_cont, n_samples, seed):
        space_dict = {f"c{i}": uniform(i, 2 * i + 1) for i in range(n_cont)}
        space_dict["k"] = ["a", "b"]
        space_dict["r"] = range(1, 17)
        space = ParamSpace(space_dict)
        rng = np.random.default_rng(seed)
        samples = space.sample(n_samples, rng)
        enc = space.encode(samples)
        assert enc.shape == (n_samples, space.dim)
        assert (enc >= -1e-9).all() and (enc <= 1 + 1e-9).all()
else:
    def test_encode_in_unit_cube_property():
        pytest.importorskip("hypothesis")


def test_loguniform_cdf_ppf_roundtrip():
    lu = loguniform(-4, 3)
    q = np.linspace(0.01, 0.99, 17)
    np.testing.assert_allclose(lu.cdf(lu.ppf(q)), q, atol=1e-9)


class _SamplingOnly:
    """A distribution exposing only the paper's minimal contract (.rvs)."""

    def rvs(self, size=None, random_state=None):
        rng = (random_state if isinstance(random_state, np.random.Generator)
               else np.random.default_rng(random_state))
        return rng.gamma(2.0, 1.5, size)


def test_sampling_only_distribution_batch_stable_encoding():
    """No-.cdf distributions must encode a value identically regardless of
    its batchmates: the persistent empirical CDF replaces the old per-batch
    min-max (which changed the GP input for the same config every batch)."""
    space = ParamSpace({"g": _SamplingOnly()})
    rng = np.random.default_rng(0)
    s = space.sample(32, rng)
    enc_alone = np.array([space.encode([c])[0, 0] for c in s])
    enc_batch = space.encode(s)[:, 0]
    np.testing.assert_array_equal(enc_alone, enc_batch)  # batch-invariant
    # stable across a fresh ParamSpace too (checkpoint/resume encodes the
    # same history to the same GP inputs)
    space2 = ParamSpace({"g": _SamplingOnly()})
    np.testing.assert_array_equal(space2.encode(s)[:, 0], enc_batch)
    assert (enc_batch >= 0).all() and (enc_batch <= 1).all()
    # monotone in the underlying value
    order = np.argsort([c["g"] for c in s])
    assert (np.diff(enc_batch[order]) >= 0).all()


def test_scipy_loguniform_columnar_fast_path_bitwise():
    """Frozen scipy loguniform gets the closed-form columnar treatment:
    ``sample_array`` must reproduce scipy's draw AND leave the RNG stream
    in the identical state (scipy's default _rvs is _ppf(uniform(n)), and
    loguniform defines no custom _rvs), and ``encode`` must equal its cdf
    bitwise — otherwise a bank checkpoint written by one path would replay
    differently under the other."""
    from scipy.stats import loguniform as sp_loguniform
    frozen = sp_loguniform(1e-4, 1e-1)
    space = ParamSpace({"lr": frozen})
    p = space.params[0]
    assert p._loguniform_abls is not None      # fast path engaged
    r_fast, r_ref = np.random.default_rng(7), np.random.default_rng(7)
    ours = p.sample_array(512, r_fast)
    ref = np.asarray(frozen.rvs(size=512, random_state=r_ref))
    np.testing.assert_array_equal(ours, ref)
    assert r_fast.bit_generator.state == r_ref.bit_generator.state
    np.testing.assert_array_equal(p.encode(list(ref))[:, 0],
                                  frozen.cdf(ref))
    # loc/scale-shifted frozen variant stays exact on the sampling stream
    shifted = sp_loguniform(2.0, 50.0, loc=1.5, scale=3.0)
    p2 = ParamSpace({"z": shifted}).params[0]
    r_fast, r_ref = np.random.default_rng(3), np.random.default_rng(3)
    np.testing.assert_array_equal(
        p2.sample_array(256, r_fast),
        np.asarray(shifted.rvs(size=256, random_state=r_ref)))
    assert r_fast.bit_generator.state == r_ref.bit_generator.state
    # out-of-support values clamp into the unit cube instead of NaN/inf
    enc = p2.encode([0.0, 1.5, 1e9])
    assert np.isfinite(enc).all()
    assert (enc >= 0).all() and (enc <= 1).all()
    # Mango's own loguniform helper has no scipy .dist: fast path stays off
    assert ParamSpace({"g": loguniform(-3, 3)}).params[0] \
        ._loguniform_abls is None
