"""Training-loop system tests: convergence, microbatching, compression,
checkpoint/restart byte-determinism."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM, host_shard
from repro.models.common import Runtime
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import ef_quantize
from repro.train.checkpoint import Checkpointer
from repro.train.step import TrainHyper, init_train_state, make_train_step

CFG = get_config("smollm-135m", reduced=True)
RT = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32,
             ce_chunk=32, attn_dense_threshold=4096)


def _pipeline(B=8, S=64, seed=7):
    return SyntheticLM(DataConfig(vocab_size=CFG.vocab_size, seq_len=S,
                                  global_batch=B, seed=seed))


def _run(steps, hyper=None, n_micro=1, state=None, data=None, start=0):
    hyper = hyper or TrainHyper(opt=AdamWConfig(lr=3e-3, warmup_steps=10,
                                                total_steps=steps))
    data = data or _pipeline()
    if state is None:
        state = init_train_state(jax.random.PRNGKey(0), CFG, RT,
                                 grad_compression=hyper.grad_compression)
    step_fn = jax.jit(make_train_step(CFG, RT, hyper, n_micro),
                      donate_argnums=0)
    losses = []
    for s in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    return losses, state


def test_loss_decreases():
    losses, _ = _run(40)
    assert losses[-1] < losses[0] - 0.3


def test_microbatch_equivalence():
    """Gradient accumulation is numerically equivalent to the full batch."""
    l1, _ = _run(3, n_micro=1)
    l2, _ = _run(3, n_micro=4)
    np.testing.assert_allclose(l1, l2, rtol=2e-4)


def test_grad_compression_converges():
    h = TrainHyper(opt=AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=30),
                   grad_compression="int8_ef")
    lc, _ = _run(30, hyper=h)
    lu, _ = _run(30)
    assert lc[-1] < lc[0] - 0.2               # still learns
    assert abs(lc[-1] - lu[-1]) < 0.25        # close to uncompressed


def test_ef_quantize_identity():
    """EF invariant: deq + new_err == g + err exactly (no signal lost)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    err = jnp.asarray(rng.normal(size=(64,)).astype(np.float32)) * 0.1
    deq, new_err = ef_quantize(g, err)
    np.testing.assert_allclose(np.asarray(deq + new_err),
                               np.asarray(g + err), rtol=1e-6)


def test_checkpoint_restart_is_bit_deterministic(tmp_path):
    """Crash/restart drill: resume == uninterrupted run."""
    data = _pipeline()
    losses_full, _ = _run(10, data=data)

    ck = Checkpointer(str(tmp_path), async_save=False)
    data2 = _pipeline()
    losses_a, state = _run(5, data=data2)
    ck.save(5, state, extra={"data_state": data2.state()})

    template = init_train_state(jax.random.PRNGKey(0), CFG, RT)
    restored, meta = ck.restore(None, template)
    data3 = _pipeline()
    data3.restore(meta["data_state"])
    losses_b, _ = _run(10, state=restored, data=data3, start=5)
    np.testing.assert_allclose(losses_a + losses_b, losses_full, rtol=1e-5)


def test_checkpoint_gc_and_atomicity(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    files = sorted(p.name for p in tmp_path.glob("step_*.npz"))
    assert files == ["step_00000003.npz", "step_00000004.npz"]
    assert not list(tmp_path.glob(".tmp*"))  # no partial files left


def test_data_determinism_and_sharding():
    d1, d2 = _pipeline(seed=3), _pipeline(seed=3)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    shard0 = host_shard(b1, 0, 4)
    shard3 = host_shard(b1, 3, 4)
    assert shard0["tokens"].shape[0] == b1["tokens"].shape[0] // 4
    assert not np.array_equal(shard0["tokens"], shard3["tokens"])


def test_markov_data_is_learnable():
    """CE drops below the ln(V) uniform floor (the stream has structure)."""
    losses, _ = _run(50)
    assert min(losses[-5:]) < np.log(CFG.vocab_size) - 0.05
