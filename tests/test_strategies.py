import numpy as np
import pytest

from repro.core.kmeans import kmeans_assign
from repro.core.strategies import (ClusteringStrategy, HallucinationStrategy,
                                   RandomStrategy)


def _data(n=20, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(size=(n, 2)).astype(np.float32)
    y = -((X[:, 0] - 0.6) ** 2 + (X[:, 1] - 0.4) ** 2)
    C = rng.uniform(size=(600, 2)).astype(np.float32)
    return X, y, C


def test_hallucination_batch_is_diverse():
    X, y, C = _data()
    s = HallucinationStrategy(2, 1e4, fit_steps=15)
    picked = s.propose(X, y, C, batch_size=5)
    assert len(set(picked)) == 5
    pts = C[picked]
    # hallucination must spread the batch: no two picks collapse together
    d = np.linalg.norm(pts[:, None] - pts[None], axis=-1)
    np.fill_diagonal(d, 1.0)
    assert d.min() > 1e-3


def test_clustering_batch_unique_and_spread():
    X, y, C = _data(seed=1)
    s = ClusteringStrategy(2, 1e4, fit_steps=15)
    picked = s.propose(X, y, C, batch_size=5)
    assert len(set(picked)) == 5


def test_batch1_reduces_to_ucb_argmax():
    X, y, C = _data(seed=2)
    h = HallucinationStrategy(2, 1e4, fit_steps=15)
    c = ClusteringStrategy(2, 1e4, fit_steps=15)
    assert h.propose(X, y, C, 1)[0] == c.propose(X, y, C, 1)[0]


def test_random_strategy_no_gp():
    s = RandomStrategy()
    picked = s.propose(None, [], np.zeros((100, 2)), 8, seed=0)
    assert len(set(picked)) == 8


def test_random_strategy_clamps_small_candidate_set():
    """batch_size > n_candidates (tiny mc_samples override) must degrade
    gracefully instead of raising ValueError from rng.choice."""
    s = RandomStrategy()
    picked = s.propose(None, [], np.zeros((3, 2)), 8, seed=0)
    assert sorted(int(p) for p in picked) == [0, 1, 2]


def test_clustering_empty_cluster_backfill_never_duplicates():
    """Duplicated candidate locations force k-means to leave clusters
    empty; the backfill must never re-select an already-picked index (the
    old ``members = rest if len(rest) else top`` path could, silently
    collapsing the batch's spatial diversity)."""
    X, y, _ = _data(seed=3)
    # 3 distinct locations repeated -> k=5 clustering has >= 2 empty slots
    base = np.array([[0.1, 0.1], [0.5, 0.5], [0.9, 0.9]], np.float32)
    C = np.repeat(base, 7, axis=0)
    for seed in range(4):
        s = ClusteringStrategy(2, 1e4, fit_steps=10)
        picked = s.propose_host(X, y, C, batch_size=5, seed=seed)
        assert len(picked) == len(set(picked)) == 5
        dev = ClusteringStrategy(2, 1e4, fit_steps=10)
        picked_dev = dev.propose(X, y, C, batch_size=5, seed=seed)
        assert len(picked_dev) == len(set(picked_dev)) == 5


def test_clustering_propose_stays_on_device(monkeypatch):
    """The fused clustering path must not materialize the acquisition
    surface on host: neither the host predict adapter nor the host k-means
    may run."""
    import repro.core.strategies as strat_mod

    def boom(*a, **k):
        raise AssertionError("host acquisition/k-means path was used")

    monkeypatch.setattr(strat_mod.ClusteringStrategy, "_predict", boom)
    monkeypatch.setattr(strat_mod, "kmeans_assign", boom)
    X, y, C = _data(seed=1)
    s = ClusteringStrategy(2, 1e4, fit_steps=15)
    picked = s.propose(X, y, C, batch_size=5, seed=0)
    assert len(set(picked)) == 5


def test_contradictory_scorer_configs_raise():
    """Invalid/contradictory scoring configs raise instead of silently
    substituting a backend (matching the repo's validation convention)."""
    with pytest.raises(ValueError, match="unknown scorer"):
        HallucinationStrategy(2, 1e4, scorer="nope")
    with pytest.raises(ValueError, match="conflicts"):
        HallucinationStrategy(2, 1e4, use_pallas=True, scorer="chol")
    with pytest.raises(ValueError, match="factor core"):
        ClusteringStrategy(2, 1e4, scorer="chol")
    # the defaults resolve, not raise
    assert ClusteringStrategy(2, 1e4).scorer == "kinv_jnp"
    assert ClusteringStrategy(2, 1e4, use_pallas=True).scorer == \
        "kinv_pallas"


def test_kmeans_partitions():
    rng = np.random.default_rng(0)
    X = np.concatenate([rng.normal(0, 0.05, (30, 2)),
                        rng.normal(1, 0.05, (30, 2))]).astype(np.float32)
    w = np.ones(60, np.float32)
    a = kmeans_assign(X, w, 2, seed=0)
    assert set(a.tolist()) == {0, 1}
    # the two blobs end up in different clusters
    assert len(set(a[:30].tolist())) == 1
    assert a[0] != a[45]
