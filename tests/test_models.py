"""Per-arch smoke tests (reduced configs): shapes, finiteness, serving parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (Runtime, forward_decode, forward_prefill,
                          forward_train, init_params)


def _batch(cfg, key, B=2, S=16, dtype=jnp.float32):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                      jnp.int32),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                      jnp.int32)}
    if cfg.vision_tokens:
        b["patches"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), dtype)
    if cfg.encoder_layers:
        b["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), dtype)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rt32):
    """One forward/loss on CPU: correct shapes, no NaNs (assignment spec)."""
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, rt32)
    batch = _batch(cfg, key)
    loss, metrics = jax.jit(
        lambda p, b: forward_train(p, b, cfg, rt32))(params, batch)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5  # ~ln(V) at init
    assert float(metrics["tokens"]) == batch["tokens"].size


@pytest.mark.parametrize("arch", ["smollm-135m", "jamba-v0.1-52b",
                                  "xlstm-1.3b", "whisper-large-v3"])
def test_prefill_decode_parity(arch, rt32):
    """Decode after prefill == one full forward (exact cache semantics)."""
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(1)
    # no-drop MoE capacity: capacity dropping is batch-composition dependent,
    # so exact parity requires unbounded capacity (see test_moe_parity_*)
    rt32 = dataclasses.replace(rt32, moe_capacity_factor=64.0)
    params = init_params(key, cfg, rt32)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size, jnp.int32)
    full = _batch(cfg, key, B, S + 1)
    full["tokens"] = toks
    pre = dict(full)
    pre["tokens"] = toks[:, :S]
    vt = cfg.vision_tokens
    full_logits, _ = forward_prefill(params, full, cfg, rt32)
    _, cache = forward_prefill(params, pre, cfg, rt32,
                               cache_size=S + 1 + vt)
    dec_logits, _ = forward_decode(params, toks[:, S:S + 1], cache,
                                   jnp.int32(S + vt), cfg, rt32)
    np.testing.assert_allclose(np.asarray(full_logits),
                               np.asarray(dec_logits), atol=5e-3)


def test_moe_parity_needs_capacity(rt32):
    """MoE drop policy: parity holds exactly when capacity is unbounded."""
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    rt = dataclasses.replace(rt32, moe_capacity_factor=64.0)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg, rt)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size, jnp.int32)
    full_logits, _ = forward_prefill(params, {"tokens": toks}, cfg, rt)
    _, cache = forward_prefill(params, {"tokens": toks[:, :S]}, cfg, rt,
                               cache_size=S + 1)
    dec_logits, _ = forward_decode(params, toks[:, S:S + 1], cache,
                                   jnp.int32(S), cfg, rt)
    np.testing.assert_allclose(np.asarray(full_logits),
                               np.asarray(dec_logits), atol=5e-3)


def test_moe_drop_fraction_reported(rt32):
    cfg = get_config("olmoe-1b-7b", reduced=True)
    rt = dataclasses.replace(rt32, moe_capacity_factor=0.5)  # force drops
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg, rt)
    _, metrics = forward_train(params, _batch(cfg, key, 2, 32), cfg, rt)
    assert float(metrics["moe_drop_frac"]) > 0.0
    assert float(metrics["moe_lb_loss"]) > 0.0


def test_long_context_flags():
    """long_500k applicability matches DESIGN.md §Arch-applicability."""
    from repro.configs import SHAPES
    runs = {a: SHAPES["long_500k"].applicable(get_config(a))[0]
            for a in ARCH_IDS}
    assert runs["jamba-v0.1-52b"] and runs["xlstm-1.3b"]
    assert sum(runs.values()) == 2  # everything else is full attention
