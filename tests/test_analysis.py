"""repro-lint engine: per-rule good/bad fixtures, noqa suppression,
baseline round-trip, CLI exit codes, and the repo-sweep-clean gate.

Deliberately jax/numpy-free: the engine is stdlib-only so the CI lint
job runs without installing the stack, and these tests keep it that way.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import Baseline, lint_paths
from repro.analysis.__main__ import main as cli_main
from repro.analysis.rules import all_rules, rule_ids

REPO = Path(__file__).resolve().parents[1]


def _write(tmp_path: Path, rel: str, src: str) -> Path:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return p


def _findings(tmp_path, rel, src, rule_id=None):
    p = _write(tmp_path, rel, src)
    res = lint_paths([str(p)])
    if rule_id is None:
        return res.findings
    return [f for f in res.findings if f.rule == rule_id]


# --------------------------------------------------------------------------- #
# fixtures: for every rule, a firing bad case and a clean good case.
# paths mimic the real tree so rule *scoping* is exercised too.
# --------------------------------------------------------------------------- #
BAD_FIXTURES = {
    "REPRO-D001": ("core/tuner.py", """
        import time

        def deadline():
            return time.time() + 5.0
        """),
    "REPRO-D002": ("core/optimizer.py", """
        import numpy as np

        def propose():
            rng = np.random.default_rng()
            return np.random.uniform(0.0, 1.0)
        """),
    "REPRO-D003": ("service/server.py", """
        import time

        def apply_op(op):
            op["at"] = time.time()
            return op
        """),
    "REPRO-J101": ("core/gp.py", """
        import jax.numpy as jnp
        import numpy as np

        def score(c):
            v = jnp.exp(c)
            return np.asarray(v)
        """),
    "REPRO-J102": ("core/studybank.py", """
        import jax.numpy as jnp

        def per_study(xs):
            return [jnp.exp(x) for x in xs]
        """),
    "REPRO-J103": ("core/acquisition.py", """
        import jax

        def make(scale):
            @jax.jit
            def inner(x):
                return x * scale
            return inner
        """),
    "REPRO-C201": ("scheduler/pool.py", """
        import threading

        class Pool:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0

            def bump(self):
                with self._lock:
                    self._n += 1

            def reset(self):
                self._n = 0
        """),
    "REPRO-C202": ("scheduler/workers.py", """
        import threading

        def start(fn):
            threading.Thread(target=fn).start()
        """),
    "REPRO-C203": ("scheduler/drops.py", """
        def run(fn):
            try:
                return fn()
            except Exception:
                pass
        """),
    "REPRO-W301": ("service/commit.py", """
        class Svc:
            def commit(self, op):
                return self.bank.apply_op(op)
        """),
    "REPRO-W302": ("service/snapshot.py", """
        import json

        def publish(path, obj):
            with open(path, "w") as fh:
                json.dump(obj, fh)
        """),
}

GOOD_FIXTURES = {
    "REPRO-D001": ("core/tuner.py", """
        import time

        def deadline():
            return time.monotonic() + 5.0
        """),
    "REPRO-D002": ("core/optimizer.py", """
        import numpy as np

        def propose(seed):
            rng = np.random.default_rng(seed)
            return rng.uniform(0.0, 1.0)
        """),
    "REPRO-D003": ("service/server.py", """
        import time

        def report():
            return time.monotonic()

        def apply_op(op):
            return dict(op)
        """),
    "REPRO-J101": ("core/gp.py", """
        import jax
        import jax.numpy as jnp

        def score(c):
            v = jnp.exp(c)
            return jax.device_get(v)
        """),
    "REPRO-J102": ("core/studybank.py", """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def traced(xs):
            return [jnp.exp(x) for x in xs]

        def tpe_kde_kernel(x_ref, o_ref):
            for j in range(4):
                o_ref[j] = jnp.exp(x_ref[j])
        """),
    "REPRO-J103": ("core/acquisition.py", """
        import functools

        import jax

        def make(scale):
            @functools.partial(jax.jit, static_argnums=1)
            def inner(x, s):
                return x * s
            return lambda x: inner(x, scale)
        """),
    "REPRO-C201": ("scheduler/pool.py", """
        import threading

        from repro.analysis.sanitizers import assert_holds

        class Pool:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0

            def bump(self):
                with self._lock:
                    self._n += 1

            def reset(self):
                with self._lock:
                    self._n = 0

            def _reset_locked(self):
                assert_holds(self._lock)
                self._n = 0
        """),
    "REPRO-C202": ("scheduler/workers.py", """
        import threading

        def start(fn):
            threading.Thread(target=fn, daemon=True).start()
        """),
    "REPRO-C203": ("scheduler/drops.py", """
        import logging

        _log = logging.getLogger(__name__)

        def run(fn):
            try:
                return fn()
            except Exception as e:
                _log.debug("dropped: %r", e)
        """),
    "REPRO-W301": ("service/commit.py", """
        class Svc:
            def commit(self, op):
                self.wal.append(op)
                return self.bank.apply_op(op)
        """),
    "REPRO-W302": ("service/snapshot.py", """
        import json
        import os

        def publish(path, obj):
            tmp = path + ".tmp"
            with open(tmp, "w") as fh:
                json.dump(obj, fh)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)
        """),
}


@pytest.mark.parametrize("rule_id", sorted(BAD_FIXTURES))
def test_bad_fixture_fires(tmp_path, rule_id):
    rel, src = BAD_FIXTURES[rule_id]
    assert _findings(tmp_path, rel, src, rule_id), \
        f"{rule_id} bad fixture produced no finding"


@pytest.mark.parametrize("rule_id", sorted(GOOD_FIXTURES))
def test_good_fixture_is_clean(tmp_path, rule_id):
    rel, src = GOOD_FIXTURES[rule_id]
    found = _findings(tmp_path, rel, src, rule_id)
    assert not found, f"{rule_id} good fixture fired: {found}"


def test_every_registered_rule_has_a_firing_bad_fixture():
    """Meta-test: adding a rule without fixtures fails here, so the
    'every rule demonstrably fires' invariant survives new rules."""
    ids = set(rule_ids())
    assert ids == set(BAD_FIXTURES), \
        "every rule needs a BAD_FIXTURES entry (and vice versa)"
    assert ids == set(GOOD_FIXTURES)
    assert len(ids) >= 8


def test_rules_scope_to_their_directories(tmp_path):
    """The same offending source outside a rule's scope is not flagged."""
    _, src = BAD_FIXTURES["REPRO-D001"]
    assert not _findings(tmp_path, "viz/plots.py", src, "REPRO-D001")
    _, src = BAD_FIXTURES["REPRO-J101"]
    assert not _findings(tmp_path, "core/plots.py", src, "REPRO-J101")


# --------------------------------------------------------------------------- #
# noqa suppression
# --------------------------------------------------------------------------- #
def test_noqa_with_rule_id_suppresses(tmp_path):
    src = """
        import time

        def deadline():
            return time.time() + 5.0  # repro: noqa REPRO-D001
        """
    assert not _findings(tmp_path, "core/a.py", src, "REPRO-D001")


def test_bare_noqa_suppresses_everything_on_the_line(tmp_path):
    src = """
        import time

        def deadline():
            return time.time() + 5.0  # repro: noqa
        """
    assert not _findings(tmp_path, "core/a.py", src)


def test_noqa_for_other_rule_does_not_suppress(tmp_path):
    src = """
        import time

        def deadline():
            return time.time() + 5.0  # repro: noqa REPRO-J101
        """
    assert _findings(tmp_path, "core/a.py", src, "REPRO-D001")


# --------------------------------------------------------------------------- #
# baseline round-trip
# --------------------------------------------------------------------------- #
def test_baseline_roundtrip_add_suppress_stale(tmp_path):
    rel, src = BAD_FIXTURES["REPRO-D001"]
    p = _write(tmp_path, rel, src)
    res = lint_paths([str(p)])
    assert res.findings and not res.ok

    bl_path = tmp_path / "baseline.json"
    Baseline.from_findings(res.findings, note="known wall clock") \
        .save(str(bl_path))
    bl = Baseline.load(str(bl_path))
    res2 = lint_paths([str(p)], baseline=bl)
    assert res2.ok
    assert len(res2.baselined) == len(res.findings)
    assert not res2.stale

    # the match key is line *content*, so pure line-number churn
    # (a comment above) keeps the entry matching ...
    p.write_text("# moved\n" + p.read_text())
    res3 = lint_paths([str(p)], baseline=bl)
    assert res3.ok and not res3.stale

    # ... and removing the offending line makes the entry stale
    fixed = src.replace("time.time()", "time.monotonic()")
    p.write_text(textwrap.dedent(fixed))
    res4 = lint_paths([str(p)], baseline=bl)
    assert res4.ok
    assert len(res4.stale) == len(res.findings)


def test_unparsable_file_is_an_error(tmp_path):
    p = _write(tmp_path, "core/broken.py", "def f(:\n")
    res = lint_paths([str(p)])
    assert res.errors and not res.ok


# --------------------------------------------------------------------------- #
# CLI exit contract
# --------------------------------------------------------------------------- #
def test_cli_exit_codes(tmp_path, capsys):
    rel, src = BAD_FIXTURES["REPRO-D001"]
    bad = _write(tmp_path, rel, src)
    assert cli_main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "REPRO-D001" in out

    bl = tmp_path / "bl.json"
    assert cli_main([str(bad), "--write-baseline", str(bl)]) == 0
    assert cli_main([str(bad), "--baseline", str(bl)]) == 0
    assert cli_main([str(bad), "--baseline", str(tmp_path / "nope")]) == 2

    good = _write(tmp_path, "core/clean.py", "X = 1\n")
    assert cli_main([str(good)]) == 0
    assert cli_main(["--list-rules"]) == 0


def test_cli_json_format(tmp_path, capsys):
    rel, src = BAD_FIXTURES["REPRO-C203"]
    bad = _write(tmp_path, rel, src)
    assert cli_main([str(bad), "--format", "json"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["unbaselined"]
    assert payload["unbaselined"][0]["rule"] == "REPRO-C203"


# --------------------------------------------------------------------------- #
# the repo itself stays clean (the CI lint gate, as a test)
# --------------------------------------------------------------------------- #
def test_repo_sweep_clean_under_committed_baseline():
    bl = Baseline.load(str(REPO / ".repro-lint-baseline"))
    res = lint_paths([str(REPO / "src")], baseline=bl)
    assert res.ok, [f.format() for f in res.unbaselined] + res.errors
    assert not res.stale, res.stale


def test_rule_metadata_complete():
    for rule in all_rules():
        assert rule.id.startswith("REPRO-")
        assert rule.family and rule.description and rule.rationale
        assert rule.scopes  # every current rule is repo-scoped
