"""xlstm-1.3b — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM), no FFN.

[arXiv:2405.04517] 48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.
xLSTM blocks contain their own up/down projections (d_ff=0 -> ffn="none").
"""
from repro.configs.base import ArchConfig, LayerSpec

_PERIOD = (
    LayerSpec("mlstm", "none"),
    LayerSpec("mlstm", "none"),
    LayerSpec("mlstm", "none"),
    LayerSpec("slstm", "none"),
    LayerSpec("mlstm", "none"),
    LayerSpec("mlstm", "none"),
    LayerSpec("mlstm", "none"),
    LayerSpec("mlstm", "none"),
)

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    period=_PERIOD,
    lstm_expand=2,
    rope=False,
    subquadratic=True,  # constant-size matrix/scalar memory
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=2, n_kv_heads=2, vocab_size=512,
    )
