"""yi-34b — llama-architecture dense GQA model.

[arXiv:2403.04652] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
56 heads are not divisible by TP=16 -> attention falls back to
KV-sequence sharding (see repro/launch/sharding.py).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    period=(LayerSpec("attn", "dense"),),
    subquadratic=False,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=128,
        vocab_size=512,
    )
