"""olmoe-1b-7b — 64 routed experts, top-8.

[arXiv:2409.02060] 16L d_model=2048 16H (kv=16) d_ff=1024(per expert)
vocab=50304, MoE 64e top-8, no shared experts.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    period=(LayerSpec("attn", "moe"),),
    n_experts=64,
    top_k=8,
    moe_d_ff=1024,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=512, n_experts=8, top_k=4, moe_d_ff=64,
    )
