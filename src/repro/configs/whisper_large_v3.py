"""whisper-large-v3 — encoder-decoder with conv frontend STUB.

[arXiv:2212.04356] 32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
Encoder-decoder: 32 encoder layers (bidirectional) + 32 decoder layers
(causal + cross-attention).  The conv1d/mel frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
(1500 x d_model).  Sinusoidal positions (no RoPE).  Vocab 51866 is padded
to a multiple of 128 for TP divisibility (padded rows masked out of loss).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    period=(LayerSpec("attn", "dense", cross_attn=True),),
    encoder_layers=32,
    encoder_seq=1500,
    norm="layernorm",
    act="gelu",
    rope=False,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, encoder_layers=2, encoder_seq=16,
    )
