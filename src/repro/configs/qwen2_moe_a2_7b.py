"""qwen2-moe-a2.7b — 4 shared + 60 routed experts, top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B] 24L d_model=2048 16H (kv=16) d_ff=1408(per expert)
vocab=151936, MoE 60e top-4 with 4 always-on shared experts.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    period=(LayerSpec("attn", "moe"),),
    n_experts=60,
    top_k=4,
    moe_d_ff=1408,
    n_shared_experts=4,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=512, n_experts=8, top_k=2, moe_d_ff=96, n_shared_experts=2,
    )
