"""--arch registry: canonical ids -> ArchConfig (full and reduced)."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig  # noqa: F401

_MODULES = {
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "yi-34b": "repro.configs.yi_34b",
    "smollm-135m": "repro.configs.smollm_135m",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "command-r-35b": "repro.configs.command_r_35b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, reduced: bool = False) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.reduced() if reduced else mod.CONFIG


def all_configs(reduced: bool = False) -> Dict[str, ArchConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}


def get_shape(shape_id: str) -> ShapeConfig:
    if shape_id not in SHAPES:
        raise KeyError(f"unknown shape {shape_id!r}; known: {sorted(SHAPES)}")
    return SHAPES[shape_id]


def cells(include_skips: bool = False):
    """Yield (arch_id, shape_id, applicable, reason) for all 40 cells."""
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, reason = SHAPES[s].applicable(cfg)
            if ok or include_skips:
                yield a, s, ok, reason
