"""Architecture & shape configuration for the repro framework.

Every assigned architecture is expressed as an ``ArchConfig``: a declarative,
framework-agnostic description of a decoder LM (optionally with an encoder and
a stubbed modality frontend).  Layers are described as a repeating *period* of
``LayerSpec``s so heterogeneous stacks (Jamba's 1:7 Mamba:attention interleave
with MoE every other layer) lower to a single ``lax.scan`` over periods.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position inside the repeating layer period."""

    mixer: str  # "attn" | "mamba" | "mlstm" | "slstm"
    ffn: str = "dense"  # "dense" | "moe" | "none"
    cross_attn: bool = False  # decoder cross-attention (whisper)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    period: Tuple[LayerSpec, ...] = (LayerSpec("attn", "dense"),)
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    n_shared_experts: int = 0  # qwen2-moe: always-on shared experts
    capacity_factor: float = 1.25

    # --- SSM (mamba) ---
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    # --- xLSTM ---
    lstm_expand: int = 2  # mLSTM up-projection factor

    # --- encoder / frontend stubs ---
    encoder_layers: int = 0  # whisper: 32
    encoder_seq: int = 0  # whisper: 1500 frames (post-conv stub)
    vision_tokens: int = 0  # internvl2: prepended patch embeddings

    # --- misc ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (swiglu) | gelu (plain mlp)
    rope: bool = True
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    subquadratic: bool = False  # can run long_500k

    # ----------------------------------------------------------------- props
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"period={len(self.period)}"
        )
        return self.n_layers // len(self.period)

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or math.ceil(self.d_model / 16)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def lstm_d_inner(self) -> int:
        return self.lstm_expand * self.d_model

    @property
    def lstm_heads(self) -> int:
        # xLSTM uses a small head count over the up-projected dim.
        return self.n_kv_heads

    def padded_vocab(self, multiple: int = 128) -> int:
        """Vocab padded for TP divisibility / MXU lane alignment (Megatron-style)."""
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    # ------------------------------------------------------------- counting
    def param_count(self) -> dict:
        """Analytic parameter counts: total and active-per-token (MoE-aware)."""
        d, ff, hd = self.d_model, self.d_ff, self.hd
        qkv = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
        attn = qkv + self.n_heads * hd * d
        dense_ffn = 3 * d * ff if self.act == "silu" else 2 * d * ff
        shared_ffn = 3 * d * (self.n_shared_experts * self.moe_d_ff)
        expert = 3 * d * self.moe_d_ff
        di, r, n = self.ssm_d_inner, self.dt_rank, self.ssm_state_dim
        mamba = (d * 2 * di + di * self.ssm_conv_dim + di * (r + 2 * n)
                 + r * di + di * n + di + di * d)
        li = self.lstm_d_inner
        nh = self.lstm_heads
        dh_l = li // max(nh, 1)
        # block-diagonal per-head q/k/v (3 * nh * dh^2 = 3 * li * dh)
        mlstm = (d * 2 * li + 3 * li * dh_l + li * 2 * nh
                 + 4 * li + li * d)
        dh_s = d // max(nh, 1)
        slstm = d * 4 * d + nh * dh_s * 4 * dh_s + d * d

        total = active = 0
        for spec in self.period:
            mix = {"attn": attn, "mamba": mamba, "mlstm": mlstm, "slstm": slstm}[spec.mixer]
            if spec.cross_attn:
                mix += attn
            total += mix
            active += mix
            if spec.ffn == "dense":
                total += dense_ffn
                active += dense_ffn
            elif spec.ffn == "moe":
                total += self.n_experts * expert + d * self.n_experts + shared_ffn
                active += self.top_k * expert + d * self.n_experts + shared_ffn
        total *= self.n_periods
        active *= self.n_periods

        if self.encoder_layers:  # whisper encoder: attn + dense mlp
            enc = self.encoder_layers * (attn + dense_ffn)
            total += enc
            active += enc

        emb = self.padded_vocab() * d
        head = 0 if self.tie_embeddings else emb
        total += emb + head
        active += emb + head
        return {"total": total, "active": active}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    def applicable(self, cfg: ArchConfig) -> Tuple[bool, str]:
        if self.name == "long_500k" and not cfg.subquadratic:
            return False, ("quadratic full attention at 524k context; "
                           "run only for SSM/hybrid/linear-attention archs")
        return True, ""


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
