from repro.configs.base import ArchConfig, LayerSpec, ShapeConfig, SHAPES
from repro.configs.registry import ARCH_IDS, all_configs, cells, get_config, get_shape

__all__ = [
    "ArchConfig", "LayerSpec", "ShapeConfig", "SHAPES",
    "ARCH_IDS", "all_configs", "cells", "get_config", "get_shape",
]
