"""phi3-mini-3.8b — RoPE SwiGLU dense model (MHA: kv=32).

[arXiv:2404.14219] 32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    period=(LayerSpec("attn", "dense"),),
    subquadratic=False,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512,
    )
