"""smollm-135m — small llama-architecture dense model.

[hf:HuggingFaceTB/SmolLM-135M] 30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152.  9 heads are not divisible by TP=16 -> KV-sequence sharding
fallback for attention.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    period=(LayerSpec("attn", "dense"),),
    tie_embeddings=True,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=72, n_heads=3, n_kv_heads=1, d_ff=192,
        vocab_size=512,
    )
