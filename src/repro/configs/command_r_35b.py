"""command-r-35b — dense GQA, no biases, 256k vocabulary.

[hf:CohereForAI/c4ai-command-r-v01] 40L d_model=8192 64H (GQA kv=8)
d_ff=22528 vocab=256000.  The 256k x 8192 embedding is vocab-sharded over the
model axis (and tied to the LM head, as in the released model).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    period=(LayerSpec("attn", "dense"),),
    tie_embeddings=True,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=1024,
    )
