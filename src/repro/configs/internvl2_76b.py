"""internvl2-76b — InternViT frontend (stub) + 80L LM backbone.

[arXiv:2404.16821] 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The InternViT-6B vision tower is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (vision_tokens x d_model) which the
backbone prepends to the token embeddings.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    period=(LayerSpec("attn", "dense"),),
    vision_tokens=256,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, vision_tokens=8,
    )
