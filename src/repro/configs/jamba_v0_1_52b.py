"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Period of 8 layers: one attention layer per period (1:7 attn:mamba), MoE on
every other layer (4 MoE positions per period).
"""
from repro.configs.base import ArchConfig, LayerSpec

_PERIOD = (
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("attn", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    period=_PERIOD,
    n_experts=16,
    top_k=2,
    moe_d_ff=14336,
    ssm_state_dim=16,
    ssm_conv_dim=4,
    ssm_expand=2,
    rope=False,  # jamba uses no positional encoding (Mamba provides position)
    subquadratic=True,  # 7/8 of layers are SSM; attn layers decode linearly
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, n_experts=4, top_k=2, moe_d_ff=128, ssm_state_dim=8,
    )
