"""StudyBank: optimizer state as a pytree of arrays (multi-tenant asks).

Mango frames HPO as a production service (paper §1/§2.4); Tune and
Auptimizer make the same point — a tuning platform hosts *many* concurrent
studies, not one notebook loop.  This module gives the engine that shape:

  * ``StudyLedger`` — a registered pytree of fixed-capacity numpy arrays
    holding every study's trial ledger (encoded X rows, raw y, status,
    completion order), counters, per-study RNG state, GP hyperparameter /
    fit-schedule state, and the last Cholesky factors ``L``/``L⁻¹``.
    ``AskTellOptimizer`` is a *view* into one row of a ledger (a bank of
    one by default), so the single-study API is unchanged while the state
    itself is array-shaped.
  * ``StudyBank`` — N studies over one ledger.  ``ask_all`` gathers the
    bank into shape-bucketed device buffers (power-of-2 trial capacity, so
    a growing study re-enters a cached compiled program instead of
    retracing) and serves every study in one vmap'd pass: the staged
    ``gp.bank_*`` pipeline, ``tpe.fused_tpe_propose_bank``, or
    ``acquisition.fused_cluster_propose_bank``.  Observation-dependent
    device state (gather, factors, standardization) is cached on the
    ledger's ``obs_stamp``, so ask/tell_failed churn never recomputes a
    Cholesky.
  * One-write fleet checkpoints — ``save`` serializes the whole ledger
    pytree (plus a JSON meta block for params dicts / RNG streams) as a
    single ``.npz`` write; ``load`` restores every study mid-flight.

Bucketing contract: device buffers are padded to ``pow2(max(16, ...))``
rows with ``n_obs``/``n_pending`` carried as masked ranks, so within a
bucket the compiled program is reused ask after ask (the
``steady_state_retrace`` bench row asserts zero retraces across a
64→1024-observation growth sweep, compiles at bucket edges aside).
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

# trial-status codes (ledger ``status`` array; 0 = empty slot)
S_EMPTY, S_PENDING, S_OBSERVED, S_FAILED = 0, 1, 2, 3

_U64 = np.uint64
_MASK64 = (1 << 64) - 1


def _pow2(n: int) -> int:
    p = 16
    while p < n:
        p *= 2
    return p


# the one bit-generator the 6-word packed layout below encodes; checkpoints
# carry it as a meta tag so a future second generator type fails loudly at
# load instead of silently unpacking garbage words into a PCG64
RNG_KIND = "PCG64"


def pack_rng_state(rng: np.random.Generator) -> np.ndarray:
    """Pack a PCG64 Generator's full state into 6 uint64 words
    (state lo/hi, inc lo/hi, has_uint32, uinteger) for array storage."""
    st = rng.bit_generator.state
    kind = st.get("bit_generator")
    if kind != RNG_KIND:
        raise ValueError(
            f"pack_rng_state only encodes {RNG_KIND} streams; this "
            f"generator is {kind!r} — its state does not fit the 6-word "
            "packed layout (add a new rng_kind to the checkpoint format)")
    s, inc = st["state"]["state"], st["state"]["inc"]
    return np.array([s & _MASK64, (s >> 64) & _MASK64,
                     inc & _MASK64, (inc >> 64) & _MASK64,
                     st["has_uint32"], st["uinteger"]], dtype=_U64)


def rng_from_state(state: Dict[str, Any]) -> np.random.Generator:
    """Generator rebuilt from a serialized bit-generator state.  The
    explicit seed is a placeholder (the state overwrite replaces it) so
    restoring a stream never draws OS entropy."""
    rng = np.random.default_rng(0)
    rng.bit_generator.state = state
    return rng


def unpack_rng_state(words: np.ndarray) -> np.random.Generator:
    w = [int(x) for x in words]
    return rng_from_state({
        "bit_generator": "PCG64",
        "state": {"state": w[0] | (w[1] << 64), "inc": w[2] | (w[3] << 64)},
        "has_uint32": w[4], "uinteger": w[5]})


class StudyLedger:
    """Pytree-of-arrays state for ``n_studies`` concurrent studies.

    Everything array-shaped lives here; params *dicts* (needed to call the
    user's objective) stay on the owning optimizer views.  Trial slot index
    == trial id (ids are dense), so gathers are plain fancy indexing.
    Capacities grow by doubling from 16 — bank-wide, so every study in the
    bank always shares one bucket shape.
    """

    # leaf order is the pytree/checkpoint contract
    ARRAY_FIELDS = (
        "X", "y", "status", "obs_seq",
        "n_trials", "ask_count", "obs_count", "n_failed",
        "log_ls", "log_var", "log_noise", "have_fit", "n_fit",
        "y_mean", "y_std", "L", "Linv", "rng_state",
    )

    # Monotone observation stamp: bumped by every mutation that can change
    # the *observed* system (tells, value/order writes, hyper refits, study
    # resets, checkpoint loads) — but NOT by pending-only traffic
    # (ask/tell_failed), which is regathered fresh each ask.  The bank's
    # staged GP dispatch keys its device cache (prescaled observations,
    # Cholesky factors, standardized y, hypers) on this stamp, so the
    # no-new-observations steady state skips the Cholesky entirely.  A
    # class attribute (not an ``__init__`` field, not a pytree leaf, never
    # serialized) so unflattened/restored ledgers start valid at 0.
    obs_stamp = 0

    def __init__(self, n_studies: int, dim: int, capacity: int = 16,
                 gp_capacity: int = 16):
        if n_studies < 1:
            raise ValueError("n_studies must be >= 1")
        B, d = int(n_studies), int(dim)
        cap = _pow2(max(16, capacity))
        self.n_studies, self.dim = B, d
        # ---- trial ledger -------------------------------------------------
        self.X = np.zeros((B, cap, d), np.float32)   # encoded rows by id
        self.y = np.zeros((B, cap), np.float64)      # raw objective values
        self.status = np.zeros((B, cap), np.int8)
        self.obs_seq = np.full((B, cap), -1, np.int32)
        self.n_trials = np.zeros((B,), np.int64)     # == next trial id
        self.ask_count = np.zeros((B,), np.int64)
        self.obs_count = np.zeros((B,), np.int64)
        self.n_failed = np.zeros((B,), np.int64)
        # ---- GP hypers + fit schedule (cold rows carry the cold-fit init
        # values, so a bank fit can always warm-start from these arrays) ----
        self.log_ls = np.full((B, d), np.log(0.5), np.float32)
        self.log_var = np.zeros((B,), np.float32)
        self.log_noise = np.full((B,), np.log(1e-2), np.float32)
        self.have_fit = np.zeros((B,), np.int8)
        self.n_fit = np.zeros((B,), np.int64)
        self.y_mean = np.zeros((B,), np.float32)
        self.y_std = np.ones((B,), np.float32)
        # ---- last Cholesky factors from the bank propose program ----------
        gcap = _pow2(max(16, gp_capacity))
        eye = np.eye(gcap, dtype=np.float32)
        self.L = np.tile(eye, (B, 1, 1))
        self.Linv = np.tile(eye, (B, 1, 1))
        # ---- per-study RNG streams (synced from the views at save time) ---
        self.rng_state = np.zeros((B, 6), _U64)

    # ------------------------------------------------------------ capacity
    @property
    def capacity(self) -> int:
        return self.X.shape[1]

    @property
    def gp_capacity(self) -> int:
        return self.L.shape[1]

    def ensure_capacity(self, n: int) -> None:
        cap = self.capacity
        if n <= cap:
            return
        new = _pow2(n)
        B, d = self.n_studies, self.dim
        X = np.zeros((B, new, d), np.float32)
        X[:, :cap] = self.X
        y = np.zeros((B, new), np.float64)
        y[:, :cap] = self.y
        status = np.zeros((B, new), np.int8)
        status[:, :cap] = self.status
        obs_seq = np.full((B, new), -1, np.int32)
        obs_seq[:, :cap] = self.obs_seq
        self.X, self.y, self.status, self.obs_seq = X, y, status, obs_seq

    def ensure_gp_capacity(self, n: int) -> None:
        gcap = self.gp_capacity
        if n <= gcap:
            return
        new = _pow2(n)
        B = self.n_studies
        eye = np.eye(new, dtype=np.float32)
        L = np.tile(eye, (B, 1, 1))
        L[:, :gcap, :gcap] = self.L
        Linv = np.tile(eye, (B, 1, 1))
        Linv[:, :gcap, :gcap] = self.Linv
        self.L, self.Linv = L, Linv

    # ----------------------------------------------------------- per-study
    def reset_study(self, b: int) -> None:
        """Clear one study's row back to the cold state (load target)."""
        self.obs_stamp += 1
        self.X[b] = 0.0
        self.y[b] = 0.0
        self.status[b] = S_EMPTY
        self.obs_seq[b] = -1
        self.n_trials[b] = self.ask_count[b] = 0
        self.obs_count[b] = self.n_failed[b] = 0
        self.log_ls[b] = np.log(0.5)
        self.log_var[b] = 0.0
        self.log_noise[b] = np.log(1e-2)
        self.have_fit[b] = 0
        self.n_fit[b] = 0
        self.y_mean[b], self.y_std[b] = 0.0, 1.0
        g = self.gp_capacity
        self.L[b] = np.eye(g, dtype=np.float32)
        self.Linv[b] = np.eye(g, dtype=np.float32)
        self.rng_state[b] = 0

    def n_observed(self) -> np.ndarray:
        return (self.status == S_OBSERVED).sum(axis=1)

    def n_pending(self) -> np.ndarray:
        return (self.status == S_PENDING).sum(axis=1)

    def obs_ids(self, b: int) -> np.ndarray:
        """Observed trial ids of study ``b`` in completion (tell) order."""
        ids = np.nonzero(self.status[b] == S_OBSERVED)[0]
        return ids[np.argsort(self.obs_seq[b, ids], kind="stable")]

    def pending_ids(self, b: int) -> np.ndarray:
        return np.nonzero(self.status[b] == S_PENDING)[0]


def _ledger_flatten(led: StudyLedger):
    return (tuple(getattr(led, f) for f in StudyLedger.ARRAY_FIELDS),
            (led.n_studies, led.dim))


def _ledger_unflatten(aux, leaves) -> StudyLedger:
    led = object.__new__(StudyLedger)
    led.n_studies, led.dim = aux
    for f, v in zip(StudyLedger.ARRAY_FIELDS, leaves):
        setattr(led, f, v)
    return led


jax.tree_util.register_pytree_node(
    StudyLedger, _ledger_flatten, _ledger_unflatten)


class StudyBank:
    """N independent studies over one ``StudyLedger``; one device dispatch
    per ``ask_all``.

    Every study shares the parameter space and strategy type (a bank is a
    homogeneous fleet — heterogeneous fleets are just multiple banks) but
    owns its RNG stream, sign, counters and GP state, so per-study results
    are reproducible independent of its bankmates' *values* (bucket shapes
    are shared, proposals are not).
    """

    def __init__(self, param_space, n_studies: int, *,
                 optimizer: str = "bayesian", seed: int = 0,
                 sign: float = 1.0, domain_size: Optional[float] = None,
                 mc_samples: Optional[int] = None, fit_steps: int = 40,
                 use_pallas: bool = False, pallas_interpret: bool = True,
                 refit_every: int = 8,
                 strategy_kwargs: Optional[Dict[str, Any]] = None):
        from repro.core.optimizer import AskTellOptimizer
        from repro.core.spaces import ParamSpace
        self.space = (param_space if isinstance(param_space, ParamSpace)
                      else ParamSpace(param_space))
        self.optimizer = optimizer
        self.mc_samples = mc_samples
        self.fit_steps = fit_steps
        self.use_pallas = use_pallas
        self.pallas_interpret = pallas_interpret
        self.refit_every = refit_every
        self.strategy_kwargs = dict(strategy_kwargs or {})
        self.seed = seed
        self.ledger = StudyLedger(n_studies, self.space.dim)
        self._gp_cache = None   # obs_stamp-keyed device state (staged ask)
        # monotonic operation sequence for journaled (WAL) deployments: the
        # last op applied through ``apply_op``; snapshots carry it so crash
        # recovery can skip journal records the snapshot already contains
        self.op_seq = 0
        self.extra = None       # side-channel meta restored by ``load``
        # bank-wide candidate stream: one flat draw of B*n_mc candidates per
        # ask_all, independent of the per-study streams
        self._rng = np.random.default_rng(seed)
        self.studies: List[AskTellOptimizer] = [
            AskTellOptimizer(self.space, optimizer=optimizer,
                             seed=seed + 1 + i, sign=sign,
                             domain_size=domain_size, mc_samples=mc_samples,
                             fit_steps=fit_steps, use_pallas=use_pallas,
                             pallas_interpret=pallas_interpret,
                             refit_every=refit_every,
                             strategy_kwargs=strategy_kwargs,
                             ledger=self.ledger, study_index=i)
            for i in range(n_studies)]

    # -------------------------------------------------------------- basics
    @property
    def n_studies(self) -> int:
        return self.ledger.n_studies

    def study(self, i: int):
        return self.studies[i]

    def tell(self, study: int, trial_id: int, value: float):
        return self.studies[study].tell(trial_id, value)

    def tell_failed(self, study: int, trial_id: int):
        return self.studies[study].tell_failed(trial_id)

    # ------------------------------------------------------ journal replay
    def next_op_seq(self) -> int:
        """Sequence number the *next* journaled operation must carry."""
        return self.op_seq + 1

    def validate_op(self, op: Dict[str, Any]) -> None:
        """Reject a malformed op *before* it is journaled.  Pure check, no
        state mutated.  The WAL contract is journal-then-apply, so anything
        appended must be guaranteed to apply — a record that journals and
        then raises would poison every future replay of the log.  Raises
        ``ValueError``/``KeyError``/``TypeError`` on a bad op."""
        kind = op["op"]
        b = int(op["study"])
        if not 0 <= b < self.n_studies:
            raise ValueError(f"op targets study row {b}, bank holds "
                             f"{self.n_studies}")
        view = self.studies[b]
        if kind == "create":
            float(op.get("sign", 1.0))
        elif kind == "ask":
            if int(op["n"]) < 1:
                raise ValueError("ask(n) requires n >= 1")
        elif kind in ("tell", "tell_failed"):
            tid = int(op["trial_id"])
            if tid not in view._trials:
                raise KeyError(f"unknown trial id {tid!r} "
                               "(tell before ask?)")
            if kind == "tell":
                float(op["value"])
        elif kind == "observe":
            # encode raises KeyError on a param name missing from the
            # space and TypeError/ValueError on un-encodable values
            self.space.encode([dict(op["params"])])
            float(op["value"])
        elif kind == "trace":
            pass
        else:
            raise ValueError(f"unknown journal op kind {kind!r}")

    def apply_op(self, op: Dict[str, Any]):
        """Apply one journaled operation to the bank (the WAL replay entry
        point).  Ops are dicts ``{"seq", "op", "study", ...}``; ``seq``
        must extend the bank's monotonic op sequence by exactly one — a
        gap or reorder means the journal does not match this snapshot and
        replay would diverge, so it raises instead of guessing.

        Because every proposal is a pure function of the bank state and
        each study's RNG stream, re-applying the op sequence from any
        snapshot reconstructs bit-identical optimizer state: an ``ask``
        record replays to the *same* trial ids and configurations the
        original call served.  Tells replay through the idempotent
        ``tell_once`` path, so an at-least-once journal (duplicate tell
        records) cannot double-apply an observation.
        """
        seq = int(op["seq"])
        if seq <= self.op_seq:
            return None     # already contained in the snapshot: skip
        if seq != self.op_seq + 1:
            raise ValueError(
                f"journal op seq {seq} does not extend bank op_seq "
                f"{self.op_seq} (missing or reordered WAL records)")
        kind = op["op"]
        b = int(op["study"])
        if not 0 <= b < self.n_studies:
            raise ValueError(f"journal op targets study row {b}, bank "
                             f"holds {self.n_studies}")
        view = self.studies[b]
        # the seq is consumed even if the apply raises: a journaled record
        # must never be half-committed — op_seq advancing past it means the
        # next op gets a fresh seq (no duplicate-seq frames) and replay
        # re-raises at the same point with the same state, so recovery can
        # skip the record deterministically instead of wedging the service
        try:
            if kind == "create":
                view.sign = float(op.get("sign", 1.0))
                result = view
            elif kind == "ask":
                result = view.ask(int(op["n"]))
            elif kind == "tell":
                result = view.tell_once(int(op["trial_id"]),
                                        float(op["value"]))
            elif kind == "tell_failed":
                result = view.tell_failed_once(int(op["trial_id"]))
            elif kind == "observe":
                result = view.observe_params(dict(op["params"]),
                                             float(op["value"]))
            elif kind == "trace":
                view.snapshot_trace()
                result = None
            else:
                raise ValueError(f"unknown journal op kind {kind!r}")
        finally:
            self.op_seq = seq
        return result

    # ------------------------------------------------------------- ask_all
    def ask_all(self, n: int = 1) -> List[list]:
        """Propose ``n`` new trials for every study.

        Studies still in the random phase (< 2 observations, or a random
        bank) ask through their own view; every GP/TPE-phase study is
        gathered into one shape-bucketed device batch and served by a
        single vmap'd fused program.  Returns ``[trials_of_study_0, ...]``.
        """
        if n < 1:
            raise ValueError("ask_all(n) requires n >= 1")
        led = self.ledger
        B = led.n_studies
        if self.optimizer == "random":
            return [v.ask(n) for v in self.studies]
        n_obs = led.n_observed()
        device = n_obs >= 2
        out: List[Optional[list]] = [None] * B
        for b in np.nonzero(~device)[0]:
            out[b] = self.studies[int(b)].ask(n)
        if not device.any():
            return out
        picks = self._ask_device(n, n_obs)
        # bulk registration: one fancy-indexed ledger write per field for
        # every device-phase study (the per-view ``_register_asked`` loop
        # was the last O(B) Python/ledger hot spot in the steady state);
        # ids stay dense (slot == trial id), statuses/obs_seq identical to
        # the per-view path.
        from repro.core.optimizer import Trial
        dev = np.array(sorted(picks))
        tids0 = led.n_trials[dev].astype(np.int64)
        led.ensure_capacity(int((tids0 + n).max()))
        rows = dev[:, None]
        slot = tids0[:, None] + np.arange(n)[None, :]
        led.X[rows, slot] = np.stack([picks[int(b)][1] for b in dev])
        led.status[rows, slot] = S_PENDING
        led.obs_seq[rows, slot] = -1
        led.n_trials[dev] = tids0 + n
        led.ask_count[dev] += 1
        for i, b in enumerate(dev):
            b = int(b)
            v = self.studies[b]
            trials = []
            for j, p in enumerate(picks[b][0]):
                t = Trial(int(tids0[i]) + j, dict(p), _ledger=led,
                          _study=b)
                v._trials[t.id] = t
                trials.append(t)
            out[b] = trials
        return out

    def _ask_device(self, n: int, n_obs: np.ndarray):
        """One staged dispatch for the whole bank; returns
        ``{study: (configs, encoded_rows)}`` for every GP-phase study."""
        led, space = self.ledger, self.space
        B, d = led.n_studies, led.dim
        k_obs = n_obs.astype(np.int32)
        k_pend = led.n_pending().astype(np.int32)
        pend_cap = max(4, -(-int(k_pend.max()) // 4) * 4)
        na = _pow2(max(16, int(k_obs.max()) + pend_cap + n))
        n_mc = self.mc_samples or self.space.mc_samples(n)
        # one columnar draw for the whole bank (no per-candidate dicts)
        cols = space.sample_columns(B * n_mc, self._rng)
        Cflat = space.encode_columns(cols, B * n_mc)
        C = np.asarray(Cflat, np.float32).reshape(B, n_mc, d)
        if self.optimizer == "tpe":
            Xd, yraw, mask = self._gather_obs(k_obs, na)
            Pd = self._gather_pend(k_pend, pend_cap)
            idx = self._dispatch_tpe(Xd, yraw, mask, Pd, C, k_obs, k_pend,
                                     n, na)
        else:
            idx = self._dispatch_gp(C, k_obs, k_pend, n, na, pend_cap)
        idx = jax.device_get(idx)   # the one designed exit sync per ask
        dev = np.nonzero(n_obs >= 2)[0]
        flat = (dev[:, None] * n_mc + idx[dev]).astype(np.int64)  # (k, n)
        cfgs = self.space.configs_at(cols, flat.ravel())
        enc = Cflat[flat.ravel()].reshape(len(dev), -1, Cflat.shape[1])
        return {int(b): (cfgs[i * n:(i + 1) * n], enc[i])
                for i, b in enumerate(dev)}

    def _gather_obs(self, k_obs: np.ndarray, na: int):
        """Masked-rank observation gather at the bucket shape, vectorized
        over the bank: one stable argsort of the completion order (empty /
        pending / failed slots pushed past the horizon by a sentinel)
        replaces the per-study ``obs_ids`` fancy-indexing loop.  Returns
        ``(Xd (B, na, d), yraw signed (B, na), mask (B, na))``."""
        led = self.ledger
        B, d, cap = led.n_studies, led.dim, led.capacity
        m = min(cap, na)
        seq = np.where(led.status == S_OBSERVED, led.obs_seq,
                       np.iinfo(np.int32).max)
        order = np.argsort(seq, axis=1, kind="stable")[:, :m]
        rows = np.arange(B)[:, None]
        valid = np.arange(m)[None, :] < k_obs[:, None]
        sign = np.array([v.sign for v in self.studies])[:, None]
        Xd = np.zeros((B, na, d), np.float32)
        yraw = np.zeros((B, na), np.float32)     # signed, unstandardized
        mask = np.zeros((B, na), np.float32)
        Xd[:, :m] = np.where(valid[..., None], led.X[rows, order], 0.0)
        yraw[:, :m] = np.where(valid, sign * led.y[rows, order],
                               0.0).astype(np.float32)
        mask[:, :m] = valid
        return Xd, yraw, mask

    def _gather_pend(self, k_pend: np.ndarray, pend_cap: int) -> np.ndarray:
        """In-flight rows at the ``pend_cap`` shape (ascending trial id,
        like ``pending_ids``), vectorized over the bank.  Never cached —
        pending churn happens every ask/tell_failed."""
        led = self.ledger
        B, d, cap = led.n_studies, led.dim, led.capacity
        Pd = np.zeros((B, pend_cap, d), np.float32)
        if int(k_pend.max()):
            ids = np.where(led.status == S_PENDING,
                           np.arange(cap)[None, :], np.iinfo(np.int32).max)
            order = np.argsort(ids, axis=1, kind="stable")[:, :pend_cap]
            rows = np.arange(B)[:, None]
            valid = np.arange(pend_cap)[None, :] < k_pend[:, None]
            Pd[:] = np.where(valid[..., None], led.X[rows, order], 0.0)
        return Pd

    def _fit_if_due(self, Xd, yraw, mask, k_obs):
        """Count-based bank fit schedule: (re)fit hypers for every study
        whose observation count advanced ``refit_every`` past its last fit
        (or that never fit).  The fit program always runs over the full
        bank at the bucket shape — selective write-back keeps non-due
        studies' frozen hypers (and frozen y standardization) bit-stable.
        """
        led = self.ledger
        due = ((led.have_fit == 0) |
               (k_obs.astype(np.int64) - led.n_fit >= self.refit_every))
        due &= k_obs >= 2
        if not due.any():
            return
        from repro.core import gp as gp_lib
        lls, lv, ln, ym, ys = gp_lib.fit_hypers_bank(
            Xd, yraw, mask, led.log_ls, led.log_var, led.log_noise,
            steps=self.fit_steps)
        sel = np.nonzero(due)[0]
        # one explicit exit transfer for all five hyper arrays
        lls, lv, ln, ym, ys = jax.device_get((lls, lv, ln, ym, ys))
        led.log_ls[sel] = lls[sel]
        led.log_var[sel] = lv[sel]
        led.log_noise[sel] = ln[sel]
        led.y_mean[sel] = ym[sel]
        led.y_std[sel] = ys[sel]
        led.n_fit[sel] = k_obs[sel]
        led.have_fit[sel] = 1
        led.obs_stamp += 1    # new hypers/standardization: factors stale

    def _dispatch_gp(self, C, k_obs, k_pend, n, na, pend_cap):
        """The staged bank ask (see the stage comments in ``core.gp``).

        Stages whose inputs depend only on *observations* — the masked
        gather, frozen standardization, hypers, prescale, Cholesky factors
        — are cached on the ledger's ``obs_stamp`` + bucket shape, so the
        ask/tell_failed steady state pays only the candidate-dependent
        stages (prescale-C, distances, exp, pick) plus a pending absorb
        when something is actually in flight.
        """
        from repro.core import acquisition as acq_lib
        from repro.core import gp as gp_lib
        led = self.ledger
        signs = tuple(v.sign for v in self.studies)
        due = ((led.have_fit == 0) |
               (k_obs.astype(np.int64) - led.n_fit >= self.refit_every))
        due &= k_obs >= 2
        cache = self._gp_cache
        key = (led.obs_stamp, na, signs)
        clustering = self.optimizer == "clustering"
        if clustering or due.any() or cache is None or cache["key"] != key:
            Xd, yraw, mask = self._gather_obs(k_obs, na)
            self._fit_if_due(Xd, yraw, mask, k_obs)
            key = (led.obs_stamp, na, signs)
        dom = float(self.studies[0].domain_size)
        if clustering:
            # frozen standardization, exactly the single-study GP contract
            z = (yraw - led.y_mean[:, None]) / led.y_std[:, None]
            z = (z * mask).astype(np.float32)
            ls = np.exp(led.log_ls).astype(np.float32)
            var = np.exp(led.log_var).astype(np.float32)
            noise = (np.exp(led.log_noise) + 1e-5).astype(np.float32)
            Pd = self._gather_pend(k_pend, pend_cap)
            from repro.core.strategies import n_top_candidates
            top_frac = self.strategy_kwargs.get("top_frac", 0.2)
            n_top = n_top_candidates(C.shape[1], n, top_frac)
            # one vmap'd seeding dispatch for the whole bank (J101/J102:
            # a per-study PRNGKey loop is B device calls + B host reads)
            keys = jax.vmap(jax.random.PRNGKey)(
                jnp.asarray(led.ask_count[:led.n_studies], jnp.uint32))
            idx, L, Linv = acq_lib.fused_cluster_propose_bank(
                Xd, z, mask, Pd, k_pend.astype(np.float32), C, ls, var,
                noise, k_obs.astype(np.float32), np.float32(dom), keys,
                batch_size=n, n_top=n_top, pend_cap=pend_cap,
                use_pallas=False, interpret=self.pallas_interpret)
            led.ensure_gp_capacity(na)
            L_host, Linv_host = jax.device_get((L, Linv))
            led.L[:, :na, :na] = L_host
            led.Linv[:, :na, :na] = Linv_host
            return idx
        cache = self._gp_cache
        if cache is None or cache["key"] != key:
            # observation-dependent stages (rebuilt only when obs changed)
            z = (yraw - led.y_mean[:, None]) / led.y_std[:, None]
            z = (z * mask).astype(np.float32)
            ls = np.exp(led.log_ls).astype(np.float32)
            var = np.exp(led.log_var).astype(np.float32)
            noise = (np.exp(led.log_noise) + 1e-5).astype(np.float32)
            L, Linv = gp_lib.bank_factors(Xd, mask, ls, var, noise)
            Xs = gp_lib.bank_prescale_X(Xd, ls)
            cache = self._gp_cache = {
                "key": key, "Xs": Xs, "z": jnp.asarray(z),
                "mask": jnp.asarray(mask), "L": L, "Linv": Linv,
                "ls": jnp.asarray(ls), "var": jnp.asarray(var),
                "noise": jnp.asarray(noise)}
            led.ensure_gp_capacity(na)
            L_host, Linv_host = jax.device_get((L, Linv))
            led.L[:, :na, :na] = L_host
            led.Linv[:, :na, :na] = Linv_host
        # candidate-dependent stages (every ask)
        Cs = gp_lib.bank_prescale_C(C, cache["ls"])
        Xs, z, maskd = cache["Xs"], cache["z"], cache["mask"]
        L, Linv = cache["L"], cache["Linv"]
        if int(k_pend.max()):
            Pd = self._gather_pend(k_pend, pend_cap)
            Xs, z, maskd, L, Linv = gp_lib.bank_absorb(
                Xs, z, maskd, L, Linv, Pd, k_pend.astype(np.float32),
                k_obs.astype(np.float32), cache["ls"], cache["var"],
                cache["noise"], pend_cap=pend_cap)
        d2, s = gp_lib.bank_dist(Cs, Xs)
        e = gp_lib.bank_exp(s)
        return gp_lib.bank_pick(
            d2, s, e, Cs, z, maskd, L, Linv, cache["var"], cache["noise"],
            (k_obs + k_pend).astype(np.float32), np.float32(dom),
            batch_size=n, S=C.shape[1])

    def _dispatch_tpe(self, Xd, yraw, mask, Pd, C, k_obs, k_pend, n, na):
        from repro.core import tpe as tpe_lib
        from repro.kernels.tpe_kde.ops import pad_dims
        led = self.ledger
        B, d = led.n_studies, led.dim
        dp = pad_dims(d)
        # TPE layout: observed rows, then pending rows, then zeros
        Xt = np.zeros((B, na, dp), np.float32)
        yt = np.zeros((B, na), np.float32)
        for b in range(B):
            ko, kp = int(k_obs[b]), int(k_pend[b])
            Xt[b, :ko, :d] = Xd[b, :ko]
            yt[b, :ko] = yraw[b, :ko]
            if kp:
                Xt[b, ko:ko + kp, :d] = Pd[b, :kp]
        Sp = C.shape[1]
        Ct = np.zeros((B, Sp, dp), np.float32)
        Ct[:, :, :d] = C
        gamma = self.strategy_kwargs.get("gamma", 0.25)
        pending_penalty = self.strategy_kwargs.get("pending_penalty", False)
        kp_eff = (k_pend if pending_penalty
                  else np.zeros_like(k_pend))
        meta = np.stack([k_obs.astype(np.float32),
                         kp_eff.astype(np.float32),
                         np.full((B,), Sp, np.float32),
                         np.full((B,), gamma, np.float32)], axis=1)
        return tpe_lib.fused_tpe_propose_bank(
            Xt, yt, Ct, meta, batch_size=n, d_true=d,
            use_pallas=False, interpret=self.pallas_interpret)

    # ---------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, Any]:
        """JSON-able fleet snapshot: the bank candidate stream plus every
        study's v1 single-study snapshot (so one study's entry is exactly
        what its view's own ``state_dict`` returns)."""
        led = self.ledger
        return {
            "version": 1,
            "kind": "study_bank",
            "n_studies": self.n_studies,
            "rng_state": self._rng.bit_generator.state,
            "studies": [v.state_dict() for v in self.studies],
            # the bank fit schedule lives in the ledger, not the views'
            # strategy GPs — carried bank-level so the per-study entries
            # stay exactly the v1 single-study format
            "gp_bank": [{
                "log_ls": [float(x) for x in led.log_ls[b]],
                "log_var": float(led.log_var[b]),
                "log_noise": float(led.log_noise[b]),
                "have_fit": int(led.have_fit[b]),
                "n_fit": int(led.n_fit[b]),
                "y_mean": float(led.y_mean[b]),
                "y_std": float(led.y_std[b]),
            } for b in range(led.n_studies)],
        }

    def load_state_dict(self, sd: Dict[str, Any]) -> None:
        if sd.get("kind") != "study_bank":
            raise ValueError("not a study_bank state dict")
        if sd["n_studies"] != self.n_studies:
            raise ValueError(f"bank holds {self.n_studies} studies, "
                             f"snapshot has {sd['n_studies']}")
        self._rng = rng_from_state(sd["rng_state"])
        for v, s in zip(self.studies, sd["studies"]):
            v.load_state_dict(s)      # resets the ledger row first
        led = self.ledger
        for b, g in enumerate(sd.get("gp_bank", [])):
            led.log_ls[b] = np.asarray(g["log_ls"], np.float32)
            led.log_var[b] = g["log_var"]
            led.log_noise[b] = g["log_noise"]
            led.have_fit[b] = g["have_fit"]
            led.n_fit[b] = g["n_fit"]
            led.y_mean[b] = g["y_mean"]
            led.y_std[b] = g["y_std"]

    def save(self, path, iteration: int = 0, extra=None) -> None:
        """One-write fleet checkpoint: every ledger array (the pytree
        leaves) plus a JSON meta block (params dicts, best traces, RNG
        streams) in a single atomically-replaced ``.npz`` file.

        ``extra`` is an optional JSON-serializable side channel stored
        verbatim in the meta block — the durable service keeps its study
        name table and ask-dedup cache there so one snapshot write covers
        the whole recovery state.  ``load`` hands it back via
        ``self.extra``; when omitted, the bank's current ``self.extra``
        is persisted so callers that set the attribute directly still
        round-trip.
        """
        from repro.core.optimizer import _to_jsonable
        led = self.ledger
        for b, v in enumerate(self.studies):
            led.rng_state[b] = pack_rng_state(v._rng)
        leaves, _ = jax.tree_util.tree_flatten(led)
        arrays = {f"led_{name}": np.asarray(leaf) for name, leaf
                  in zip(StudyLedger.ARRAY_FIELDS, leaves)}
        meta = {
            "version": 1,
            "kind": "study_bank",
            "rng_kind": RNG_KIND,
            "iteration": iteration,
            "op_seq": self.op_seq,
            "extra": self.extra if extra is None else extra,
            "n_studies": self.n_studies,
            "dim": led.dim,
            "bank_rng_state": self._rng.bit_generator.state,
            "studies": [{
                "sign": v.sign,
                "best_trace": list(v._best_trace),
                "gp": (getattr(v._strat, "gp", None).export_state()
                       if getattr(v._strat, "gp", None) is not None
                       else v._gp_snapshot),
                "params": [_to_jsonable(v._trials[i].params)
                           for i in range(int(led.n_trials[b]))],
            } for b, v in enumerate(self.studies)],
        }
        p = Path(path)
        tmp = p.with_suffix(".tmp")
        with open(tmp, "wb") as fh:
            np.savez(fh, meta=np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8), **arrays)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, p)  # atomic: a crash never corrupts the checkpoint

    def load(self, path) -> int:
        """Restore a ``save`` checkpoint in place; returns the stored
        iteration.  Arrays are restored directly (no re-encode), params
        dicts and RNG streams come from the meta block."""
        from repro.core.optimizer import Trial
        with np.load(path) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            if meta.get("kind") != "study_bank":
                raise ValueError("not a study_bank checkpoint")
            # checkpoints written before the tag existed are all PCG64
            rng_kind = meta.get("rng_kind", RNG_KIND)
            if rng_kind != RNG_KIND:
                raise ValueError(
                    f"checkpoint packs {rng_kind!r} RNG streams but this "
                    f"build only decodes {RNG_KIND}; the 6-word rng_state "
                    "rows would unpack into a different generator's state")
            if meta["n_studies"] != self.n_studies:
                raise ValueError(
                    f"bank holds {self.n_studies} studies, checkpoint has "
                    f"{meta['n_studies']}")
            arrays = {name: z[f"led_{name}"]
                      for name in StudyLedger.ARRAY_FIELDS}
        led = self.ledger
        for name in StudyLedger.ARRAY_FIELDS:
            setattr(led, name, arrays[name])
        led.obs_stamp += 1   # wholesale array swap: device cache is stale
        self._rng = rng_from_state(meta["bank_rng_state"])
        for b, v in enumerate(self.studies):
            ms = meta["studies"][b]
            v.sign = ms["sign"]
            v._best_trace = list(ms["best_trace"])
            v._gp_snapshot = ms["gp"]
            v._strat = None
            v._rng = unpack_rng_state(led.rng_state[b])
            v._trials = {
                tid: Trial(tid, dict(params), _ledger=led, _study=b)
                for tid, params in enumerate(ms["params"])}
        self.op_seq = int(meta.get("op_seq", 0))
        self.extra = meta.get("extra")
        return meta["iteration"]
