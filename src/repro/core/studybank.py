"""StudyBank: optimizer state as a pytree of arrays (multi-tenant asks).

Mango frames HPO as a production service (paper §1/§2.4); Tune and
Auptimizer make the same point — a tuning platform hosts *many* concurrent
studies, not one notebook loop.  This module gives the engine that shape:

  * ``StudyLedger`` — a registered pytree of fixed-capacity numpy arrays
    holding every study's trial ledger (encoded X rows, raw y, status,
    completion order), counters, per-study RNG state, GP hyperparameter /
    fit-schedule state, and the last Cholesky factors ``L``/``L⁻¹``.
    ``AskTellOptimizer`` is a *view* into one row of a ledger (a bank of
    one by default), so the single-study API is unchanged while the state
    itself is array-shaped.
  * ``StudyBank`` — N studies over one ledger.  ``ask_all`` gathers the
    bank into shape-bucketed device buffers (power-of-2 trial capacity, so
    a growing study re-enters a cached compiled program instead of
    retracing) and serves every study through the ONE staged proposal
    pipeline: ``gp.bank_*`` stages feeding ``bank_pick`` (GP-BUCB),
    ``bank_cluster_pick`` (clustering) or ``tpe.fused_tpe_propose_bank``.
    Strategies are per-study data (a bank may mix GP, TPE and clustering
    studies — ``ask_all`` sub-batches the dispatch per strategy family
    within one columnar candidate draw).  Observation-dependent device
    state (gather, factors, standardization) is cached on the ledger's
    ``obs_stamp``, so ask/tell_failed churn never recomputes a Cholesky.
  * Bank-of-one: a standalone ``AskTellOptimizer.ask`` routes through
    ``ask_view`` on this same bucketed pipeline (``StudyBank._wrap_view``),
    so the single-study hot path compiles once per power-of-2 bucket and
    never retraces across observation growth.
  * One-write fleet checkpoints — ``save`` serializes the whole ledger
    pytree (plus a JSON meta block for params dicts / RNG streams) as a
    single ``.npz`` write; ``load`` restores every study mid-flight.

Bucketing contract: device buffers are padded to ``pow2(max(16, ...))``
rows with ``n_obs``/``n_pending`` carried as masked ranks, so within a
bucket the compiled program is reused ask after ask (the
``steady_state_retrace`` bench row asserts zero retraces across a
64→1024-observation growth sweep, compiles at bucket edges aside).
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

# trial-status codes (ledger ``status`` array; 0 = empty slot)
S_EMPTY, S_PENDING, S_OBSERVED, S_FAILED = 0, 1, 2, 3

_U64 = np.uint64
_MASK64 = (1 << 64) - 1


def _pow2(n: int) -> int:
    p = 16
    while p < n:
        p *= 2
    return p


# strategy name -> dispatch family for the bank pipeline.  "gp" and
# "cluster" share the staged obs-dependent stages (factors, prescale,
# standardization) and differ only in the pick head; "tpe" has its own
# buffer layout; "random"/"legacy" rows ask through their own view.
_FAMILY = {
    "bayesian": "gp",
    "hallucination": "gp",
    "clustering": "cluster",
    "tpe": "tpe",
    "random": "random",
    "hallucination_ref": "legacy",
}


def _y_standardization(v: np.ndarray):
    """Frozen-standardization scalars over a signed f32 history, with the
    exact op sequence of ``GaussianProcess.fit``: f32 numpy mean (exact
    f32 round-trip) and ``float(v.std()) + 1e-6`` (f64 add, rounded to f32
    at the consuming op).  Used by the bank fit schedule AND v1-checkpoint
    restore so a resumed run standardizes bit-identically."""
    v = np.asarray(v, np.float32)
    if not len(v):
        return np.float32(0.0), np.float32(1.0)
    return np.float32(v.mean()), np.float32(float(v.std()) + 1e-6)


# the one bit-generator the 6-word packed layout below encodes; checkpoints
# carry it as a meta tag so a future second generator type fails loudly at
# load instead of silently unpacking garbage words into a PCG64
RNG_KIND = "PCG64"


def pack_rng_state(rng: np.random.Generator) -> np.ndarray:
    """Pack a PCG64 Generator's full state into 6 uint64 words
    (state lo/hi, inc lo/hi, has_uint32, uinteger) for array storage."""
    st = rng.bit_generator.state
    kind = st.get("bit_generator")
    if kind != RNG_KIND:
        raise ValueError(
            f"pack_rng_state only encodes {RNG_KIND} streams; this "
            f"generator is {kind!r} — its state does not fit the 6-word "
            "packed layout (add a new rng_kind to the checkpoint format)")
    s, inc = st["state"]["state"], st["state"]["inc"]
    return np.array([s & _MASK64, (s >> 64) & _MASK64,
                     inc & _MASK64, (inc >> 64) & _MASK64,
                     st["has_uint32"], st["uinteger"]], dtype=_U64)


def rng_from_state(state: Dict[str, Any]) -> np.random.Generator:
    """Generator rebuilt from a serialized bit-generator state.  The
    explicit seed is a placeholder (the state overwrite replaces it) so
    restoring a stream never draws OS entropy."""
    rng = np.random.default_rng(0)
    rng.bit_generator.state = state
    return rng


def unpack_rng_state(words: np.ndarray) -> np.random.Generator:
    w = [int(x) for x in words]
    return rng_from_state({
        "bit_generator": "PCG64",
        "state": {"state": w[0] | (w[1] << 64), "inc": w[2] | (w[3] << 64)},
        "has_uint32": w[4], "uinteger": w[5]})


class StudyLedger:
    """Pytree-of-arrays state for ``n_studies`` concurrent studies.

    Everything array-shaped lives here; params *dicts* (needed to call the
    user's objective) stay on the owning optimizer views.  Trial slot index
    == trial id (ids are dense), so gathers are plain fancy indexing.
    Capacities grow by doubling from 16 — bank-wide, so every study in the
    bank always shares one bucket shape.
    """

    # leaf order is the pytree/checkpoint contract
    ARRAY_FIELDS = (
        "X", "y", "status", "obs_seq",
        "n_trials", "ask_count", "obs_count", "n_failed",
        "log_ls", "log_var", "log_noise", "have_fit", "n_fit",
        "y_mean", "y_std", "L", "Linv", "rng_state",
    )

    # Monotone observation stamp: bumped by every mutation that can change
    # the *observed* system (tells, value/order writes, hyper refits, study
    # resets, checkpoint loads) — but NOT by pending-only traffic
    # (ask/tell_failed), which is regathered fresh each ask.  The bank's
    # staged GP dispatch keys its device cache (prescaled observations,
    # Cholesky factors, standardized y, hypers) on this stamp, so the
    # no-new-observations steady state skips the Cholesky entirely.  A
    # class attribute (not an ``__init__`` field, not a pytree leaf, never
    # serialized) so unflattened/restored ledgers start valid at 0.
    obs_stamp = 0

    def __init__(self, n_studies: int, dim: int, capacity: int = 16,
                 gp_capacity: int = 16):
        if n_studies < 1:
            raise ValueError("n_studies must be >= 1")
        B, d = int(n_studies), int(dim)
        cap = _pow2(max(16, capacity))
        self.n_studies, self.dim = B, d
        # ---- trial ledger -------------------------------------------------
        self.X = np.zeros((B, cap, d), np.float32)   # encoded rows by id
        self.y = np.zeros((B, cap), np.float64)      # raw objective values
        self.status = np.zeros((B, cap), np.int8)
        self.obs_seq = np.full((B, cap), -1, np.int32)
        self.n_trials = np.zeros((B,), np.int64)     # == next trial id
        self.ask_count = np.zeros((B,), np.int64)
        self.obs_count = np.zeros((B,), np.int64)
        self.n_failed = np.zeros((B,), np.int64)
        # ---- GP hypers + fit schedule (cold rows carry the cold-fit init
        # values, so a bank fit can always warm-start from these arrays) ----
        self.log_ls = np.full((B, d), np.log(0.5), np.float32)
        self.log_var = np.zeros((B,), np.float32)
        self.log_noise = np.full((B,), np.log(1e-2), np.float32)
        self.have_fit = np.zeros((B,), np.int8)
        self.n_fit = np.zeros((B,), np.int64)
        self.y_mean = np.zeros((B,), np.float32)
        self.y_std = np.ones((B,), np.float32)
        # ---- last Cholesky factors from the bank propose program ----------
        gcap = _pow2(max(16, gp_capacity))
        eye = np.eye(gcap, dtype=np.float32)
        self.L = np.tile(eye, (B, 1, 1))
        self.Linv = np.tile(eye, (B, 1, 1))
        # ---- per-study RNG streams (synced from the views at save time) ---
        self.rng_state = np.zeros((B, 6), _U64)

    # ------------------------------------------------------------ capacity
    @property
    def capacity(self) -> int:
        return self.X.shape[1]

    @property
    def gp_capacity(self) -> int:
        return self.L.shape[1]

    def ensure_capacity(self, n: int) -> None:
        cap = self.capacity
        if n <= cap:
            return
        new = _pow2(n)
        B, d = self.n_studies, self.dim
        X = np.zeros((B, new, d), np.float32)
        X[:, :cap] = self.X
        y = np.zeros((B, new), np.float64)
        y[:, :cap] = self.y
        status = np.zeros((B, new), np.int8)
        status[:, :cap] = self.status
        obs_seq = np.full((B, new), -1, np.int32)
        obs_seq[:, :cap] = self.obs_seq
        self.X, self.y, self.status, self.obs_seq = X, y, status, obs_seq

    def ensure_gp_capacity(self, n: int) -> None:
        gcap = self.gp_capacity
        if n <= gcap:
            return
        new = _pow2(n)
        B = self.n_studies
        eye = np.eye(new, dtype=np.float32)
        L = np.tile(eye, (B, 1, 1))
        L[:, :gcap, :gcap] = self.L
        Linv = np.tile(eye, (B, 1, 1))
        Linv[:, :gcap, :gcap] = self.Linv
        self.L, self.Linv = L, Linv

    # ----------------------------------------------------------- per-study
    def reset_study(self, b: int) -> None:
        """Clear one study's row back to the cold state (load target)."""
        self.obs_stamp += 1
        self.X[b] = 0.0
        self.y[b] = 0.0
        self.status[b] = S_EMPTY
        self.obs_seq[b] = -1
        self.n_trials[b] = self.ask_count[b] = 0
        self.obs_count[b] = self.n_failed[b] = 0
        self.log_ls[b] = np.log(0.5)
        self.log_var[b] = 0.0
        self.log_noise[b] = np.log(1e-2)
        self.have_fit[b] = 0
        self.n_fit[b] = 0
        self.y_mean[b], self.y_std[b] = 0.0, 1.0
        g = self.gp_capacity
        self.L[b] = np.eye(g, dtype=np.float32)
        self.Linv[b] = np.eye(g, dtype=np.float32)
        self.rng_state[b] = 0

    def n_observed(self) -> np.ndarray:
        return (self.status == S_OBSERVED).sum(axis=1)

    def n_pending(self) -> np.ndarray:
        return (self.status == S_PENDING).sum(axis=1)

    def obs_ids(self, b: int) -> np.ndarray:
        """Observed trial ids of study ``b`` in completion (tell) order."""
        ids = np.nonzero(self.status[b] == S_OBSERVED)[0]
        return ids[np.argsort(self.obs_seq[b, ids], kind="stable")]

    def pending_ids(self, b: int) -> np.ndarray:
        return np.nonzero(self.status[b] == S_PENDING)[0]


def _ledger_flatten(led: StudyLedger):
    return (tuple(getattr(led, f) for f in StudyLedger.ARRAY_FIELDS),
            (led.n_studies, led.dim))


def _ledger_unflatten(aux, leaves) -> StudyLedger:
    led = object.__new__(StudyLedger)
    led.n_studies, led.dim = aux
    for f, v in zip(StudyLedger.ARRAY_FIELDS, leaves):
        setattr(led, f, v)
    return led


jax.tree_util.register_pytree_node(
    StudyLedger, _ledger_flatten, _ledger_unflatten)


class StudyBank:
    """N independent studies over one ``StudyLedger``; one sub-batched
    device dispatch per strategy family per ``ask_all``.

    Every study shares the parameter space but owns its strategy, RNG
    stream, sign, counters and GP state, so per-study results are
    reproducible independent of its bankmates' *values* (bucket shapes are
    shared, proposals are not).  ``optimizer`` may be one strategy name
    (homogeneous fleet) or a per-study list — a mixed GP + TPE +
    clustering fleet is served from one process with one columnar
    candidate draw, the dispatch sub-batched per family.
    """

    def __init__(self, param_space, n_studies: int, *,
                 optimizer=None, seed: int = 0,
                 sign: float = 1.0, domain_size: Optional[float] = None,
                 mc_samples: Optional[int] = None, fit_steps: int = 40,
                 use_pallas: bool = False, pallas_interpret: bool = True,
                 refit_every: int = 8,
                 strategy_kwargs: Optional[Dict[str, Any]] = None):
        from repro.core.optimizer import AskTellOptimizer
        from repro.core.spaces import ParamSpace
        self.space = (param_space if isinstance(param_space, ParamSpace)
                      else ParamSpace(param_space))
        if optimizer is None:
            optimizer = "bayesian"
        names = (list(optimizer)
                 if isinstance(optimizer, (list, tuple))
                 else [optimizer] * int(n_studies))
        if len(names) != int(n_studies):
            raise ValueError(
                f"optimizer list has {len(names)} entries for "
                f"{n_studies} studies")
        self.strategy_names: List[str] = names
        self.optimizer = (names[0] if len(set(names)) == 1 else "mixed")
        self.mc_samples = mc_samples
        self.fit_steps = fit_steps
        self.use_pallas = use_pallas
        self.pallas_interpret = pallas_interpret
        self.refit_every = refit_every
        self.strategy_kwargs = dict(strategy_kwargs or {})
        self.seed = seed
        self.ledger = StudyLedger(n_studies, self.space.dim)
        self._gp_cache = None   # obs_stamp-keyed device state (staged ask)
        # monotonic operation sequence for journaled (WAL) deployments: the
        # last op applied through ``apply_op``; snapshots carry it so crash
        # recovery can skip journal records the snapshot already contains
        self.op_seq = 0
        self.extra = None       # side-channel meta restored by ``load``
        # bank-wide candidate stream: one flat draw of B*n_mc candidates per
        # ask_all, independent of the per-study streams
        self._rng = np.random.default_rng(seed)
        self.studies: List[AskTellOptimizer] = [
            AskTellOptimizer(self.space, optimizer=names[i],
                             seed=seed + 1 + i, sign=sign,
                             domain_size=domain_size, mc_samples=mc_samples,
                             fit_steps=fit_steps, use_pallas=use_pallas,
                             pallas_interpret=pallas_interpret,
                             refit_every=refit_every,
                             strategy_kwargs=strategy_kwargs,
                             ledger=self.ledger, study_index=i)
            for i in range(n_studies)]
        for v in self.studies:
            v._bank = self
        self._members = {i: v for i, v in enumerate(self.studies)}
        self._rebuild_groups()

    @classmethod
    def _wrap_view(cls, view) -> "StudyBank":
        """Bank-of-one engine over an existing view's ledger (what a
        standalone ``AskTellOptimizer.ask`` routes through).  Shares the
        view's ledger row and settings; the bank candidate stream is unused
        (``ask_view`` draws through the view's own RNG, preserving the
        pre-refactor per-study stream bit-for-bit)."""
        bank = object.__new__(cls)
        bank.space = view.space
        bank.optimizer = view.optimizer
        bank.mc_samples = view.mc_samples
        bank.fit_steps = view.fit_steps
        bank.use_pallas = view.use_pallas
        bank.pallas_interpret = view.pallas_interpret
        bank.refit_every = view.refit_every
        bank.strategy_kwargs = dict(view.strategy_kwargs)
        bank.seed = None
        bank.ledger = view._led
        bank._gp_cache = None
        bank.op_seq = 0
        bank.extra = None
        bank._rng = None
        bank.studies = [view]
        bank.strategy_names = [view.optimizer]
        bank._members = {view._b: view}
        bank._rebuild_groups()
        return bank

    def _rebuild_groups(self) -> None:
        """Recompute the strategy-family routing tables (and drop the
        device cache, whose row layout depends on them)."""
        fams = {b: _FAMILY.get(v.optimizer, "legacy")
                for b, v in self._members.items()}
        self._fams = fams
        gpr = sorted(b for b, f in fams.items() if f in ("gp", "cluster"))
        self._gp_fam_rows = np.array(gpr, np.int64)
        self._gp_pos = {int(r): i for i, r in enumerate(gpr)}
        bankable = np.zeros(self.ledger.n_studies, bool)
        for b, f in fams.items():
            bankable[b] = f in ("gp", "cluster", "tpe")
        self._bankable = bankable
        self._gp_cache = None

    def set_strategy(self, b: int, name: str) -> None:
        """Switch study ``b``'s strategy (per-study data, not bank code
        paths).  Counters/observations are untouched; the next ask routes
        through the new family's pick head."""
        from repro.core.strategies import STRATEGIES
        if name not in STRATEGIES:
            raise ValueError(f"unknown optimizer {name!r}; "
                             f"choose from {sorted(STRATEGIES)}")
        b = int(b)
        v = self.studies[b]
        if v.optimizer != name:
            v.optimizer = name
            v._strat = None
            self.strategy_names[b] = name
        self.optimizer = (self.strategy_names[0]
                          if len(set(self.strategy_names)) == 1
                          else "mixed")
        self._rebuild_groups()

    # -------------------------------------------------------------- basics
    @property
    def n_studies(self) -> int:
        return self.ledger.n_studies

    def study(self, i: int):
        return self.studies[i]

    def tell(self, study: int, trial_id: int, value: float):
        return self.studies[study].tell(trial_id, value)

    def tell_failed(self, study: int, trial_id: int):
        return self.studies[study].tell_failed(trial_id)

    # ------------------------------------------------------ journal replay
    def next_op_seq(self) -> int:
        """Sequence number the *next* journaled operation must carry."""
        return self.op_seq + 1

    def validate_op(self, op: Dict[str, Any]) -> None:
        """Reject a malformed op *before* it is journaled.  Pure check, no
        state mutated.  The WAL contract is journal-then-apply, so anything
        appended must be guaranteed to apply — a record that journals and
        then raises would poison every future replay of the log.  Raises
        ``ValueError``/``KeyError``/``TypeError`` on a bad op."""
        kind = op["op"]
        b = int(op["study"])
        if not 0 <= b < self.n_studies:
            raise ValueError(f"op targets study row {b}, bank holds "
                             f"{self.n_studies}")
        view = self.studies[b]
        if kind == "create":
            float(op.get("sign", 1.0))
            nm = op.get("optimizer")
            if nm is not None:
                from repro.core.strategies import STRATEGIES
                if nm not in STRATEGIES:
                    raise ValueError(
                        f"unknown optimizer {nm!r}; choose from "
                        f"{sorted(STRATEGIES)}")
        elif kind == "ask":
            if int(op["n"]) < 1:
                raise ValueError("ask(n) requires n >= 1")
        elif kind in ("tell", "tell_failed"):
            tid = int(op["trial_id"])
            if tid not in view._trials:
                raise KeyError(f"unknown trial id {tid!r} "
                               "(tell before ask?)")
            if kind == "tell":
                float(op["value"])
        elif kind == "observe":
            # encode raises KeyError on a param name missing from the
            # space and TypeError/ValueError on un-encodable values
            self.space.encode([dict(op["params"])])
            float(op["value"])
        elif kind == "trace":
            pass
        else:
            raise ValueError(f"unknown journal op kind {kind!r}")

    def apply_op(self, op: Dict[str, Any]):
        """Apply one journaled operation to the bank (the WAL replay entry
        point).  Ops are dicts ``{"seq", "op", "study", ...}``; ``seq``
        must extend the bank's monotonic op sequence by exactly one — a
        gap or reorder means the journal does not match this snapshot and
        replay would diverge, so it raises instead of guessing.

        Because every proposal is a pure function of the bank state and
        each study's RNG stream, re-applying the op sequence from any
        snapshot reconstructs bit-identical optimizer state: an ``ask``
        record replays to the *same* trial ids and configurations the
        original call served.  Tells replay through the idempotent
        ``tell_once`` path, so an at-least-once journal (duplicate tell
        records) cannot double-apply an observation.
        """
        seq = int(op["seq"])
        if seq <= self.op_seq:
            return None     # already contained in the snapshot: skip
        if seq != self.op_seq + 1:
            raise ValueError(
                f"journal op seq {seq} does not extend bank op_seq "
                f"{self.op_seq} (missing or reordered WAL records)")
        kind = op["op"]
        b = int(op["study"])
        if not 0 <= b < self.n_studies:
            raise ValueError(f"journal op targets study row {b}, bank "
                             f"holds {self.n_studies}")
        view = self.studies[b]
        # the seq is consumed even if the apply raises: a journaled record
        # must never be half-committed — op_seq advancing past it means the
        # next op gets a fresh seq (no duplicate-seq frames) and replay
        # re-raises at the same point with the same state, so recovery can
        # skip the record deterministically instead of wedging the service
        try:
            if kind == "create":
                view.sign = float(op.get("sign", 1.0))
                nm = op.get("optimizer")
                if nm is not None:
                    self.set_strategy(b, nm)
                result = view
            elif kind == "ask":
                result = view.ask(int(op["n"]))
            elif kind == "tell":
                result = view.tell_once(int(op["trial_id"]),
                                        float(op["value"]))
            elif kind == "tell_failed":
                result = view.tell_failed_once(int(op["trial_id"]))
            elif kind == "observe":
                result = view.observe_params(dict(op["params"]),
                                             float(op["value"]))
            elif kind == "trace":
                view.snapshot_trace()
                result = None
            else:
                raise ValueError(f"unknown journal op kind {kind!r}")
        finally:
            self.op_seq = seq
        return result

    # ------------------------------------------------------------- ask_all
    def ask_all(self, n: int = 1) -> List[list]:
        """Propose ``n`` new trials for every study.

        Studies still in the random phase (< 2 observations) or whose
        strategy has no bank family (random / reference strategies) ask
        through their own view; every other study is gathered into one
        shape-bucketed device batch and served by the staged pipeline,
        sub-batched per strategy family.  Returns
        ``[trials_of_study_0, ...]``.
        """
        if n < 1:
            raise ValueError("ask_all(n) requires n >= 1")
        led = self.ledger
        B = led.n_studies
        n_obs = led.n_observed()
        device = (n_obs >= 2) & self._bankable
        out: List[Optional[list]] = [None] * B
        for b in np.nonzero(~device)[0]:
            out[b] = self.studies[int(b)].ask(n)
        if not device.any():
            return out
        picks = self._ask_device(n, n_obs, device)
        # bulk registration: one fancy-indexed ledger write per field for
        # every device-phase study (the per-view ``_register_asked`` loop
        # was the last O(B) Python/ledger hot spot in the steady state);
        # ids stay dense (slot == trial id), statuses/obs_seq identical to
        # the per-view path.
        from repro.core.optimizer import Trial
        dev = np.array(sorted(picks))
        tids0 = led.n_trials[dev].astype(np.int64)
        led.ensure_capacity(int((tids0 + n).max()))
        rows = dev[:, None]
        slot = tids0[:, None] + np.arange(n)[None, :]
        led.X[rows, slot] = np.stack([picks[int(b)][1] for b in dev])
        led.status[rows, slot] = S_PENDING
        led.obs_seq[rows, slot] = -1
        led.n_trials[dev] = tids0 + n
        led.ask_count[dev] += 1
        for i, b in enumerate(dev):
            b = int(b)
            v = self.studies[b]
            trials = []
            for j, p in enumerate(picks[b][0]):
                t = Trial(int(tids0[i]) + j, dict(p), _ledger=led,
                          _study=b)
                v._trials[t.id] = t
                trials.append(t)
            out[b] = trials
        return out

    def _ask_device(self, n: int, n_obs: np.ndarray, device: np.ndarray):
        """Per-family sub-batched dispatch over ONE columnar candidate
        draw; returns ``{study: (configs, encoded_rows)}`` for every
        device-phase study.  GP and clustering rows share the obs-stage
        cache (gather, standardization, factors); each family pays one
        pick program and one exit sync."""
        led, space = self.ledger, self.space
        B, d = led.n_studies, led.dim
        k_obs = n_obs.astype(np.int32)
        k_pend = led.n_pending().astype(np.int32)
        pend_cap = max(4, -(-int(k_pend.max()) // 4) * 4)
        na = _pow2(max(16, int(k_obs.max()) + pend_cap + n))
        n_mc = self.mc_samples or self.space.mc_samples(n)
        # one columnar draw for the whole bank (no per-candidate dicts)
        cols = space.sample_columns(B * n_mc, self._rng)
        Cflat = np.asarray(space.encode_columns(cols, B * n_mc), np.float32)
        C = Cflat.reshape(B, n_mc, d)
        dev = np.nonzero(device)[0]
        groups: Dict[str, np.ndarray] = {}
        for f in ("gp", "cluster", "tpe"):
            rows = np.array([int(b) for b in dev
                             if self._fams[int(b)] == f], np.int64)
            if len(rows):
                groups[f] = rows
        cache = None
        if "gp" in groups or "cluster" in groups:
            cache = self._obs_stage(k_obs, na)
        picks: Dict[int, tuple] = {}
        for f, rows in groups.items():
            if f == "tpe":
                Xd, yraw, _ = self._gather_obs(k_obs[rows], na, rows)
                Pd = self._gather_pend(k_pend[rows], pend_cap, rows)
                idx = self._dispatch_tpe(Xd, yraw, Pd, C[rows],
                                         k_obs[rows], k_pend[rows], n, na)
            else:
                idx = self._pick_gp(cache, rows, f, C[rows], k_obs[rows],
                                    k_pend[rows], n, na, pend_cap)
            idx = np.asarray(jax.device_get(idx))   # one exit sync / family
            flat = (rows[:, None] * n_mc + idx).astype(np.int64)  # (R, n)
            cfgs = space.configs_at(cols, flat.ravel())
            enc = Cflat[flat.ravel()].reshape(len(rows), -1, Cflat.shape[1])
            for i, b in enumerate(rows):
                picks[int(b)] = (cfgs[i * n:(i + 1) * n], enc[i])
        return picks

    def ask_view(self, view, n: int, cols, n_mc: int):
        """Bank-of-one ask: one view's proposal served by the bucketed
        pipeline.  Candidates arrive columnar, drawn by the *view's* own
        RNG stream (so the pre-refactor per-study stream is preserved
        bit-for-bit); bucket shapes stay bank-wide so a view inside a
        fleet re-enters the same compiled programs as ``ask_all``.
        Returns ``(configs, encoded_rows)`` for ``n`` picks."""
        led, space = self.ledger, self.space
        b = view._b
        n = min(n, n_mc)
        fam = self._fams[b]
        k_obs = led.n_observed().astype(np.int32)
        k_pend = led.n_pending().astype(np.int32)
        pend_cap = max(4, -(-int(k_pend.max()) // 4) * 4)
        na = _pow2(max(16, int(k_obs.max()) + pend_cap + n))
        Cflat = np.asarray(space.encode_columns(cols, n_mc), np.float32)
        C = Cflat.reshape(1, n_mc, led.dim)
        rows = np.array([b], np.int64)
        if fam == "tpe":
            Xd, yraw, _ = self._gather_obs(k_obs[rows], na, rows)
            Pd = self._gather_pend(k_pend[rows], pend_cap, rows)
            idx = self._dispatch_tpe(Xd, yraw, Pd, C, k_obs[rows],
                                     k_pend[rows], n, na)
        else:
            cache = self._obs_stage(k_obs, na)
            idx = self._pick_gp(cache, rows, fam, C, k_obs[rows],
                                k_pend[rows], n, na, pend_cap)
        idx = np.asarray(jax.device_get(idx))[0].astype(np.int64)
        return space.configs_at(cols, idx), Cflat[idx]

    def _gather_obs(self, k_obs: np.ndarray, na: int, rows: np.ndarray):
        """Masked-rank observation gather at the bucket shape for the
        ``rows`` sub-batch: one stable argsort of the completion order
        (empty / pending / failed slots pushed past the horizon by a
        sentinel) replaces the per-study ``obs_ids`` fancy-indexing loop.
        Returns ``(Xd (R, na, d), yraw signed (R, na), mask (R, na))``."""
        led = self.ledger
        d, cap = led.dim, led.capacity
        R = len(rows)
        m = min(cap, na)
        status = led.status[rows]
        seq = np.where(status == S_OBSERVED, led.obs_seq[rows],
                       np.iinfo(np.int32).max)
        order = np.argsort(seq, axis=1, kind="stable")[:, :m]
        rr = np.arange(R)[:, None]
        valid = np.arange(m)[None, :] < k_obs[:, None]
        sign = np.array([self._members[int(b)].sign
                         for b in rows])[:, None]
        Xsub, ysub = led.X[rows], led.y[rows]
        Xd = np.zeros((R, na, d), np.float32)
        yraw = np.zeros((R, na), np.float32)     # signed, unstandardized
        mask = np.zeros((R, na), np.float32)
        Xd[:, :m] = np.where(valid[..., None], Xsub[rr, order], 0.0)
        yraw[:, :m] = np.where(valid, sign * ysub[rr, order],
                               0.0).astype(np.float32)
        mask[:, :m] = valid
        return Xd, yraw, mask

    def _gather_pend(self, k_pend: np.ndarray, pend_cap: int,
                     rows: np.ndarray) -> np.ndarray:
        """In-flight rows at the ``pend_cap`` shape (ascending trial id,
        like ``pending_ids``) for the ``rows`` sub-batch.  Never cached —
        pending churn happens every ask/tell_failed."""
        led = self.ledger
        d, cap = led.dim, led.capacity
        R = len(rows)
        Pd = np.zeros((R, pend_cap, d), np.float32)
        if int(k_pend.max()):
            status = led.status[rows]
            ids = np.where(status == S_PENDING,
                           np.arange(cap)[None, :], np.iinfo(np.int32).max)
            order = np.argsort(ids, axis=1, kind="stable")[:, :pend_cap]
            rr = np.arange(R)[:, None]
            valid = np.arange(pend_cap)[None, :] < k_pend[:, None]
            Pd[:] = np.where(valid[..., None], led.X[rows][rr, order], 0.0)
        return Pd

    def _fit_if_due(self, Xd, yraw, mask, ko, rows) -> bool:
        """Count-based fit schedule over the gp-family sub-batch: (re)fit
        hypers for every study whose observation count advanced
        ``refit_every`` past its last fit (or that never fit).  The fit
        program runs over the whole sub-batch at the bucket shape —
        selective write-back keeps non-due studies' frozen hypers (and
        frozen y standardization) bit-stable.  Standardization scalars are
        computed on the host with the exact single-study op sequence
        (``_y_standardization``), so a study served by the bank
        standardizes bit-identically to the pre-refactor engine.
        Returns True when anything refit (obs stamp was bumped)."""
        led = self.ledger
        ko64 = ko.astype(np.int64)
        due = ((led.have_fit[rows] == 0) |
               (ko64 - led.n_fit[rows] >= self.refit_every))
        # frozen-standardization sanity (the ``GaussianProcess.observe``
        # guard): a degenerate fit (y_std ~ 1e-6 from constant initial
        # observations) would blow incoming values up to ~1e6 standardized
        # and wreck the acquisition surface for up to refit_every asks —
        # re-tune immediately instead.  Checked over everything observed
        # since the last fit so replay reaches the same decision.
        for i, r in enumerate(rows):
            if due[i] or not led.have_fit[r]:
                continue
            nf, k = int(led.n_fit[r]), int(ko64[i])
            if k > nf:
                zt = (np.abs(yraw[i, nf:k] - led.y_mean[r])
                      / led.y_std[r])
                if zt.size and float(zt.max()) > 1e3:
                    due[i] = True
        due &= ko64 >= 2
        if not due.any():
            return False
        from repro.core import gp as gp_lib
        ym = led.y_mean[rows].copy()
        ys = led.y_std[rows].copy()
        sel = np.nonzero(due)[0]
        for i in sel:
            ym[i], ys[i] = _y_standardization(yraw[i, :int(ko64[i])])
        lls, lv, ln = gp_lib.fit_hypers_bank(
            Xd, yraw, mask, led.log_ls[rows], led.log_var[rows],
            led.log_noise[rows], ym, ys, steps=self.fit_steps)
        # one explicit exit transfer for the three hyper arrays
        lls, lv, ln = jax.device_get((lls, lv, ln))
        g = np.asarray(rows)[sel]
        led.log_ls[g] = lls[sel]
        led.log_var[g] = lv[sel]
        led.log_noise[g] = ln[sel]
        led.y_mean[g] = ym[sel]
        led.y_std[g] = ys[sel]
        led.n_fit[g] = ko64[sel]
        led.have_fit[g] = 1
        led.obs_stamp += 1    # new hypers/standardization: factors stale
        return True

    def _obs_stage(self, k_obs: np.ndarray, na: int):
        """Observation-dependent stages for every gp-family row (GP and
        clustering share them): masked gather, fit schedule, frozen
        standardization, prescale, Cholesky factors + condition estimate.
        Cached on the ledger's ``obs_stamp`` + bucket shape, so the
        ask/tell_failed steady state pays only the candidate-dependent
        pick stages."""
        led = self.ledger
        gpr = self._gp_fam_rows
        ko = k_obs[gpr]
        signs = tuple(self._members[int(b)].sign for b in gpr)
        key = (led.obs_stamp, na, signs)
        cache = self._gp_cache
        if cache is not None and cache["key"] == key:
            return cache
        from repro.core import gp as gp_lib
        Xd, yraw, mask = self._gather_obs(ko, na, gpr)
        if self._fit_if_due(Xd, yraw, mask, ko, gpr):
            key = (led.obs_stamp, na, signs)
        # frozen standardization, exactly the single-study GP contract
        z = (yraw - led.y_mean[gpr][:, None]) / led.y_std[gpr][:, None]
        z = (z * mask).astype(np.float32)
        ls = np.exp(led.log_ls[gpr]).astype(np.float32)
        var = np.exp(led.log_var[gpr]).astype(np.float32)
        noise = (np.exp(led.log_noise[gpr]) + 1e-5).astype(np.float32)
        L, Linv, cond = gp_lib.bank_factors(Xd, mask, ls, var, noise)
        Xs = gp_lib.bank_prescale_X(Xd, ls)
        led.ensure_gp_capacity(na)
        L_host, Linv_host, cond_host = jax.device_get((L, Linv, cond))
        led.L[gpr, :na, :na] = L_host
        led.Linv[gpr, :na, :na] = Linv_host
        cache = self._gp_cache = {
            "key": key, "Xs": Xs, "z": jnp.asarray(z),
            "mask": jnp.asarray(mask), "L": L, "Linv": Linv,
            "ls": jnp.asarray(ls), "var": jnp.asarray(var),
            "noise": jnp.asarray(noise),
            "cond": np.asarray(cond_host, np.float64)}
        self._warn_if_ill_conditioned(cache["cond"], gpr)
        return cache

    def _warn_if_ill_conditioned(self, cond: np.ndarray,
                                 gpr: np.ndarray) -> None:
        import warnings
        from repro.core import scoring
        if getattr(self, "_cond_warned", False):
            return
        bad = np.nonzero(cond > scoring.COND_PROXY_WARN)[0]
        if len(bad):
            self._cond_warned = True
            b = int(gpr[bad[0]])
            warnings.warn(
                f"study {b}: GP kernel condition estimate "
                f"{cond[bad[0]]:.2e} exceeds {scoring.COND_PROXY_WARN:.0e};"
                " posterior scores may be unreliable (consider more noise"
                " or fewer near-duplicate observations)", RuntimeWarning)

    def _pick_gp(self, cache, rows, fam, C, ko, kp, n, na, pend_cap):
        """Candidate-dependent stages for one family sub-batch, sliced
        out of the shared obs-stage cache: prescale-C, pending absorb,
        distances, exp, and the family's pick head (GP-BUCB downdate loop
        or clustered-batch top-k/k-means/argmax)."""
        from repro.core import gp as gp_lib
        led = self.ledger
        pos = np.array([self._gp_pos[int(r)] for r in rows])
        full = (len(pos) == len(self._gp_fam_rows)
                and np.array_equal(pos, np.arange(len(pos))))
        take = (lambda a: a) if full else (lambda a: a[pos])
        ls, var, noise = take(cache["ls"]), take(cache["var"]), \
            take(cache["noise"])
        Xs, z, maskd = take(cache["Xs"]), take(cache["z"]), \
            take(cache["mask"])
        L, Linv = take(cache["L"]), take(cache["Linv"])
        Cs = gp_lib.bank_prescale_C(C, ls)
        if int(kp.max()):
            Pd = self._gather_pend(kp, pend_cap, rows)
            Xs, z, maskd, L, Linv = gp_lib.bank_absorb(
                Xs, z, maskd, L, Linv, Pd, kp.astype(np.float32),
                ko.astype(np.float32), ls, var, noise, pend_cap=pend_cap)
        d2, s = gp_lib.bank_dist(Cs, Xs)
        e = gp_lib.bank_exp(s)
        n_eff = (ko + kp).astype(np.float32)
        dom = np.float32(self._members[int(rows[0])].domain_size)
        if fam == "cluster":
            from repro.core.strategies import n_top_candidates
            top_frac = self.strategy_kwargs.get("top_frac", 0.2)
            n_top = n_top_candidates(C.shape[1], n, top_frac)
            # one vmap'd seeding dispatch for the sub-batch (J101/J102:
            # a per-study PRNGKey loop is R device calls + R host reads)
            keys = jax.vmap(jax.random.PRNGKey)(
                jnp.asarray(led.ask_count[rows], jnp.uint32))
            return gp_lib.bank_cluster_pick(
                d2, s, e, jnp.asarray(C), z, maskd, Linv, var, noise,
                n_eff, dom, keys, batch_size=n, n_top=n_top, S=C.shape[1])
        return gp_lib.bank_pick(
            d2, s, e, Cs, z, maskd, L, Linv, var, noise, n_eff,
            dom, batch_size=n, S=C.shape[1])

    def _dispatch_tpe(self, Xd, yraw, Pd, C, k_obs, k_pend, n, na):
        from repro.core import tpe as tpe_lib
        from repro.kernels.tpe_kde.ops import pad_dims
        d = self.ledger.dim
        R = Xd.shape[0]
        dp = pad_dims(d)
        # TPE layout: observed rows, then pending rows, then zeros
        Xt = np.zeros((R, na, dp), np.float32)
        yt = np.zeros((R, na), np.float32)
        for i in range(R):
            ko, kp = int(k_obs[i]), int(k_pend[i])
            Xt[i, :ko, :d] = Xd[i, :ko]
            yt[i, :ko] = yraw[i, :ko]
            if kp:
                Xt[i, ko:ko + kp, :d] = Pd[i, :kp]
        Sp = C.shape[1]
        Ct = np.zeros((R, Sp, dp), np.float32)
        Ct[:, :, :d] = C
        gamma = self.strategy_kwargs.get("gamma", 0.25)
        pending_penalty = self.strategy_kwargs.get("pending_penalty", False)
        kp_eff = (k_pend if pending_penalty
                  else np.zeros_like(k_pend))
        meta = np.stack([k_obs.astype(np.float32),
                         kp_eff.astype(np.float32),
                         np.full((R,), Sp, np.float32),
                         np.full((R,), gamma, np.float32)], axis=1)
        return tpe_lib.fused_tpe_propose_bank(
            Xt, yt, Ct, meta, batch_size=n, d_true=d,
            use_pallas=False, interpret=self.pallas_interpret)

    # ---------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, Any]:
        """JSON-able fleet snapshot: the bank candidate stream plus every
        study's v1 single-study snapshot (so one study's entry is exactly
        what its view's own ``state_dict`` returns)."""
        led = self.ledger
        return {
            "version": 1,
            "kind": "study_bank",
            "n_studies": self.n_studies,
            "rng_state": self._rng.bit_generator.state,
            "strategies": list(self.strategy_names),
            "studies": [v.state_dict() for v in self.studies],
            # the bank fit schedule lives in the ledger, not the views'
            # strategy GPs — carried bank-level so the per-study entries
            # stay exactly the v1 single-study format
            "gp_bank": [{
                "log_ls": [float(x) for x in led.log_ls[b]],
                "log_var": float(led.log_var[b]),
                "log_noise": float(led.log_noise[b]),
                "have_fit": int(led.have_fit[b]),
                "n_fit": int(led.n_fit[b]),
                "y_mean": float(led.y_mean[b]),
                "y_std": float(led.y_std[b]),
            } for b in range(led.n_studies)],
        }

    def load_state_dict(self, sd: Dict[str, Any]) -> None:
        if sd.get("kind") != "study_bank":
            raise ValueError("not a study_bank state dict")
        if sd["n_studies"] != self.n_studies:
            raise ValueError(f"bank holds {self.n_studies} studies, "
                             f"snapshot has {sd['n_studies']}")
        self._rng = rng_from_state(sd["rng_state"])
        # restore per-study strategies before the view loads (pre-mixed
        # snapshots carry no "strategies" key: names stay as constructed)
        for b, nm in enumerate(sd.get("strategies", [])):
            self.set_strategy(b, nm)
        for v, s in zip(self.studies, sd["studies"]):
            v.load_state_dict(s)      # resets the ledger row first
        led = self.ledger
        for b, g in enumerate(sd.get("gp_bank", [])):
            led.log_ls[b] = np.asarray(g["log_ls"], np.float32)
            led.log_var[b] = g["log_var"]
            led.log_noise[b] = g["log_noise"]
            led.have_fit[b] = g["have_fit"]
            led.n_fit[b] = g["n_fit"]
            led.y_mean[b] = g["y_mean"]
            led.y_std[b] = g["y_std"]

    def save(self, path, iteration: int = 0, extra=None) -> None:
        """One-write fleet checkpoint: every ledger array (the pytree
        leaves) plus a JSON meta block (params dicts, best traces, RNG
        streams) in a single atomically-replaced ``.npz`` file.

        ``extra`` is an optional JSON-serializable side channel stored
        verbatim in the meta block — the durable service keeps its study
        name table and ask-dedup cache there so one snapshot write covers
        the whole recovery state.  ``load`` hands it back via
        ``self.extra``; when omitted, the bank's current ``self.extra``
        is persisted so callers that set the attribute directly still
        round-trip.
        """
        from repro.core.optimizer import _to_jsonable
        led = self.ledger
        for b, v in enumerate(self.studies):
            led.rng_state[b] = pack_rng_state(v._rng)
        leaves, _ = jax.tree_util.tree_flatten(led)
        arrays = {f"led_{name}": np.asarray(leaf) for name, leaf
                  in zip(StudyLedger.ARRAY_FIELDS, leaves)}
        meta = {
            # v2: per-study "strategy" column (mixed banks); v1 checkpoints
            # (no strategy key) load unchanged — names stay as constructed
            "version": 2,
            "kind": "study_bank",
            "rng_kind": RNG_KIND,
            "iteration": iteration,
            "op_seq": self.op_seq,
            "extra": self.extra if extra is None else extra,
            "n_studies": self.n_studies,
            "dim": led.dim,
            "bank_rng_state": self._rng.bit_generator.state,
            "studies": [{
                "sign": v.sign,
                "strategy": self.strategy_names[b],
                "best_trace": list(v._best_trace),
                "gp": v._gp_export(),
                "params": [_to_jsonable(v._trials[i].params)
                           for i in range(int(led.n_trials[b]))],
            } for b, v in enumerate(self.studies)],
        }
        p = Path(path)
        tmp = p.with_suffix(".tmp")
        with open(tmp, "wb") as fh:
            np.savez(fh, meta=np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8), **arrays)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, p)  # atomic: a crash never corrupts the checkpoint

    def load(self, path) -> int:
        """Restore a ``save`` checkpoint in place; returns the stored
        iteration.  Arrays are restored directly (no re-encode), params
        dicts and RNG streams come from the meta block."""
        from repro.core.optimizer import Trial
        with np.load(path) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            if meta.get("kind") != "study_bank":
                raise ValueError("not a study_bank checkpoint")
            # checkpoints written before the tag existed are all PCG64
            rng_kind = meta.get("rng_kind", RNG_KIND)
            if rng_kind != RNG_KIND:
                raise ValueError(
                    f"checkpoint packs {rng_kind!r} RNG streams but this "
                    f"build only decodes {RNG_KIND}; the 6-word rng_state "
                    "rows would unpack into a different generator's state")
            if meta["n_studies"] != self.n_studies:
                raise ValueError(
                    f"bank holds {self.n_studies} studies, checkpoint has "
                    f"{meta['n_studies']}")
            arrays = {name: z[f"led_{name}"]
                      for name in StudyLedger.ARRAY_FIELDS}
        led = self.ledger
        for name in StudyLedger.ARRAY_FIELDS:
            setattr(led, name, arrays[name])
        led.obs_stamp += 1   # wholesale array swap: device cache is stale
        self._rng = rng_from_state(meta["bank_rng_state"])
        for b, v in enumerate(self.studies):
            ms = meta["studies"][b]
            nm = ms.get("strategy")
            if nm is not None:     # v2 meta; v1 keeps constructed names
                self.set_strategy(b, nm)
            v.sign = ms["sign"]
            v._best_trace = list(ms["best_trace"])
            v._gp_snapshot = ms["gp"]
            v._strat = None
            v._rng = unpack_rng_state(led.rng_state[b])
            v._trials = {
                tid: Trial(tid, dict(params), _ledger=led, _study=b)
                for tid, params in enumerate(ms["params"])}
        self.op_seq = int(meta.get("op_seq", 0))
        self.extra = meta.get("extra")
        return meta["iteration"]
