from repro.core.spaces import (ParamSpace, loguniform, Int, LogInt, Choice,
                               CHOICE_KEY)
from repro.core.optimizer import AskTellOptimizer, Trial
from repro.core.studybank import StudyBank, StudyLedger
from repro.core.tuner import Tuner, TunerResults
from repro.core.async_tuner import AsyncTuner

__all__ = ["ParamSpace", "loguniform", "Int", "LogInt", "Choice",
           "CHOICE_KEY", "AskTellOptimizer", "Trial",
           "StudyBank", "StudyLedger", "Tuner", "TunerResults",
           "AsyncTuner"]
from repro.core import tpe as _tpe  # registers optimizer="tpe"
