from repro.core.spaces import ParamSpace, loguniform
from repro.core.tuner import Tuner, TunerResults

__all__ = ["ParamSpace", "loguniform", "Tuner", "TunerResults"]
from repro.core import tpe as _tpe  # registers optimizer="tpe"
