"""JAX Gaussian-process surrogate for batched bandit search.

Design points (vs. the sklearn GP the original Mango wraps):
  * Matern-5/2 ARD kernel, hyperparameters fit by a short jit'd Adam run on
    the log marginal likelihood (the paper uses sklearn defaults; MLE fitting
    is a recorded beyond-paper improvement).
  * fixed-size padded buffers (power-of-two) so the jit cache stays small
    across tuner iterations,
  * O(n^2) rank-1 Cholesky *hallucination* updates for GP-BUCB batch
    selection (Desautels et al. 2014): the posterior mean stays fixed within
    a batch while the variance contracts — the paper's first parallel
    strategy.  The original refits the GP per batch slot (O(n^3) each).
  * ``fused_propose``: the whole GP-BUCB batch loop (posterior -> adaptive-
    beta UCB -> argmax -> rank-1 hallucination) as one jit'd ``lax.fori_loop``
    with zero host transfers inside the loop; only the final pick indices
    leave the device.
  * incremental observation appends (``GaussianProcess.observe``): real
    completions extend the Cholesky in O(n^2) instead of refitting in
    O(fit_steps * n^3); hyperparameters are re-tuned (full refit) only every
    ``refit_every`` new observations.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring
from repro.core.scoring import (JITTER, adaptive_beta_dev,  # noqa: F401
                                jitter as _jitter, linv_from_chol,
                                schur_floor as _schur_floor)


# --------------------------------------------------------------------------- #
# Kernel
# --------------------------------------------------------------------------- #
def matern52(x1: jax.Array, x2: jax.Array, ls: jax.Array,
             var: jax.Array) -> jax.Array:
    """x1 (n, d), x2 (m, d), ls (d,) ARD lengthscales -> (n, m)."""
    z1 = x1 / ls
    z2 = x2 / ls
    d2 = (jnp.sum(z1 * z1, -1)[:, None] + jnp.sum(z2 * z2, -1)[None, :]
          - 2.0 * z1 @ z2.T)
    r = jnp.sqrt(jnp.maximum(d2, 1e-12))
    s = jnp.sqrt(5.0) * r
    return var * (1.0 + s + (5.0 / 3.0) * d2) * jnp.exp(-s)


def _masked_kernel(X: jax.Array, mask: jax.Array, ls, var, noise):
    K = matern52(X, X, ls, var)
    m2 = mask[:, None] * mask[None, :]
    K = K * m2
    diag = jnp.where(mask > 0, var + noise + _jitter(var), 1.0)
    return K.at[jnp.diag_indices(X.shape[0])].set(diag)


# --------------------------------------------------------------------------- #
# Marginal-likelihood fit (jit, static buffer)
# --------------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("steps",))
def fit_hypers(X: jax.Array, y: jax.Array, mask: jax.Array, steps: int = 40,
               init: Optional[dict] = None,
               ) -> Tuple[jax.Array, jax.Array, jax.Array, dict]:
    """Returns (lengthscales (d,), signal var, noise, raw log-params) by Adam
    on -log ML.  ``init`` warm-starts Adam from a previous fit's log-params
    (fresh moments), so refit boundaries pay a short polish run instead of
    re-converging from the default initialization."""
    d = X.shape[1]
    n_eff = jnp.maximum(mask.sum(), 1.0)

    def nll(params):
        ls = jnp.exp(params["log_ls"])
        var = jnp.exp(params["log_var"])
        noise = jnp.exp(params["log_noise"]) + 1e-5
        K = _masked_kernel(X, mask, ls, var, noise)
        L = jnp.linalg.cholesky(K)
        alpha = jax.scipy.linalg.cho_solve((L, True), y * mask)
        ll = (-0.5 * jnp.sum((y * mask) * alpha)
              - jnp.sum(jnp.log(jnp.diagonal(L)) * mask)
              - 0.5 * n_eff * jnp.log(2 * jnp.pi))
        return -ll / n_eff

    if init is None:
        params = {"log_ls": jnp.zeros((d,)) + jnp.log(0.5),
                  "log_var": jnp.zeros(()),
                  "log_noise": jnp.log(jnp.asarray(1e-2))}
    else:
        params = {k: jnp.asarray(v, jnp.float32) for k, v in init.items()}
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    lr, b1, b2 = 0.08, 0.9, 0.999

    def step(carry, i):
        params, m, v = carry
        g = jax.grad(nll)(params)
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        t = i.astype(jnp.float32) + 1
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * (mm / (1 - b1 ** t))
            / (jnp.sqrt(vv / (1 - b2 ** t)) + 1e-8), params, m, v)
        params["log_ls"] = jnp.clip(params["log_ls"], jnp.log(0.01),
                                    jnp.log(10.0))
        return (params, m, v), None

    (params, _, _), _ = jax.lax.scan(step, (params, m, v),
                                     jnp.arange(steps))
    return (jnp.exp(params["log_ls"]), jnp.exp(params["log_var"]),
            jnp.exp(params["log_noise"]) + 1e-5, params)


# --------------------------------------------------------------------------- #
# Posterior with incremental (hallucination) Cholesky extension
# --------------------------------------------------------------------------- #
@jax.jit
def cholesky_masked(X, mask, ls, var, noise) -> jax.Array:
    return jnp.linalg.cholesky(_masked_kernel(X, mask, ls, var, noise))


@jax.jit
def posterior(X: jax.Array, y: jax.Array, mask: jax.Array, L: jax.Array,
              Xs: jax.Array, ls, var, noise
              ) -> Tuple[jax.Array, jax.Array]:
    """mu/sigma^2 at Xs (m, d) given padded train (n, d) and its Cholesky."""
    Ks = matern52(X, Xs, ls, var) * mask[:, None]        # (n, m)
    alpha = jax.scipy.linalg.cho_solve((L, True), y * mask)
    mu = Ks.T @ alpha
    V = jax.scipy.linalg.solve_triangular(L, Ks, lower=True)  # (n, m)
    var_s = jnp.maximum(var + noise - jnp.sum(V * V, axis=0), 1e-10)
    return mu, var_s


@jax.jit
def chol_append(L: jax.Array, X: jax.Array, mask: jax.Array, idx: jax.Array,
                x_new: jax.Array, ls, var, noise
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-1 extension: write x_new into padded row ``idx`` and extend L.

    Returns (L', X', mask').  O(n^2) instead of a full O(n^3) refit.
    """
    n = X.shape[0]
    X = X.at[idx].set(x_new)
    k_vec = (matern52(X, x_new[None, :], ls, var)[:, 0] * mask)  # (n,)
    l_vec = jax.scipy.linalg.solve_triangular(L, k_vec, lower=True)
    l_vec = jnp.where(jnp.arange(n) < idx, l_vec, 0.0)
    l_nn = jnp.sqrt(jnp.maximum(var + noise + _jitter(var)
                                - jnp.sum(l_vec * l_vec),
                                _schur_floor(var, noise)))
    row = l_vec.at[idx].set(l_nn)
    L = L.at[idx, :].set(row)
    mask = mask.at[idx].set(1.0)
    return L, X, mask


@jax.jit
def kinv_from_chol(L: jax.Array) -> jax.Array:
    """K^{-1} from its Cholesky (identity rows/cols at padded slots).

    Legacy: the live scoring core tracks ``Linv = L^{-1}`` instead
    (``scoring.linv_from_chol``); this survives as the float32-Schur
    baseline for the ``kinv_f32/f64`` benchmark rows and kernel tests.
    """
    return jax.scipy.linalg.cho_solve(
        (L, True), jnp.eye(L.shape[0], dtype=L.dtype))


@jax.jit
def chol_factor_append(L: jax.Array, Linv: jax.Array, X: jax.Array,
                       mask: jax.Array, idx: jax.Array, x_new: jax.Array,
                       ls, var, noise
                       ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array]:
    """``chol_append`` + the rank-1 extension of Linv in one program.

    The track_factor append path: shares the Matern column and the forward
    solve between the L row and the Linv row through the hardened
    ``scoring.factor_append`` (float64 Schur accumulation when x64 is
    enabled, one iterative-refinement step otherwise).
    """
    X = X.at[idx].set(x_new)
    k_vec = matern52(X, x_new[None, :], ls, var)[:, 0] * mask   # (n,)
    L, Linv, _, _ = scoring.factor_append(L, Linv, idx, k_vec, var, noise)
    mask = mask.at[idx].set(1.0)
    return L, Linv, X, mask


def _append_core_uv(L: jax.Array, Kinv: jax.Array, idx: jax.Array,
                    k_vec: jax.Array, var, noise
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Legacy float32 K^{-1} Schur append (L row + block-inverse extension).

    This is the PR-3 path whose conditioning loses picks on near-noiseless
    objectives: the full-matrix rewrite ``Kinv += uuᵀ/schur`` compounds
    float32 error every slot, and downstream scoring pays the cancelling
    ``k(K⁻¹k)`` quadratic form.  Kept (not wired into any strategy) as the
    baseline the ``kinv_f32_schur_*`` benchmark rows measure the hardened
    ``scoring.factor_append`` against.
    """
    n = L.shape[0]
    l_vec = jax.scipy.linalg.solve_triangular(L, k_vec, lower=True)
    u = jax.scipy.linalg.solve_triangular(L, l_vec, trans=1, lower=True)
    schur = jnp.maximum(var + noise + _jitter(var) - k_vec @ u,
                        _schur_floor(var, noise))
    Kinv = _schur_extend(Kinv, u, schur, idx)
    l_vec = jnp.where(jnp.arange(n) < idx, l_vec, 0.0)
    l_nn = jnp.sqrt(jnp.maximum(var + noise + _jitter(var)
                                - jnp.sum(l_vec * l_vec),
                                _schur_floor(var, noise)))
    L = L.at[idx, :].set(l_vec.at[idx].set(l_nn))
    return L, Kinv, u, schur


def _schur_extend(Kinv: jax.Array, u: jax.Array, schur: jax.Array,
                  idx: jax.Array) -> jax.Array:
    """Write the block-inverse extension into row/col ``idx`` of Kinv."""
    Kinv = Kinv + jnp.outer(u, u) / schur
    Kinv = Kinv.at[idx, :].set(-u / schur)
    Kinv = Kinv.at[:, idx].set(-u / schur)
    return Kinv.at[idx, idx].set(1.0 / schur)


# --------------------------------------------------------------------------- #
# Fused device-resident GP-BUCB batch proposal
# --------------------------------------------------------------------------- #
def _fused_pick(X: jax.Array, y: jax.Array, mask: jax.Array, L: jax.Array,
                C: jax.Array, ls, var, noise, n_obs: jax.Array,
                domain_size: jax.Array, batch_size: int) -> jax.Array:
    """GP-BUCB batch selection as one device program (the tentpole hot path).

    One heavy posterior pass (O(n^2 S): cross-covariance + triangular solve)
    runs *once* per batch; a ``lax.fori_loop`` over batch slots then fuses
    adaptive-beta UCB -> argmax -> rank-1 Cholesky hallucination, extending
    the candidate solve ``V = L^{-1} Ks`` by exactly the one new row forward
    substitution would produce — O(n S) per slot instead of the reference's
    per-slot O(n^2 S) recompute.  Nothing crosses the host boundary until
    the final ``(batch_size,)`` pick indices are read out.

    Numerically equivalent to ``HallucinationStrategy``'s Python loop (the
    reference implementation it is tested against): row ``slot`` is the only
    row of V' a from-scratch solve would change, the hallucinated mean
    recomputation is identical, and the standardized UCB surface differs
    from the de-standardized one by a positive affine map — so the argmax,
    and therefore the picks, are identical.
    """
    S = C.shape[0]
    Ks0 = matern52(X, C, ls, var) * mask[:, None]                 # (n, S)
    V0 = jax.scipy.linalg.solve_triangular(L, Ks0, lower=True)
    sig2_0 = jnp.maximum(var + noise - jnp.sum(V0 * V0, axis=0), 1e-10)
    alpha0 = jax.scipy.linalg.cho_solve((L, True), y * mask)
    mu0 = Ks0.T @ alpha0                                          # (S,)

    def pick(b, mu, sig2, avail, picks):
        beta = adaptive_beta_dev(n_obs + b, domain_size)
        acq = mu + jnp.sqrt(beta) * jnp.sqrt(sig2)
        acq = jnp.where(avail, acq, -jnp.inf)
        idx = jnp.argmax(acq).astype(jnp.int32)
        return idx, picks.at[b].set(idx), avail.at[idx].set(False)

    def body(b, carry):
        X, y, mask, L, Ks, V, mu, sig2, avail, picks = carry
        idx, picks, avail = pick(b, mu, sig2, avail, picks)
        slot = (n_obs + b).astype(jnp.int32)
        L, X, mask = chol_append(L, X, mask, slot, C[idx], ls, var, noise)
        # extend the posterior: new cross-covariance row + the one new row
        # of V' = L'^{-1} Ks' (rows < slot are unchanged by construction)
        k_row = matern52(C[idx][None, :], C, ls, var)[0]          # (S,)
        Ks = Ks.at[slot].set(k_row)
        l_row = L[slot]                                           # (n,)
        v_new = (k_row - l_row @ V) / l_row[slot]
        V = V.at[slot].set(v_new)
        sig2 = jnp.maximum(sig2 - v_new * v_new, 1e-10)
        # hallucinate at the posterior mean, then refresh mu the way the
        # reference does (alpha from the extended system)
        y = y.at[slot].set(mu[idx])
        alpha = jax.scipy.linalg.cho_solve((L, True), y * mask)
        mu = Ks.T @ alpha
        return X, y, mask, L, Ks, V, mu, sig2, avail, picks

    # the final slot needs only its pick — the hallucination update after it
    # is unobservable, so loop batch_size-1 times and pick once more outside
    carry = (X.astype(jnp.float32), y.astype(jnp.float32),
             mask.astype(jnp.float32), L, Ks0, V0, mu0, sig2_0,
             jnp.ones((S,), bool), jnp.zeros((batch_size,), jnp.int32))
    carry = jax.lax.fori_loop(0, batch_size - 1, body, carry)
    _, _, _, _, _, _, mu, sig2, avail, picks = carry
    _, picks, _ = pick(jnp.int32(batch_size - 1), mu, sig2, avail, picks)
    return picks


@functools.partial(jax.jit, static_argnames=("batch_size",))
def fused_propose(X: jax.Array, y: jax.Array, mask: jax.Array, L: jax.Array,
                  C: jax.Array, ls, var, noise, n_obs: jax.Array,
                  domain_size: jax.Array, batch_size: int) -> jax.Array:
    """One jit'd device program for the whole GP-BUCB batch (no pending)."""
    return _fused_pick(X, y, mask, L, C, ls, var, noise, n_obs,
                       domain_size, batch_size)


@functools.partial(jax.jit, static_argnames=("batch_size", "pend_cap"))
def fused_propose_pending(X: jax.Array, y: jax.Array, mask: jax.Array,
                          L: jax.Array, P: jax.Array, n_pending: jax.Array,
                          C: jax.Array, ls, var, noise, n_obs: jax.Array,
                          domain_size: jax.Array, batch_size: int,
                          pend_cap: int) -> jax.Array:
    """``fused_propose`` with in-flight trials hallucinated *inside* the
    program (the async replacement-pick hot path).

    A leading ``fori_loop`` over the (padded, ``pend_cap``) pending buffer
    absorbs each in-flight configuration exactly the way the host-side
    ``GaussianProcess.hallucinate`` does — posterior mean at the pending
    point from the current extended system, rank-1 Cholesky append, phantom
    y at the mean — then the standard pick loop runs with the observation
    counter advanced by ``n_pending`` (reproducing the batch-index term of
    the adaptive-beta schedule).  One device dispatch total, vs. the seed's
    one O(n^2) program *per pending trial* per replacement pick.
    """
    def absorb(j, carry):
        def do(c):
            X, y, mask, L = c
            x_new = P[j]
            k_vec = matern52(X, x_new[None, :], ls, var)[:, 0] * mask
            alpha = jax.scipy.linalg.cho_solve((L, True), y * mask)
            mu = k_vec @ alpha
            slot = (n_obs + j).astype(jnp.int32)
            L2, X2, mask2 = chol_append(L, X, mask, slot, x_new,
                                        ls, var, noise)
            return X2, y.at[slot].set(mu), mask2, L2
        return jax.lax.cond(j < n_pending, do, lambda c: c, carry)

    carry = (X.astype(jnp.float32), y.astype(jnp.float32),
             mask.astype(jnp.float32), L)
    X, y, mask, L = jax.lax.fori_loop(0, pend_cap, absorb, carry)
    return _fused_pick(X, y, mask, L, C, ls, var, noise,
                       n_obs + n_pending, domain_size, batch_size)


@functools.partial(jax.jit, static_argnames=("batch_size", "block_s",
                                             "interpret", "use_pallas"))
def fused_propose_pallas(X: jax.Array, y: jax.Array, mask: jax.Array,
                         L: jax.Array, Linv: jax.Array, C: jax.Array,
                         ls, var, noise, n_obs: jax.Array,
                         domain_size: jax.Array, batch_size: int,
                         block_s: int = 256, interpret: bool = True,
                         use_pallas: bool = True) -> jax.Array:
    """``fused_propose`` on the shared conditioning-hardened scoring core.

    Scoring runs through ``scoring.posterior_scores`` — the
    ``kernels/gp_acquisition`` Pallas kernels when ``use_pallas`` (fused
    Matern + posterior epilogue on the MXU/VPU) or their jnp oracle twin
    otherwise (the "K⁻¹-jit" parity path) — which consumes the triangular
    inverse factor Linv and evaluates variance as a monotone sum of
    squares.  Hallucination extends (L, Linv) via the hardened
    ``scoring.factor_append``; the same (u, schur) pair drives the rank-1
    variance downdate, so per-slot rescoring is O(n S), not O(n^2 S).
    """
    S = C.shape[0]
    Xs, Cs = scoring.prescale(X, C, ls, block_s)
    return scoring.pick_downdate_loop(
        Cs, Xs, S, y.astype(jnp.float32), mask.astype(jnp.float32), L,
        Linv, var, noise, n_obs, domain_size, batch_size,
        use_pallas=use_pallas, block_s=block_s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("batch_size", "pend_cap",
                                             "block_s", "interpret",
                                             "use_pallas"))
def fused_propose_pallas_pending(X: jax.Array, y: jax.Array,
                                 mask: jax.Array, L: jax.Array,
                                 Linv: jax.Array, P: jax.Array,
                                 n_pending: jax.Array, C: jax.Array,
                                 ls, var, noise, n_obs: jax.Array,
                                 domain_size: jax.Array, batch_size: int,
                                 pend_cap: int, block_s: int = 256,
                                 interpret: bool = True,
                                 use_pallas: bool = True) -> jax.Array:
    """``fused_propose_pallas`` with in-flight trials absorbed *inside* the
    program (the async replacement-pick hot path on the shared core).

    The leading absorb loop is ``scoring.absorb_pending`` — hardened
    factor appends (float64 Schur accumulation / iterative refinement),
    posterior mean at each pending point from the current extended system,
    phantom y at the mean — then the downdate pick loop runs with the
    observation counter advanced by ``n_pending``.  One device dispatch
    total, and the identical absorb loop serves the clustering pipeline
    (``acquisition.fused_cluster_propose``).
    """
    S = C.shape[0]
    Xs, Cs = scoring.prescale(X, C, ls, block_s)
    dp = Xs.shape[1]
    d = X.shape[1]
    Ps = jnp.zeros((pend_cap, dp), jnp.float32).at[:, :d].set(P / ls)
    Xs, y, mask, L, Linv = scoring.absorb_pending(
        Xs, y, mask, L, Linv, Ps, n_pending, n_obs, var, noise, pend_cap)
    return scoring.pick_downdate_loop(
        Cs, Xs, S, y, mask, L, Linv, var, noise, n_obs + n_pending,
        domain_size, batch_size, use_pallas=use_pallas, block_s=block_s,
        interpret=interpret)


# --------------------------------------------------------------------------- #
# StudyBank entry points: N studies, one dispatch
# --------------------------------------------------------------------------- #
# The bank ask runs as a STAGED pipeline of small jits rather than one
# monolithic program, for two reasons measured on CPU:
#
#   * XLA:CPU emits a *scalar* ``expf`` per element whenever ``exp`` is
#     fused with any producer (~8x the vectorized cost on a multi-million
#     element Matern block); compiled standalone it vectorizes.
#     ``lax.optimization_barrier`` does not split CPU fusion regions, so
#     the only reliable seam is a jit boundary.  ``bank_exp`` therefore
#     owns the ``exp(-s)`` evaluation and nothing else.
#   * the stages have different invalidation cadences: factors and
#     prescaled observations change only when a study's *observations*
#     change, while candidates are fresh every ask.  Separate entry
#     points let the ledger cache the slow stages (see
#     ``StudyBank._dispatch_gp``) instead of recomputing the Cholesky of
#     every study per ask.
#
# Staging is bitwise-safe: each stage reproduces the exact op sequence of
# the fused single-study program (division by the lengthscales, the raw-d2
# Matern polynomial, left-associated products, the hardened factor loop),
# and f32 elementwise/dot ops produce identical bits whether or not they
# share a fusion region — verified empirically against ``score_cov_ref``
# and exercised by the bank-vs-single pick-parity suite.
@jax.jit
def bank_factors(X: jax.Array, mask: jax.Array, ls, var, noise):
    """Masked-kernel Cholesky factors for every study: (B, na, d) ->
    ``(L, Linv, cond)`` at (B, na, na) / (B,).  Deterministic from ledger
    state alone — what makes a resumed bank replay bit-identical — and
    written back so the fleet checkpoint carries ``L``/``L⁻¹``.  ``cond``
    is the power-iteration estimate of cond₂(K) (``scoring.cond_estimate``)
    riding along with the factorization so ``last_cond_proxy`` lands within
    ~2x of the true condition number instead of the 20-50x-low diagonal
    bound."""

    def one(X, mask, ls, var, noise):
        L = cholesky_masked(X, mask, ls, var, noise)
        return L, scoring.linv_from_chol(L), scoring.cond_estimate(L, mask)

    return jax.vmap(one)(X, mask, ls, var, noise)


@jax.jit
def bank_prescale_X(X: jax.Array, ls: jax.Array) -> jax.Array:
    """Lengthscale-divide + lane-pad the observation block (B, na, d) ->
    (B, na, dp); cached with the factors (same invalidation cadence)."""
    d = X.shape[-1]
    dp = max(8, -(-d // 8) * 8)

    def one(X, ls):
        return jnp.zeros((X.shape[0], dp), jnp.float32).at[:, :d].set(
            X / ls)

    return jax.vmap(one)(X, ls)


@jax.jit
def bank_prescale_C(C: jax.Array, ls: jax.Array) -> jax.Array:
    """Prescale the fresh candidate block (B, S, d) -> (B, S, dp).

    Unlike the single-study ``scoring.prescale`` there is NO padding of S
    to a Pallas block multiple: the bank pipeline is pure jnp, every
    per-candidate row is independent (distances, posterior moments, and
    downdates are row-local; the argmax never saw padded rows, they were
    masked unavailable), so padded rows were 4x wasted elementwise work at
    small ``mc_samples`` with bitwise-identical picks either way."""
    d = C.shape[-1]
    dp = max(8, -(-d // 8) * 8)

    def one(C, ls):
        return jnp.zeros((C.shape[0], dp), jnp.float32).at[:, :d].set(
            C / ls)

    return jax.vmap(one)(C, ls)


@functools.partial(jax.jit, static_argnames=("pend_cap",))
def bank_absorb(Xs: jax.Array, y: jax.Array, mask: jax.Array,
                L: jax.Array, Linv: jax.Array, P: jax.Array,
                n_pending: jax.Array, n_obs: jax.Array,
                ls, var, noise, pend_cap: int):
    """Hallucinate each study's in-flight trials into its extended system
    (prescales the raw pending block in-program).  Only dispatched when
    some study has pending trials: with ``n_pending == 0`` the absorb
    loop is an identity, so the no-pending steady state skips the stage
    entirely (bitwise-safely) instead of paying the fori_loop."""
    d = P.shape[-1]
    dp = Xs.shape[-1]

    def one(Xs, y, mask, L, Linv, P, n_pending, n_obs, ls, var, noise):
        Ps = jnp.zeros((pend_cap, dp), jnp.float32).at[:, :d].set(P / ls)
        return scoring.absorb_pending(Xs, y, mask, L, Linv, Ps, n_pending,
                                      n_obs, var, noise, pend_cap)

    return jax.vmap(one)(Xs, y, mask, L, Linv, P, n_pending, n_obs, ls,
                         var, noise)


@jax.jit
def bank_dist(Cs: jax.Array, Xs: jax.Array):
    """Pairwise squared distances and the Matern argument ``s = sqrt(5) r``
    for every study: (B, Sp, dp) x (B, na, dp) -> (d2, s) at (B, Sp, na).
    The polynomial uses the *raw* d2 (the clamp lives only under the
    sqrt) — exactly ``kernels.gp_acquisition.ref.matern52``."""

    def one(c, x):
        d2 = (jnp.sum(c * c, -1)[:, None] + jnp.sum(x * x, -1)[None, :]
              - 2.0 * c @ x.T)
        r = jnp.sqrt(jnp.maximum(d2, 1e-12))
        return d2, jnp.sqrt(5.0) * r

    return jax.vmap(one)(Cs, Xs)


@jax.jit
def bank_exp(s: jax.Array) -> jax.Array:
    """``exp(-s)`` and NOTHING else — the one stage that must stay alone
    in its program so XLA:CPU emits the vectorized exponential."""
    return jnp.exp(-s)


@functools.partial(jax.jit, static_argnames=("batch_size", "S"))
def bank_pick(d2: jax.Array, s: jax.Array, e: jax.Array, Cs: jax.Array,
              y: jax.Array, mask: jax.Array, L: jax.Array,
              Linv: jax.Array, var, noise, n_obs_eff: jax.Array,
              domain_size: jax.Array, batch_size: int, S: int):
    """Assemble the masked Matern block from the staged pieces, score
    every candidate through the conditioning-hardened sum-of-squares form,
    and run the GP-BUCB slot loop — one vmap'd dispatch for the bank.
    ``n_obs_eff`` is ``n_obs + n_pending`` (the absorb-advanced counter).
    Returns picked candidate indices (B, batch_size)."""

    def one(d2, s, e, Cs, y, mask, L, Linv, var, noise, n_obs_eff):
        K = var * (1.0 + s + (5.0 / 3.0) * d2) * e * mask[None, :]
        alpha = scoring.kinv_matvec(Linv, y * mask)
        mu = K @ alpha
        t = K @ Linv.T
        q = jnp.sum(t * t, axis=-1)
        sig2 = jnp.maximum(var + noise - q, 1e-10)
        return scoring.pick_downdate_from_scores(
            Cs, S, mu, sig2, K, L, Linv, var, noise, n_obs_eff,
            domain_size, batch_size, use_pallas=False)

    return jax.vmap(one)(d2, s, e, Cs, y, mask, L, Linv, var, noise,
                         n_obs_eff)


@functools.partial(jax.jit, static_argnames=("batch_size", "n_top", "S"))
def bank_cluster_pick(d2: jax.Array, s: jax.Array, e: jax.Array,
                      C: jax.Array, y: jax.Array, mask: jax.Array,
                      Linv: jax.Array, var, noise, n_obs_eff: jax.Array,
                      domain_size: jax.Array, keys: jax.Array,
                      batch_size: int, n_top: int, S: int):
    """The clustering head on the staged bank pipeline: assemble the masked
    Matern block from the shared ``bank_dist``/``bank_exp`` pieces, score
    every candidate through the hardened sum-of-squares form, then UCB ->
    ``top_k`` -> weighted k-means over the RAW candidate rows -> one
    exploitative pick per cluster — op-for-op the tail of
    ``acquisition.fused_cluster_propose``, vmap'd over the bank.  ``C`` is
    the *unscaled* candidate block (k-means clusters in raw space);
    ``keys`` carries each study's per-ask PRNG key.  Returns picked
    candidate indices (B, batch_size)."""
    from repro.core.kmeans import _kmeans

    def one(d2, s, e, C, y, mask, Linv, var, noise, n_obs_eff, key):
        K = var * (1.0 + s + (5.0 / 3.0) * d2) * e * mask[None, :]
        alpha = scoring.kinv_matvec(Linv, y * mask)
        mu = K @ alpha
        t = K @ Linv.T
        q = jnp.sum(t * t, axis=-1)
        sig2 = jnp.maximum(var + noise - q, 1e-10)
        beta = adaptive_beta_dev(n_obs_eff, domain_size)
        acq = mu + jnp.sqrt(beta) * jnp.sqrt(sig2)
        acq = jnp.where(jnp.arange(C.shape[0]) < S, acq, -jnp.inf)
        top_vals, top_idx = jax.lax.top_k(acq, n_top)
        w = top_vals - top_vals[n_top - 1] + 1e-6
        assign = _kmeans(C[top_idx], w, key, batch_size)

        def body(c, carry):
            picked, picks = carry
            in_c = (assign == c) & ~picked
            sel = jnp.where(jnp.any(in_c), in_c, ~picked)
            vals = jnp.where(sel, top_vals, -jnp.inf)
            j = jnp.argmax(vals).astype(jnp.int32)
            return picked.at[j].set(True), picks.at[c].set(top_idx[j])

        _, picks = jax.lax.fori_loop(
            0, batch_size, body,
            (jnp.zeros((n_top,), bool),
             jnp.zeros((batch_size,), jnp.int32)))
        return picks

    return jax.vmap(one)(d2, s, e, C, y, mask, Linv, var, noise,
                         n_obs_eff, keys)


@functools.partial(jax.jit, static_argnames=("steps",))
def fit_hypers_bank(X: jax.Array, y: jax.Array, mask: jax.Array,
                    log_ls: jax.Array, log_var: jax.Array,
                    log_noise: jax.Array, y_mean: jax.Array,
                    y_std: jax.Array, steps: int = 40):
    """``fit_hypers`` for every study in a bank, one dispatch.

    ``y`` is raw signed values at the bucket shape; the frozen
    ``(y_mean, y_std)`` standardization scalars are computed on the HOST
    (``studybank._y_standardization``) with the exact numpy op sequence of
    the single-study ``GaussianProcess.fit``, and passed in — z is then a
    pure elementwise transform, bit-identical to the host standardization,
    which is what makes the bank-of-one ask path reproduce the pre-refactor
    single-study fits exactly.  Warm-starts from the passed per-study
    log-hypers — ledger rows that never fit carry the cold-init values, so
    one fixed-``steps`` program serves cold and warm fits alike (a static
    warm/cold split would double the cache entries per bucket).
    """

    def one(X, y, mask, lls, lv, ln, mean, std):
        z = ((y - mean) / std) * mask
        _, _, _, params = fit_hypers(
            X, z, mask, steps=steps,
            init={"log_ls": lls, "log_var": lv, "log_noise": ln})
        return params["log_ls"], params["log_var"], params["log_noise"]

    return jax.vmap(one)(X, y, mask, log_ls, log_var, log_noise, y_mean,
                         y_std)


# Every jitted bank entry point, by name: the retrace benchmark
# (``benchmarks/multi_study.py``) audits each one's jit cache against the
# number of shape buckets it was dispatched at — one compile per bucket,
# ever, is the shape-bucketing contract.
BANK_JITS = {
    "bank_factors": bank_factors,
    "bank_prescale_X": bank_prescale_X,
    "bank_prescale_C": bank_prescale_C,
    "bank_absorb": bank_absorb,
    "bank_dist": bank_dist,
    "bank_exp": bank_exp,
    "bank_pick": bank_pick,
    "bank_cluster_pick": bank_cluster_pick,
    "fit_hypers_bank": fit_hypers_bank,
}


# --------------------------------------------------------------------------- #
# Numpy-facing wrapper
# --------------------------------------------------------------------------- #
def _pad_to(n: int) -> int:
    p = 16
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class GPState:
    X: np.ndarray          # (n_pad, d)
    y: np.ndarray          # (n_pad,)
    mask: np.ndarray       # (n_pad,)
    L: Optional[jax.Array]
    ls: jax.Array
    var: jax.Array
    noise: jax.Array
    n: int
    y_mean: float
    y_std: float
    Linv: Optional[jax.Array] = None   # L^{-1}, only when track_factor


def _grow_state(st: GPState) -> GPState:
    """Double the padded buffers; identity rows keep L/Linv consistent."""
    grow = st.X.shape[0]
    pad_idx = jnp.arange(grow, 2 * grow)
    L = jnp.pad(st.L, ((0, grow), (0, grow)))
    L = L.at[pad_idx, pad_idx].set(1.0)
    Linv = st.Linv
    if Linv is not None:
        Linv = jnp.pad(Linv, ((0, grow), (0, grow)))
        Linv = Linv.at[pad_idx, pad_idx].set(1.0)
    return dataclasses.replace(
        st,
        X=np.concatenate([st.X, np.zeros_like(st.X)], 0),
        y=np.concatenate([st.y, np.zeros_like(st.y)], 0),
        mask=np.concatenate([st.mask, np.zeros_like(st.mask)], 0),
        L=L,
        Linv=Linv,
    )


class GaussianProcess:
    """Stateful fit/predict facade used by the batch strategies.

    ``fit`` is the full O(fit_steps * n^3) hyperparameter re-tune; ``observe``
    is the incremental entry point used by the fused proposal path — it
    appends new observations in O(n^2) and falls back to ``fit`` only when
    the observed prefix changed, the data shrank, or ``refit_every`` new
    points accumulated since the last hyperparameter tune.
    """

    def __init__(self, dim: int, fit_steps: int = 40, refit_every: int = 8,
                 track_factor: bool = False,
                 warm_fit_steps: Optional[int] = None):
        self.dim = dim
        self.fit_steps = fit_steps
        # refit boundaries warm-start Adam from the previous log-params and
        # run a short polish instead of the full from-scratch schedule
        self.warm_fit_steps = (max(8, fit_steps // 4)
                               if warm_fit_steps is None else warm_fit_steps)
        self.refit_every = max(1, int(refit_every))
        # maintain Linv = L^{-1} alongside L (the shared scoring core's
        # device-resident operand; was a tracked K^{-1} before ISSUE 5)
        self.track_factor = track_factor
        self.state: Optional[GPState] = None
        self.n_fit = 0                 # obs count at the last full fit
        self._fit_params: Optional[dict] = None  # log-params of the last fit
        self._obs_X: Optional[np.ndarray] = None
        self._obs_y: Optional[np.ndarray] = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> GPState:
        X = np.asarray(X, dtype=np.float32)
        y = np.asarray(y, dtype=np.float32)
        n = X.shape[0]
        n_pad = _pad_to(n)
        y_mean = float(y.mean()) if n else 0.0
        y_std = float(y.std()) + 1e-6 if n else 1.0
        Xp = np.zeros((n_pad, self.dim), np.float32)
        yp = np.zeros((n_pad,), np.float32)
        mp = np.zeros((n_pad,), np.float32)
        Xp[:n] = X
        yp[:n] = (y - y_mean) / y_std
        mp[:n] = 1.0
        steps = self.fit_steps if self._fit_params is None \
            else self.warm_fit_steps
        ls, var, noise, params = fit_hypers(
            jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray(mp), steps=steps,
            init=self._fit_params)
        self._fit_params = params
        L = cholesky_masked(jnp.asarray(Xp), jnp.asarray(mp), ls, var, noise)
        Linv = linv_from_chol(L) if self.track_factor else None
        self.state = GPState(Xp, yp, mp, L, ls, var, noise, n, y_mean, y_std,
                             Linv=Linv)
        self.n_fit = n
        self._obs_X, self._obs_y = X, y
        return self.state

    def _append(self, st: GPState, x_new: np.ndarray, y_raw: float
                ) -> GPState:
        """Extend the state with one *real* observation in O(n^2)."""
        if st.n >= st.X.shape[0]:
            st = _grow_state(st)
        idx = jnp.int32(st.n)
        Linv = st.Linv
        if Linv is not None:
            L, Linv, X, mask = chol_factor_append(
                st.L, Linv, jnp.asarray(st.X), jnp.asarray(st.mask), idx,
                jnp.asarray(x_new, jnp.float32), st.ls, st.var, st.noise)
        else:
            L, X, mask = chol_append(st.L, jnp.asarray(st.X),
                                     jnp.asarray(st.mask), idx,
                                     jnp.asarray(x_new, jnp.float32),
                                     st.ls, st.var, st.noise)
        y = st.y.copy()
        y[st.n] = (float(y_raw) - st.y_mean) / st.y_std
        X, mask = jax.device_get((X, mask))  # explicit host-pipeline exit
        return dataclasses.replace(st, X=X, y=y, mask=mask, L=L,
                                   n=st.n + 1, Linv=Linv)

    def observe(self, X: np.ndarray, y: np.ndarray) -> GPState:
        """Incremental fit on the full observation history (X, y)."""
        X = np.asarray(X, dtype=np.float32)
        y = np.asarray(y, dtype=np.float32)
        n = len(y)
        st = self.state
        stale = (
            st is None or n < st.n
            or (n - self.n_fit) >= self.refit_every
            or self._obs_X is None
            or not np.array_equal(self._obs_X[:st.n], X[:st.n])
            or not np.array_equal(self._obs_y[:st.n], y[:st.n]))
        if not stale and n > self.n_fit:
            # frozen standardization sanity: a degenerate fit (y_std ~ 1e-6
            # from constant initial observations) would blow incoming values
            # up to ~1e6 standardized and wreck the acquisition surface for
            # up to refit_every iterations — re-tune immediately instead.
            # Checked over everything appended since the last fit (not just
            # this call's new rows) so a checkpoint-resume replay, whose
            # appends bypass observe(), reaches the same refit decision at
            # the same propose step as the uninterrupted run.
            z = np.abs(y[self.n_fit:n] - st.y_mean) / st.y_std
            stale = bool(z.size) and float(z.max()) > 1e3
        if stale:
            return self.fit(X, y)
        for i in range(st.n, n):
            st = self._append(st, X[i], y[i])
        self.state = st
        self._obs_X, self._obs_y = X, y
        return st

    def restore(self, X: np.ndarray, y: np.ndarray, n_fit: int) -> GPState:
        """Rebuild the exact state an uninterrupted incremental run has:
        full fit on the first ``n_fit`` rows (bit-identical hypers on the
        same device), then replay the rest as O(n^2) appends."""
        X = np.asarray(X, dtype=np.float32)
        y = np.asarray(y, dtype=np.float32)
        n_fit = max(1, min(int(n_fit), len(y)))
        st = self.fit(X[:n_fit], y[:n_fit])
        for i in range(n_fit, len(y)):
            st = self._append(st, X[i], y[i])
        self.state = st
        self._obs_X, self._obs_y = X, y
        return st

    # -------------------------------------------------- exact checkpointing
    def export_state(self) -> Optional[dict]:
        """JSON-able snapshot of the fit schedule: the last full fit's
        observation count and raw log-hyperparameters.  Everything else
        (buffers, Cholesky, standardization) is a pure function of the
        observation history and this pair, so ``restore_exact`` rebuilds the
        live state bit-for-bit without re-running Adam — which matters now
        that fits warm-start from the previous fit in a chain a single
        from-scratch ``restore`` cannot reproduce."""
        if self.state is None or self._fit_params is None:
            return None
        return {"n_fit": int(self.n_fit),
                "log_params": {k: np.asarray(v, np.float32).tolist()
                               for k, v in self._fit_params.items()}}

    def restore_exact(self, X: np.ndarray, y: np.ndarray,
                      snap: dict) -> GPState:
        """Rebuild the exact live state from an ``export_state`` snapshot:
        padded buffers and Cholesky at ``n_fit`` under the stored
        hyperparameters, then replay the remaining rows as O(n^2) appends —
        identical ops to the uninterrupted incremental run."""
        X = np.asarray(X, dtype=np.float32)
        y = np.asarray(y, dtype=np.float32)
        n_fit = max(1, min(int(snap["n_fit"]), len(y)))
        lp = {k: jnp.asarray(np.asarray(v, np.float32))
              for k, v in snap["log_params"].items()}
        self._fit_params = lp
        n_pad = _pad_to(n_fit)
        y_mean = float(y[:n_fit].mean())
        y_std = float(y[:n_fit].std()) + 1e-6
        Xp = np.zeros((n_pad, self.dim), np.float32)
        yp = np.zeros((n_pad,), np.float32)
        mp = np.zeros((n_pad,), np.float32)
        Xp[:n_fit] = X[:n_fit]
        yp[:n_fit] = (y[:n_fit] - y_mean) / y_std
        mp[:n_fit] = 1.0
        ls = jnp.exp(lp["log_ls"])
        var = jnp.exp(lp["log_var"])
        noise = jnp.exp(lp["log_noise"]) + 1e-5
        L = cholesky_masked(jnp.asarray(Xp), jnp.asarray(mp), ls, var, noise)
        Linv = linv_from_chol(L) if self.track_factor else None
        st = GPState(Xp, yp, mp, L, ls, var, noise, n_fit, y_mean, y_std,
                     Linv=Linv)
        self.n_fit = n_fit
        for i in range(n_fit, len(y)):
            st = self._append(st, X[i], y[i])
        self.state = st
        self._obs_X, self._obs_y = X, y
        return st

    def ensure_capacity(self, st: GPState, extra: int) -> GPState:
        """Grow padded buffers until ``extra`` more rows fit (no refit).

        Returns a grown *copy* without persisting it: the stored state only
        grows inside ``_append``, so the buffer-growth schedule is a pure
        function of the observation sequence and checkpoint-resume replay
        (``restore``) reproduces it exactly.
        """
        while st.n + extra > st.X.shape[0]:
            st = _grow_state(st)
        return st

    def predict(self, Xs: np.ndarray, state: Optional[GPState] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
        st = state or self.state
        mu, var_s = posterior(jnp.asarray(st.X), jnp.asarray(st.y),
                              jnp.asarray(st.mask), st.L,
                              jnp.asarray(Xs, dtype=jnp.float32),
                              st.ls, st.var, st.noise)
        mu, var_s = jax.device_get((mu, var_s))  # one explicit exit sync
        mu = mu * st.y_std + st.y_mean
        sd = np.sqrt(var_s) * st.y_std
        return mu, sd

    def hallucinate(self, st: GPState, x_new: np.ndarray) -> GPState:
        """GP-BUCB: extend with a phantom observation at the posterior mean.

        Mean is unchanged (y entry = mu in standardized space); the variance
        contracts through the extended Cholesky.
        """
        if st.n >= st.X.shape[0]:  # grow the padded buffers
            st = _grow_state(st)
        mu_std, _ = posterior(jnp.asarray(st.X), jnp.asarray(st.y),
                              jnp.asarray(st.mask), st.L,
                              jnp.asarray(x_new[None, :], dtype=jnp.float32),
                              st.ls, st.var, st.noise)
        Linv = st.Linv
        if Linv is not None:
            L, Linv, X, mask = chol_factor_append(
                st.L, Linv, jnp.asarray(st.X), jnp.asarray(st.mask),
                jnp.int32(st.n), jnp.asarray(x_new, dtype=jnp.float32),
                st.ls, st.var, st.noise)
        else:
            L, X, mask = chol_append(st.L, jnp.asarray(st.X),
                                     jnp.asarray(st.mask), jnp.int32(st.n),
                                     jnp.asarray(x_new, dtype=jnp.float32),
                                     st.ls, st.var, st.noise)
        y = st.y.copy()
        y[st.n] = float(mu_std[0])
        X, mask = jax.device_get((X, mask))  # explicit host-pipeline exit
        return dataclasses.replace(st, X=X, y=y, mask=mask, L=L,
                                   n=st.n + 1, Linv=Linv)
