"""JAX Gaussian-process surrogate for batched bandit search.

Design points (vs. the sklearn GP the original Mango wraps):
  * Matern-5/2 ARD kernel, hyperparameters fit by a short jit'd Adam run on
    the log marginal likelihood (the paper uses sklearn defaults; MLE fitting
    is a recorded beyond-paper improvement).
  * fixed-size padded buffers (power-of-two) so the jit cache stays small
    across tuner iterations,
  * O(n^2) rank-1 Cholesky *hallucination* updates for GP-BUCB batch
    selection (Desautels et al. 2014): the posterior mean stays fixed within
    a batch while the variance contracts — the paper's first parallel
    strategy.  The original refits the GP per batch slot (O(n^3) each).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

JITTER = 1e-6


# --------------------------------------------------------------------------- #
# Kernel
# --------------------------------------------------------------------------- #
def matern52(x1: jax.Array, x2: jax.Array, ls: jax.Array,
             var: jax.Array) -> jax.Array:
    """x1 (n, d), x2 (m, d), ls (d,) ARD lengthscales -> (n, m)."""
    z1 = x1 / ls
    z2 = x2 / ls
    d2 = (jnp.sum(z1 * z1, -1)[:, None] + jnp.sum(z2 * z2, -1)[None, :]
          - 2.0 * z1 @ z2.T)
    r = jnp.sqrt(jnp.maximum(d2, 1e-12))
    s = jnp.sqrt(5.0) * r
    return var * (1.0 + s + (5.0 / 3.0) * d2) * jnp.exp(-s)


def _masked_kernel(X: jax.Array, mask: jax.Array, ls, var, noise):
    K = matern52(X, X, ls, var)
    m2 = mask[:, None] * mask[None, :]
    K = K * m2
    diag = jnp.where(mask > 0, var + noise + JITTER, 1.0)
    return K.at[jnp.diag_indices(X.shape[0])].set(diag)


# --------------------------------------------------------------------------- #
# Marginal-likelihood fit (jit, static buffer)
# --------------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("steps",))
def fit_hypers(X: jax.Array, y: jax.Array, mask: jax.Array, steps: int = 40
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (lengthscales (d,), signal var, noise) by Adam on -log ML."""
    d = X.shape[1]
    n_eff = jnp.maximum(mask.sum(), 1.0)

    def nll(params):
        ls = jnp.exp(params["log_ls"])
        var = jnp.exp(params["log_var"])
        noise = jnp.exp(params["log_noise"]) + 1e-5
        K = _masked_kernel(X, mask, ls, var, noise)
        L = jnp.linalg.cholesky(K)
        alpha = jax.scipy.linalg.cho_solve((L, True), y * mask)
        ll = (-0.5 * jnp.sum((y * mask) * alpha)
              - jnp.sum(jnp.log(jnp.diagonal(L)) * mask)
              - 0.5 * n_eff * jnp.log(2 * jnp.pi))
        return -ll / n_eff

    params = {"log_ls": jnp.zeros((d,)) + jnp.log(0.5),
              "log_var": jnp.zeros(()),
              "log_noise": jnp.log(jnp.asarray(1e-2))}
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    lr, b1, b2 = 0.08, 0.9, 0.999

    def step(carry, i):
        params, m, v = carry
        g = jax.grad(nll)(params)
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        t = i.astype(jnp.float32) + 1
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * (mm / (1 - b1 ** t))
            / (jnp.sqrt(vv / (1 - b2 ** t)) + 1e-8), params, m, v)
        params["log_ls"] = jnp.clip(params["log_ls"], jnp.log(0.01),
                                    jnp.log(10.0))
        return (params, m, v), None

    (params, _, _), _ = jax.lax.scan(step, (params, m, v),
                                     jnp.arange(steps))
    return (jnp.exp(params["log_ls"]), jnp.exp(params["log_var"]),
            jnp.exp(params["log_noise"]) + 1e-5)


# --------------------------------------------------------------------------- #
# Posterior with incremental (hallucination) Cholesky extension
# --------------------------------------------------------------------------- #
@jax.jit
def cholesky_masked(X, mask, ls, var, noise) -> jax.Array:
    return jnp.linalg.cholesky(_masked_kernel(X, mask, ls, var, noise))


@jax.jit
def posterior(X: jax.Array, y: jax.Array, mask: jax.Array, L: jax.Array,
              Xs: jax.Array, ls, var, noise
              ) -> Tuple[jax.Array, jax.Array]:
    """mu/sigma^2 at Xs (m, d) given padded train (n, d) and its Cholesky."""
    Ks = matern52(X, Xs, ls, var) * mask[:, None]        # (n, m)
    alpha = jax.scipy.linalg.cho_solve((L, True), y * mask)
    mu = Ks.T @ alpha
    V = jax.scipy.linalg.solve_triangular(L, Ks, lower=True)  # (n, m)
    var_s = jnp.maximum(var + noise - jnp.sum(V * V, axis=0), 1e-10)
    return mu, var_s


@jax.jit
def chol_append(L: jax.Array, X: jax.Array, mask: jax.Array, idx: jax.Array,
                x_new: jax.Array, ls, var, noise
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-1 extension: write x_new into padded row ``idx`` and extend L.

    Returns (L', X', mask').  O(n^2) instead of a full O(n^3) refit.
    """
    n = X.shape[0]
    X = X.at[idx].set(x_new)
    k_vec = (matern52(X, x_new[None, :], ls, var)[:, 0] * mask)  # (n,)
    l_vec = jax.scipy.linalg.solve_triangular(L, k_vec, lower=True)
    l_vec = jnp.where(jnp.arange(n) < idx, l_vec, 0.0)
    l_nn = jnp.sqrt(jnp.maximum(var + noise + JITTER
                                - jnp.sum(l_vec * l_vec), 1e-10))
    row = l_vec.at[idx].set(l_nn)
    L = L.at[idx, :].set(row)
    mask = mask.at[idx].set(1.0)
    return L, X, mask


# --------------------------------------------------------------------------- #
# Numpy-facing wrapper
# --------------------------------------------------------------------------- #
def _pad_to(n: int) -> int:
    p = 16
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class GPState:
    X: np.ndarray          # (n_pad, d)
    y: np.ndarray          # (n_pad,)
    mask: np.ndarray       # (n_pad,)
    L: Optional[jax.Array]
    ls: jax.Array
    var: jax.Array
    noise: jax.Array
    n: int
    y_mean: float
    y_std: float


class GaussianProcess:
    """Stateful fit/predict facade used by the batch strategies."""

    def __init__(self, dim: int, fit_steps: int = 40):
        self.dim = dim
        self.fit_steps = fit_steps
        self.state: Optional[GPState] = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> GPState:
        X = np.asarray(X, dtype=np.float32)
        y = np.asarray(y, dtype=np.float32)
        n = X.shape[0]
        n_pad = _pad_to(n)
        y_mean = float(y.mean()) if n else 0.0
        y_std = float(y.std()) + 1e-6 if n else 1.0
        Xp = np.zeros((n_pad, self.dim), np.float32)
        yp = np.zeros((n_pad,), np.float32)
        mp = np.zeros((n_pad,), np.float32)
        Xp[:n] = X
        yp[:n] = (y - y_mean) / y_std
        mp[:n] = 1.0
        ls, var, noise = fit_hypers(jnp.asarray(Xp), jnp.asarray(yp),
                                    jnp.asarray(mp), steps=self.fit_steps)
        L = cholesky_masked(jnp.asarray(Xp), jnp.asarray(mp), ls, var, noise)
        self.state = GPState(Xp, yp, mp, L, ls, var, noise, n, y_mean, y_std)
        return self.state

    def predict(self, Xs: np.ndarray, state: Optional[GPState] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
        st = state or self.state
        mu, var_s = posterior(jnp.asarray(st.X), jnp.asarray(st.y),
                              jnp.asarray(st.mask), st.L,
                              jnp.asarray(Xs, dtype=jnp.float32),
                              st.ls, st.var, st.noise)
        mu = np.asarray(mu) * st.y_std + st.y_mean
        sd = np.sqrt(np.asarray(var_s)) * st.y_std
        return mu, sd

    def hallucinate(self, st: GPState, x_new: np.ndarray) -> GPState:
        """GP-BUCB: extend with a phantom observation at the posterior mean.

        Mean is unchanged (y entry = mu in standardized space); the variance
        contracts through the extended Cholesky.
        """
        if st.n >= st.X.shape[0]:  # grow the padded buffers
            grow = st.X.shape[0]
            L = jnp.pad(st.L, ((0, grow), (0, grow)))
            pad_idx = jnp.arange(grow, 2 * grow)
            L = L.at[pad_idx, pad_idx].set(1.0)  # identity rows for padding
            st = dataclasses.replace(
                st,
                X=np.concatenate([st.X, np.zeros_like(st.X)], 0),
                y=np.concatenate([st.y, np.zeros_like(st.y)], 0),
                mask=np.concatenate([st.mask, np.zeros_like(st.mask)], 0),
                L=L,
            )
        mu_std, _ = posterior(jnp.asarray(st.X), jnp.asarray(st.y),
                              jnp.asarray(st.mask), st.L,
                              jnp.asarray(x_new[None, :], dtype=jnp.float32),
                              st.ls, st.var, st.noise)
        L, X, mask = chol_append(st.L, jnp.asarray(st.X),
                                 jnp.asarray(st.mask), jnp.int32(st.n),
                                 jnp.asarray(x_new, dtype=jnp.float32),
                                 st.ls, st.var, st.noise)
        y = st.y.copy()
        y[st.n] = float(mu_std[0])
        return dataclasses.replace(
            st, X=np.asarray(X), y=y, mask=np.asarray(mask), L=L, n=st.n + 1)
