"""Hyperparameter search-space abstraction (paper §2.1).

A space is a plain dict: ``{"C": uniform(0.1, 10), "kernel": ["rbf", "poly"],
"depth": range(1, 10), "lr": loguniform(-4, 3)}`` — values may be:

  * any scipy.stats frozen distribution (all 70+ work: the only contract is
    ``.rvs(size, random_state)``; ``.cdf`` is used for unit-cube encoding
    when available, as in Garrido-Merchan & Hernandez-Lobato's treatment of
    continuous variables),
  * Python ``range`` (uniform integer),
  * list / tuple / np.ndarray (categorical, sampled uniformly),
  * a constant (held fixed).

``ParamSpace`` turns the dict into: native samplers (Monte-Carlo acquisition
candidates are always *valid* configurations — the paper's approach to
discrete/categorical parameters), a unit-cube encoder for the GP, and a
domain-size estimate used by the adaptive-beta heuristic.

Structured extensions (beyond the paper's flat spaces):

  * ``Int(lo, hi)`` / ``LogInt(lo, hi)`` — uniform / log-uniform integer
    dimensions (tile sizes, microbatch counts) that encode on their own
    (log-)scale instead of riding the categorical-list treatment,
  * ``Choice({branch: {child: ...}})`` — a *conditional* subspace: a
    categorical root whose child parameters exist only when their branch
    is active.  Sampled configs carry ``{"_choice": branch, **children}``;
    the encoding is fixed-width and masked — the root one-hot doubles as
    the per-branch mask column and inactive child dims are imputed at 0.5
    (Garrido-Merchan & Hernandez-Lobato's treatment extended to
    hierarchies) — so the GP/TPE/clustering device pipelines, columnar
    bank draws, and v1 checkpoints all work unchanged,
  * ``ParamSpace(space, constraints=[...])`` — predicate callables over
    the config dict; sampling rejection-resamples violating rows, so
    every Monte-Carlo candidate is a *valid* configuration.

Flat spaces (no Choice/Int/LogInt, no constraints) take exactly the
pre-existing code paths: samples, RNG streams, and encodings are
bit-identical to the unextended ``ParamSpace``.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

# key carrying the active branch name inside a sampled Choice value
CHOICE_KEY = "_choice"
# encoded value of an inactive conditional dim (center of the unit cube:
# zero-information imputation for the GP; the mask column disambiguates)
IMPUTED = 0.5
# rounds of constraint rejection-resampling before giving up
_MAX_RESAMPLE = 100


class loguniform:
    """Mango's log-uniform: 10**uniform(lo_exp, lo_exp+size_exp).

    Defined by extending the scipy sampling contract (.rvs/.cdf/.ppf), as the
    paper prescribes for new distributions.
    """

    def __init__(self, lo_exp: float, size_exp: float):
        self.lo = float(lo_exp)
        self.size = float(size_exp)

    def rvs(self, size=None, random_state=None):
        if isinstance(random_state, np.random.Generator):
            rng = random_state
        else:
            rng = np.random.default_rng(random_state)
        e = rng.uniform(self.lo, self.lo + self.size, size)
        return np.power(10.0, e)

    def cdf(self, x):
        x = np.asarray(x, dtype=float)
        e = np.log10(np.maximum(x, 1e-300))
        return np.clip((e - self.lo) / max(self.size, 1e-12), 0.0, 1.0)

    def ppf(self, q):
        return np.power(10.0, self.lo + np.asarray(q) * self.size)


class Int:
    """Uniform integer dimension over the inclusive range [lo, hi]."""

    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)
        if self.hi < self.lo:
            raise ValueError(f"Int: hi ({hi}) < lo ({lo})")


class LogInt(Int):
    """Log-uniform integer over [lo, hi] (lo >= 1): tile sizes, widths."""

    def __init__(self, lo: int, hi: int):
        super().__init__(lo, hi)
        if self.lo < 1:
            raise ValueError(f"LogInt: lo must be >= 1, got {lo}")


class Choice:
    """Conditional subspace: categorical root + per-branch child params.

    ``Choice({"zero1": {}, "zero3": {"remat": ["none", "full"]}})`` samples
    to ``{"_choice": "zero3", "remat": "full"}`` — child params exist only
    when their branch is active.  Child values may be anything a flat space
    accepts (dist / range / list / const / Int / LogInt) but not another
    Choice: one level of conditionality keeps the masked encoding exact.
    """

    def __init__(self, branches: Dict[str, Dict[str, Any]]):
        if not isinstance(branches, dict) or not branches:
            raise ValueError("Choice: branches must be a non-empty dict")
        for bname, sub in branches.items():
            if not isinstance(sub, dict):
                raise ValueError(
                    f"Choice[{bname!r}]: branch must be a dict of params")
            for cname, cv in sub.items():
                if cname == CHOICE_KEY:
                    raise ValueError(
                        f"Choice[{bname!r}]: {CHOICE_KEY!r} is reserved")
                if isinstance(cv, Choice):
                    raise ValueError(
                        f"Choice[{bname!r}][{cname!r}]: nested Choice is "
                        "not supported (flatten into branch names)")
        self.branches = branches


def _is_distribution(v: Any) -> bool:
    return hasattr(v, "rvs")


def _py(x: Any) -> Any:
    """numpy scalar -> Python scalar (keeps configs JSON-serializable)."""
    return x.item() if isinstance(x, np.generic) else x


class _Param:
    kind: str  # "dist" | "range" | "cat" | "const" | "int" | "logint"
    #            | "choice"

    def __init__(self, name: str, v: Any):
        self.name = name
        if isinstance(v, Choice):
            self.kind = "choice"
            self.branches = [(bname, [_Param(cn, cv)
                                      for cn, cv in sub.items()])
                             for bname, sub in v.branches.items()]
            self.n_branches = len(self.branches)
            # fixed-width layout: root one-hot (doubles as the per-branch
            # mask), then every branch's child blocks in declaration order;
            # per-branch column offsets are kept for decode()
            self._child_cols = []
            col = self.n_branches
            for bname, children in self.branches:
                offs = []
                for c in children:
                    offs.append((c, col, col + c.dims))
                    col += c.dims
                self._child_cols.append(offs)
            self.dims = col
        elif isinstance(v, LogInt):
            self.kind = "logint"
            self.lo, self.hi = v.lo, v.hi
            self.dims = 1
        elif isinstance(v, Int):
            self.kind = "int"
            self.lo, self.hi = v.lo, v.hi
            self.dims = 1
        elif _is_distribution(v):
            self.kind = "dist"
            self.dist = v
            self.dims = 1
            self._ecdf_ref = None   # lazy, for sampling-only distributions
            # Frozen scipy uniform gets a closed-form columnar fast path:
            # rvs == rng.uniform(n)*scale + loc and cdf == (x-loc)/scale
            # bitwise (scipy evaluates exactly these expressions), so the
            # bank's 10^4-10^5-candidate draws skip scipy's per-call arg
            # machinery without perturbing the RNG stream or the encoding.
            self._uniform_ls = None
            # loguniform (scipy name "reciprocal") gets the same treatment:
            # it defines no custom _rvs, so scipy draws it as
            # _ppf(rng.uniform(n)) = exp(log a + u*(log b - log a)), and
            # cdf is (log x - log a)/(log b - log a) — both reproduced here
            # expression-for-expression so values AND the RNG stream stay
            # bitwise identical to the scipy path.
            self._loguniform_abls = None
            try:
                dname = getattr(getattr(v, "dist", None), "name", "")
                if dname == "uniform":
                    _, loc, scale = v.dist._parse_args(*v.args, **v.kwds)
                    self._uniform_ls = (float(loc), float(scale))
                elif dname in ("loguniform", "reciprocal"):
                    (a, b), loc, scale = v.dist._parse_args(*v.args,
                                                            **v.kwds)
                    self._loguniform_abls = (float(a), float(b),
                                             float(loc), float(scale))
            except Exception:
                self._uniform_ls = None
                self._loguniform_abls = None
        elif isinstance(v, range):
            self.kind = "range"
            self.choices = np.array(list(v))
            if len(self.choices) == 0:
                raise ValueError(f"{name}: empty range")
            self.dims = 1
        elif isinstance(v, (list, tuple, np.ndarray)):
            self.kind = "cat"
            self.choices = list(v)
            if len(self.choices) == 0:
                raise ValueError(f"{name}: empty categorical list")
            # numeric lists are ordinal (single dim); strings are one-hot
            self.numeric = all(isinstance(c, (int, float, np.number))
                               for c in self.choices)
            self.dims = 1 if self.numeric else len(self.choices)
        else:
            self.kind = "const"
            self.value = v
            self.dims = 0

    # ---- sampling (native distribution; always-valid configs) -------------
    def sample(self, n: int, rng: np.random.Generator) -> List[Any]:
        if self.kind == "dist":
            out = np.asarray(self.dist.rvs(size=n, random_state=rng))
            return list(out)
        if self.kind == "range":
            return list(rng.choice(self.choices, size=n))
        if self.kind == "cat":
            idx = rng.integers(0, len(self.choices), size=n)
            return [self.choices[i] for i in idx]
        if self.kind in ("int", "logint", "choice"):
            return self._sample_structured(n, rng, as_array=False)
        return [self.value] * n

    def sample_array(self, n: int, rng: np.random.Generator):
        """Columnar ``sample``: same RNG stream, but numeric kinds return the
        ndarray itself instead of a list of Python scalars (the list round
        trip dominates host time at bank scale: B*mc rows per ask)."""
        if self.kind == "dist":
            if self._uniform_ls is not None:
                loc, scale = self._uniform_ls
                return rng.uniform(size=n) * scale + loc
            if self._loguniform_abls is not None:
                a, b, loc, scale = self._loguniform_abls
                u = rng.uniform(size=n)
                return np.exp(np.log(a)
                              + u * (np.log(b) - np.log(a))) * scale + loc
            return np.asarray(self.dist.rvs(size=n, random_state=rng))
        if self.kind == "range":
            return rng.choice(self.choices, size=n)
        if self.kind in ("int", "logint", "choice"):
            return self._sample_structured(n, rng, as_array=True)
        return self.sample(n, rng)   # cat / const stay object lists

    def _sample_structured(self, n: int, rng: np.random.Generator,
                           as_array: bool):
        """One shared draw routine for the structured kinds so the scalar
        (``sample``) and columnar (``sample_array``) paths consume the RNG
        stream identically — the bitwise-parity contract the bank's
        columnar asks rely on extends to conditional spaces for free."""
        if self.kind == "int":
            out = rng.integers(self.lo, self.hi + 1, size=n)
            return out if as_array else [int(v) for v in out]
        if self.kind == "logint":
            u = rng.uniform(size=n)
            e = np.log(self.lo) + u * (np.log(self.hi) - np.log(self.lo))
            out = np.clip(np.rint(np.exp(e)), self.lo,
                          self.hi).astype(np.int64)
            return out if as_array else [int(v) for v in out]
        # choice: draw the root, then a FULL n-length column per child of
        # EVERY branch in declaration order (inactive draws discarded).
        # Full-length columns cost extra draws but make the stream a pure
        # function of (space, n) — never of which branches happened to win —
        # which is what keeps scalar/columnar and resume replays bit-equal.
        ridx = rng.integers(0, self.n_branches, size=n)
        cols = [{c.name: c.sample_array(n, rng) for c in children}
                for _, children in self.branches]
        out = []
        for i in range(n):
            j = int(ridx[i])
            bname, children = self.branches[j]
            val = {CHOICE_KEY: bname}
            for c in children:
                val[c.name] = _py(cols[j][c.name][i])
            out.append(val)
        return out

    def _ecdf(self) -> np.ndarray:
        """Persistent empirical CDF for sampling-only distributions.

        Fitted once from a dedicated fixed-seed draw (not the tuner's RNG
        stream), so the same value encodes identically in every batch and
        across checkpoint/resume — a per-batch min-max fallback would map
        the same config to different GP inputs depending on its batchmates,
        corrupting the surrogate.
        """
        if self._ecdf_ref is None:
            draw = np.asarray(self.dist.rvs(
                size=2048, random_state=np.random.default_rng(0xEC0F)),
                dtype=float)
            self._ecdf_ref = np.sort(draw.reshape(-1))
        return self._ecdf_ref

    # ---- unit-cube encoding ------------------------------------------------
    def encode(self, values: Sequence[Any]) -> np.ndarray:
        n = len(values)
        if self.kind == "dist":
            v = np.asarray(values, dtype=float)
            if self._uniform_ls is not None:
                loc, scale = self._uniform_ls
                enc = np.nan_to_num(np.clip((v - loc) / scale, 0.0, 1.0),
                                    nan=0.5)
                return enc.reshape(n, 1)
            if self._loguniform_abls is not None:
                a, b, loc, scale = self._loguniform_abls
                with np.errstate(all="ignore"):
                    q = ((np.log((v - loc) / scale) - np.log(a))
                         / (np.log(b) - np.log(a)))
                    enc = np.nan_to_num(np.clip(q, 0.0, 1.0), nan=0.5)
                return enc.reshape(n, 1)
            if hasattr(self.dist, "cdf"):
                with np.errstate(all="ignore"):
                    enc = np.nan_to_num(
                        np.asarray(self.dist.cdf(v), dtype=float), nan=0.5)
            else:  # sampling-only distribution: persistent empirical CDF
                ref = self._ecdf()
                enc = np.interp(v, ref, np.linspace(0.0, 1.0, len(ref)))
            return enc.reshape(n, 1)
        if self.kind == "range":
            lo, hi = self.choices[0], self.choices[-1]
            v = np.asarray(values, dtype=float)
            return ((v - lo) / max(hi - lo, 1)).reshape(n, 1)
        if self.kind == "cat":
            if self.numeric:
                arr = np.asarray(self.choices, dtype=float)
                lo, hi = arr.min(), arr.max()
                v = np.asarray(values, dtype=float)
                return ((v - lo) / max(hi - lo, 1e-12)).reshape(n, 1)
            onehot = np.zeros((n, len(self.choices)))
            index = {c: i for i, c in enumerate(self.choices)}
            for r, val in enumerate(values):
                onehot[r, index[val]] = 1.0
            return onehot
        if self.kind == "int":
            v = np.asarray(values, dtype=float)
            return ((v - self.lo) / max(self.hi - self.lo, 1)).reshape(n, 1)
        if self.kind == "logint":
            v = np.log(np.maximum(np.asarray(values, dtype=float), 1.0))
            span = max(np.log(self.hi) - np.log(self.lo), 1e-12)
            return np.clip((v - np.log(self.lo)) / span,
                           0.0, 1.0).reshape(n, 1)
        if self.kind == "choice":
            # root one-hot (the active column IS the branch mask) + every
            # branch's child blocks, inactive rows imputed at IMPUTED
            bindex = {bname: j for j, (bname, _) in enumerate(self.branches)}
            ridx = np.array([bindex[v[CHOICE_KEY]] for v in values],
                            dtype=np.int64)
            onehot = np.zeros((n, self.n_branches))
            if n:
                onehot[np.arange(n), ridx] = 1.0
            blocks = [onehot]
            for j, (_, children) in enumerate(self.branches):
                rows = np.nonzero(ridx == j)[0]
                for c in children:
                    if c.dims == 0:
                        continue
                    block = np.full((n, c.dims), IMPUTED)
                    if len(rows):
                        block[rows] = c.encode(
                            [values[r][c.name] for r in rows])
                    blocks.append(block)
            return np.concatenate(blocks, axis=1)
        return np.zeros((n, 0))

    # ---- inverse encoding (unit cube -> native values) ---------------------
    def decode(self, X: np.ndarray) -> List[Any]:
        """Inverse of ``encode`` up to quantization: continuous dims invert
        the CDF, discrete dims snap to the nearest choice, one-hot blocks
        argmax.  ``decode(encode(vals)) == vals`` for every discrete kind;
        continuous kinds round-trip to float precision."""
        X = np.asarray(X, dtype=float)
        n = X.shape[0]
        if self.kind == "const":
            return [self.value] * n
        if self.kind == "dist":
            q = np.clip(X[:, 0], 0.0, 1.0)
            if self._uniform_ls is not None:
                loc, scale = self._uniform_ls
                return list(loc + q * scale)
            if self._loguniform_abls is not None:
                a, b, loc, scale = self._loguniform_abls
                return list(np.exp(np.log(a)
                                   + q * (np.log(b) - np.log(a)))
                            * scale + loc)
            if hasattr(self.dist, "ppf"):
                return list(np.asarray(self.dist.ppf(q), dtype=float))
            ref = self._ecdf()
            return list(np.interp(q, np.linspace(0.0, 1.0, len(ref)), ref))
        if self.kind == "int":
            v = self.lo + X[:, 0] * max(self.hi - self.lo, 1)
            return [int(x) for x in
                    np.clip(np.rint(v), self.lo, self.hi)]
        if self.kind == "logint":
            e = (np.log(self.lo)
                 + X[:, 0] * max(np.log(self.hi) - np.log(self.lo), 1e-12))
            return [int(x) for x in
                    np.clip(np.rint(np.exp(e)), self.lo, self.hi)]
        if self.kind == "range":
            arr = np.asarray(self.choices, dtype=float)
            lo, hi = self.choices[0], self.choices[-1]
            v = lo + X[:, 0] * max(hi - lo, 1)
            idx = np.abs(arr[None, :] - v[:, None]).argmin(axis=1)
            return [_py(self.choices[i]) for i in idx]
        if self.kind == "cat":
            if self.numeric:
                arr = np.asarray(self.choices, dtype=float)
                lo, hi = arr.min(), arr.max()
                v = lo + X[:, 0] * max(hi - lo, 1e-12)
                idx = np.abs(arr[None, :] - v[:, None]).argmin(axis=1)
            else:
                idx = X.argmax(axis=1)
            return [self.choices[i] for i in idx]
        # choice: argmax the root one-hot, then decode only the winning
        # branch's child block for each row
        ridx = X[:, :self.n_branches].argmax(axis=1)
        out: List[Any] = []
        for i in range(n):
            j = int(ridx[i])
            bname, _ = self.branches[j]
            val = {CHOICE_KEY: bname}
            for c, lo_col, hi_col in self._child_cols[j]:
                if c.dims == 0:
                    val[c.name] = c.value
                else:
                    val[c.name] = _py(
                        c.decode(X[i:i + 1, lo_col:hi_col])[0])
            out.append(val)
        return out

    @property
    def cardinality(self) -> float:
        if self.kind == "dist":
            return 100.0  # continuous: effective resolution heuristic
        if self.kind in ("range", "cat"):
            return float(len(self.choices))
        if self.kind in ("int", "logint"):
            return float(self.hi - self.lo + 1)
        if self.kind == "choice":
            total = 0.0
            for _, children in self.branches:
                prod = 1.0
                for c in children:
                    prod *= c.cardinality
                total += prod
            return total
        return 1.0


class ParamSpace:
    def __init__(self, space: Dict[str, Any],
                 constraints: Optional[
                     Sequence[Callable[[Dict], bool]]] = None):
        if not isinstance(space, dict) or not space:
            raise ValueError("param space must be a non-empty dict")
        self.params = [_Param(k, v) for k, v in space.items()]
        self.names = [p.name for p in self.params]
        self.dim = sum(p.dims for p in self.params)
        self.constraints = list(constraints) if constraints else []
        for f in self.constraints:
            if not callable(f):
                raise ValueError("constraints must be callables cfg -> bool")
        self.is_conditional = any(p.kind == "choice" for p in self.params)

    def feasible(self, cfg: Dict) -> bool:
        return all(f(cfg) for f in self.constraints)

    def sample(self, n: int, rng: np.random.Generator) -> List[Dict]:
        rows = self._sample_rows(n, rng)
        if not self.constraints:
            return rows
        # rejection resampling: every returned row satisfies every
        # constraint, so Monte-Carlo candidates stay *valid* configurations
        ok = [r for r in rows if self.feasible(r)]
        for _ in range(_MAX_RESAMPLE):
            if len(ok) >= n:
                break
            ok.extend(r for r in self._sample_rows(n, rng)
                      if self.feasible(r))
        if len(ok) < n:
            raise RuntimeError(
                f"constraints rejected >{_MAX_RESAMPLE}x oversampling; "
                "the feasible region is (near-)empty — relax the "
                "constraints or shrink the space")
        return ok[:n]

    def _sample_rows(self, n: int, rng: np.random.Generator) -> List[Dict]:
        cols = {p.name: p.sample(n, rng) for p in self.params}
        return [{k: cols[k][i] for k in cols} for i in range(n)]

    # ---- columnar sampling (StudyBank's batched-candidate fast path) ----
    # Draws the *same* RNG stream as ``sample(n, rng)`` (one per-param draw
    # each, in declaration order) but skips materializing n row dicts, so a
    # bank ask can sample B*n_mc candidates and encode them in one pass;
    # only the few winning rows ever become config dicts (``config_at``).
    def sample_columns(self, n: int,
                       rng: np.random.Generator) -> Dict[str, Any]:
        if self.constraints:
            # constrained spaces route through the row sampler so columnar
            # and scalar draws stay trivially the same stream (rejection
            # makes the draw count data-dependent; no columnar shortcut)
            rows = self.sample(n, rng)
            return {p.name: [r[p.name] for r in rows] for p in self.params}
        return {p.name: p.sample_array(n, rng) for p in self.params}

    def encode_columns(self, cols: Dict[str, List[Any]],
                       n: int) -> np.ndarray:
        blocks = [p.encode(cols[p.name]) for p in self.params if p.dims]
        return (np.concatenate(blocks, axis=1) if blocks
                else np.zeros((n, 0)))

    def config_at(self, cols: Dict[str, Any], i: int) -> Dict:
        # .item() unwraps ndarray columns to Python scalars so trial params
        # stay JSON-serializable (state_dict carries them verbatim)
        return {p.name: (cols[p.name][i].item()
                         if isinstance(cols[p.name], np.ndarray)
                         else cols[p.name][i])
                for p in self.params}

    def configs_at(self, cols: Dict[str, Any], idx) -> List[Dict]:
        """Batched ``config_at``: one fancy-index + ``tolist`` per column
        instead of a per-row dictcomp with per-scalar ``.item()`` calls
        (the bank materializes B*n winner configs per ask)."""
        idx = np.asarray(idx, dtype=np.int64)
        names = [p.name for p in self.params]
        pulled = []
        for p in self.params:
            c = cols[p.name]
            if isinstance(c, np.ndarray):
                pulled.append(c[idx].tolist())   # tolist -> Python scalars
            else:
                pulled.append([c[i] for i in idx])
        return [dict(zip(names, row)) for row in zip(*pulled)]

    def encode(self, configs: List[Dict]) -> np.ndarray:
        if not configs:
            return np.zeros((0, self.dim))
        blocks = [p.encode([c[p.name] for c in configs]) for p in self.params
                  if p.dims]
        return np.concatenate(blocks, axis=1) if blocks else np.zeros(
            (len(configs), 0))

    def decode(self, X: np.ndarray) -> List[Dict]:
        """Inverse of ``encode``: unit-cube rows back to config dicts
        (discrete dims snap to the nearest valid choice; conditional
        params argmax their mask columns and decode only the active
        branch).  Useful for interpreting GP argmax points and for
        round-trip testing the masked encoding."""
        X = np.asarray(X, dtype=float)
        out: List[Dict] = [dict() for _ in range(X.shape[0])]
        col = 0
        for p in self.params:
            vals = p.decode(X[:, col:col + p.dims])
            col += p.dims
            for i, v in enumerate(vals):
                out[i][p.name] = v
        return out

    @property
    def domain_size(self) -> float:
        s = 1.0
        for p in self.params:
            s *= p.cardinality
        return min(s, 1e12)

    def mc_samples(self, batch_size: int = 1) -> int:
        """Paper §2.3: sample count scales with #params / space complexity."""
        base = 1000 * max(self.dim, 1) + 200 * int(math.log10(
            self.domain_size + 1))
        return int(np.clip(base * max(1, batch_size // 2), 2000, 32768))
