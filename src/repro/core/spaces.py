"""Hyperparameter search-space abstraction (paper §2.1).

A space is a plain dict: ``{"C": uniform(0.1, 10), "kernel": ["rbf", "poly"],
"depth": range(1, 10), "lr": loguniform(-4, 3)}`` — values may be:

  * any scipy.stats frozen distribution (all 70+ work: the only contract is
    ``.rvs(size, random_state)``; ``.cdf`` is used for unit-cube encoding
    when available, as in Garrido-Merchan & Hernandez-Lobato's treatment of
    continuous variables),
  * Python ``range`` (uniform integer),
  * list / tuple / np.ndarray (categorical, sampled uniformly),
  * a constant (held fixed).

``ParamSpace`` turns the dict into: native samplers (Monte-Carlo acquisition
candidates are always *valid* configurations — the paper's approach to
discrete/categorical parameters), a unit-cube encoder for the GP, and a
domain-size estimate used by the adaptive-beta heuristic.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Sequence

import numpy as np


class loguniform:
    """Mango's log-uniform: 10**uniform(lo_exp, lo_exp+size_exp).

    Defined by extending the scipy sampling contract (.rvs/.cdf/.ppf), as the
    paper prescribes for new distributions.
    """

    def __init__(self, lo_exp: float, size_exp: float):
        self.lo = float(lo_exp)
        self.size = float(size_exp)

    def rvs(self, size=None, random_state=None):
        if isinstance(random_state, np.random.Generator):
            rng = random_state
        else:
            rng = np.random.default_rng(random_state)
        e = rng.uniform(self.lo, self.lo + self.size, size)
        return np.power(10.0, e)

    def cdf(self, x):
        x = np.asarray(x, dtype=float)
        e = np.log10(np.maximum(x, 1e-300))
        return np.clip((e - self.lo) / max(self.size, 1e-12), 0.0, 1.0)

    def ppf(self, q):
        return np.power(10.0, self.lo + np.asarray(q) * self.size)


def _is_distribution(v: Any) -> bool:
    return hasattr(v, "rvs")


class _Param:
    kind: str  # "dist" | "range" | "cat" | "const"

    def __init__(self, name: str, v: Any):
        self.name = name
        if _is_distribution(v):
            self.kind = "dist"
            self.dist = v
            self.dims = 1
            self._ecdf_ref = None   # lazy, for sampling-only distributions
            # Frozen scipy uniform gets a closed-form columnar fast path:
            # rvs == rng.uniform(n)*scale + loc and cdf == (x-loc)/scale
            # bitwise (scipy evaluates exactly these expressions), so the
            # bank's 10^4-10^5-candidate draws skip scipy's per-call arg
            # machinery without perturbing the RNG stream or the encoding.
            self._uniform_ls = None
            # loguniform (scipy name "reciprocal") gets the same treatment:
            # it defines no custom _rvs, so scipy draws it as
            # _ppf(rng.uniform(n)) = exp(log a + u*(log b - log a)), and
            # cdf is (log x - log a)/(log b - log a) — both reproduced here
            # expression-for-expression so values AND the RNG stream stay
            # bitwise identical to the scipy path.
            self._loguniform_abls = None
            try:
                dname = getattr(getattr(v, "dist", None), "name", "")
                if dname == "uniform":
                    _, loc, scale = v.dist._parse_args(*v.args, **v.kwds)
                    self._uniform_ls = (float(loc), float(scale))
                elif dname in ("loguniform", "reciprocal"):
                    (a, b), loc, scale = v.dist._parse_args(*v.args,
                                                            **v.kwds)
                    self._loguniform_abls = (float(a), float(b),
                                             float(loc), float(scale))
            except Exception:
                self._uniform_ls = None
                self._loguniform_abls = None
        elif isinstance(v, range):
            self.kind = "range"
            self.choices = np.array(list(v))
            if len(self.choices) == 0:
                raise ValueError(f"{name}: empty range")
            self.dims = 1
        elif isinstance(v, (list, tuple, np.ndarray)):
            self.kind = "cat"
            self.choices = list(v)
            if len(self.choices) == 0:
                raise ValueError(f"{name}: empty categorical list")
            # numeric lists are ordinal (single dim); strings are one-hot
            self.numeric = all(isinstance(c, (int, float, np.number))
                               for c in self.choices)
            self.dims = 1 if self.numeric else len(self.choices)
        else:
            self.kind = "const"
            self.value = v
            self.dims = 0

    # ---- sampling (native distribution; always-valid configs) -------------
    def sample(self, n: int, rng: np.random.Generator) -> List[Any]:
        if self.kind == "dist":
            out = np.asarray(self.dist.rvs(size=n, random_state=rng))
            return list(out)
        if self.kind == "range":
            return list(rng.choice(self.choices, size=n))
        if self.kind == "cat":
            idx = rng.integers(0, len(self.choices), size=n)
            return [self.choices[i] for i in idx]
        return [self.value] * n

    def sample_array(self, n: int, rng: np.random.Generator):
        """Columnar ``sample``: same RNG stream, but numeric kinds return the
        ndarray itself instead of a list of Python scalars (the list round
        trip dominates host time at bank scale: B*mc rows per ask)."""
        if self.kind == "dist":
            if self._uniform_ls is not None:
                loc, scale = self._uniform_ls
                return rng.uniform(size=n) * scale + loc
            if self._loguniform_abls is not None:
                a, b, loc, scale = self._loguniform_abls
                u = rng.uniform(size=n)
                return np.exp(np.log(a)
                              + u * (np.log(b) - np.log(a))) * scale + loc
            return np.asarray(self.dist.rvs(size=n, random_state=rng))
        if self.kind == "range":
            return rng.choice(self.choices, size=n)
        return self.sample(n, rng)   # cat / const stay object lists

    def _ecdf(self) -> np.ndarray:
        """Persistent empirical CDF for sampling-only distributions.

        Fitted once from a dedicated fixed-seed draw (not the tuner's RNG
        stream), so the same value encodes identically in every batch and
        across checkpoint/resume — a per-batch min-max fallback would map
        the same config to different GP inputs depending on its batchmates,
        corrupting the surrogate.
        """
        if self._ecdf_ref is None:
            draw = np.asarray(self.dist.rvs(
                size=2048, random_state=np.random.default_rng(0xEC0F)),
                dtype=float)
            self._ecdf_ref = np.sort(draw.reshape(-1))
        return self._ecdf_ref

    # ---- unit-cube encoding ------------------------------------------------
    def encode(self, values: Sequence[Any]) -> np.ndarray:
        n = len(values)
        if self.kind == "dist":
            v = np.asarray(values, dtype=float)
            if self._uniform_ls is not None:
                loc, scale = self._uniform_ls
                enc = np.nan_to_num(np.clip((v - loc) / scale, 0.0, 1.0),
                                    nan=0.5)
                return enc.reshape(n, 1)
            if self._loguniform_abls is not None:
                a, b, loc, scale = self._loguniform_abls
                with np.errstate(all="ignore"):
                    q = ((np.log((v - loc) / scale) - np.log(a))
                         / (np.log(b) - np.log(a)))
                    enc = np.nan_to_num(np.clip(q, 0.0, 1.0), nan=0.5)
                return enc.reshape(n, 1)
            if hasattr(self.dist, "cdf"):
                with np.errstate(all="ignore"):
                    enc = np.nan_to_num(
                        np.asarray(self.dist.cdf(v), dtype=float), nan=0.5)
            else:  # sampling-only distribution: persistent empirical CDF
                ref = self._ecdf()
                enc = np.interp(v, ref, np.linspace(0.0, 1.0, len(ref)))
            return enc.reshape(n, 1)
        if self.kind == "range":
            lo, hi = self.choices[0], self.choices[-1]
            v = np.asarray(values, dtype=float)
            return ((v - lo) / max(hi - lo, 1)).reshape(n, 1)
        if self.kind == "cat":
            if self.numeric:
                arr = np.asarray(self.choices, dtype=float)
                lo, hi = arr.min(), arr.max()
                v = np.asarray(values, dtype=float)
                return ((v - lo) / max(hi - lo, 1e-12)).reshape(n, 1)
            onehot = np.zeros((n, len(self.choices)))
            index = {c: i for i, c in enumerate(self.choices)}
            for r, val in enumerate(values):
                onehot[r, index[val]] = 1.0
            return onehot
        return np.zeros((n, 0))

    @property
    def cardinality(self) -> float:
        if self.kind == "dist":
            return 100.0  # continuous: effective resolution heuristic
        if self.kind in ("range", "cat"):
            return float(len(self.choices))
        return 1.0


class ParamSpace:
    def __init__(self, space: Dict[str, Any]):
        if not isinstance(space, dict) or not space:
            raise ValueError("param space must be a non-empty dict")
        self.params = [_Param(k, v) for k, v in space.items()]
        self.names = [p.name for p in self.params]
        self.dim = sum(p.dims for p in self.params)

    def sample(self, n: int, rng: np.random.Generator) -> List[Dict]:
        cols = {p.name: p.sample(n, rng) for p in self.params}
        return [{k: cols[k][i] for k in cols} for i in range(n)]

    # ---- columnar sampling (StudyBank's batched-candidate fast path) ----
    # Draws the *same* RNG stream as ``sample(n, rng)`` (one per-param draw
    # each, in declaration order) but skips materializing n row dicts, so a
    # bank ask can sample B*n_mc candidates and encode them in one pass;
    # only the few winning rows ever become config dicts (``config_at``).
    def sample_columns(self, n: int,
                       rng: np.random.Generator) -> Dict[str, Any]:
        return {p.name: p.sample_array(n, rng) for p in self.params}

    def encode_columns(self, cols: Dict[str, List[Any]],
                       n: int) -> np.ndarray:
        blocks = [p.encode(cols[p.name]) for p in self.params if p.dims]
        return (np.concatenate(blocks, axis=1) if blocks
                else np.zeros((n, 0)))

    def config_at(self, cols: Dict[str, Any], i: int) -> Dict:
        # .item() unwraps ndarray columns to Python scalars so trial params
        # stay JSON-serializable (state_dict carries them verbatim)
        return {p.name: (cols[p.name][i].item()
                         if isinstance(cols[p.name], np.ndarray)
                         else cols[p.name][i])
                for p in self.params}

    def configs_at(self, cols: Dict[str, Any], idx) -> List[Dict]:
        """Batched ``config_at``: one fancy-index + ``tolist`` per column
        instead of a per-row dictcomp with per-scalar ``.item()`` calls
        (the bank materializes B*n winner configs per ask)."""
        idx = np.asarray(idx, dtype=np.int64)
        names = [p.name for p in self.params]
        pulled = []
        for p in self.params:
            c = cols[p.name]
            if isinstance(c, np.ndarray):
                pulled.append(c[idx].tolist())   # tolist -> Python scalars
            else:
                pulled.append([c[i] for i in idx])
        return [dict(zip(names, row)) for row in zip(*pulled)]

    def encode(self, configs: List[Dict]) -> np.ndarray:
        if not configs:
            return np.zeros((0, self.dim))
        blocks = [p.encode([c[p.name] for c in configs]) for p in self.params
                  if p.dims]
        return np.concatenate(blocks, axis=1) if blocks else np.zeros(
            (len(configs), 0))

    @property
    def domain_size(self) -> float:
        s = 1.0
        for p in self.params:
            s *= p.cardinality
        return min(s, 1e12)

    def mc_samples(self, batch_size: int = 1) -> int:
        """Paper §2.3: sample count scales with #params / space complexity."""
        base = 1000 * max(self.dim, 1) + 200 * int(math.log10(
            self.domain_size + 1))
        return int(np.clip(base * max(1, batch_size // 2), 2000, 32768))
