"""Parallel batch-selection strategies (paper §2.3).

  * ``bayesian`` (default): the fused GP-BUCB path — one jit'd device
    program per batch (``gp.fused_propose``) on top of incremental O(n^2)
    Cholesky observation appends.
  * ``hallucination_ref``: Batched GP Bandits / GP-BUCB (Desautels et al.
    2014) as a numpy-facing Python loop — sequentially pick argmax UCB, then
    *hallucinate* the observation at the posterior mean so the variance
    contracts and the next pick explores a different region.  Kept as the
    reference implementation the fused path is tested against.
  * ``clustering``: (Groves & Pyzer-Knapp 2018) — compute the acquisition
    surface on the MC candidates, keep the top quantile, k-means it into
    ``batch_size`` spatially distinct clusters, return each cluster's argmax.
  * ``random``: batch of valid random samples (the paper's third optimizer).

All strategies consume an *encoded* candidate matrix sampled from the native
parameter distributions, so every proposed configuration is valid (discrete /
categorical parameters included).
"""
from __future__ import annotations

import warnings
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import gp as gp_lib
from repro.core import scoring
from repro.core.acquisition import adaptive_beta, ucb
from repro.core.gp import GaussianProcess
from repro.core.kmeans import kmeans_assign

SCORERS = ("chol", "kinv_jnp", "kinv_pallas")


def n_top_candidates(S: int, batch_size: int, top_frac: float) -> int:
    """Top-quantile size for the clustering pipeline.  Module-level so the
    StudyBank's batched clustering ask computes the exact same (static)
    ``n_top`` as the single-study strategy."""
    return min(max(batch_size * 4, int(S * top_frac)), S)


class BaseStrategy:
    """A strategy consumes encoded observations + candidates and returns
    pick indices.  ``propose`` additionally accepts ``pending`` — the
    encoded configurations of trials currently in flight (the ask/tell
    core's ledger) — which GP strategies hallucinate (GP-BUCB semantics:
    variance contraction, no mean update) before picking.

    ``scorer`` selects the GP scoring backend:

      * ``"chol"`` (default) — the L-based fused path (``gp.fused_propose``),
      * ``"kinv_pallas"`` — the shared conditioning-hardened factor core
        through the ``gp_acquisition`` Pallas kernels (what
        ``use_pallas=True`` resolves to),
      * ``"kinv_jnp"`` — the same core executed as the kernels' jnp oracle
        twin (the parity path the 3-way near-tie tests drive).

    Every propose through a fitted GP also stages ``last_cond_proxy`` — a
    host-visible condition-number estimate for K (power iteration on
    K and K^{-1} through the Cholesky factor, ``scoring.cond_estimate``;
    typically within ~2x of ``numpy.linalg.cond``, where the old
    Cholesky-diagonal bound sat 20-50x low), computed lazily on access
    (reading it costs one small device program + sync; not reading it
    costs nothing); above ``scoring.COND_PROXY_WARN`` a one-time warning
    fires on access (float32 posterior scoring is presumed unreliable
    there).
    """

    needs_gp = True

    def __init__(self, dim: int, domain_size: float, fit_steps: int = 40,
                 use_pallas: bool = False, pallas_interpret: bool = True,
                 refit_every: int = 8, scorer: Optional[str] = None):
        self._scorer_explicit = scorer is not None
        if scorer is None:
            scorer = "kinv_pallas" if use_pallas else "chol"
        elif scorer not in SCORERS:
            raise ValueError(f"unknown scorer {scorer!r}; "
                             f"choose from {SCORERS}")
        elif use_pallas and scorer != "kinv_pallas":
            # contradictory request: raise like every other invalid config
            # instead of silently dropping one of the two flags
            raise ValueError(f"use_pallas=True conflicts with "
                             f"scorer={scorer!r} (the Pallas kernels are "
                             f"scorer='kinv_pallas')")
        self.scorer = scorer
        self.use_pallas = scorer == "kinv_pallas"
        self.gp = GaussianProcess(dim, fit_steps=fit_steps,
                                  refit_every=refit_every,
                                  track_factor=scorer != "chol")
        self.domain_size = domain_size
        self.pallas_interpret = pallas_interpret
        self._cond_src = None
        self._cond_warned = False

    def _update_cond_proxy(self, st, na: Optional[int] = None) -> None:
        """Stage the conditioning diagnostic for the active window (the
        proxy itself is computed lazily on ``last_cond_proxy`` access, so
        an ask that never reads it pays no extra device dispatch or host
        sync — the one-device-program-per-ask contract holds)."""
        self._cond_src = (st.L, st.mask, na)

    @property
    def last_cond_proxy(self) -> Optional[float]:
        """Condition-number estimate for the last propose's active kernel
        window (None before the first GP-backed propose)."""
        if self._cond_src is None:
            return None
        L, m, na = self._cond_src
        if na is not None:
            L, m = L[:na, :na], m[:na]
        val = float(scoring.cond_estimate(L, jnp.asarray(m)))
        if val > scoring.COND_PROXY_WARN and not self._cond_warned:
            self._cond_warned = True
            warnings.warn(
                f"GP kernel condition estimate {val:.2e} exceeds "
                f"{scoring.COND_PROXY_WARN:.0e}: float32 posterior scores "
                "may be unreliable (consider a larger noise floor, or "
                "enabling x64 for float64 Schur accumulation)",
                RuntimeWarning, stacklevel=2)
        return val

    def _predict(self, st, C: np.ndarray):
        if self.use_pallas:
            from repro.kernels.gp_acquisition import ops as gp_ops
            return gp_ops.gp_mean_std(st, C,
                                      interpret=self.pallas_interpret)
        return self.gp.predict(C, st)

    def _absorb_pending(self, st, pending):
        """Host-loop fallback: hallucinate in-flight rows one by one."""
        st = self.gp.ensure_capacity(st, len(pending))
        for p in np.asarray(pending, dtype=np.float32):
            st = self.gp.hallucinate(st, p)
        return st

    def propose(self, X: np.ndarray, y: np.ndarray, candidates: np.ndarray,
                batch_size: int, seed: int = 0,
                pending: Optional[np.ndarray] = None) -> List[int]:
        raise NotImplementedError


class HallucinationStrategy(BaseStrategy):
    def propose(self, X, y, candidates, batch_size, seed=0, pending=None):
        st = self.gp.fit(X, y)
        n_pend = 0 if pending is None else len(pending)
        if n_pend:
            st = self._absorb_pending(st, pending)
        n_evals = len(y) + n_pend
        picked: List[int] = []
        avail = np.ones(len(candidates), dtype=bool)
        for b in range(batch_size):
            mu, sd = self._predict(st, candidates)
            beta = adaptive_beta(n_evals, self.domain_size, batch_index=b)
            acq = ucb(mu, sd, beta)
            acq[~avail] = -np.inf
            idx = int(np.argmax(acq))
            picked.append(idx)
            avail[idx] = False
            if b + 1 < batch_size:
                st = self.gp.hallucinate(st, candidates[idx])
        return picked


class FusedHallucinationStrategy(BaseStrategy):
    """GP-BUCB on the fused device-resident hot path (the default).

    Observations are absorbed incrementally (O(n^2) Cholesky appends, full
    hyperparameter refit every ``refit_every`` new points) and the whole
    batch loop runs as a single jit'd ``lax.fori_loop`` — picks identical
    candidate indices to ``HallucinationStrategy`` on fixed seeds.
    """

    def propose(self, X, y, candidates, batch_size, seed=0, pending=None):
        n_pend = 0 if pending is None else len(pending)
        st = self.gp.observe(X, y)
        st = self.gp.ensure_capacity(st, batch_size + n_pend)
        return self.pick_from_state(st, candidates, batch_size,
                                    pending=pending)

    def pick_from_state(self, st, candidates, batch_size, pending=None):
        """Window + dispatch the fused program against an explicit state.

        ``pending`` (encoded in-flight rows) rides along into the device
        program: ``fused_propose_pending`` (or, on the factor-core scorer
        paths, ``fused_propose_pallas_pending`` with the shared hardened
        ``scoring.absorb_pending`` loop) hallucinates them inside the jit'd
        fori_loop, so an async replacement pick is exactly one GP program
        dispatch on *every* path.
        """
        n_pend = 0 if pending is None else len(pending)
        # active window: a 64-multiple slice covering n + pending +
        # batch_size rows.  The leading principal block of L is the Cholesky
        # of the leading block of K (and of L^{-1} the inverse of that
        # block), so slicing is exact — it just avoids paying the
        # power-of-two padded size (up to 2n) in the O(n^2 S) posterior.
        n_pad = st.X.shape[0]
        na = min(n_pad, max(16,
                            -(-(st.n + n_pend + batch_size) // 64) * 64))
        self._update_cond_proxy(st, na)
        C = jnp.asarray(np.ascontiguousarray(candidates, dtype=np.float32))
        args = (jnp.asarray(st.X[:na]), jnp.asarray(st.y[:na]),
                jnp.asarray(st.mask[:na]))
        tail = (C, st.ls, st.var, st.noise, jnp.int32(st.n),
                jnp.float32(self.domain_size))
        if n_pend:
            # pad the pending buffer to a small static cap so the jit cache
            # sees a handful of shapes, not one per in-flight count
            cap = -(-n_pend // 4) * 4
            P = np.zeros((cap, st.X.shape[1]), np.float32)
            P[:n_pend] = np.asarray(pending, dtype=np.float32)
        if self.scorer != "chol" and n_pend:
            picks = gp_lib.fused_propose_pallas_pending(
                *args, st.L[:na, :na], st.Linv[:na, :na],
                jnp.asarray(P), jnp.int32(n_pend), *tail,
                batch_size=batch_size, pend_cap=cap,
                interpret=self.pallas_interpret,
                use_pallas=self.use_pallas)
        elif self.scorer != "chol":
            picks = gp_lib.fused_propose_pallas(
                *args, st.L[:na, :na], st.Linv[:na, :na], *tail,
                batch_size=batch_size, interpret=self.pallas_interpret,
                use_pallas=self.use_pallas)
        elif n_pend:
            picks = gp_lib.fused_propose_pending(
                args[0], args[1], args[2], st.L[:na, :na],
                jnp.asarray(P), jnp.int32(n_pend), *tail,
                batch_size=batch_size, pend_cap=cap)
        else:
            picks = gp_lib.fused_propose(*args, st.L[:na, :na], *tail,
                                         batch_size=batch_size)
        return [int(i) for i in np.asarray(picks)]


class ClusteringStrategy(BaseStrategy):
    """Groves & Pyzer-Knapp 2018 batch selection, fully on-device.

    ``propose`` dispatches ``acquisition.fused_cluster_propose`` — pending
    absorb, posterior + UCB, ``lax.top_k``, weighted k-means, and the
    per-cluster argmax all run inside one jit'd program; the (n_mc,)
    acquisition surface never reaches the host.  Scoring and pending
    absorption go through the shared conditioning-hardened factor core
    (``core.scoring``) — the same backend as the fused GP-BUCB path, with
    ``use_pallas`` selecting the ``gp_acquisition`` kernels and the default
    running their jnp twin.  ``propose_host`` keeps the numpy pipeline as
    the parity reference (with the empty-cluster backfill fixed to never
    re-select an already-picked index).
    """

    def __init__(self, *args, top_frac: float = 0.2, **kwargs):
        super().__init__(*args, **kwargs)
        if self.scorer == "chol":
            if self._scorer_explicit:
                # an explicitly requested L-path scorer cannot be honored:
                # raise instead of silently substituting a backend
                raise ValueError(
                    "ClusteringStrategy scores through the shared factor "
                    "core; scorer must be 'kinv_jnp' or 'kinv_pallas'")
            # default: the shared factor core's jnp backend — the L-based
            # posterior clustering used before ISSUE 5 was a second,
            # divergent scoring backend
            self.scorer = "kinv_jnp"
            self.gp.track_factor = True
        self.top_frac = top_frac

    def _n_top(self, S: int, batch_size: int) -> int:
        return n_top_candidates(S, batch_size, self.top_frac)

    def propose(self, X, y, candidates, batch_size, seed=0, pending=None):
        import jax

        from repro.core.acquisition import fused_cluster_propose

        S = len(candidates)
        batch_size = min(batch_size, S)
        st = self.gp.observe(X, y)
        n_pend = 0 if pending is None else len(pending)
        st = self.gp.ensure_capacity(st, n_pend)
        # pad the pending buffer to a small static cap (>= 4 so the no-
        # pending trace never indexes an empty buffer)
        cap = max(4, -(-n_pend // 4) * 4)
        P = np.zeros((cap, st.X.shape[1]), np.float32)
        if n_pend:
            P[:n_pend] = np.asarray(pending, dtype=np.float32)
        n_pad = st.X.shape[0]
        na = min(n_pad, max(16, -(-(st.n + n_pend) // 64) * 64))
        self._update_cond_proxy(st, na)
        picks = fused_cluster_propose(
            jnp.asarray(st.X[:na]), jnp.asarray(st.y[:na]),
            jnp.asarray(st.mask[:na]), st.L[:na, :na], st.Linv[:na, :na],
            jnp.asarray(P), jnp.int32(n_pend),
            jnp.asarray(np.ascontiguousarray(candidates, dtype=np.float32)),
            st.ls, st.var, st.noise, jnp.int32(st.n),
            jnp.float32(self.domain_size), jax.random.PRNGKey(seed),
            batch_size=batch_size, n_top=self._n_top(S, batch_size),
            pend_cap=cap, use_pallas=self.use_pallas,
            interpret=self.pallas_interpret)
        return [int(i) for i in np.asarray(picks)]

    def propose_host(self, X, y, candidates, batch_size, seed=0,
                     pending=None):
        """Numpy reference pipeline (the parity oracle for the device
        program): standardized acquisition surface, descending-sorted top
        slice, host k-means, per-cluster argmax excluding prior picks."""
        import jax

        batch_size = min(batch_size, len(candidates))
        st = self.gp.observe(X, y)
        n_pend = 0 if pending is None else len(pending)
        if n_pend:
            st = self._absorb_pending(st, pending)
        mu, var_s = gp_lib.posterior(
            jnp.asarray(st.X), jnp.asarray(st.y), jnp.asarray(st.mask),
            st.L, jnp.asarray(candidates, dtype=jnp.float32),
            st.ls, st.var, st.noise)
        mu, sd = np.asarray(mu), np.sqrt(np.asarray(var_s))
        beta = adaptive_beta(len(y) + n_pend, self.domain_size)
        acq = ucb(mu, sd, beta)
        if batch_size == 1:
            return [int(np.argmax(acq))]
        n_top = self._n_top(len(candidates), batch_size)
        top = np.argsort(-acq, kind="stable")[:n_top]
        w = acq[top] - acq[top].min() + 1e-6
        assign = kmeans_assign(candidates[top], w, batch_size, seed=seed)
        picked: List[int] = []
        for c in range(batch_size):
            members = top[assign == c]
            members = members[~np.isin(members, picked)]
            if len(members) == 0:   # empty cluster: back-fill from the
                members = top[~np.isin(top, picked)]   # unpicked remainder
            if len(members) == 0:
                break
            picked.append(int(members[np.argmax(acq[members])]))
        return picked


class RandomStrategy(BaseStrategy):
    needs_gp = False

    def __init__(self, dim: int = 0, domain_size: float = 1.0, **kwargs):
        pass

    def propose(self, X, y, candidates, batch_size, seed=0, pending=None):
        rng = np.random.default_rng(seed)
        # clamp: a small mc_samples override can leave fewer candidates
        # than batch slots — return what exists instead of raising
        return list(rng.choice(len(candidates),
                               size=min(batch_size, len(candidates)),
                               replace=False))


STRATEGIES = {
    "bayesian": FusedHallucinationStrategy,     # mango's default name
    "hallucination": FusedHallucinationStrategy,
    "hallucination_ref": HallucinationStrategy,  # numpy reference path
    "clustering": ClusteringStrategy,
    "random": RandomStrategy,
}
