"""Tree-structured Parzen Estimator baseline (the Hyperopt algorithm).

The paper's evaluation compares Mango against Hyperopt; hyperopt is not
installable offline, so we reimplement its TPE core faithfully enough for
the comparison:

  * split observations into good/bad by the gamma-quantile of y,
  * model each encoded dimension with 1D Parzen windows (Gaussian KDE with
    Scott bandwidth; categoricals are one-hot-encoded so the same KDE works
    as a smoothed frequency estimate),
  * score candidates by l(x)/g(x) (expected-improvement surrogate) and take
    the top of the Monte-Carlo candidate set,
  * parallel batches take the top-b scores (Hyperopt's naive parallelism —
    no information-gain machinery, which is exactly the gap Mango's
    hallucination/clustering strategies target).

Registered as ``optimizer="tpe"`` so every Tuner feature (schedulers, fault
tolerance, checkpointing) applies to the baseline too.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.strategies import STRATEGIES, BaseStrategy


class TPEStrategy(BaseStrategy):
    needs_gp = True  # needs observations (not an actual GP)

    def __init__(self, dim: int, domain_size: float, gamma: float = 0.25,
                 **kwargs):
        self.dim = dim
        self.gamma = gamma

    @staticmethod
    def _log_kde(pts: np.ndarray, x: np.ndarray) -> np.ndarray:
        """1D-product Parzen log-density of x (m, d) under pts (n, d)."""
        n = max(len(pts), 1)
        bw = max(n ** (-1.0 / (pts.shape[1] + 4)), 1e-2) * 0.5 + 1e-3
        # (m, n, d) distances -> product over d of mean-over-n kernels
        d2 = (x[:, None, :] - pts[None, :, :]) ** 2
        k = np.exp(-0.5 * d2 / bw ** 2)  # (m, n, d)
        dens = k.mean(axis=1) + 1e-12    # (m, d)
        return np.log(dens).sum(axis=1)

    def propose(self, X, y, candidates, batch_size, seed=0,
                pending=None) -> List[int]:
        # TPE has no variance machinery to contract; pending trials are
        # ignored (Hyperopt's naive parallelism, as documented above)
        y = np.asarray(y, dtype=float)
        n = len(y)
        n_good = max(1, int(np.ceil(self.gamma * n)))
        order = np.argsort(-y)  # maximization
        good = np.asarray(X)[order[:n_good]]
        bad = np.asarray(X)[order[n_good:]]
        if len(bad) == 0:
            bad = good
        score = self._log_kde(good, candidates) - self._log_kde(bad,
                                                                candidates)
        top = np.argsort(-score)[:batch_size]
        return [int(i) for i in top]


STRATEGIES["tpe"] = TPEStrategy
