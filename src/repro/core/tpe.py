"""Tree-structured Parzen Estimator baseline (the Hyperopt algorithm),
device-resident.

The paper's evaluation compares Mango against Hyperopt; hyperopt is not
installable offline, so we reimplement its TPE core faithfully enough for
the comparison:

  * split observations into good/bad by the gamma-quantile of y,
  * model each encoded dimension with 1D Parzen windows (Gaussian KDE with
    a per-dimension bandwidth: Scott base scaled by each dim's split
    spread, so one-hot-encoded categoricals — whose 0/1 support a d-global
    rule oversmooths — act as a sharper smoothed frequency estimate),
  * score candidates by l(x)/g(x) (expected-improvement surrogate) and take
    the top of the Monte-Carlo candidate set,
  * parallel batches take the top-b scores (Hyperopt's naive parallelism —
    no information-gain machinery, which is exactly the gap Mango's
    hallucination/clustering strategies target).

As of ISSUE 4 the whole proposal is ONE jit'd device program per ask
(``fused_tpe_propose``, mirroring ``gp.fused_propose_pallas_pending``): the
good/bad split runs as masked ranks over the padded observation buffer, the
O(m n d) product-Parzen scorer is either the pure-jnp oracle or the
``kernels/tpe_kde`` Pallas kernel (``use_pallas=True``), and the batch is
selected with ``lax.top_k`` — only the (batch_size,) pick indices leave the
device.  The seed numpy loop is kept as ``propose_host``, the parity oracle.

Pending trials: Hyperopt's parallelism is *naive* — in-flight trials are
ignored, so an async replacement pick degenerates to re-proposing the
current top-b.  ``pending_penalty=True`` (opt-in, off by default to keep
baseline semantics) hallucinates the in-flight configurations into the
*bad*-split KDE ("pessimistic liar"): g(x) rises around pending points, so
replacement picks steer away from duplicating work already in flight.  The
absorb is just one extra membership mask over the same buffer — still one
device program per ask, no matter how many trials are outstanding.

Registered as ``optimizer="tpe"`` so every Tuner feature (schedulers, fault
tolerance, checkpointing) applies to the baseline too.  ``propose`` is
stateless — it never mutates strategy or shared buffers, so concurrent
drivers can share one instance.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import STRATEGIES, BaseStrategy
from repro.kernels.tpe_kde.ops import pad_dims, pad_rows
from repro.kernels.tpe_kde.ref import scott_bandwidth, tpe_scores_ref
from repro.kernels.tpe_kde.tpe_kde import tpe_scores_pallas


@functools.partial(jax.jit, static_argnames=(
    "batch_size", "d_true", "use_pallas", "interpret", "block_s"))
def fused_tpe_propose(X, y, C, meta, *, batch_size: int, d_true: int,
                      use_pallas: bool = False, interpret: bool = True,
                      block_s: int = 256):
    """One device program per ask: split -> l/g scoring -> ``lax.top_k``.

    X (na, dp) is the padded buffer of observed rows followed by pending
    rows (the penalty's in-flight set, empty unless enabled) and zero
    padding, in that order; y (na,) carries the observed objective values.
    C (Sp, dp) are the padded Monte-Carlo candidates.  ``meta`` packs the
    four scalars [n_obs, n_pend, n_cand, gamma] as one f32 row — one
    host->device transfer instead of six; every row mask is derived from it
    in-program.  Returns the (batch_size,) pick indices.
    """
    n_obs = meta[0].astype(jnp.int32)
    n_pend = meta[1].astype(jnp.int32)
    n_cand = meta[2].astype(jnp.int32)
    gamma = meta[3]
    row = jnp.arange(X.shape[0], dtype=jnp.int32)
    is_obs = row < n_obs
    pend_mask = ((row >= n_obs) & (row < n_obs + n_pend)) \
        .astype(jnp.float32)
    # rank observed rows best-first (stable, like the host argsort)
    neg = jnp.where(is_obs, -y, jnp.inf)
    order = jnp.argsort(neg)
    rank = jnp.zeros_like(row).at[order].set(row)
    # split count in float32 on BOTH paths so ceil ties can't flip vs host
    n_good = jnp.maximum(
        1, jnp.ceil(gamma * n_obs.astype(jnp.float32))).astype(jnp.int32)
    good = (rank < n_good) & is_obs
    wg = good.astype(jnp.float32)
    wb_obs = ((rank >= n_good) & is_obs).astype(jnp.float32)
    wb_obs = jnp.where(n_obs > n_good, wb_obs, wg)   # empty bad -> good
    wb = jnp.minimum(wb_obs + pend_mask, 1.0)        # pessimistic liar
    ng = jnp.sum(wg)
    nb = jnp.sum(wb)
    # per-DIM bandwidths: Scott base scaled by each split's per-dim spread
    # (clipped 2*std), so low-variance dims — categorical one-hot columns
    # especially — get a sharper kernel than the d-global rule's
    Xd = X[:, :d_true]
    mg = (wg @ Xd) / jnp.maximum(ng, 1.0)                     # (d,)
    vg = (wg @ (Xd - mg) ** 2) / jnp.maximum(ng, 1.0)
    mb = (wb @ Xd) / jnp.maximum(nb, 1.0)
    vb = (wb @ (Xd - mb) ** 2) / jnp.maximum(nb, 1.0)
    bw_g = scott_bandwidth(ng, d_true) \
        * jnp.clip(2.0 * jnp.sqrt(vg), 0.1, 1.0)             # (d,)
    bw_b = scott_bandwidth(nb, d_true) \
        * jnp.clip(2.0 * jnp.sqrt(vb), 0.1, 1.0)
    # per-row per-dim bandwidth scale: gamma <= 0.5 keeps the splits
    # disjoint, so each row carries its own split's 1/(2 bw_j^2) vector and
    # one exp per (candidate, row, dim) feeds both densities
    a = jnp.zeros(X.shape, jnp.float32).at[:, :d_true].set(
        jnp.where(good[:, None], (0.5 / (bw_g * bw_g))[None, :],
                  (0.5 / (bw_b * bw_b))[None, :]))
    scal = jnp.stack([1.0 / ng, 1.0 / nb, jnp.float32(0.0),
                      jnp.float32(0.0)])[None, :]
    if use_pallas:
        score = tpe_scores_pallas(C, X, a, wg, wb, scal, d_true=d_true,
                                  block_s=block_s, interpret=interpret)
    else:
        score = tpe_scores_ref(C, X, a, wg, wb, scal, d_true=d_true)
    score = jnp.where(jnp.arange(C.shape[0]) < n_cand, score, -jnp.inf)
    _, idx = jax.lax.top_k(score, batch_size)
    return idx


@functools.partial(jax.jit, static_argnames=(
    "batch_size", "d_true", "use_pallas", "interpret", "block_s"))
def fused_tpe_propose_bank(X, y, C, meta, *, batch_size: int, d_true: int,
                           use_pallas: bool = False, interpret: bool = True,
                           block_s: int = 256):
    """``fused_tpe_propose`` vmapped over a leading study axis (the
    StudyBank ask path): X (B, na, dp), y (B, na), C (B, Sp, dp) and one
    packed meta row per study.  The per-study masked ranks come from
    ``meta``, so the whole bank shares one bucketed program regardless of
    how many observations each study holds.  Returns (B, batch_size) pick
    indices."""
    one = functools.partial(fused_tpe_propose, batch_size=batch_size,
                            d_true=d_true, use_pallas=use_pallas,
                            interpret=interpret, block_s=block_s)
    return jax.vmap(one)(X, y, C, meta)


class TPEStrategy(BaseStrategy):
    needs_gp = True  # needs observations (not an actual GP)

    def __init__(self, dim: int, domain_size: float, gamma: float = 0.25,
                 pending_penalty: bool = False, fit_steps: int = 40,
                 use_pallas: bool = False, pallas_interpret: bool = True,
                 refit_every: int = 8):
        # fit_steps/refit_every belong to the standard strategy-constructor
        # contract; TPE has no GP to apply them to, so they are accepted and
        # unused.  Anything else is a typo -> TypeError, like the other
        # strategies.
        if dim < 1:
            raise ValueError(f"TPE needs dim >= 1, got {dim}")
        # gamma is the GOOD quantile; > 0.5 would make the "good" model the
        # majority (nonsensical for TPE) and is what lets the fused program
        # score both splits with one exp per row (disjoint splits)
        if not 0.0 < gamma <= 0.5:
            raise ValueError(f"gamma must be in (0, 0.5], got {gamma}")
        if not domain_size > 0:
            raise ValueError(f"domain_size must be > 0, got {domain_size}")
        self.dim = int(dim)
        self.domain_size = float(domain_size)
        self.gamma = float(gamma)
        self.pending_penalty = bool(pending_penalty)
        self.use_pallas = bool(use_pallas)
        self.pallas_interpret = bool(pallas_interpret)

    # ------------------------------------------------------------ host oracle
    def _split_count(self, n: int) -> int:
        """Good-split size, computed in float32 like the device program."""
        return max(1, int(np.ceil(np.float32(self.gamma) * np.float32(n))))

    @staticmethod
    def _scott_bw(n_pts: int, d: int) -> np.float32:
        """Scott-rule base bandwidth, computed in float32 like the device."""
        return max(np.float32(max(n_pts, 1)) ** np.float32(-1.0 / (d + 4)),
                   np.float32(1e-2)) * np.float32(0.5) + np.float32(1e-3)

    @staticmethod
    def _dim_scale(pts: np.ndarray) -> np.ndarray:
        """Per-dim bandwidth scale clip(2*std_j, 0.1, 1.0) in f32 — the
        host twin of the device's masked-moment computation."""
        p = np.asarray(pts, np.float32)
        n = np.float32(max(len(p), 1))
        mean = p.sum(axis=0, dtype=np.float32) / n
        var = ((p - mean) ** 2).sum(axis=0, dtype=np.float32) / n
        return np.clip(np.float32(2.0) * np.sqrt(var),
                       np.float32(0.1), np.float32(1.0))

    @staticmethod
    def _kde_sum(pts: np.ndarray, x: np.ndarray, bw) -> np.ndarray:
        """(m, d) per-dim SUM of Gaussian Parzen kernels of x under pts."""
        inv2bw2 = np.float32(0.5) / np.float32(bw * bw)
        d2 = (x[:, None, :] - pts[None, :, :]) ** 2     # (m, n, d)
        return np.exp(-d2 * inv2bw2).sum(axis=1)

    @classmethod
    def _log_kde(cls, pts: np.ndarray, x: np.ndarray) -> np.ndarray:
        """1D-product Parzen log-density of x (m, d) under pts (n, d)."""
        n = max(len(pts), 1)
        dens = cls._kde_sum(pts, x, cls._scott_bw(n, pts.shape[1])) / n
        return np.log(dens + 1e-12).sum(axis=1)

    def propose_host(self, X, y, candidates, batch_size, seed=0,
                     pending=None) -> List[int]:
        """The seed numpy pipeline, kept as the parity oracle for the fused
        device program (same split, per-split bandwidths, tie-breaking).

        Pending rows (when the penalty is on) join the bad mixture at the
        bad split's bandwidth.  In the degenerate empty-bad case — only
        reachable with a single observation, the optimizer never asks with
        fewer than two — the good rows stand in for the bad split at their
        own bandwidth (exactly the device program's per-row-scale
        semantics)."""
        y = np.asarray(y, dtype=float)
        n = len(y)
        d = np.asarray(X).shape[1]
        n_good = self._split_count(n)
        order = np.argsort(-y, kind="stable")  # maximization
        Xa = np.asarray(X)
        good = Xa[order[:n_good]]
        bad = Xa[order[n_good:]]
        pend = (np.asarray(pending, dtype=Xa.dtype)
                if (self.pending_penalty and pending is not None
                    and len(pending)) else Xa[:0])
        ng = len(good)
        nb = (len(bad) if len(bad) else ng) + len(pend)
        bad_eff = bad if len(bad) else good
        b_pts = (np.concatenate([bad_eff, pend]) if len(pend) else bad_eff)
        bw_g = self._scott_bw(ng, d) * self._dim_scale(good)      # (d,)
        bw_b = self._scott_bw(nb, d) * self._dim_scale(b_pts)
        candidates = np.asarray(candidates)
        batch_size = min(batch_size, len(candidates))
        lg = np.log(self._kde_sum(good, candidates, bw_g) / ng
                    + 1e-12).sum(axis=1)
        bad_sum = (self._kde_sum(bad, candidates, bw_b) if len(bad)
                   else self._kde_sum(good, candidates, bw_g))
        if len(pend):
            bad_sum = bad_sum + self._kde_sum(pend, candidates, bw_b)
        lb = np.log(bad_sum / nb + 1e-12).sum(axis=1)
        top = np.argsort(-(lg - lb), kind="stable")[:batch_size]
        return [int(i) for i in top]

    # --------------------------------------------------------- device program
    def propose(self, X, y, candidates, batch_size, seed=0,
                pending=None) -> List[int]:
        X = np.asarray(X, dtype=np.float32)
        y = np.asarray(y, dtype=np.float32)
        C = np.ascontiguousarray(candidates, dtype=np.float32)
        n, d = X.shape
        S = len(C)
        batch_size = min(batch_size, S)
        n_pend = (len(pending)
                  if self.pending_penalty and pending is not None else 0)
        dp = pad_dims(d)
        # pad rows/candidates to stable multiples: a handful of jit cache
        # entries over a whole run, not one per observation count
        na = pad_rows(n + n_pend, 64)
        Sp = pad_rows(S, 256)
        Xb = np.zeros((na, dp), np.float32)
        Xb[:n, :d] = X
        yb = np.zeros(na, np.float32)
        yb[:n] = y
        if n_pend:
            Xb[n:n + n_pend, :d] = np.asarray(pending, dtype=np.float32)
        Cb = np.zeros((Sp, dp), np.float32)
        Cb[:S, :d] = C
        meta = np.array([n, n_pend, S, self.gamma], np.float32)
        picks = fused_tpe_propose(
            Xb, yb, Cb, meta, batch_size=batch_size, d_true=d,
            use_pallas=self.use_pallas, interpret=self.pallas_interpret)
        picks = jax.device_get(picks)  # one explicit exit sync
        return [int(i) for i in picks]


STRATEGIES["tpe"] = TPEStrategy
