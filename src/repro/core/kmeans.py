"""jit'd k-means (k-means++ seeding + Lloyd iterations) for the clustering
batch strategy (Groves & Pyzer-Knapp 2018)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def _kmeans(X: jax.Array, w: jax.Array, key, k: int, iters: int = 10
            ) -> jax.Array:
    """X (n, d) points, w (n,) weights -> cluster assignment (n,)."""
    n = X.shape[0]

    # k-means++ seeding (weighted by w)
    def seed_body(carry, i):
        centers, d2min, key = carry
        key, sub = jax.random.split(key)
        probs = d2min * w
        probs = jnp.where(probs.sum() > 0, probs / probs.sum(),
                          jnp.ones(n) / n)
        idx = jax.random.choice(sub, n, p=probs)
        c = X[idx]
        centers = centers.at[i].set(c)
        d2 = jnp.sum((X - c) ** 2, axis=-1)
        return (centers, jnp.minimum(d2min, d2), key), None

    key, sub = jax.random.split(key)
    first = X[jax.random.choice(sub, n, p=w / jnp.maximum(w.sum(), 1e-9))]
    centers0 = jnp.zeros((k, X.shape[1])).at[0].set(first)
    d2min0 = jnp.sum((X - first) ** 2, axis=-1)
    (centers, _, _), _ = jax.lax.scan(seed_body, (centers0, d2min0, key),
                                      jnp.arange(1, k))

    def lloyd(centers, _):
        d2 = jnp.sum((X[:, None, :] - centers[None]) ** 2, axis=-1)  # (n, k)
        assign = jnp.argmin(d2, axis=-1)
        onehot = jax.nn.one_hot(assign, k) * w[:, None]
        sums = onehot.T @ X
        counts = onehot.sum(0)[:, None]
        new_centers = jnp.where(counts > 0, sums / jnp.maximum(counts, 1e-9),
                                centers)
        return new_centers, None

    centers, _ = jax.lax.scan(lloyd, centers, None, length=iters)
    d2 = jnp.sum((X[:, None, :] - centers[None]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=-1)


def kmeans_assign(X: np.ndarray, weights: np.ndarray, k: int,
                  seed: int = 0, iters: int = 10) -> np.ndarray:
    if len(X) <= k:
        return np.arange(len(X))
    return np.asarray(_kmeans(jnp.asarray(X, dtype=jnp.float32),
                              jnp.asarray(weights, dtype=jnp.float32),
                              jax.random.PRNGKey(seed), k, iters))
