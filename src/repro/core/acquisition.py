"""UCB acquisition with Mango's adaptive exploration/exploitation schedule.

beta follows the GP-UCB schedule (Srinivas et al.), scaled — as the paper
describes — by search-space size, completed evaluations, and the position
within the parallel batch (GP-BUCB increments t per hallucinated pick):

    beta_t = 2 * log(domain_size * t^2 * pi^2 / (6 * delta))
"""
from __future__ import annotations

import math

import numpy as np


def adaptive_beta(n_evals: int, domain_size: float, batch_index: int = 0,
                  delta: float = 0.1) -> float:
    t = max(n_evals + batch_index, 1)
    beta = 2.0 * math.log(
        max(domain_size, 2.0) * t * t * math.pi ** 2 / (6.0 * delta))
    return float(np.clip(beta, 1.0, 100.0))


def ucb(mu: np.ndarray, sigma: np.ndarray, beta: float) -> np.ndarray:
    return mu + math.sqrt(beta) * sigma
