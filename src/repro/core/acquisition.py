"""UCB acquisition with Mango's adaptive exploration/exploitation schedule,
and the fused device-side clustering proposal built on top of it.

beta follows the GP-UCB schedule (Srinivas et al.), scaled — as the paper
describes — by search-space size, completed evaluations, and the position
within the parallel batch (GP-BUCB increments t per hallucinated pick):

    beta_t = 2 * log(domain_size * t^2 * pi^2 / (6 * delta))

``fused_cluster_propose`` is the clustering strategy's (Groves &
Pyzer-Knapp 2018) whole pipeline as one jit'd device program: pending-trial
absorb -> posterior + UCB -> ``jax.lax.top_k`` -> weighted k-means
(``kmeans._kmeans``) -> per-cluster argmax.  Only the ``(batch_size,)``
pick indices ever leave the device — the (n_mc,) acquisition surface and
the top-quantile slice stay on it.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import _kmeans


def adaptive_beta(n_evals: int, domain_size: float, batch_index: int = 0,
                  delta: float = 0.1) -> float:
    t = max(n_evals + batch_index, 1)
    beta = 2.0 * math.log(
        max(domain_size, 2.0) * t * t * math.pi ** 2 / (6.0 * delta))
    return float(np.clip(beta, 1.0, 100.0))


def ucb(mu: np.ndarray, sigma: np.ndarray, beta: float) -> np.ndarray:
    return mu + math.sqrt(beta) * sigma


@functools.partial(jax.jit, static_argnames=("batch_size", "n_top",
                                             "pend_cap"))
def fused_cluster_propose(X: jax.Array, y: jax.Array, mask: jax.Array,
                          L: jax.Array, P: jax.Array, n_pending: jax.Array,
                          C: jax.Array, ls, var, noise, n_obs: jax.Array,
                          domain_size: jax.Array, key,
                          batch_size: int, n_top: int,
                          pend_cap: int) -> jax.Array:
    """Device-resident clustering batch proposal: one program per ask.

    1. Absorb the (padded, ``pend_cap``) pending buffer exactly the way the
       host loop does — posterior mean at each in-flight point, rank-1
       Cholesky hallucination (GP-BUCB semantics).
    2. Posterior + adaptive-beta UCB over all candidates (standardized y
       space; the de-standardized surface differs by a positive affine map,
       so top-k and argmax are identical).
    3. ``jax.lax.top_k`` keeps the ``n_top`` best; their scores (shifted to
       positive) weight the k-means.
    4. Weighted k-means (k-means++ seeding + Lloyd, ``kmeans._kmeans``)
       splits the top set into ``batch_size`` spatial clusters.
    5. Each cluster contributes its acquisition argmax; already-picked
       points are excluded *before* each cluster's argmax and empty
       clusters back-fill from the unpicked remainder of the top set, so
       the batch is unique by construction (the host implementation's
       post-hoc dedupe could silently collapse spatial diversity).
    """
    from repro.core import gp as gp_lib

    def absorb(j, carry):
        def do(c):
            X, y, mask, L = c
            x_new = P[j]
            k_vec = gp_lib.matern52(X, x_new[None, :], ls, var)[:, 0] * mask
            mu = k_vec @ jax.scipy.linalg.cho_solve((L, True), y * mask)
            slot = (n_obs + j).astype(jnp.int32)
            L2, X2, mask2 = gp_lib.chol_append(L, X, mask, slot, x_new,
                                               ls, var, noise)
            return X2, y.at[slot].set(mu), mask2, L2
        return jax.lax.cond(j < n_pending, do, lambda c: c, carry)

    carry = (X.astype(jnp.float32), y.astype(jnp.float32),
             mask.astype(jnp.float32), L)
    X, y, mask, L = jax.lax.fori_loop(0, pend_cap, absorb, carry)

    Ks = gp_lib.matern52(X, C, ls, var) * mask[:, None]         # (n, S)
    alpha = jax.scipy.linalg.cho_solve((L, True), y * mask)
    mu = Ks.T @ alpha
    V = jax.scipy.linalg.solve_triangular(L, Ks, lower=True)
    sig2 = jnp.maximum(var + noise - jnp.sum(V * V, axis=0), 1e-10)
    beta = gp_lib.adaptive_beta_dev(n_obs + n_pending, domain_size)
    acq = mu + jnp.sqrt(beta) * jnp.sqrt(sig2)

    top_vals, top_idx = jax.lax.top_k(acq, n_top)
    w = top_vals - top_vals[n_top - 1] + 1e-6
    assign = _kmeans(C[top_idx], w, key, batch_size)

    def body(c, carry):
        picked, picks = carry
        in_c = (assign == c) & ~picked
        sel = jnp.where(jnp.any(in_c), in_c, ~picked)   # empty-cluster fill
        vals = jnp.where(sel, top_vals, -jnp.inf)
        j = jnp.argmax(vals).astype(jnp.int32)
        return picked.at[j].set(True), picks.at[c].set(top_idx[j])

    _, picks = jax.lax.fori_loop(
        0, batch_size, body,
        (jnp.zeros((n_top,), bool), jnp.zeros((batch_size,), jnp.int32)))
    return picks
