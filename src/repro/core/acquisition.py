"""UCB acquisition with Mango's adaptive exploration/exploitation schedule,
and the fused device-side clustering proposal built on top of it.

beta follows the GP-UCB schedule (Srinivas et al.), scaled — as the paper
describes — by search-space size, completed evaluations, and the position
within the parallel batch (GP-BUCB increments t per hallucinated pick):

    beta_t = 2 * log(domain_size * t^2 * pi^2 / (6 * delta))

``fused_cluster_propose`` is the clustering strategy's (Groves &
Pyzer-Knapp 2018) whole pipeline as one jit'd device program: pending-trial
absorb -> posterior + UCB -> ``jax.lax.top_k`` -> weighted k-means
(``kmeans._kmeans``) -> per-cluster argmax.  Only the ``(batch_size,)``
pick indices ever leave the device — the (n_mc,) acquisition surface and
the top-quantile slice stay on it.  Scoring and pending absorption run
through ``core.scoring`` — the same conditioning-hardened core (and, with
``use_pallas``, the same ``gp_acquisition`` kernels) as
``gp.fused_propose_pallas_pending``, so there is exactly one GP scoring
backend in the tree.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import _kmeans


def adaptive_beta(n_evals: int, domain_size: float, batch_index: int = 0,
                  delta: float = 0.1) -> float:
    t = max(n_evals + batch_index, 1)
    beta = 2.0 * math.log(
        max(domain_size, 2.0) * t * t * math.pi ** 2 / (6.0 * delta))
    return float(np.clip(beta, 1.0, 100.0))


def ucb(mu: np.ndarray, sigma: np.ndarray, beta: float) -> np.ndarray:
    return mu + math.sqrt(beta) * sigma


@functools.partial(jax.jit, static_argnames=("batch_size", "n_top",
                                             "pend_cap", "use_pallas",
                                             "block_s", "interpret"))
def fused_cluster_propose(X: jax.Array, y: jax.Array, mask: jax.Array,
                          L: jax.Array, Linv: jax.Array, P: jax.Array,
                          n_pending: jax.Array,
                          C: jax.Array, ls, var, noise, n_obs: jax.Array,
                          domain_size: jax.Array, key,
                          batch_size: int, n_top: int,
                          pend_cap: int, use_pallas: bool = False,
                          block_s: int = 256,
                          interpret: bool = True) -> jax.Array:
    """Device-resident clustering batch proposal: one program per ask.

    1. Absorb the (padded, ``pend_cap``) pending buffer through the shared
       core's hardened absorb loop (``scoring.absorb_pending``) — posterior
       mean at each in-flight point, rank-1 (L, Linv) factor append
       (GP-BUCB semantics), exactly the loop the fused Pallas proposal
       runs.
    2. Posterior + adaptive-beta UCB over all candidates through the one
       shared scorer (``scoring.posterior_scores`` — the Pallas
       ``gp_acquisition`` kernel when ``use_pallas``, its jnp twin
       otherwise; standardized y space — the de-standardized surface
       differs by a positive affine map, so top-k and argmax are
       identical).
    3. ``jax.lax.top_k`` keeps the ``n_top`` best; their scores (shifted to
       positive) weight the k-means.
    4. Weighted k-means (k-means++ seeding + Lloyd, ``kmeans._kmeans``)
       splits the top set into ``batch_size`` spatial clusters.
    5. Each cluster contributes its acquisition argmax; already-picked
       points are excluded *before* each cluster's argmax and empty
       clusters back-fill from the unpicked remainder of the top set, so
       the batch is unique by construction (the host implementation's
       post-hoc dedupe could silently collapse spatial diversity).
    """
    from repro.core import scoring

    S = C.shape[0]
    Xs, Cs = scoring.prescale(X, C, ls, block_s)
    dp = Xs.shape[1]
    d = X.shape[1]
    Ps = jnp.zeros((pend_cap, dp), jnp.float32).at[:, :d].set(P / ls)
    Xs, y, mask, L, Linv = scoring.absorb_pending(
        Xs, y, mask, L, Linv, Ps, n_pending, n_obs, var, noise, pend_cap)

    mu, sig2, _, _ = scoring.posterior_scores(
        Cs, Xs, y, mask, Linv, var, noise, use_pallas=use_pallas,
        block_s=block_s, interpret=interpret)
    beta = scoring.adaptive_beta_dev(n_obs + n_pending, domain_size)
    acq = mu + jnp.sqrt(beta) * jnp.sqrt(sig2)
    acq = jnp.where(jnp.arange(Cs.shape[0]) < S, acq, -jnp.inf)

    top_vals, top_idx = jax.lax.top_k(acq, n_top)
    w = top_vals - top_vals[n_top - 1] + 1e-6
    assign = _kmeans(C[top_idx], w, key, batch_size)

    def body(c, carry):
        picked, picks = carry
        in_c = (assign == c) & ~picked
        sel = jnp.where(jnp.any(in_c), in_c, ~picked)   # empty-cluster fill
        vals = jnp.where(sel, top_vals, -jnp.inf)
        j = jnp.argmax(vals).astype(jnp.int32)
        return picked.at[j].set(True), picks.at[c].set(top_idx[j])

    _, picks = jax.lax.fori_loop(
        0, batch_size, body,
        (jnp.zeros((n_top,), bool), jnp.zeros((batch_size,), jnp.int32)))
    return picks


# NOTE: the monolithic ``fused_cluster_propose_bank`` (which refactored the
# factors in-program per ask) is gone — clustering fleets now ride the
# bank's STAGED pipeline (``gp.bank_factors``/``bank_dist``/``bank_exp``
# feeding ``gp.bank_cluster_pick``), sharing the obs-stamp cache with the
# GP-BUCB rows instead of recomputing every study's Cholesky every ask.
