"""Tuner: Mango's user-facing orchestration (paper Fig. 1 workflow).

The objective-function contract is the paper's fault-tolerance mechanism
(§2.2/§2.4): the tuner passes a *list* of configurations; the objective
returns ``(evals, params)`` — any subset, in any order.  Missing entries
(failed workers, stragglers past the scheduler deadline) are simply never
observed.  The tuner keeps going as long as at least one result ever returns.

Config keys (mirroring Mango's ``conf_dict``):
  batch_size (1), num_iteration (20), initial_random (2),
  optimizer ("bayesian" | "clustering" | "random"),
  domain_size (None -> heuristic), mc_samples (None -> heuristic),
  seed (0), early_stopping (callable(results) -> bool),
  checkpoint_path (None), fit_steps (40), use_pallas (False),
  pallas_interpret (True; set False on real TPU for the compiled kernel),
  refit_every (8; full GP hyperparameter re-tune every N new observations —
  in between, observations extend the Cholesky incrementally in O(n^2)).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.spaces import ParamSpace
from repro.core.strategies import STRATEGIES

DEFAULTS = dict(batch_size=1, num_iteration=20, initial_random=2,
                optimizer="bayesian", domain_size=None, mc_samples=None,
                seed=0, early_stopping=None, checkpoint_path=None,
                fit_steps=40, use_pallas=False, pallas_interpret=True,
                refit_every=8)


@dataclasses.dataclass
class TunerResults:
    best_objective: float
    best_params: Dict[str, Any]
    params_tried: List[Dict[str, Any]]
    objective_values: List[float]
    best_trace: List[float]          # best-so-far per iteration
    iterations: int
    n_failed: int
    wall_time_s: float

    def as_dict(self):
        return dataclasses.asdict(self)


def _to_jsonable(cfg: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in cfg.items():
        if isinstance(v, (np.integer,)):
            out[k] = int(v)
        elif isinstance(v, (np.floating,)):
            out[k] = float(v)
        elif isinstance(v, np.ndarray):
            out[k] = v.tolist()
        else:
            out[k] = v
    return out


class Tuner:
    def __init__(self, param_space: Dict[str, Any],
                 objective: Callable[[List[Dict]], Any],
                 config: Optional[Dict[str, Any]] = None):
        self.space = ParamSpace(param_space)
        self.objective = objective
        self.conf = {**DEFAULTS, **(config or {})}
        unknown = set(self.conf) - set(DEFAULTS)
        if unknown:
            raise ValueError(f"unknown Tuner config keys: {sorted(unknown)}")
        opt = self.conf["optimizer"]
        if opt not in STRATEGIES:
            raise ValueError(f"unknown optimizer {opt!r}; "
                             f"choose from {sorted(STRATEGIES)}")
        self._rng = np.random.default_rng(self.conf["seed"])
        self._X: List[Dict[str, Any]] = []   # observed configs
        self._y: List[float] = []            # observed objective values
        self._best_trace: List[float] = []
        self._iteration = 0
        self._n_failed = 0
        self._sign = 1.0
        self._strat = None
        self._gp_n_fit = 0   # obs count at the GP's last full fit (resume)
        ckpt = self.conf["checkpoint_path"]
        if ckpt and Path(ckpt).exists():
            self.load_state(ckpt)

    # ------------------------------------------------------------- plumbing
    def _evaluate(self, batch: List[Dict]) -> None:
        """Dispatch a batch and observe whatever subset comes back."""
        out = self.objective(list(batch))
        if out is None:
            evals, params = [], []
        elif isinstance(out, tuple) and len(out) == 2:
            evals, params = out
        else:  # plain list of values, aligned with the batch
            evals, params = list(out), list(batch)
        if len(evals) != len(params):
            raise ValueError(
                "objective must return (evals, params) of equal length")
        self._n_failed += len(batch) - len(evals)
        for v, p in zip(evals, params):
            v = float(v)
            if not np.isfinite(v):
                self._n_failed += 1
                continue
            self._X.append(dict(p))
            self._y.append(self._sign * v)

    def _strategy(self):
        cls = STRATEGIES[self.conf["optimizer"]]
        domain = self.conf["domain_size"] or self.space.domain_size
        strat = cls(self.space.dim, domain, fit_steps=self.conf["fit_steps"],
                    use_pallas=self.conf["use_pallas"],
                    pallas_interpret=self.conf["pallas_interpret"],
                    refit_every=self.conf["refit_every"])
        if self._gp_n_fit and self._y and strat.needs_gp:
            # replay the checkpointed fit/append schedule so resumed runs
            # produce the same remaining proposals as uninterrupted ones
            strat.gp.restore(self.space.encode(self._X),
                             np.asarray(self._y, np.float32),
                             self._gp_n_fit)
        return strat

    def _propose(self, strategy, batch_size: int) -> List[Dict]:
        n_mc = self.conf["mc_samples"] or self.space.mc_samples(batch_size)
        candidates = self.space.sample(n_mc, self._rng)
        if not self._y or not strategy.needs_gp:
            idx = strategy.propose(None, [], self.space.encode(candidates),
                                   batch_size, seed=self._iteration) \
                if not strategy.needs_gp else \
                list(self._rng.choice(n_mc, size=batch_size, replace=False))
            return [candidates[i] for i in idx]
        C = self.space.encode(candidates)
        X = self.space.encode(self._X)
        idx = strategy.propose(X, np.asarray(self._y), C, batch_size,
                               seed=self._iteration)
        return [candidates[i] for i in idx]

    # ---------------------------------------------------------------- public
    def maximize(self) -> TunerResults:
        return self._run(sign=1.0)

    def minimize(self) -> TunerResults:
        return self._run(sign=-1.0)

    # mango-compatible alias
    run = maximize

    def _run(self, sign: float) -> TunerResults:
        self._sign = sign
        t0 = time.time()
        bs = self.conf["batch_size"]
        strategy = self._strat = self._strategy()

        if self._iteration == 0 and not self._y:
            n0 = max(self.conf["initial_random"], 1)
            init = self.space.sample(n0, self._rng)
            self._evaluate(init)
            self._checkpoint()

        while self._iteration < self.conf["num_iteration"]:
            batch = self._propose(strategy, bs)
            self._evaluate(batch)
            self._iteration += 1
            if self._y:
                self._best_trace.append(float(np.max(self._y)))
            self._checkpoint()
            es = self.conf["early_stopping"]
            if es and self._y and es(self._partial_results()):
                break
        return self._partial_results(wall=time.time() - t0)

    def _partial_results(self, wall: float = 0.0) -> TunerResults:
        if self._y:
            i = int(np.argmax(self._y))
            best_y = self._sign * self._y[i]
            best_p = self._X[i]
        else:
            best_y, best_p = float("nan"), {}
        return TunerResults(
            best_objective=best_y,
            best_params=best_p,
            params_tried=list(self._X),
            objective_values=[self._sign * v for v in self._y],
            best_trace=[self._sign * v for v in self._best_trace],
            iterations=self._iteration,
            n_failed=self._n_failed,
            wall_time_s=wall,
        )

    # ------------------------------------------------------------ checkpoint
    def _checkpoint(self):
        path = self.conf["checkpoint_path"]
        if not path:
            return
        gp = getattr(self._strat, "gp", None)
        state = {
            "iteration": self._iteration,
            "X": [_to_jsonable(x) for x in self._X],
            "y": self._y,
            "best_trace": self._best_trace,
            "n_failed": self._n_failed,
            "sign": self._sign,
            "rng_state": self._rng.bit_generator.state,
            "gp_n_fit": gp.n_fit if gp is not None else 0,
        }
        p = Path(path)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(state))
        tmp.replace(p)  # atomic swap: a crash never corrupts the checkpoint

    def load_state(self, path):
        state = json.loads(Path(path).read_text())
        self._iteration = state["iteration"]
        self._X = state["X"]
        self._y = state["y"]
        self._best_trace = state["best_trace"]
        self._n_failed = state["n_failed"]
        self._sign = state.get("sign", 1.0)
        self._gp_n_fit = state.get("gp_n_fit", 0)
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng_state"]
