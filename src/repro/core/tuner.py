"""Tuner: the synchronous batch driver over ``AskTellOptimizer``.

All optimizer state (space, strategy/GP, RNG, trial ledger, checkpoint
schedule) lives in the ask/tell core (``repro.core.optimizer``); this class
only runs the paper's Fig. 1 workflow: ask a batch, dispatch it through the
objective, tell back whatever subset returns, repeat.  Since ISSUE 6 the
core itself is a bank-of-one view over a ``StudyLedger`` — the driver API
and every checkpoint stay unchanged, but fleets of tuners can share one
``StudyBank`` and be served by a single vmap'd ask (see
``repro.core.studybank``).

The objective-function contract is the paper's fault-tolerance mechanism
(§2.2/§2.4): the tuner passes a *list* of configurations; the objective
returns ``(evals, params)`` — any subset, in any order.  Missing entries
(failed workers, stragglers past the scheduler deadline) are told as failed
and never reach the surrogate.

Config keys (mirroring Mango's ``conf_dict``):
  batch_size (1), num_iteration (20), initial_random (2),
  optimizer ("bayesian" | "clustering" | "random" | "tpe"),
  domain_size (None -> heuristic), mc_samples (None -> heuristic),
  seed (0), early_stopping (callable(results) -> bool),
  checkpoint_path (None), fit_steps (40), use_pallas (False),
  pallas_interpret (True; set False on real TPU for the compiled kernel),
  refit_every (8; full GP hyperparameter re-tune every N new observations —
  in between, observations extend the Cholesky incrementally in O(n^2)),
  scheduler (None; any ``repro.scheduler`` Scheduler — then ``objective``
  is a *per-trial* callable and the scheduler wraps it into the batch
  objective, so ``Tuner`` and ``AsyncTuner`` take the same inputs),
  strategy_kwargs (None; dict of strategy-specific knobs forwarded to the
  strategy constructor — e.g. ``{"gamma": 0.2}`` or
  ``{"pending_penalty": True}`` for ``optimizer="tpe"``, ``{"top_frac":
  0.1}`` for ``clustering``; unknown keys raise TypeError).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

from repro.core.optimizer import AskTellOptimizer, Trial

DEFAULTS = dict(batch_size=1, num_iteration=20, initial_random=2,
                optimizer="bayesian", domain_size=None, mc_samples=None,
                seed=0, early_stopping=None, checkpoint_path=None,
                fit_steps=40, use_pallas=False, pallas_interpret=True,
                refit_every=8, scheduler=None, strategy_kwargs=None)


@dataclasses.dataclass
class TunerResults:
    best_objective: float
    best_params: Dict[str, Any]
    params_tried: List[Dict[str, Any]]
    objective_values: List[float]
    best_trace: List[float]          # best-so-far per iteration
    iterations: int
    n_failed: int
    wall_time_s: float

    def as_dict(self):
        return dataclasses.asdict(self)

    def __getitem__(self, key):      # legacy dict-style access
        return getattr(self, key)


class Tuner:
    def __init__(self, param_space: Dict[str, Any],
                 objective: Callable[..., Any],
                 config: Optional[Dict[str, Any]] = None):
        self.conf = {**DEFAULTS, **(config or {})}
        unknown = set(self.conf) - set(DEFAULTS)
        if unknown:
            raise ValueError(f"unknown Tuner config keys: {sorted(unknown)}")
        sched = self.conf["scheduler"]
        if sched is not None:
            # unified signature: objective is a per-trial fn, the scheduler
            # wraps it into the paper's batch objective
            objective = sched.make_objective(objective)
        self.objective = objective
        if sched is not None and hasattr(sched, "make_engine"):
            # the scheduler supplies the ask/tell core itself (e.g.
            # ServiceScheduler: a remote study on the durable tuning
            # service, where strategy config lives server-side)
            self.opt = sched.make_engine(param_space, self.conf)
        else:
            self.opt = AskTellOptimizer(
                param_space, optimizer=self.conf["optimizer"],
                seed=self.conf["seed"],
                domain_size=self.conf["domain_size"],
                mc_samples=self.conf["mc_samples"],
                fit_steps=self.conf["fit_steps"],
                use_pallas=self.conf["use_pallas"],
                pallas_interpret=self.conf["pallas_interpret"],
                refit_every=self.conf["refit_every"],
                strategy_kwargs=self.conf["strategy_kwargs"])
        self.space = self.opt.space
        self._iteration = 0
        ckpt = self.conf["checkpoint_path"]
        if ckpt and Path(ckpt).exists():
            self.load_state(ckpt)

    # ------------------------------------------------------------- plumbing
    def _run_batch(self, trials: List[Trial]) -> None:
        """Dispatch a batch and tell back whatever subset comes back."""
        out = self.objective([t.params for t in trials])
        if out is None:
            evals, params = [], []
        elif isinstance(out, tuple) and len(out) == 2:
            evals, params = out
        else:  # plain list of values, aligned with the batch
            evals, params = list(out), [t.params for t in trials]
        if len(evals) != len(params):
            raise ValueError(
                "objective must return (evals, params) of equal length")
        remaining = list(trials)
        for v, p in zip(evals, params):
            t = self._match(remaining, p)
            if t is None and remaining:
                # legacy contract: objectives may return *transformed*
                # configs (derived keys, rounding).  The returned params are
                # authoritative; pair with a pending slot so the failure
                # count stays len(batch) - len(evals), not len(batch)
                t = remaining.pop(0)
            if t is not None:
                t.params = dict(p)
                self.opt.tell(t.id, v)
            else:   # more results than the batch had slots
                self.opt.observe_params(p, v)
        for t in remaining:   # never came back -> failed (paper contract)
            self.opt.tell_failed(t.id)

    @staticmethod
    def _match(remaining: List[Trial], params) -> Optional[Trial]:
        """Pair a returned config with its pending trial: objectives may
        reorder or copy, so match by identity first, then equality."""
        for i, t in enumerate(remaining):
            if t.params is params:
                return remaining.pop(i)
        for i, t in enumerate(remaining):
            try:
                if t.params == params:
                    return remaining.pop(i)
            except ValueError:     # array-valued params: skip equality
                continue
        return None

    # ---------------------------------------------------------------- public
    def maximize(self) -> TunerResults:
        return self._run(sign=1.0)

    def minimize(self) -> TunerResults:
        return self._run(sign=-1.0)

    # mango-compatible alias
    run = maximize

    def _run(self, sign: float) -> TunerResults:
        self.opt.sign = sign
        t0 = time.time()
        bs = self.conf["batch_size"]

        if self.opt.num_trials == 0:
            n0 = max(self.conf["initial_random"], 1)
            self._run_batch(self.opt.ask(n0))
            self._checkpoint()

        while self._iteration < self.conf["num_iteration"]:
            self._run_batch(self.opt.ask(bs))
            self._iteration += 1
            self.opt.snapshot_trace()
            self._checkpoint()
            es = self.conf["early_stopping"]
            if es and self.opt.n_observed and es(self._partial_results()):
                break
        return self._partial_results(wall=time.time() - t0)

    def _partial_results(self, wall: float = 0.0) -> TunerResults:
        return self.opt.results(iterations=self._iteration, wall=wall)

    # ------------------------------------------------------------ checkpoint
    def _checkpoint(self):
        path = self.conf["checkpoint_path"]
        if path:
            self.opt.save(path, iteration=self._iteration)

    def load_state(self, path):
        self._iteration = self.opt.load(path)
