"""Asynchronous tuner: the completion-event driver over ``AskTellOptimizer``.

The synchronous tuner waits for a whole batch before proposing again.  With
heterogeneous trial times (the common case for NAS/big-model tuning), workers
idle at every barrier.  ``AsyncTuner`` keeps up to ``batch_size`` trials in
flight: whenever one completes it is told back to the shared ask/tell core
and one replacement trial is asked — the core hands the full pending set to
the fused GP-BUCB program, which hallucinates the in-flight rows *inside*
its jit'd ``lax.fori_loop`` (one device dispatch per replacement pick; the
seed implementation ran one O(n^2) program per pending trial).

The event loop blocks on the scheduler's completion condition
(``wait_any``), waking exactly when a trial finishes — no ``time.sleep``
polling.  Any scheduler works: native async ones (``TaskQueueScheduler``)
are used directly, batch-objective ones are wrapped by
``BatchToAsyncAdapter``.

Because the ledger (including in-flight trials) lives in the core,
``checkpoint_path`` gives the async loop the same kill/resume guarantee as
the sync tuner: pending trials are re-dispatched on resume and the
remaining proposals replay exactly.  Returns ``TunerResults`` like
``Tuner`` (dict-style access still works for legacy callers).

Since ISSUE 6 the core is a bank-of-one view over a ``StudyLedger``
(``repro.core.studybank``); nothing changes for a single async loop, but
N concurrent tuning jobs can share one ``StudyBank`` and checkpoint the
whole fleet with one atomic ``save``.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

from repro.core.optimizer import AskTellOptimizer
from repro.core.tuner import TunerResults
from repro.scheduler.base import as_async


class AsyncTuner:
    def __init__(self, param_space: Dict[str, Any],
                 trial_fn: Callable[[Dict[str, Any]], float],
                 scheduler, num_evals: int = 40, batch_size: int = 4,
                 initial_random: int = 4, seed: int = 0,
                 mc_samples: Optional[int] = None,
                 poll_interval: float = 0.01, refit_every: int = 8,
                 optimizer: str = "bayesian", fit_steps: int = 40,
                 use_pallas: bool = False, pallas_interpret: bool = True,
                 domain_size: Optional[float] = None,
                 early_stopping: Optional[Callable[[TunerResults], bool]]
                 = None,
                 checkpoint_path: Optional[str] = None,
                 strategy_kwargs: Optional[Dict[str, Any]] = None):
        self.trial_fn = trial_fn
        # poll_interval only matters for submit-only schedulers without a
        # completion condition; everything in-repo wakes on wait_any
        self.sched = as_async(scheduler, poll=poll_interval)
        self.num_evals = num_evals
        self.batch_size = batch_size
        self.initial_random = initial_random
        self.poll = poll_interval
        self.early_stopping = early_stopping
        self.checkpoint_path = checkpoint_path
        if hasattr(scheduler, "make_engine"):
            # scheduler-supplied ask/tell core (ServiceScheduler: a remote
            # study on the durable service; strategy config is server-side)
            self.opt = scheduler.make_engine(param_space, None)
        else:
            self.opt = AskTellOptimizer(
                param_space, optimizer=optimizer, seed=seed,
                domain_size=domain_size, mc_samples=mc_samples,
                fit_steps=fit_steps, use_pallas=use_pallas,
                pallas_interpret=pallas_interpret, refit_every=refit_every,
                strategy_kwargs=strategy_kwargs)
        self.space = self.opt.space
        if checkpoint_path and Path(checkpoint_path).exists():
            self.load_state(checkpoint_path)

    # ---------------------------------------------------------------- public
    def maximize(self) -> TunerResults:
        return self._run(sign=1.0)

    def minimize(self) -> TunerResults:
        return self._run(sign=-1.0)

    def _done_count(self) -> int:
        return self.opt.n_observed + self.opt.n_failed

    def _run(self, sign: float) -> TunerResults:
        self.opt.sign = sign
        t0 = time.time()
        opt = self.opt
        inflight = {}   # TaskHandle -> trial id

        def dispatch(trial):
            handle = self.sched.submit(self.trial_fn, trial.params)
            inflight[handle] = trial.id

        # resume: the ledger still holds trials that were in flight when the
        # run died — re-dispatch them so the replay matches the
        # uninterrupted schedule
        for t in opt.pending_trials():
            dispatch(t)
        if opt.num_trials == 0:
            n0 = min(max(self.initial_random, 1), self.num_evals)
            for t in opt.ask(n0):
                dispatch(t)

        while self._done_count() < self.num_evals:
            # keep the pipeline full: one replacement ask per free slot
            while (opt.num_trials < self.num_evals
                   and len(inflight) < self.batch_size):
                for t in opt.ask(1):
                    dispatch(t)
            done = self.sched.wait_any(list(inflight))
            for handle in done:
                trial_id = inflight.pop(handle)
                if handle.error is None:
                    opt.tell(trial_id, handle.result)
                else:
                    opt.tell_failed(trial_id)
                opt.snapshot_trace()
            self._checkpoint()
            es = self.early_stopping
            if es and opt.n_observed and es(self._partial_results()):
                break
        return self._partial_results(wall=time.time() - t0)

    def _partial_results(self, wall: float = 0.0) -> TunerResults:
        return self.opt.results(iterations=self._done_count(), wall=wall)

    # ------------------------------------------------------------ checkpoint
    def _checkpoint(self):
        if self.checkpoint_path:
            self.opt.save(self.checkpoint_path,
                          iteration=self._done_count())

    def load_state(self, path):
        self.opt.load(path)
