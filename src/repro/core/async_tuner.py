"""Asynchronous tuner (beyond-paper): continuous batching of trials.

The synchronous tuner waits for a whole batch before refitting.  With
heterogeneous trial times (the common case for NAS/big-model tuning), workers
idle at every barrier.  ``AsyncTuner`` keeps exactly ``batch_size`` trials in
flight: whenever one completes it is observed, pending trials are
*hallucinated* (GP-BUCB semantics extend naturally to the async setting —
pending configs contribute variance contraction but no mean update), and one
replacement trial is dispatched.

Completions are absorbed through the incremental GP path: each new
observation is an O(n^2) Cholesky append (full O(n^3) hyperparameter refit
only every ``refit_every`` completions), and the replacement pick runs on the
fused device-resident proposal program — the seed implementation refit the
GP from scratch and re-hallucinated every pending trial on *every*
completion.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.spaces import ParamSpace
from repro.core.strategies import FusedHallucinationStrategy
from repro.scheduler.distributed import TaskQueueScheduler


class AsyncTuner:
    def __init__(self, param_space: Dict[str, Any],
                 trial_fn: Callable[[Dict[str, Any]], float],
                 scheduler: TaskQueueScheduler,
                 num_evals: int = 40, batch_size: int = 4,
                 initial_random: int = 4, seed: int = 0,
                 mc_samples: Optional[int] = None,
                 poll_interval: float = 0.01, refit_every: int = 8):
        self.space = ParamSpace(param_space)
        self.trial_fn = trial_fn
        self.sched = scheduler
        self.num_evals = num_evals
        self.batch_size = batch_size
        self.initial_random = initial_random
        self.mc_samples = mc_samples
        self.poll = poll_interval
        self.refit_every = refit_every
        self._rng = np.random.default_rng(seed)

    def maximize(self) -> Dict[str, Any]:
        t0 = time.time()
        strat = FusedHallucinationStrategy(
            self.space.dim, self.space.domain_size,
            refit_every=self.refit_every)
        X_obs: List[Dict] = []
        y_obs: List[float] = []
        pending = {}  # task -> params
        dispatched = 0
        failed = 0

        def launch(params):
            nonlocal dispatched
            t = self.sched.submit(self.trial_fn, params)
            pending[t] = params
            dispatched += 1

        for p in self.space.sample(
                min(self.initial_random, self.num_evals), self._rng):
            launch(p)

        while y_obs.__len__() + failed < self.num_evals:
            done = [t for t in pending if t.done.is_set()]
            if not done:
                time.sleep(self.poll)
                continue
            for t in done:
                params = pending.pop(t)
                if t.error is None and np.isfinite(t.result):
                    X_obs.append(params)
                    y_obs.append(float(t.result))
                else:
                    failed += 1
            while (dispatched < self.num_evals
                   and len(pending) < self.batch_size):
                if len(y_obs) < 2:
                    launch(self.space.sample(1, self._rng)[0])
                    continue
                n_mc = self.mc_samples or self.space.mc_samples(
                    self.batch_size)
                cands = self.space.sample(n_mc, self._rng)
                C = self.space.encode(cands)
                # incremental absorb of completions (O(n^2) appends; full
                # refit only every refit_every observations)
                st = strat.gp.observe(self.space.encode(X_obs),
                                      np.asarray(y_obs))
                st = strat.gp.ensure_capacity(st, len(pending) + 1)
                for pp in pending.values():  # hallucinate in-flight trials
                    st = strat.gp.hallucinate(
                        st, self.space.encode([pp])[0])
                # fused device program; t = n_obs + n_pending reproduces the
                # batch_index term of the adaptive-beta schedule
                picks = strat.pick_from_state(st, C, 1)
                launch(cands[picks[0]])

        best = int(np.argmax(y_obs)) if y_obs else -1
        return {
            "best_objective": y_obs[best] if y_obs else float("nan"),
            "best_params": X_obs[best] if y_obs else {},
            "objective_values": y_obs,
            "params_tried": X_obs,
            "n_failed": failed,
            "wall_time_s": time.time() - t0,
        }
