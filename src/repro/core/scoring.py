"""Conditioning-hardened device-resident GP posterior-scoring core.

The single scoring backend behind every GP strategy (ISSUE 5): the fused
GP-BUCB Pallas path (``gp.fused_propose_pallas[_pending]``) and the fused
clustering pipeline (``acquisition.fused_cluster_propose``) both score
candidates, absorb pending trials, and extend the system through the
functions in this module — one implementation of the posterior math, with
``use_pallas`` only toggling whether the scoring pass executes as the
``kernels/gp_acquisition`` Pallas kernels or as their pure-jnp oracle twin.

Why the old K⁻¹ path flipped picks (the ROADMAP PR-3 follow-up this module
fixes): on near-noiseless objectives the fitted noise collapses, K becomes
ill-conditioned, and the float32 quadratic form ``q = k·(K⁻¹k)`` cancels
catastrophically — its intermediates (``t = k K⁻¹``) are large and
mixed-sign.  Measured on the repro surface, sig2 through the quadratic form
carried ~250x the error of the Cholesky path (6e-4 vs 2.6e-6 on a 1.3e-2
posterior variance — a 5% relative error that flips near-tied argmaxes),
and a same-precision Newton step on K⁻¹ does not help because the
cancellation is in *evaluating* the form, not only in K⁻¹ itself.

Hardening, at the source:

  * the device-resident operand is the *triangular inverse factor*
    ``Linv = L⁻¹`` rather than ``K⁻¹``; posterior variance is the monotone
    sum of squares ``sig2 = var + noise − ‖k_c Linvᵀ‖²`` — still one MXU
    matmul per candidate block, but numerically the Cholesky path's own
    formula (measured 2.2e-6, i.e. parity with the L-based scorer);
  * rank-1 appends extend (L, Linv) by one new row each and never rewrite
    previous rows — the K⁻¹ Schur update (``K⁻¹ += uuᵀ/schur``) rewrote the
    whole matrix every append, compounding error across batch slots;
  * the Schur solves accumulate in float64 when the backend has x64
    enabled, and otherwise apply one step of iterative refinement in
    float32 (``harden=True``, the default);
  * the Schur complement is computed as ``c − Σl²`` (sum of positives, the
    Cholesky pivot formula) instead of ``c − k·u``, and floors are
    *relative* to the signal scale and shared bit-for-bit with the
    Cholesky path (``scoring.jitter`` / ``scoring.schur_floor``), so a
    binding floor can never split the two paths;
  * ``cond_proxy_from_chol`` surfaces a condition-number diagnostic to the
    host (strategies expose it as ``last_cond_proxy``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gp_acquisition.ref import (matern52, score_cov_ref,
                                              var_downdate_ref)

# condition proxy above which float32 posterior scoring is presumed
# unreliable (cond * eps_f32 ~ 1): strategies surface the proxy and docs
# point users at raising the noise floor / enabling x64 beyond it
COND_PROXY_WARN = 1e7

JITTER = 1e-6


def jitter(var) -> jax.Array:
    """Diagonal jitter floor, *relative* to the signal variance (1e-6
    absolute or 1e-6·var, whichever is larger).  One definition shared by
    the Cholesky path (``gp._masked_kernel``/``chol_append``) and the
    hardened factor appends — a floor that binds on one path but not the
    other would itself flip near-ties."""
    return JITTER * jnp.maximum(jnp.asarray(var, jnp.float32), 1.0)


def schur_floor(var, noise) -> jax.Array:
    """Floor for the Schur complement / Cholesky pivot, relative to the
    diagonal scale (keeps 1/schur and 1/l_nn finite when a duplicate point
    is absorbed).  Shared by every append path."""
    return jnp.maximum(jnp.float32(1e-10),
                       1e-8 * (jnp.asarray(var, jnp.float32) + noise))


def compute_dtype():
    """float64 when the backend has x64 enabled (trace-time decision; the
    x64 flag participates in the jit cache key), else float32."""
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


def adaptive_beta_dev(t: jax.Array, domain_size: jax.Array) -> jax.Array:
    """jnp twin of ``acquisition.adaptive_beta`` (delta=0.1), trace-safe."""
    t = jnp.maximum(t.astype(jnp.float32), 1.0)
    beta = 2.0 * jnp.log(jnp.maximum(domain_size, 2.0) * t * t
                         * (jnp.pi ** 2) / 0.6)
    return jnp.clip(beta, 1.0, 100.0)


@jax.jit
def linv_from_chol(L: jax.Array) -> jax.Array:
    """L⁻¹ (identity rows/cols at padded slots, like L itself)."""
    return jax.scipy.linalg.solve_triangular(
        L, jnp.eye(L.shape[0], dtype=L.dtype), lower=True)


@jax.jit
def cond_proxy_from_chol(L: jax.Array, mask: jax.Array) -> jax.Array:
    """Cheap 2-norm condition proxy of K from its Cholesky diagonal:
    ``cond₂(K) >= (max diag L / min diag L)²`` on the active block.  A
    lower bound, not an estimate — but it tracks exactly the collapse mode
    that loses float32 picks (fitted noise → 0 → tiny pivots)."""
    d = jnp.abs(jnp.diagonal(L))
    act = mask > 0
    dmax = jnp.max(jnp.where(act, d, 0.0))
    dmin = jnp.min(jnp.where(act, d, jnp.inf))
    return (dmax / jnp.maximum(dmin, 1e-30)) ** 2


@functools.partial(jax.jit, static_argnames=("iters",))
def cond_estimate(L: jax.Array, mask: jax.Array, iters: int = 16) -> jax.Array:
    """Power-iteration estimate of cond₂(K) from its masked Cholesky factor.

    ``cond_proxy_from_chol`` is a diagonal lower bound that runs 20-50x low
    on correlated kernels; this estimate runs ``iters`` power-iteration
    steps for λmax(K) (via ``K v = L (Lᵀ v)``) and λmax(K⁻¹) (via two
    triangular solves) and multiplies the Rayleigh quotients, which lands
    within ~2x of ``np.linalg.cond`` on the repro surface.  The masked
    region of L is exactly identity (block-diagonal by construction), so
    masking the start vector and every matvec keeps the iteration in the
    active block.  Still cheap enough for the bank factor stage: O(iters·n²)
    per study against the O(n³) Cholesky it rides along with.
    """
    m = (mask > 0).astype(L.dtype)
    v0 = m / jnp.maximum(jnp.sqrt(jnp.sum(m)), 1.0)

    def rayleigh(mv):
        def body(v, _):
            w = mv(v)
            nrm = jnp.sqrt(jnp.sum(w * w))
            return w / jnp.maximum(nrm, 1e-30), None
        v, _ = jax.lax.scan(body, v0, None, length=iters)
        return jnp.sum(v * mv(v))

    def k_mv(v):
        return (L @ ((v * m) @ L)) * m

    def kinv_mv(v):
        t = jax.scipy.linalg.solve_triangular(L, v * m, lower=True)
        t = jax.scipy.linalg.solve_triangular(L, t, lower=True, trans=1)
        return t * m

    return jnp.maximum(rayleigh(k_mv) * rayleigh(kinv_mv), 1.0)


def prescale(X, C, ls, block_s):
    """Zero-pad d to a lane multiple and S to a block multiple, pre-divided
    by the ARD lengthscales (padded columns contribute 0 to distances)."""
    n, d = X.shape
    S = C.shape[0]
    dp = max(8, -(-d // 8) * 8)
    Sp = -(-S // block_s) * block_s
    Xs = jnp.zeros((n, dp), jnp.float32).at[:, :d].set(X / ls)
    Cs = jnp.zeros((Sp, dp), jnp.float32).at[:S, :d].set(C / ls)
    return Xs, Cs


# --------------------------------------------------------------------------- #
# Hardened rank-1 factor extension (the fixed Schur append)
# --------------------------------------------------------------------------- #
def factor_append(L: jax.Array, Linv: jax.Array, idx: jax.Array,
                  k_vec: jax.Array, var, noise, harden: bool = True):
    """Extend (L, Linv) by the point whose masked Matern column is k_vec.

    Returns ``(L', Linv', u, schur)`` where ``u = K⁻¹k`` is the Schur
    vector (feeds the rank-1 variance downdate) and ``schur`` the Schur
    complement.  The new Linv row is ``[-u/l_nn, 1/l_nn]`` — the same
    block-inverse algebra as the old K⁻¹ extension, but written into one
    fresh row instead of rewriting the whole inverse.

    Conditioning (``harden=True``): the two triangular solves run as Linv
    matvecs accumulated in float64 when x64 is enabled; on float32-only
    backends each gets one step of iterative refinement (residual against
    L, corrected through Linv).  The Schur complement uses the Cholesky
    pivot formula ``c − Σl²`` and the shared relative floors.
    """
    n = L.shape[0]
    dt = compute_dtype()
    f64 = dt == jnp.float64
    Lc = L.astype(dt)
    Li = Linv.astype(dt)
    kc = k_vec.astype(dt)
    # transposed products are written vector-first (v @ M == Mᵀ @ v): XLA
    # contracts over M's leading axis in place instead of materializing an
    # (n, n) transpose per op, which dominated the append cost at n=1024
    l_vec = Li @ kc                       # forward solve L l = k
    if harden and not f64:
        l_vec = l_vec + Li @ (kc - Lc @ l_vec)
    u = l_vec @ Li                        # back solve  Lᵀ u = l
    if harden and not f64:
        u = u + (l_vec - u @ Lc) @ Li
    c = (var + noise + jitter(var)).astype(dt)
    active = jnp.arange(n) < idx
    l_vec = jnp.where(active, l_vec, 0.0)
    u = jnp.where(active, u, 0.0)
    schur = jnp.maximum(c - jnp.sum(l_vec * l_vec),
                        schur_floor(var, noise).astype(dt))
    l_nn = jnp.sqrt(schur)
    l32 = l_vec.astype(jnp.float32)
    u32 = u.astype(jnp.float32)
    l_nn32 = l_nn.astype(jnp.float32)
    L = L.at[idx, :].set(l32.at[idx].set(l_nn32))
    Linv = Linv.at[idx, :].set((-u32 / l_nn32).at[idx].set(1.0 / l_nn32))
    return L, Linv, u32, schur.astype(jnp.float32)


def kinv_matvec(Linv: jax.Array, v: jax.Array) -> jax.Array:
    """K⁻¹v through the factor (two triangular matvecs) — alpha etc.
    Vector-first form: no materialized (n, n) transpose."""
    return (Linv @ v) @ Linv


# --------------------------------------------------------------------------- #
# The one scoring entry point (Pallas kernel or jnp twin — same math)
# --------------------------------------------------------------------------- #
def posterior_scores(Cs: jax.Array, Xs: jax.Array, y: jax.Array,
                     mask: jax.Array, Linv: jax.Array, var, noise, *,
                     use_pallas: bool, block_s: int = 256,
                     interpret: bool = True):
    """(mu, sig2, Kc, alpha) for pre-scaled candidates Cs against the
    pre-scaled training set (Xs, mask) through the factor Linv.

    Every GP strategy's device program scores through this function — the
    fused GP-BUCB slot loop and the clustering pipeline alike (the "one
    scoring backend" contract; tests monkeypatch it to verify dispatch).
    """
    from repro.kernels.gp_acquisition.gp_acquisition import score_cov_pallas

    alpha = kinv_matvec(Linv, y * mask)
    if use_pallas:
        mu, sig2, Kc = score_cov_pallas(Cs, Xs, mask, Linv, alpha, var,
                                        noise, block_s=block_s,
                                        interpret=interpret)
    else:
        mu, sig2, Kc = score_cov_ref(Cs, Xs, mask, Linv, alpha,
                                     jnp.float32(1.0), var, noise)
    return mu, sig2, Kc, alpha


def var_downdate(Cs, x_star, Kc, u, schur, sig2, var, *, use_pallas: bool,
                 block_s: int = 256, interpret: bool = True):
    """Rank-1 variance downdate after absorbing x*: kernel or jnp twin."""
    from repro.kernels.gp_acquisition.gp_acquisition import \
        var_downdate_pallas

    if use_pallas:
        return var_downdate_pallas(Cs, x_star, Kc, u, schur, sig2, var,
                                   block_s=block_s, interpret=interpret)
    return var_downdate_ref(Cs, x_star, Kc, u, schur, sig2,
                            jnp.float32(1.0), var)


# --------------------------------------------------------------------------- #
# Shared pending absorption (hardened factor appends, in-program)
# --------------------------------------------------------------------------- #
def absorb_pending(Xs: jax.Array, y: jax.Array, mask: jax.Array,
                   L: jax.Array, Linv: jax.Array, Ps: jax.Array,
                   n_pending: jax.Array, n_obs: jax.Array, var, noise,
                   pend_cap: int):
    """Hallucinate the (padded, ``pend_cap``) pending buffer in-program.

    GP-BUCB semantics, identical to the host ``GaussianProcess.hallucinate``
    loop: posterior mean at each in-flight point from the current extended
    system, hardened rank-1 (L, Linv) append, phantom y at the mean.  Both
    the fused Pallas proposal and the clustering pipeline absorb through
    this one loop.  ``Ps`` rows are pre-scaled like ``Xs``.
    """
    def absorb(j, carry):
        def do(c):
            Xs, y, mask, L, Linv = c
            x_new = Ps[j]
            k_vec = matern52(Xs, x_new[None, :], jnp.float32(1.0),
                             var)[:, 0] * mask
            mu = k_vec @ kinv_matvec(Linv, y * mask)
            slot = (n_obs + j).astype(jnp.int32)
            L2, Linv2, _, _ = factor_append(L, Linv, slot, k_vec, var,
                                            noise)
            return (Xs.at[slot].set(x_new), y.at[slot].set(mu),
                    mask.at[slot].set(1.0), L2, Linv2)
        return jax.lax.cond(j < n_pending, do, lambda c: c, carry)

    carry = (Xs, y.astype(jnp.float32), mask.astype(jnp.float32), L, Linv)
    return jax.lax.fori_loop(0, pend_cap, absorb, carry)


# --------------------------------------------------------------------------- #
# Shared GP-BUCB pick loop (scoring pass + per-slot rank-1 downdates)
# --------------------------------------------------------------------------- #
def pick_downdate_loop(Cs: jax.Array, Xs: jax.Array, S: int, y: jax.Array,
                       mask: jax.Array, L: jax.Array, Linv: jax.Array,
                       var, noise, n_obs: jax.Array,
                       domain_size: jax.Array, batch_size: int, *,
                       use_pallas: bool, block_s: int = 256,
                       interpret: bool = True) -> jax.Array:
    """GP-BUCB slot loop on the shared scorer with O(n S) per-slot rescores.

    One ``posterior_scores`` pass scores every candidate *and* caches the
    masked cross-covariance block k(C, X).  Hallucinating at the posterior
    mean leaves the mean invariant, so per slot only the variance moves:
    the rank-1 downdate contracts it by ``(k(c, x*) − k_cᵀu)²/schur`` from
    the cached block — O(n S) — instead of re-running the O(n² S)
    quadratic form per slot.  The cached block gains the picked point's
    column each slot, so later downdates see the full extended system.
    """
    # module-attribute call: the "one scoring backend" dispatch test
    # monkeypatches ``scoring.posterior_scores`` and must see this call
    import repro.core.scoring as scoring

    mu, sig2, Kc, _ = scoring.posterior_scores(
        Cs, Xs, y, mask, Linv, var, noise, use_pallas=use_pallas,
        block_s=block_s, interpret=interpret)
    return pick_downdate_from_scores(
        Cs, S, mu, sig2, Kc, L, Linv, var, noise, n_obs, domain_size,
        batch_size, use_pallas=use_pallas, block_s=block_s,
        interpret=interpret)


def pick_downdate_from_scores(Cs: jax.Array, S: int, mu: jax.Array,
                              sig2: jax.Array, Kc: jax.Array, L: jax.Array,
                              Linv: jax.Array, var, noise,
                              n_obs: jax.Array, domain_size: jax.Array,
                              batch_size: int, *, use_pallas: bool,
                              block_s: int = 256,
                              interpret: bool = True) -> jax.Array:
    """The slot loop of ``pick_downdate_loop`` given an already-scored
    candidate set — op-for-op the same program, split out so the staged
    bank pipeline (``gp.bank_pick``) can feed scores whose Matern ``exp``
    was evaluated in its own jit (XLA:CPU scalarizes ``exp`` whenever it
    is fused with any producer; standalone it vectorizes)."""
    import repro.core.scoring as scoring

    Sp = Cs.shape[0]

    def pick(b, sig2, avail, picks):
        beta = adaptive_beta_dev(n_obs + b, domain_size)
        acq = mu + jnp.sqrt(beta) * jnp.sqrt(sig2)
        acq = jnp.where(avail, acq, -jnp.inf)
        idx = jnp.argmax(acq).astype(jnp.int32)
        return idx, picks.at[b].set(idx), avail.at[idx].set(False)

    def body(b, carry):
        L, Linv, Kc, sig2, avail, picks = carry
        idx, picks, avail = pick(b, sig2, avail, picks)
        slot = (n_obs + b).astype(jnp.int32)
        # the cached row IS the masked Matern column of the picked point
        # (columns of not-yet-active slots are zero by construction)
        k_vec = Kc[idx]
        L, Linv, u, schur = factor_append(L, Linv, slot, k_vec, var, noise)
        sig2, k_new = scoring.var_downdate(
            Cs, Cs[idx], Kc, u, schur, sig2, var, use_pallas=use_pallas,
            block_s=block_s, interpret=interpret)
        Kc = Kc.at[:, slot].set(k_new)
        return L, Linv, Kc, sig2, avail, picks

    carry = (L, Linv.astype(jnp.float32), Kc, sig2,
             jnp.arange(Sp) < S, jnp.zeros((batch_size,), jnp.int32))
    carry = jax.lax.fori_loop(0, batch_size - 1, body, carry)
    _, _, _, sig2, avail, picks = carry
    _, picks, _ = pick(jnp.int32(batch_size - 1), sig2, avail, picks)
    return picks
