"""Ask/tell optimizer core: the one engine behind every tuner and scheduler.

Mango's headline contribution is a scheduler-agnostic optimizer (paper
§2.2/§2.4); this module makes that literal.  ``AskTellOptimizer`` owns *all*
optimizer state — the parameter space, the strategy/GP, the RNG, and a trial
ledger with stable ids — behind four calls:

    trials = opt.ask(n)          # propose n new configurations
    opt.tell(trial.id, value)    # observe a completed trial
    opt.tell_failed(trial.id)    # a crashed / dropped / non-finite trial
    sd = opt.state_dict()        # full serializable snapshot (JSON-able)

``Tuner`` is then nothing but the synchronous batch loop over this core and
``AsyncTuner`` the completion-event loop; any execution model (serial,
thread/process pools, the Celery-style task queue, or a user's own system)
can drive the same optimizer (the design Tune and Orchestrate argue for).

Pending trials are first-class in the ledger: ``ask`` hands the full
in-flight set to the strategy, and the default fused GP-BUCB path
hallucinates them *inside* its jit'd ``lax.fori_loop`` — one device program
per ask, no matter how many trials are outstanding.

Fault tolerance is the objective contract from the paper: trials that never
come back are simply never told; ``tell_failed`` (or a non-finite ``tell``)
records the loss without ever contaminating the GP.

``state_dict()/load_state_dict()`` serialize the ledger, the RNG stream, and
the GP's fit schedule (observation count + log-hyperparameters of the last
full fit), so a run killed mid-flight — sync or async — resumes to the exact
proposals of an uninterrupted one.

Since the StudyBank refactor the array-shaped part of that state (encoded X
rows, raw y, status, completion order, counters) lives in a ``StudyLedger``
— a registered pytree of fixed-capacity arrays — and an ``AskTellOptimizer``
is a *view* into one ledger row.  Stand-alone construction makes a private
bank of one; ``StudyBank`` passes a shared ledger so N studies checkpoint
as one pytree and ask through one vmap'd device program.  The single-study
compute path (what ``ask`` dispatches) is unchanged.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.spaces import ParamSpace
from repro.core.strategies import STRATEGIES
from repro.core.studybank import (S_FAILED, S_OBSERVED, S_PENDING,
                                  StudyLedger, _y_standardization,
                                  rng_from_state)

PENDING = "pending"
OBSERVED = "observed"
FAILED = "failed"

# strategies whose asks are served by the bucketed StudyBank pipeline
# (bank-of-one for stand-alone optimizers).  The legacy reference
# strategies (hallucination_ref) and random keep their own propose paths.
_BANKABLE = {"bayesian", "hallucination", "tpe", "clustering"}

_STATUS_CODE = {PENDING: S_PENDING, OBSERVED: S_OBSERVED, FAILED: S_FAILED}
_STATUS_NAME = {v: k for k, v in _STATUS_CODE.items()}


class Trial:
    """One proposed configuration, tracked from ask to tell.

    When attached to a ``StudyLedger`` (every trial an optimizer hands out
    is), ``status``/``value``/``obs_seq`` read through to the ledger arrays
    — the trial object is a view, not a copy, so fleet checkpoints and the
    Python API can never disagree.  Detached construction (no ledger) keeps
    the old plain-record behaviour."""

    __slots__ = ("id", "params", "_led", "_b",
                 "_status", "_value", "_obs_seq")

    def __init__(self, id: int, params: Dict[str, Any],
                 status: str = PENDING, value: Optional[float] = None,
                 obs_seq: Optional[int] = None, *,
                 _ledger: Optional[StudyLedger] = None, _study: int = 0):
        self.id = id
        self.params = params
        self._led = _ledger
        self._b = _study
        self._status = status
        self._value = value
        self._obs_seq = obs_seq

    @property
    def status(self) -> str:
        if self._led is None:
            return self._status
        return _STATUS_NAME.get(int(self._led.status[self._b, self.id]),
                                PENDING)

    @status.setter
    def status(self, v: str) -> None:
        self._status = v
        if self._led is not None:
            code = _STATUS_CODE[v]
            # entering/leaving the observed set changes the GP system:
            # invalidate the bank's obs_stamp-keyed device cache.  Pending
            # churn (ask / tell_failed) deliberately does NOT bump.
            if (code == S_OBSERVED or
                    int(self._led.status[self._b, self.id]) == S_OBSERVED):
                self._led.obs_stamp += 1
            self._led.status[self._b, self.id] = code

    @property
    def value(self) -> Optional[float]:
        if self._led is None:
            return self._value
        if int(self._led.status[self._b, self.id]) != S_OBSERVED:
            return None
        return float(self._led.y[self._b, self.id])

    @value.setter
    def value(self, v: Optional[float]) -> None:
        self._value = v
        if self._led is not None and v is not None:
            self._led.y[self._b, self.id] = float(v)
            self._led.obs_stamp += 1

    @property
    def obs_seq(self) -> Optional[int]:
        if self._led is None:
            return self._obs_seq
        s = int(self._led.obs_seq[self._b, self.id])
        return None if s < 0 else s

    @obs_seq.setter
    def obs_seq(self, v: Optional[int]) -> None:
        self._obs_seq = v
        if self._led is not None and v is not None:
            self._led.obs_seq[self._b, self.id] = int(v)
            self._led.obs_stamp += 1

    def __repr__(self) -> str:
        return (f"Trial(id={self.id}, params={self.params!r}, "
                f"status={self.status!r}, value={self.value!r}, "
                f"obs_seq={self.obs_seq!r})")


def _to_jsonable(cfg: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in cfg.items():
        if isinstance(v, (np.integer,)):
            out[k] = int(v)
        elif isinstance(v, (np.floating,)):
            out[k] = float(v)
        elif isinstance(v, np.ndarray):
            out[k] = v.tolist()
        elif isinstance(v, dict):
            # conditional (Choice) params nest {"_choice": ..., child: ...}
            out[k] = _to_jsonable(v)
        else:
            out[k] = v
    return out


class AskTellOptimizer:
    """Serializable ask/tell engine over the batch-selection strategies."""

    def __init__(self, param_space, *, optimizer: str = "bayesian",
                 seed: int = 0, sign: float = 1.0,
                 domain_size: Optional[float] = None,
                 mc_samples: Optional[int] = None, fit_steps: int = 40,
                 use_pallas: bool = False, pallas_interpret: bool = True,
                 refit_every: int = 8,
                 strategy_kwargs: Optional[Dict[str, Any]] = None,
                 ledger: Optional[StudyLedger] = None,
                 study_index: int = 0):
        self.space = (param_space if isinstance(param_space, ParamSpace)
                      else ParamSpace(param_space))
        if optimizer not in STRATEGIES:
            raise ValueError(f"unknown optimizer {optimizer!r}; "
                             f"choose from {sorted(STRATEGIES)}")
        self.optimizer = optimizer
        self.mc_samples = mc_samples
        self.fit_steps = fit_steps
        self.use_pallas = use_pallas
        self.pallas_interpret = pallas_interpret
        self.refit_every = refit_every
        # strategy-specific knobs (e.g. tpe's gamma/pending_penalty,
        # clustering's top_frac) forwarded verbatim to the constructor —
        # unknown keys raise TypeError there, so typos can't be dropped
        self.strategy_kwargs = dict(strategy_kwargs or {})
        self.domain_size = domain_size or self.space.domain_size
        self.sign = sign                   # +1 maximize, -1 minimize
        self._rng = np.random.default_rng(seed)
        # array-shaped state lives in the ledger (a private bank of one
        # unless a StudyBank passed its shared ledger); params dicts and
        # the trace stay on the view
        self._led = (ledger if ledger is not None
                     else StudyLedger(1, self.space.dim))
        self._b = int(study_index)
        if not 0 <= self._b < self._led.n_studies:
            raise ValueError(f"study_index {study_index} out of range for "
                             f"a {self._led.n_studies}-study ledger")
        if self._led.dim != self.space.dim:
            raise ValueError("ledger dim does not match the param space")
        self._trials: Dict[int, Trial] = {}   # insertion order == ask order
        self._best_trace: List[float] = []    # raw best-so-far snapshots
        self._strat = None
        self._gp_snapshot = None   # pending restore from load_state_dict
        # the bank engine serving this view's asks: the owning StudyBank
        # (set by its constructor) or a lazily-built bank of one
        self._bank = None

    # ---- ledger-backed counters (the view's scalars ARE the array row) ----
    @property
    def _next_id(self) -> int:
        return int(self._led.n_trials[self._b])

    @_next_id.setter
    def _next_id(self, v: int) -> None:
        self._led.ensure_capacity(v)
        self._led.n_trials[self._b] = v

    @property
    def _ask_count(self) -> int:
        return int(self._led.ask_count[self._b])

    @_ask_count.setter
    def _ask_count(self, v: int) -> None:
        self._led.ask_count[self._b] = v

    @property
    def _obs_count(self) -> int:
        return int(self._led.obs_count[self._b])

    @_obs_count.setter
    def _obs_count(self, v: int) -> None:
        self._led.obs_count[self._b] = v

    @property
    def _n_failed(self) -> int:
        return int(self._led.n_failed[self._b])

    @_n_failed.setter
    def _n_failed(self, v: int) -> None:
        self._led.n_failed[self._b] = v

    # ------------------------------------------------------------- ledger
    def trials(self) -> List[Trial]:
        return list(self._trials.values())

    def pending_trials(self) -> List[Trial]:
        return [t for t in self._trials.values() if t.status == PENDING]

    def observed_trials(self) -> List[Trial]:
        """Observed trials in *completion* order.  Async completions land
        out of ask order; keeping the GP history in tell order makes it
        append-only, so ``GaussianProcess.observe``'s prefix check stays
        satisfied and observations extend the Cholesky incrementally
        instead of tripping a full refit on almost every ask."""
        obs = [t for t in self._trials.values() if t.status == OBSERVED]
        obs.sort(key=lambda t: t.obs_seq)
        return obs

    @property
    def num_trials(self) -> int:
        return len(self._trials)

    @property
    def n_observed(self) -> int:
        return len(self.observed_trials())

    @property
    def n_failed(self) -> int:
        return self._n_failed

    # ----------------------------------------------------------- strategy
    def _ensure_strategy(self):
        if self._strat is None:
            cls = STRATEGIES[self.optimizer]
            self._strat = cls(self.space.dim, self.domain_size,
                              fit_steps=self.fit_steps,
                              use_pallas=self.use_pallas,
                              pallas_interpret=self.pallas_interpret,
                              refit_every=self.refit_every,
                              **self.strategy_kwargs)
            if self.optimizer not in _BANKABLE:
                # legacy strategies replay their GP from the snapshot; the
                # bank-served paths restored theirs into the ledger at
                # load_state_dict time (the strategy GP stays untouched)
                gp = getattr(self._strat, "gp", None)
                if gp is not None and self._gp_snapshot is not None:
                    obs = self.observed_trials()
                    if obs:
                        gp.restore_exact(
                            self.space.encode([t.params for t in obs]),
                            self._signed_y(obs), self._gp_snapshot)
                self._gp_snapshot = None
        return self._strat

    def _engine(self):
        """The StudyBank serving this view's asks — the owning bank when
        this view is a fleet member, else a lazily-built bank of one over
        the private ledger (same bucketed pipeline, same compiled
        programs)."""
        if self._bank is None:
            from repro.core.studybank import StudyBank
            self._bank = StudyBank._wrap_view(self)
        return self._bank

    def _signed_y(self, obs: List[Trial]) -> np.ndarray:
        return np.asarray([self.sign * t.value for t in obs],
                          dtype=np.float32)

    # ---------------------------------------------------------------- ask
    def ask(self, n: int = 1) -> List[Trial]:
        """Propose ``n`` new trials; they enter the ledger as pending."""
        if n < 1:
            raise ValueError("ask(n) requires n >= 1")
        strat = self._ensure_strategy()
        obs = self.observed_trials()
        seed = self._ask_count
        if not strat.needs_gp:
            n_mc = self.mc_samples or self.space.mc_samples(n)
            cands = self.space.sample(n_mc, self._rng)
            idx = strat.propose(None, [], self.space.encode(cands), n,
                                seed=seed)
            chosen = [cands[i] for i in idx]
        elif len(obs) < 2:
            # not enough observations to model: explore at random (the
            # drivers' initial_random phase lands here too)
            chosen = self.space.sample(n, self._rng)
        elif self.optimizer in _BANKABLE:
            # bank-of-one: the bucketed StudyBank pipeline serves the ask
            # (zero retraces across observation growth).  Candidates come
            # from this view's own RNG via the columnar sampler, which
            # consumes the exact byte stream ``sample`` would — proposals
            # are bit-identical to the retired per-strategy fused path.
            n_mc = self.mc_samples or self.space.mc_samples(n)
            cols = self.space.sample_columns(n_mc, self._rng)
            cfgs, enc = self._engine().ask_view(self, n, cols, n_mc)
            self._ask_count += 1
            return self._register_asked(list(cfgs), enc)
        else:
            n_mc = self.mc_samples or self.space.mc_samples(n)
            cands = self.space.sample(n_mc, self._rng)
            C = self.space.encode(cands)
            X = self.space.encode([t.params for t in obs])
            y = self._signed_y(obs)
            pend = self.pending_trials()
            P = (self.space.encode([t.params for t in pend])
                 if pend else None)
            idx = strat.propose(X, y, C, n, seed=seed, pending=P)
            chosen = [cands[i] for i in idx]
        self._ask_count += 1
        return self._register_asked(chosen)

    def _register_asked(self, chosen: List[Dict[str, Any]],
                        enc: Optional[np.ndarray] = None) -> List[Trial]:
        """Enter proposed configs into the ledger as pending trials.
        ``enc`` (their encoded rows) avoids a re-encode when the caller —
        the bank's batched ask — already has them."""
        if enc is None:
            enc = self.space.encode(list(chosen))
        led, b = self._led, self._b
        out = []
        for p, row in zip(chosen, enc):
            tid = self._next_id
            self._next_id = tid + 1          # grows ledger capacity too
            led.X[b, tid, :] = row
            led.status[b, tid] = S_PENDING
            led.obs_seq[b, tid] = -1
            t = Trial(tid, dict(p), _ledger=led, _study=b)
            self._trials[tid] = t
            out.append(t)
        return out

    # --------------------------------------------------------------- tell
    def _get_pending(self, trial_id: int) -> Trial:
        t = self._trials.get(trial_id)
        if t is None:
            raise KeyError(f"unknown trial id {trial_id!r} "
                           "(tell before ask?)")
        if t.status != PENDING:
            raise ValueError(f"trial {trial_id} already {t.status}")
        return t

    def tell(self, trial_id: int, value: float) -> Trial:
        """Observe a completed trial.  Non-finite values count as failures
        (the paper's contract: they must never reach the surrogate)."""
        t = self._get_pending(trial_id)
        v = float(value)
        if not np.isfinite(v):
            t.status = FAILED
            self._n_failed += 1
            return t
        t.status = OBSERVED
        t.value = v
        t.obs_seq = self._obs_count
        self._obs_count += 1
        # drivers may rebind t.params to the exact config the objective ran
        # (the batch tuner does) — re-encode so the ledger row matches
        self._led.X[self._b, t.id, :] = self.space.encode([t.params])[0]
        return t

    def tell_failed(self, trial_id: int) -> Trial:
        """Record a crashed/dropped trial; it is never observed."""
        t = self._get_pending(trial_id)
        t.status = FAILED
        self._n_failed += 1
        return t

    # ------------------------------------------------- idempotent tell (WAL)
    # The durable tuning service delivers tells at-least-once: a client that
    # lost the response to a journaled tell retries it, and crash recovery
    # replays a WAL suffix that may overlap the snapshot.  Dedup is by trial
    # id: the first resolution wins, a repeat is a no-op (never an error and
    # never a second ledger write).

    def tell_once(self, trial_id: int, value: float):
        """Idempotent ``tell``: returns ``(trial, applied)``.  A trial that
        is already observed/failed is left untouched (``applied=False``);
        an unknown id still raises ``KeyError`` (tell-before-ask is a
        protocol violation, not a duplicate)."""
        t = self._trials.get(trial_id)
        if t is None:
            raise KeyError(f"unknown trial id {trial_id!r} "
                           "(tell before ask?)")
        if t.status != PENDING:
            return t, False
        return self.tell(trial_id, value), True

    def tell_failed_once(self, trial_id: int):
        """Idempotent ``tell_failed``; same contract as ``tell_once``."""
        t = self._trials.get(trial_id)
        if t is None:
            raise KeyError(f"unknown trial id {trial_id!r} "
                           "(tell before ask?)")
        if t.status != PENDING:
            return t, False
        return self.tell_failed(trial_id), True

    def observe_params(self, params: Dict[str, Any], value: float) -> Trial:
        """Observe a configuration that never went through ``ask`` (an
        objective returning params outside its batch — the legacy contract
        lets it).  Enters the ledger directly as observed/failed."""
        # anything that can fail runs before any state mutates: a bad
        # config (param missing from the space, un-encodable value) must
        # not burn a trial id or leave a half-registered phantom trial —
        # the durable service relies on a failed observe being a no-op
        params = dict(params)
        v = float(value)
        enc = self.space.encode([params])[0]
        led, b = self._led, self._b
        tid = self._next_id
        self._next_id = tid + 1
        t = Trial(tid, params, _ledger=led, _study=b)
        self._trials[tid] = t
        led.X[b, tid, :] = enc
        led.status[b, tid] = S_PENDING
        if np.isfinite(v):
            t.status = OBSERVED
            t.value = v
            t.obs_seq = self._obs_count
            self._obs_count += 1
        else:
            t.status = FAILED
            self._n_failed += 1
        return t

    # ------------------------------------------------------------ results
    def snapshot_trace(self) -> None:
        """Append the current raw best to the best-so-far trace (drivers
        call this at their iteration/completion boundaries)."""
        obs = self.observed_trials()
        if obs:
            self._best_trace.append(
                self.sign * max(self.sign * t.value for t in obs))

    def results(self, iterations: Optional[int] = None, wall: float = 0.0):
        from repro.core.tuner import TunerResults
        obs = self.observed_trials()
        if obs:
            best = max(obs, key=lambda t: self.sign * t.value)
            best_y, best_p = best.value, best.params
        else:
            best_y, best_p = float("nan"), {}
        return TunerResults(
            best_objective=best_y,
            best_params=best_p,
            params_tried=[t.params for t in obs],
            objective_values=[t.value for t in obs],
            best_trace=list(self._best_trace),
            iterations=(self._ask_count if iterations is None
                        else iterations),
            n_failed=self._n_failed,
            wall_time_s=wall,
        )

    # --------------------------------------------------------- state dict
    def _gp_export(self) -> Optional[Dict[str, Any]]:
        """Fit-schedule snapshot for the state dict's ``"gp"`` key, in the
        v1 ``GaussianProcess.export_state`` format: the live strategy GP
        when it has one (legacy propose paths), else the ledger row's bank
        fit schedule (the bank-served paths), else whatever snapshot a
        load handed us that hasn't been consumed yet."""
        gp = getattr(self._strat, "gp", None) if self._strat else None
        snap = gp.export_state() if gp is not None else None
        if snap is not None:
            return snap
        led, b = self._led, self._b
        if int(led.have_fit[b]):
            return {
                "n_fit": int(led.n_fit[b]),
                "log_params": {
                    "log_ls": np.asarray(led.log_ls[b],
                                         np.float32).tolist(),
                    "log_var": np.float32(led.log_var[b]).tolist(),
                    "log_noise": np.float32(led.log_noise[b]).tolist(),
                }}
        return self._gp_snapshot

    def state_dict(self) -> Dict[str, Any]:
        """Full JSON-able snapshot: ledger (pending trials included, so a
        driver can re-dispatch them on resume), RNG stream, counters, and
        the GP fit schedule."""
        return {
            "version": 1,
            "next_id": self._next_id,
            "ask_count": self._ask_count,
            "n_failed": self._n_failed,
            "sign": self.sign,
            "best_trace": list(self._best_trace),
            "trials": [{"id": t.id, "params": _to_jsonable(t.params),
                        "status": t.status, "value": t.value,
                        "obs_seq": t.obs_seq}
                       for t in self._trials.values()],
            "rng_state": self._rng.bit_generator.state,
            "gp": self._gp_export(),
        }

    def load_state_dict(self, sd: Dict[str, Any]) -> None:
        led, b = self._led, self._b
        led.reset_study(b)
        self._next_id = sd["next_id"]
        self._ask_count = sd["ask_count"]
        self._n_failed = sd["n_failed"]
        self.sign = sd.get("sign", 1.0)
        self._best_trace = list(sd.get("best_trace", []))
        self._trials = {}
        recs = sd["trials"]
        if recs:
            enc = self.space.encode([rec["params"] for rec in recs])
        for i, rec in enumerate(recs):
            tid = rec["id"]
            t = Trial(tid, rec["params"], _ledger=led, _study=b)
            led.X[b, tid, :] = enc[i]
            led.status[b, tid] = _STATUS_CODE[rec["status"]]
            if rec["value"] is not None:
                led.y[b, tid] = float(rec["value"])
            seq = rec.get("obs_seq")
            led.obs_seq[b, tid] = -1 if seq is None else int(seq)
            self._trials[tid] = t
        self._obs_count = 1 + max(
            (t.obs_seq for t in self._trials.values()
             if t.obs_seq is not None), default=-1)
        self._rng = rng_from_state(sd["rng_state"])
        self._gp_snapshot = sd.get("gp")
        self._strat = None   # rebuilt (with GP replay) on the next ask
        snap = self._gp_snapshot
        if snap and self.optimizer in _BANKABLE:
            # bank-served paths keep their fit schedule in the ledger:
            # restore the log-hypers and the frozen standardization over
            # the first n_fit observations (the exact scalars the
            # uninterrupted run froze at its last refit), so the resumed
            # bank replays bit-identical proposals
            obs = self.observed_trials()
            if obs:
                lp = snap["log_params"]
                led.log_ls[b] = np.asarray(lp["log_ls"], np.float32)
                led.log_var[b] = np.float32(lp["log_var"])
                led.log_noise[b] = np.float32(lp["log_noise"])
                n_fit = max(1, min(int(snap["n_fit"]), len(obs)))
                led.n_fit[b] = n_fit
                led.have_fit[b] = 1
                led.y_mean[b], led.y_std[b] = _y_standardization(
                    self._signed_y(obs)[:n_fit])
                led.obs_stamp += 1   # defensive: hypers changed

    # ------------------------------------------------------- file checkpoint
    def save(self, path, iteration: int = 0) -> None:
        """Atomically write ``{"iteration", "optimizer"}`` to ``path`` (the
        one checkpoint format both drivers share)."""
        p = Path(path)
        tmp = p.with_suffix(".tmp")
        with open(tmp, "w") as fh:
            fh.write(json.dumps({"iteration": iteration,
                                 "optimizer": self.state_dict()}))
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, p)  # atomic swap: a crash never publishes a torn file

    def load(self, path) -> int:
        """Load a ``save`` checkpoint; returns the stored iteration."""
        state = json.loads(Path(path).read_text())
        self.load_state_dict(state["optimizer"])
        return state["iteration"]
