"""Sharded train-state checkpointing with elastic (re-mesh) restore.

Format: one ``.npz`` per checkpoint step holding every pytree leaf under its
"/"-joined path, plus a JSON sidecar with step, data-pipeline state, and
tuner/hyper metadata.  Leaves are gathered to host before writing (on a real
fleet each host writes its own shard slice; here the single-process dry-run
semantics are: fully addressable arrays -> np.asarray).

Elastic restore: arrays are written *unsharded*, so a checkpoint saved on the
(16,16) mesh restores onto (2,16,16), (4,4), or a single device — the caller
just passes the new shardings.  Tested in tests/test_checkpoint.py.

Fault-tolerance drill: ``save`` writes to a temp name and atomically renames,
and keeps the last ``keep`` checkpoints, so a crash mid-save never corrupts
the latest restorable state.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    def fill(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        return arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr

    return jax.tree_util.tree_map_with_path(fill, template)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, state, extra: Optional[Dict[str, Any]] = None
             ) -> None:
        flat = _flatten(state)  # host gather happens here
        if self._thread is not None:
            self._thread.join()  # never overlap two writes

        def write():
            tmp = self.dir / f".tmp_step_{step:08d}.npz"
            final = self.dir / f"step_{step:08d}.npz"
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)  # atomic, durable publish
            meta = {"step": step, **(extra or {})}
            mtmp = self.dir / f".tmp_step_{step:08d}.json"
            with open(mtmp, "w") as f:
                f.write(json.dumps(meta))
                f.flush()
                os.fsync(f.fileno())
            os.replace(mtmp, self.dir / f"step_{step:08d}.json")
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        ckpts = sorted(self.dir.glob("step_*.npz"))
        for old in ckpts[:-self.keep]:
            old.unlink(missing_ok=True)
            old.with_suffix(".json").unlink(missing_ok=True)

    # -------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("step_*.npz"))
        if not ckpts:
            return None
        return int(ckpts[-1].stem.split("_")[1])

    def restore(self, step: Optional[int], state_template,
                shardings=None) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the template's structure, placing onto ``shardings``
        (any mesh — elastic re-mesh restore)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with np.load(self.dir / f"step_{step:08d}.npz") as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(state_template, flat)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        meta = json.loads((self.dir / f"step_{step:08d}.json").read_text())
        return state, meta
