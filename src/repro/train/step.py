"""Train / prefill / decode step builders.

``make_train_step`` returns a pure (state, batch) -> (state, metrics) function:
gradient accumulation over microbatches (lax.scan), global-norm clipping,
AdamW update — ready for ``jax.jit`` with donated state.

Microbatch count is auto-chosen (unless overridden) so the per-chip live
activation estimate stays under a budget — this is what lets 80-layer
internvl2-76b fit the v5e 16GB HBM at train_4k (see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.common import Runtime
from repro.models.transformer import forward_decode, forward_train
from repro.optim.adamw import AdamWConfig, opt_init, opt_update


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    n_microbatches: int = 0  # 0 -> auto
    grad_compression: str = "none"  # none | int8_ef


def auto_microbatches(cfg: ArchConfig, shape: ShapeConfig, rt: Runtime,
                      act_budget_bytes: float = 2.5e9) -> int:
    """Pick #microbatches so saved period-boundary activations fit the budget.

    With remat policy "full", the live backward-pass footprint per chip is
    ~ n_layers * B_micro_local * S * d_model * 2 bytes (boundary residuals).
    """
    dp = max(rt.sc.dp, 1)
    b_local = max(shape.global_batch // dp, 1)
    per_b = cfg.n_layers * shape.seq_len * cfg.d_model * 2
    n = 1
    while b_local % (2 * n) == 0 and (b_local // n) * per_b > act_budget_bytes:
        n *= 2
    return max(n, 1)


def make_train_step(cfg: ArchConfig, rt: Runtime, hyper: TrainHyper,
                    n_microbatches: int = 1) -> Callable:
    n_micro = max(n_microbatches, 1)

    def loss_fn(params, micro_batch):
        return forward_train(params, micro_batch, cfg, rt)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]
                   ) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
        params = state["params"]

        # Microbatch layout (B/n, n, ...): keeps the DP-sharded rows of each
        # microbatch contiguous on their owning chip (no resharding per step).
        def micro_slices(t):
            B = t.shape[0]
            return t.reshape((B // n_micro, n_micro) + t.shape[1:])

        micro = jax.tree.map(micro_slices, batch)

        def accum(carry, m_idx):
            g_acc, m_acc = carry
            mb = jax.tree.map(
                lambda t: jax.lax.dynamic_index_in_dim(
                    t, m_idx, axis=1, keepdims=False), micro)
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_micro,
                g_acc, grads)
            m_acc = jax.tree.map(lambda a, m: a + m / n_micro, m_acc, metrics)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"loss": 0.0, "ce": 0.0, "tokens": 0.0, "moe_lb_loss": 0.0,
              "moe_router_z": 0.0, "moe_drop_frac": 0.0}
        m0 = jax.tree.map(lambda x: jnp.zeros((), jnp.float32), m0)
        if n_micro == 1:
            (grads, metrics), _ = accum(
                (g0, m0), jnp.zeros((), jnp.int32))
        else:
            (grads, metrics), _ = jax.lax.scan(
                accum, (g0, m0), jnp.arange(n_micro))

        new_state = {}
        if hyper.grad_compression == "int8_ef":
            from repro.optim.compression import ef_compress_tree
            grads, new_ef = ef_compress_tree(grads, state["ef"])
            new_state["ef"] = new_ef

        new_params, new_opt, opt_metrics = opt_update(
            hyper.opt, params, grads, state["opt"])
        new_state.update(params=new_params, opt=new_opt)
        metrics = {**metrics, **opt_metrics}
        return new_state, metrics

    return train_step


def init_train_state(key, cfg: ArchConfig, rt: Runtime,
                     grad_compression: str = "none") -> Dict[str, Any]:
    from repro.models.transformer import init_params
    params = init_params(key, cfg, rt)
    state = {"params": params, "opt": opt_init(params)}
    if grad_compression == "int8_ef":
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


# --------------------------------------------------------------------------- #
# Serving
# --------------------------------------------------------------------------- #
def make_decode_step(cfg: ArchConfig, rt: Runtime) -> Callable:
    def decode_step(params, tokens, cache, cache_len):
        logits, new_cache = forward_decode(params, tokens, cache, cache_len,
                                           cfg, rt)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # greedy
        return next_tok, new_cache

    return decode_step


def make_prefill_step(cfg: ArchConfig, rt: Runtime,
                      cache_size: Optional[int] = None) -> Callable:
    from repro.models.transformer import forward_prefill

    def prefill_step(params, batch):
        logits, cache = forward_prefill(params, batch, cfg, rt,
                                        cache_size=cache_size)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return prefill_step
