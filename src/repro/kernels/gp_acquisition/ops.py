"""jit'd wrapper: pads shapes to kernel-friendly sizes and dispatches.

``gp_mean_std`` adapts a ``repro.core.gp.GPState`` to the fused kernel so the
batch strategies can use it via ``Tuner(config={"use_pallas": True})``.
On CPU the kernel runs in interpret mode (correctness path); on TPU set
``interpret=False`` for the compiled kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gp_acquisition.gp_acquisition import score_cov_pallas


def _pad_rows(a: np.ndarray, m: int) -> np.ndarray:
    return np.pad(a, [(0, m - a.shape[0])] + [(0, 0)] * (a.ndim - 1))


def _prescale(cands, X, ls, block_s):
    cands = np.asarray(cands, np.float32)
    S, d = cands.shape
    dp = max(8, int(math.ceil(d / 8)) * 8)
    Sp = int(math.ceil(S / block_s)) * block_s
    ls = np.broadcast_to(np.asarray(ls, np.float32), (d,))
    c = np.zeros((Sp, dp), np.float32)
    c[:S, :d] = cands / ls
    Xp = np.zeros((X.shape[0], dp), np.float32)
    Xp[:, :d] = np.asarray(X, np.float32) / ls
    return c, Xp, S


def score_cov(cands, X, mask, Linv, alpha, ls, var, noise, *,
              block_s: int = 256, interpret: bool = True):
    """(mu, sig2) for every candidate in ONE kernel dispatch (the cached
    cross-covariance block the kernel also emits is dropped here).
    ``Linv`` is the triangular inverse factor L^{-1}."""
    c, Xp, S = _prescale(cands, X, ls, block_s)
    mu, sig2, _ = score_cov_pallas(
        jnp.asarray(c), jnp.asarray(Xp), jnp.asarray(mask, jnp.float32),
        jnp.asarray(Linv, jnp.float32), jnp.asarray(alpha, jnp.float32),
        jnp.asarray(var, jnp.float32), jnp.asarray(noise, jnp.float32),
        block_s=block_s, interpret=interpret)
    mu, sig2 = jax.device_get((mu, sig2))  # one explicit adapter exit
    return mu[:S], sig2[:S]


def gp_mean_std(st, cands, interpret: bool = True):
    """GPState-facing adapter returning (mu, sd) in the original y scale."""
    if getattr(st, "Linv", None) is not None:
        # incrementally-maintained factor (track_factor): no O(n^3) solve
        Linv = np.asarray(st.Linv)
    else:
        L = np.asarray(st.L)
        eye = np.eye(L.shape[0], dtype=np.float32)
        import scipy.linalg as sla
        Linv = sla.solve_triangular(L, eye, lower=True)
    alpha = Linv.T @ (Linv @ (np.asarray(st.y, np.float32)
                              * np.asarray(st.mask, np.float32)))
    var = float(st.var)
    noise = float(st.noise)
    # one scoring-kernel dispatch yields both moments (the old path ran
    # the UCB kernel twice, with beta=0 and beta=1, to recover sd)
    mu, sig2 = score_cov(cands, st.X, st.mask, Linv, alpha,
                         np.asarray(st.ls), var, noise, interpret=interpret)
    return mu * st.y_std + st.y_mean, np.sqrt(sig2) * st.y_std
