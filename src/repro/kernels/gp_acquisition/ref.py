"""Pure-jnp oracles for the fused GP-UCB acquisition scorer.

Given the padded training set (X, mask), the triangular inverse factor
Linv = L^-1 of its Cholesky, and alpha = K^-1 y, score S candidates:

    k_i   = matern52(X, c_i)            (n,)
    mu_i  = k_i . alpha
    var_i = var + noise - ||k_i Linv^T||^2     (monotone sum of squares)
    ucb_i = mu_i + sqrt(beta) * sqrt(var_i)

The sum-of-squares form is the conditioning-hardened scoring contract
(ISSUE 5) shared with the Pallas kernels; ``score_cov_ref`` doubles as the
shared core's jnp execution backend.  ``ucb_scores_ref`` alone retains the
legacy K^-1 quadratic form ``k . (Kinv k)`` as a *numerical contrast
oracle* (``benchmarks/kernel_bench.py`` and the conditioning tests use it
to show the cancellation the hardening removed); the ``pallas_rescore_*``
benchmark rows measure the factor scorer ``score_cov_pallas`` directly.

This is Mango's Monte-Carlo acquisition-maximization hot loop (paper §2.3):
S is 10^3..10^5 per pick, times batch_size picks, times iterations.
"""
from __future__ import annotations

import jax.numpy as jnp


def matern52(x1, x2, ls, var):
    z1 = x1 / ls
    z2 = x2 / ls
    d2 = (jnp.sum(z1 * z1, -1)[:, None] + jnp.sum(z2 * z2, -1)[None, :]
          - 2.0 * z1 @ z2.T)
    r = jnp.sqrt(jnp.maximum(d2, 1e-12))
    s = jnp.sqrt(5.0) * r
    return var * (1.0 + s + (5.0 / 3.0) * d2) * jnp.exp(-s)


def ucb_scores_ref(cands, X, mask, Kinv, alpha, ls, var, noise, beta):
    """cands (S, d); X (n, d); mask (n,); Kinv (n, n); alpha (n,) -> (S,)."""
    K = matern52(cands, X, ls, var) * mask[None, :]       # (S, n)
    mu = K @ alpha
    t = K @ Kinv                                          # (S, n)
    q = jnp.sum(t * K, axis=-1)
    sig2 = jnp.maximum(var + noise - q, 1e-10)
    return mu + jnp.sqrt(beta) * jnp.sqrt(sig2)


def score_cov_ref(cands, X, mask, Linv, alpha, ls, var, noise):
    """Oracle for the score+cross-covariance kernel: (mu, sig2, k(C, X)).

    Consumes the triangular inverse factor ``Linv = L^{-1}`` and evaluates
    the posterior variance as the monotone sum of squares ``var + noise −
    ‖k Linvᵀ‖²`` — the conditioning-hardened form shared with the Pallas
    kernel (the legacy K^{-1} quadratic form above cancels catastrophically
    on near-noiseless fits).  Doubles as the shared scoring core's jnp
    execution backend (``scoring.posterior_scores(use_pallas=False)``).
    """
    K = matern52(cands, X, ls, var) * mask[None, :]       # (S, n)
    mu = K @ alpha
    t = K @ Linv.T
    q = jnp.sum(t * t, axis=-1)
    sig2 = jnp.maximum(var + noise - q, 1e-10)
    return mu, sig2, K


def var_downdate_ref(cands, x_star, Kc, u, schur, sig2, ls, var):
    """Oracle for the rank-1 variance downdate kernel.

    After absorbing x* with Schur vector u = K^{-1} k_* and complement
    ``schur``, each candidate's posterior variance contracts by
    ``(k(c, x*) - k_c^T u)^2 / schur`` — exactly the extended system's
    block-inverse quadratic form, at O(n) per candidate.
    """
    knew = matern52(cands, x_star[None, :], ls, var)[:, 0]      # (S,)
    proj = knew - Kc @ u
    return jnp.maximum(sig2 - proj * proj / schur, 1e-10), knew
