"""Pallas TPU kernel: fused Matern-5/2 + GP posterior scoring.

Tiling: candidates are blocked (BS rows per grid step) into VMEM; the padded
training set (n <= 512 typically), the triangular inverse factor, and alpha
are small enough to live in VMEM for the whole kernel.  Per block:

    MXU:  cross-covariance k (BS, n)  via the |c - x|^2 expansion (one matmul)
          t = k @ L^{-T}              (BS, n)
    VPU:  matern transform, mu/var epilogue (+ rank-1 downdates)

which avoids 3 HBM round-trips of the (S, n) covariance the unfused jnp
version makes (k, t, and the elementwise products each materialize).

The candidate dim d is zero-padded to a lane multiple by ops.py; padded
columns contribute 0 to the distance because both operands are 0 there.

The original fused-UCB kernel (dense K^{-1} quadratic form, beta baked into
the epilogue) was retired with the K^{-1} scoring path: ``score_cov_pallas``
is the one scoring kernel (factor-based, variance as a monotone sum of
squares) and acquisition epilogues live in ``core.scoring``/``core.
acquisition`` on top of its (mu, sig2) output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_cov_kernel(c_ref, x_ref, mask_ref, linvt_ref, alpha_ref, scal_ref,
                      mu_ref, sig2_ref, k_ref):
    """Posterior scoring pass that also *emits* the masked cross-covariance
    block k(C, X) so the batch slot loop can rescore candidates with O(n S)
    rank-1 variance downdates (``_downdate_kernel``) instead of re-running
    the O(n^2 S) quadratic form per slot.

    Conditioning (ISSUE 5): the resident (n, n) operand is the *transposed
    triangular inverse factor* L^{-T}, not K^{-1}, and the posterior
    variance is the monotone sum of squares ``sig2 = var + noise −
    Σ_j (k L^{-T})_j²`` — the Cholesky path's own formula, evaluated as one
    MXU matmul.  The old ``q = Σ (k K^{-1}) · k`` form cancels its large
    mixed-sign intermediates and measured ~250x the float32 error when the
    fitted noise collapses, flipping near-tied argmaxes."""
    c = c_ref[...]                      # (BS, d)  already / lengthscale
    x = x_ref[...]                      # (n, d)   already / lengthscale
    mask = mask_ref[...]                # (1, n)
    var = scal_ref[0, 0]
    noise = scal_ref[0, 1]

    c2 = jnp.sum(c * c, axis=-1, keepdims=True)          # (BS, 1)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True).T        # (1, n)
    d2 = jnp.maximum(c2 + x2 - 2.0 * jax.lax.dot(
        c, x.T, preferred_element_type=jnp.float32), 0.0)
    r = jnp.sqrt(jnp.maximum(d2, 1e-12))
    s = jnp.sqrt(5.0) * r
    k = var * (1.0 + s + (5.0 / 3.0) * d2) * jnp.exp(-s) * mask  # (BS, n)

    t = jax.lax.dot(k, linvt_ref[...],
                    preferred_element_type=jnp.float32)   # (BS, n) = k L^-T
    q = jnp.sum(t * t, axis=-1)
    mu = jnp.sum(k * alpha_ref[...], axis=-1)             # alpha (1, n)
    sig2 = jnp.maximum(var + noise - q, 1e-10)
    mu_ref[...] = mu[:, None]
    sig2_ref[...] = sig2[:, None]
    k_ref[...] = k


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def score_cov_pallas(cands, X, mask, Linv, alpha, var, noise,
                     block_s: int = 256, interpret: bool = True):
    """(mu, sig2, cross-covariance block) for cands (S, d) pre-scaled.

    ``Linv`` is the triangular inverse factor L^{-1} (the shared scoring
    core's device-resident operand); the kernel receives its transpose so
    the variance pass is one plain ``dot``.
    """
    S, d = cands.shape
    n = X.shape[0]
    scal = jnp.stack([var, noise, jnp.zeros_like(var),
                      jnp.zeros_like(var)])[None, :]
    grid = (S // block_s,)
    mu, sig2, k = pl.pallas_call(
        _score_cov_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),         # L^-T (resident)
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_s, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, n), jnp.float32),
        ],
        interpret=interpret,
    )(cands.astype(jnp.float32), X.astype(jnp.float32),
      mask[None, :].astype(jnp.float32), Linv.T.astype(jnp.float32),
      alpha[None, :].astype(jnp.float32), scal.astype(jnp.float32))
    return mu[:, 0], sig2[:, 0], k


def _downdate_kernel(c_ref, xs_ref, kc_ref, u_ref, sig2_ref, scal_ref,
                     sig2_out_ref, knew_ref):
    """Rank-1 GP-BUCB variance downdate for one absorbed point x*.

    Per candidate c: the posterior variance of the system extended by x*
    contracts by ``(k(c, x*) - k_c^T u)^2 / schur`` where ``u = K^{-1} k_*``
    is the Schur vector of the append and ``k_c`` the *cached* cross-
    covariance row — O(n) per candidate (one matvec against the cached
    block + a fresh (BS,) Matern column) instead of the O(n^2) quadratic
    form a full rescore pays.  Emits the new column k(C, x*) so the caller
    can extend the cached block for the next slot.
    """
    c = c_ref[...]                      # (BS, d)  already / lengthscale
    xs = xs_ref[...]                    # (1, d)   the absorbed point, scaled
    var = scal_ref[0, 0]
    schur = scal_ref[0, 1]

    c2 = jnp.sum(c * c, axis=-1, keepdims=True)          # (BS, 1)
    x2 = jnp.sum(xs * xs, axis=-1, keepdims=True).T      # (1, 1)
    d2 = jnp.maximum(c2 + x2 - 2.0 * jax.lax.dot(
        c, xs.T, preferred_element_type=jnp.float32), 0.0)
    r = jnp.sqrt(jnp.maximum(d2, 1e-12))
    s = jnp.sqrt(5.0) * r
    knew = var * (1.0 + s + (5.0 / 3.0) * d2) * jnp.exp(-s)      # (BS, 1)

    proj = knew - jax.lax.dot(kc_ref[...], u_ref[...].T,
                              preferred_element_type=jnp.float32)  # (BS, 1)
    sig2 = jnp.maximum(sig2_ref[...] - proj * proj / schur, 1e-10)
    sig2_out_ref[...] = sig2
    knew_ref[...] = knew


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def var_downdate_pallas(cands, x_star, Kc, u, schur, sig2, var,
                        block_s: int = 256, interpret: bool = True):
    """Apply the rank-1 downdate; returns (sig2', k(C, x*)).

    cands (S, d) and x_star (d,) pre-scaled by lengthscale; Kc (S, n) the
    cached masked cross-covariance block; u (n,) the Schur vector.
    """
    S, d = cands.shape
    n = Kc.shape[1]
    scal = jnp.stack([var, schur, jnp.zeros_like(var),
                      jnp.zeros_like(var)])[None, :]
    grid = (S // block_s,)
    sig2_out, knew = pl.pallas_call(
        _downdate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((block_s, n), lambda i: (i, 0)),   # cached block
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
        ],
        interpret=interpret,
    )(cands.astype(jnp.float32), x_star[None, :].astype(jnp.float32),
      Kc.astype(jnp.float32), u[None, :].astype(jnp.float32),
      sig2[:, None].astype(jnp.float32), scal.astype(jnp.float32))
    return sig2_out[:, 0], knew[:, 0]
