"""Pallas TPU kernel: fused Matern-5/2 + GP posterior + UCB scoring.

Tiling: candidates are blocked (BS rows per grid step) into VMEM; the padded
training set (n <= 512 typically), Kinv, and alpha are small enough to live
in VMEM for the whole kernel.  Per block:

    MXU:  cross-covariance k (BS, n)  via the |c - x|^2 expansion (one matmul)
          t = k @ Kinv                (BS, n)
    VPU:  matern transform, mu/var/UCB epilogue

which avoids 3 HBM round-trips of the (S, n) covariance the unfused jnp
version makes (k, t, and the elementwise products each materialize).

The candidate dim d is zero-padded to a lane multiple by ops.py; padded
columns contribute 0 to the distance because both operands are 0 there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ucb_kernel(c_ref, x_ref, mask_ref, kinv_ref, alpha_ref, scal_ref,
                out_ref):
    """One grid step: score a (BS, d) block of candidates.

    scal_ref holds [var, noise, beta] broadcast as a (1, 4) f32 row (SMEM-
    friendly scalars are awkward across interpret/TPU; a tiny VMEM row works
    everywhere).
    """
    c = c_ref[...]                      # (BS, d)  already / lengthscale
    x = x_ref[...]                      # (n, d)   already / lengthscale
    mask = mask_ref[...]                # (1, n)
    var = scal_ref[0, 0]
    noise = scal_ref[0, 1]
    beta = scal_ref[0, 2]

    # squared distances via expansion (the matmul hits the MXU)
    c2 = jnp.sum(c * c, axis=-1, keepdims=True)          # (BS, 1)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True).T        # (1, n)
    d2 = jnp.maximum(c2 + x2 - 2.0 * jax.lax.dot(
        c, x.T, preferred_element_type=jnp.float32), 0.0)
    r = jnp.sqrt(jnp.maximum(d2, 1e-12))
    s = jnp.sqrt(5.0) * r
    k = var * (1.0 + s + (5.0 / 3.0) * d2) * jnp.exp(-s) * mask  # (BS, n)

    t = jax.lax.dot(k, kinv_ref[...],
                    preferred_element_type=jnp.float32)   # (BS, n)
    q = jnp.sum(t * k, axis=-1)
    mu = jnp.sum(k * alpha_ref[...], axis=-1)             # alpha (1, n)
    sig2 = jnp.maximum(var + noise - q, 1e-10)
    out_ref[...] = (mu + jnp.sqrt(beta) * jnp.sqrt(sig2))[:, None]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def ucb_scores_pallas(cands, X, mask, Kinv, alpha, var, noise, beta,
                      block_s: int = 256, interpret: bool = True):
    """cands (S, d) pre-divided by lengthscale; X (n, d) likewise."""
    S, d = cands.shape
    n = X.shape[0]
    scal = jnp.stack([var, noise, beta, jnp.zeros_like(var)])[None, :]

    grid = (S // block_s,)
    out = pl.pallas_call(
        _ucb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),   # candidate tile
            pl.BlockSpec((n, d), lambda i: (0, 0)),         # train (resident)
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),         # Kinv (resident)
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, 1), jnp.float32),
        interpret=interpret,
    )(cands.astype(jnp.float32), X.astype(jnp.float32),
      mask[None, :].astype(jnp.float32), Kinv.astype(jnp.float32),
      alpha[None, :].astype(jnp.float32), scal.astype(jnp.float32))
    return out[:, 0]
