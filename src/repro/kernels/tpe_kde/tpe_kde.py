"""Pallas TPU kernel: fused product-Parzen (TPE) l/g log-density scoring.

Tiling mirrors the ``gp_acquisition`` suite: candidates are blocked (BS rows
per grid step) into VMEM; the padded observation buffer, the two split
masks, and the per-row bandwidth scales are small enough to stay resident
for the whole kernel.  Per block and per (static) true dimension j:

    VPU:  d2 = (c_j - x_j)^2                     (BS, n)  one broadcast
          k  = exp(-d2 * a)      a = per-row 1/(2 bw^2) of the row's split
          acc += log(<k, wg>/n_g) - log(<k, wb>/n_b)

The good/bad split is two 0/1 masks plus one scale vector over ONE
observation buffer: with gamma <= 0.5 every row belongs to exactly one
split, so a single exp per (candidate, row, dim) feeds both densities —
the same m*n*d exp count as the numpy host oracle.  The O(m n d)
product-KDE never leaves the chip; only the (S,) score vector does (and in
the fused proposal not even that — ``lax.top_k`` runs on it before
anything transfers).

Padded candidate dims are never touched (``d_true`` is a static closure
argument); padded observation rows carry mask 0 in both splits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tpe_score_kernel(c_ref, x_ref, a_ref, wg_ref, wb_ref, scal_ref,
                      out_ref, *, d_true: int):
    """One grid step: score a (BS, dp) block of candidates.

    a_ref is the (n, dp) per-row per-DIM ``1/(2 bw_j^2)`` scale (each row
    carries its split's bandwidth vector; per-dim bandwidths sharpen
    low-variance dims such as categorical one-hots); scal_ref packs
    [1/n_good, 1/n_bad, 0, 0] as a (1, 4) f32 row (the suite's
    SMEM-portable scalar idiom).
    """
    c = c_ref[...]                      # (BS, dp)
    x = x_ref[...]                      # (n, dp)
    a = a_ref[...]                      # (n, dp) per-row per-dim scale
    wg = wg_ref[...]                    # (1, n)  good-split membership
    wb = wb_ref[...]                    # (1, n)  bad-split membership
    inv_ng = scal_ref[0, 0]
    inv_nb = scal_ref[0, 1]

    acc = jnp.zeros((c.shape[0],), jnp.float32)
    for j in range(d_true):             # static: true dims only
        d2 = (c[:, j:j + 1] - x[:, j:j + 1].T) ** 2          # (BS, n)
        k = jnp.exp(-d2 * a[:, j:j + 1].T)   # one exp serves both densities
        densg = jnp.sum(k * wg, axis=-1) * inv_ng + 1e-12    # (BS,)
        densb = jnp.sum(k * wb, axis=-1) * inv_nb + 1e-12
        acc = acc + jnp.log(densg) - jnp.log(densb)
    out_ref[...] = acc[:, None]


@functools.partial(jax.jit,
                   static_argnames=("d_true", "block_s", "interpret"))
def tpe_scores_pallas(cands, pts, a, wg, wb, scal, *, d_true: int,
                      block_s: int = 256, interpret: bool = True):
    """cands (S, dp) with S a block multiple; pts (n, dp); a (n, dp)
    per-row per-dim bandwidth scale; wg/wb (n,); scal (1, 4).  Returns
    the (S,) l/g log-ratio scores."""
    S, dp = cands.shape
    n = pts.shape[0]
    grid = (S // block_s,)
    out = pl.pallas_call(
        functools.partial(_tpe_score_kernel, d_true=d_true),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, dp), lambda i: (i, 0)),   # candidate tile
            pl.BlockSpec((n, dp), lambda i: (0, 0)),         # obs (resident)
            pl.BlockSpec((n, dp), lambda i: (0, 0)),         # per-dim scale
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, 1), jnp.float32),
        interpret=interpret,
    )(cands.astype(jnp.float32), pts.astype(jnp.float32),
      a.astype(jnp.float32), wg[None, :].astype(jnp.float32),
      wb[None, :].astype(jnp.float32), scal.astype(jnp.float32))
    return out[:, 0]


def _parzen_kernel(c_ref, x_ref, w_ref, scal_ref, out_ref, *, d_true: int):
    """Single-density variant: product-Parzen log-density under one masked
    point set (scal packs [inv2bw2, 1/n, 0, 0])."""
    c = c_ref[...]
    x = x_ref[...]
    w = w_ref[...]
    inv2 = scal_ref[0, 0]
    inv_n = scal_ref[0, 1]
    acc = jnp.zeros((c.shape[0],), jnp.float32)
    for j in range(d_true):
        d2 = (c[:, j:j + 1] - x[:, j:j + 1].T) ** 2
        dens = jnp.sum(jnp.exp(-d2 * inv2) * w, axis=-1) * inv_n + 1e-12
        acc = acc + jnp.log(dens)
    out_ref[...] = acc[:, None]


@functools.partial(jax.jit,
                   static_argnames=("d_true", "block_s", "interpret"))
def parzen_logdens_pallas(cands, pts, w, scal, *, d_true: int,
                          block_s: int = 256, interpret: bool = True):
    """(S,) product-Parzen log-density of each candidate under (pts, w)."""
    S, dp = cands.shape
    n = pts.shape[0]
    grid = (S // block_s,)
    out = pl.pallas_call(
        functools.partial(_parzen_kernel, d_true=d_true),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, dp), lambda i: (i, 0)),
            pl.BlockSpec((n, dp), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, 1), jnp.float32),
        interpret=interpret,
    )(cands.astype(jnp.float32), pts.astype(jnp.float32),
      w[None, :].astype(jnp.float32), scal.astype(jnp.float32))
    return out[:, 0]
