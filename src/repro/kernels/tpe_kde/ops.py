"""Host-facing wrapper: pads shapes to kernel-friendly sizes and dispatches.

``parzen_logdens`` scores unpadded numpy inputs through the Pallas kernel
(interpret mode on CPU — the correctness path; set ``interpret=False`` on
real TPU), matching ``TPEStrategy``'s numpy ``_log_kde`` oracle.  The fused
proposal program (``repro.core.tpe.fused_tpe_propose``) calls the raw
kernels directly with pre-padded buffers, like ``gp.fused_propose_pallas``
does for the ``gp_acquisition`` suite.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tpe_kde.ref import scott_bandwidth
from repro.kernels.tpe_kde.tpe_kde import parzen_logdens_pallas


def pad_dims(d: int) -> int:
    """Lane-pad the encoded dim (>= 8, multiple of 8)."""
    return max(8, int(math.ceil(d / 8)) * 8)


def pad_rows(n: int, multiple: int) -> int:
    return max(multiple, int(math.ceil(n / multiple)) * multiple)


def parzen_logdens(cands, pts, *, bw=None, block_s: int = 256,
                   interpret: bool = True):
    """(m,) product-Parzen log-density of cands (m, d) under pts (n, d).

    ``bw`` defaults to the Scott-rule bandwidth the TPE strategy uses
    (count/dim-dependent scalar).  Pads m to a block multiple, d to a lane
    multiple, and n to a sublane multiple; padded rows carry weight 0 and
    padded dims are never iterated, so padding is exact.
    """
    cands = np.asarray(cands, np.float32)
    pts = np.asarray(pts, np.float32)
    m, d = cands.shape
    n = pts.shape[0]
    dp = pad_dims(d)
    mp = pad_rows(m, block_s)
    npad = pad_rows(n, 8)
    cb = np.zeros((mp, dp), np.float32)
    cb[:m, :d] = cands
    xb = np.zeros((npad, dp), np.float32)
    xb[:n, :d] = pts
    w = np.zeros(npad, np.float32)
    w[:n] = 1.0
    if bw is None:
        bw = float(jax.device_get(scott_bandwidth(jnp.float32(n), d)))
    inv2bw2 = np.float32(0.5 / (float(bw) ** 2))
    scal = np.array([[inv2bw2, 1.0 / max(n, 1), 0.0, 0.0]], np.float32)
    out = parzen_logdens_pallas(
        jnp.asarray(cb), jnp.asarray(xb), jnp.asarray(w),
        jnp.asarray(scal), d_true=d, block_s=block_s, interpret=interpret)
    return jax.device_get(out)[:m]
