"""Pure-jnp oracle for the product-Parzen (TPE) KDE scorer.

TPE (Bergstra et al. 2011; the Hyperopt algorithm) models each encoded
dimension of the good/bad observation splits with a 1D Gaussian Parzen
window and scores candidates by the log-density ratio l(x)/g(x):

    dens_j(c) = (1/n) sum_i w_i * exp(-(c_j - x_ij)^2 / (2 bw^2))
    log_kde(c) = sum_j log(dens_j(c) + 1e-12)
    score(c)   = log_kde_good(c) - log_kde_bad(c)

``w`` is a 0/1 membership mask over the (padded) observation buffer — the
good/bad split is *two masks plus a per-row bandwidth-scale vector over one
buffer*, which is what lets the fused proposal run split + scoring + top-k
as one device program with a single exp per (candidate, row, dim).  Padded
observation rows carry w=0; padded trailing dims are simply not iterated
(``d_true`` is static), so padding never perturbs the density.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scott_bandwidth(n_pts, d_true: int):
    """The host oracle's Scott-rule bandwidth: scalar, count- and dim-
    dependent only (not data-dependent), floored away from zero."""
    n = jnp.maximum(n_pts, 1.0)
    return jnp.maximum(n ** (-1.0 / (d_true + 4)), 1e-2) * 0.5 + 1e-3


def parzen_logdens_ref(cands, pts, w, inv2bw2, inv_n, d_true: int):
    """Product-Parzen log-density of cands (S, dp) under the masked point
    set pts (n, dp), w (n,).  O(S n d); dims beyond ``d_true`` are padding.
    """
    d2 = (cands[:, None, :d_true] - pts[None, :, :d_true]) ** 2   # (S, n, d)
    dens = jnp.einsum("snd,n->sd", jnp.exp(-d2 * inv2bw2), w) \
        * inv_n + 1e-12
    return jnp.sum(jnp.log(dens), axis=-1)


_MAX_ELEMS = 4_000_000   # (block, n, 2d) temporary cap (16 MB f32)


def tpe_scores_ref(cands, pts, a, wg, wb, scal, *, d_true: int):
    """l(x)/g(x) log-ratio for every candidate; the oracle the fused kernel
    is tested against.

    ``a`` (n, dp) is the per-row per-DIM ``1/(2 bw_j^2)`` scale — with
    gamma <= 0.5 every observation belongs to exactly one split, so each
    row carries its own split's bandwidth vector and ONE exp per
    (candidate, row, dim) covers both densities — the same m*n*d exp count
    as the numpy host oracle (the two-mask dual-exp formulation paid
    exactly double).  Per-dim bandwidths (Scott base scaled by each dim's
    split spread) sharpen low-variance dims — categorical one-hot columns
    especially, whose 0/1 support a d-global bandwidth oversmooths.
    ``wg``/``wb`` (n,) are the 0/1 split memberships and ``scal`` packs
    [1/n_g, 1/n_b, 0, 0] (the (1, 4) row the Pallas kernel consumes).

    Shapes are static at trace time, so the streaming decision is free:
    problems whose (S, n, d) temporary fits ``_MAX_ELEMS`` score in one
    block (no ``lax.map`` per-chunk overhead — it costs real latency at
    small sizes); larger ones stream candidates through the biggest
    256-multiple chunk that both fits the cap and divides S, so the
    temporary stays ~16 MB at any mc_samples.
    """
    S = cands.shape[0]
    n = pts.shape[0]
    Xd = pts[:, :d_true]

    def score_block(cb):
        d2 = (cb[:, None, :d_true] - Xd[None, :, :]) ** 2     # (b, n, d)
        E = jnp.exp(-d2 * a[None, :, :d_true])                # (b, n, d)
        densg = jnp.einsum("snd,n->sd", E, wg) * scal[0, 0] + 1e-12
        densb = jnp.einsum("snd,n->sd", E, wb) * scal[0, 1] + 1e-12
        return jnp.sum(jnp.log(densg) - jnp.log(densb), axis=-1)

    nd = n * d_true
    if S * nd <= _MAX_ELEMS:
        return score_block(cands)
    block = min(S, max(256, _MAX_ELEMS // nd // 256 * 256))
    while block > 256 and S % block:
        block -= 256
    if S % block:
        # direct oracle use with a non-256-multiple S: zero-pad up to the
        # block grid (padded rows score garbage, sliced off below) so the
        # temporary cap holds for ANY candidate count
        Sp = -(-S // block) * block
        cands = jnp.pad(cands, ((0, Sp - S), (0, 0)))
    Sp = cands.shape[0]
    out = jax.lax.map(score_block, cands.reshape(Sp // block, block, -1))
    return out.reshape(Sp)[:S]
