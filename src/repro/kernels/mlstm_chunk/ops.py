"""jit'd wrapper for the chunkwise mLSTM kernel."""
from __future__ import annotations

from repro.kernels.mlstm_chunk.mlstm_chunk import mlstm_chunk
from repro.kernels.mlstm_chunk.ref import mlstm_ref


def mlstm_mixer(q, k, v, logi, logf, *, use_pallas=True, interpret=True,
                chunk=64):
    if use_pallas:
        return mlstm_chunk(q, k, v, logi, logf, chunk=chunk,
                           interpret=interpret)
    return mlstm_ref(q, k, v, logi, logf)
