"""Pallas TPU kernel: chunkwise stabilized mLSTM (xLSTM matrix memory).

grid = (B*NH, S/L) with the chunk dimension sequential; VMEM scratch carries
the (dh, dh) matrix memory C, the (dh,) normalizer n, and the (1,) stabilizer
m across chunks.  Within a chunk the intra-chunk part is two MXU matmuls
((L, dh) x (dh, L) scores and (L, L) x (L, dh) values) plus a VPU decay-matrix
epilogue — the standard chunkwise-parallel linear-attention decomposition,
with the xLSTM max-stabilizer threaded through exactly as in the recurrent
form so exp() never overflows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, out_ref,
                  C_ref, n_ref, m_ref, *, L: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    q = q_ref[0].astype(jnp.float32)          # (L, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0, :, 0]                       # (L,)
    lf = lf_ref[0, :, 0]
    m_in = m_ref[0, 0]

    b = jnp.cumsum(lf)                         # (L,) inclusive cum log f
    # D[t, s] = b_t - b_s + i_s for s <= t
    D = b[:, None] - b[None, :] + li[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    D = jnp.where(tri, D, NEG)
    m_intra = jnp.max(D, axis=-1)              # (L,)
    m_comb = jnp.maximum(jnp.maximum(m_intra, b + m_in), NEG)
    Dn = jnp.exp(D - m_comb[:, None])
    inter_w = jnp.exp(b + m_in - m_comb)       # (L,)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * Dn
    h_num = (jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
             + inter_w[:, None] * jax.lax.dot(
                 q, C_ref[...], preferred_element_type=jnp.float32))
    denom = (jnp.sum(scores, axis=-1)
             + inter_w * jnp.sum(q * n_ref[0:1, :], axis=-1))
    denom = jnp.maximum(jnp.abs(denom), jnp.exp(-m_comb))
    out_ref[0] = (h_num / denom[:, None]).astype(out_ref.dtype)

    # ---- state update to end of chunk ----
    bL = b[L - 1]
    dec = bL - b + li                          # (L,)
    m_new = jnp.maximum(bL + m_in, jnp.max(dec))
    w_state = jnp.exp(bL + m_in - m_new)
    w_tok = jnp.exp(dec - m_new)               # (L,)
    kw = k * w_tok[:, None]                    # (L, dh)
    C_ref[...] = (w_state * C_ref[...]
                  + jax.lax.dot_general(kw, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    n_ref[...] = w_state * n_ref[...] + jnp.sum(kw, axis=0)[None, :]
    m_ref[...] = jnp.full_like(m_ref, m_new)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, logi, logf, *, chunk: int = 64,
                interpret: bool = True):
    """q/k/v (B, NH, S, dh); logi/logf (B, NH, S) -> h (B, NH, S, dh)."""
    B, NH, S, dh = q.shape
    L = min(chunk, S)
    n_s = S // L
    qr = q.reshape(B * NH, S, dh)
    kr = k.reshape(B * NH, S, dh)
    vr = v.reshape(B * NH, S, dh)
    lir = logi.reshape(B * NH, S, 1)
    lfr = logf.reshape(B * NH, S, 1)

    out = pl.pallas_call(
        functools.partial(_mlstm_kernel, L=L),
        grid=(B * NH, n_s),
        in_specs=[
            pl.BlockSpec((1, L, dh), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, L, dh), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, L, dh), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, L, 1), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, L, 1), lambda bh, s: (bh, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, dh), lambda bh, s: (bh, s, 0)),
        out_shape=jax.ShapeDtypeStruct((B * NH, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),  # C
            pltpu.VMEM((1, dh), jnp.float32),   # n
            pltpu.VMEM((1, 1), jnp.float32),    # m
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr, lir, lfr)
    return out.reshape(B, NH, S, dh)
