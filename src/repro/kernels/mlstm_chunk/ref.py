"""Pure-jnp oracle: fully-recurrent stabilized mLSTM (xLSTM matrix memory).

The slow-but-obviously-correct sequential form the chunkwise kernel must
match:  per step t (per head):
    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(logi_t - m_t) v_t k_t^T
    n_t likewise;  h_t = C_t^T q_t / max(|n_t . q_t|, exp(-m_t))
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, logi, logf):
    """q/k/v (B, NH, S, dh) fp32; logi/logf (B, NH, S) -> h (B, NH, S, dh)."""
    B, NH, S, dh = q.shape

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)                     # (B, NH)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(li - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhd,bhdv->bhv", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    C0 = jnp.zeros((B, NH, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, NH, dh), jnp.float32)
    m0 = jnp.full((B, NH), -1e30, jnp.float32)
    xs = (jnp.moveaxis(q, 2, 0), jnp.moveaxis(k, 2, 0),
          jnp.moveaxis(v, 2, 0), jnp.moveaxis(logi, 2, 0),
          jnp.moveaxis(logf, 2, 0))
    (_, _, _), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 2)
