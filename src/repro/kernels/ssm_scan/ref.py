"""Pure-jnp oracle for the chunked selective-scan (Mamba) recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(Abar, Bx, Cc):
    """Abar/Bx (B, S, di, N) fp32; Cc (B, S, N) -> y (B, S, di).

    h_t = Abar_t * h_{t-1} + Bx_t ;  y_t = sum_N h_t * C_t
    """
    def step(h, inp):
        a, b, c = inp
        h = a * h + b
        return h, jnp.einsum("bin,bn->bi", h, c)

    B, S, di, N = Abar.shape
    h0 = jnp.zeros((B, di, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (Abar.swapaxes(0, 1), Bx.swapaxes(0, 1),
                          Cc.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)
