"""jit'd wrapper for the selective-scan kernel."""
from __future__ import annotations

from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ssm_scan.ssm_scan import ssm_scan


def selective_scan(Abar, Bx, Cc, *, use_pallas=True, interpret=True,
                   block_d=512, chunk=64):
    if use_pallas:
        return ssm_scan(Abar, Bx, Cc, block_d=block_d, chunk=chunk,
                        interpret=interpret)
    return ssm_scan_ref(Abar, Bx, Cc)
