"""Pallas TPU kernel: chunked selective-scan recurrence (Mamba).

TPU adaptation: the CUDA reference parallelizes the scan across warps with
shared-memory prefix products; the TPU-native shape is a *chunked time loop
over VMEM-resident channel tiles*:

  grid = (B, di/BD, S/CK)   — the time dimension is sequential ("arbitrary"),
                               the channel dimension is parallel
  scratch = h (BD, N) fp32  — the SSM state persists in VMEM across chunks
  per step: CK sequential VPU updates on the (BD, N) tile, then the
  y = <h, C> contraction accumulates into the (CK, BD) output block.

Sequential-in-time, parallel-in-channel is the right trade on the VPU: each
update is an (BD, N) elementwise FMA, which vectorizes across lanes, while
the O(log S) tree of an associative scan would materialize S x BD x N
intermediates in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _ssm_kernel(a_ref, b_ref, c_ref, out_ref, h_ref, *, ck: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        a = a_ref[0, t]                        # (BD, N)
        b = b_ref[0, t]
        c = c_ref[0, t]                        # (1, N)
        h = a * h + b
        y = jnp.sum(h * c, axis=-1)            # (BD,)
        out_ref[0, t] = y.astype(out_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, ck, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def ssm_scan(Abar, Bx, Cc, *, block_d: int = 512, chunk: int = 64,
             interpret: bool = True):
    """Abar/Bx (B, S, di, N) fp32; Cc (B, S, N) -> y (B, S, di) fp32."""
    B, S, di, N = Abar.shape
    block_d = min(block_d, di)
    chunk = min(chunk, S)
    n_d = di // block_d
    n_s = S // chunk

    # layout: (B, S, di, N) -> blocks (1, CK, BD, N); C (1, CK, 1, N)
    out = pl.pallas_call(
        functools.partial(_ssm_kernel, ck=chunk),
        grid=(B, n_d, n_s),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d, N),
                         lambda b, d, s: (b, s, d, 0)),
            pl.BlockSpec((1, chunk, block_d, N),
                         lambda b, d, s: (b, s, d, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, d, s: (b, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(Abar, Bx, Cc[:, :, None, :])
    return out
