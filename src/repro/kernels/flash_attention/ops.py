"""jit'd wrapper with layout adaptation for the model's (B, S, H, hd)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def sdpa(q_bshd, k_bskd, v_bskd, *, causal=True, interpret=True,
         use_pallas=True, block_q=128, block_k=128):
    """Model-layout entry: q (B, Sq, H, hd), k/v (B, Sk, KV, hd)."""
    q = q_bshd.swapaxes(1, 2)
    k = k_bskd.swapaxes(1, 2)
    v = v_bskd.swapaxes(1, 2)
    if use_pallas:
        out = flash_attention(q, k, v, causal=causal, interpret=interpret,
                              block_q=block_q, block_k=block_k)
    else:
        out = attention_ref(q, k, v, causal=causal)
    return out.swapaxes(1, 2)
