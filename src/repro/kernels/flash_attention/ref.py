"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True):
    """q (B, H, Sq, hd); k/v (B, KV, Sk, hd); GQA groups = H // KV."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=1)
        v = jnp.repeat(v, G, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        iq = jax.lax.broadcasted_iota(jnp.int32, (Sq, Sk), 0)
        ik = jax.lax.broadcasted_iota(jnp.int32, (Sq, Sk), 1)
        scores = jnp.where((ik <= iq + (Sk - Sq))[None, None], scores,
                           -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
