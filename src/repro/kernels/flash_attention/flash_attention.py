"""Pallas TPU flash attention (causal, GQA) with online softmax.

TPU adaptation of the FlashAttention GPU algorithm:
  * grid = (B*H, Sq/BQ, Sk/BK); the innermost (KV) grid dimension is
    sequential ("arbitrary") so the (m, l, acc) running statistics live in
    VMEM scratch across KV steps — the TPU analogue of a CUDA thread-block's
    shared-memory accumulators,
  * q/k/v tiles are mapped into VMEM by BlockSpecs; GQA is handled in the
    *index map* (q head h reads kv head h // G) so grouped KV is never
    materialized to H heads in HBM,
  * MXU does the two (BQ, BK) x (BK, hd) matmuls per step; the VPU does the
    online-softmax epilogue in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, scale: float, block_q: int, block_k: int,
                  n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BQ, BK)
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]                               # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                            # (BQ, BK)
    corr = jnp.exp(m_prev - m_new)                    # (BQ, 1)
    l_new = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)                  # (BK, hd)
    pv = jax.lax.dot(p, v, preferred_element_type=jnp.float32)
    acc_ref[...] = corr * acc_ref[...] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _done():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q (B, H, Sq, hd); k/v (B, KV, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    n_q = Sq // block_q
    n_k = Sk // block_k
    scale = hd ** -0.5

    from jax.experimental.pallas import tpu as pltpu

    from repro.compat import tpu_compiler_params

    kernel = functools.partial(_flash_kernel, causal=causal, scale=scale,
                               block_q=block_q, block_k=block_k, n_k=n_k)
    qr = q.reshape(B * H, Sq, hd)
    kr = k.reshape(B * KV, Sk, hd)
    vr = v.reshape(B * KV, Sk, hd)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, hd)
