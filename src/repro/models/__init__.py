from repro.models.common import Runtime, ShardCtx
from repro.models.transformer import (forward_decode, forward_prefill,
                                      forward_train, init_cache, init_params)

__all__ = ["Runtime", "ShardCtx", "forward_decode", "forward_prefill",
           "forward_train", "init_cache", "init_params"]
