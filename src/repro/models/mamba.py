"""Mamba selective-SSM mixer (Jamba's sequence layer).

TPU adaptation: the GPU reference uses a fused warp-parallel scan; here the
recurrence is *chunked* — ``lax.scan`` over sequence chunks with an
associative (Blelloch) scan inside each chunk, so the working set is a
VMEM-sized (B, chunk, d_inner, N) tile instead of the full sequence.  The
Pallas kernel in ``repro/kernels/ssm_scan`` implements the same chunking with
explicit BlockSpecs; this module is the lowering/oracle path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Runtime, dense_init


def mamba_init(key, cfg: ArchConfig, rt: Runtime) -> dict:
    d, di = cfg.d_model, cfg.ssm_d_inner
    r, N, Kc = cfg.dt_rank, cfg.ssm_state_dim, cfg.ssm_conv_dim
    ks = jax.random.split(key, 5)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "w_in": dense_init(ks[0], d, (d, 2 * di), rt.param_dtype),
        "conv_w": dense_init(ks[1], Kc, (Kc, di), rt.param_dtype),
        "w_x": dense_init(ks[2], di, (di, r + 2 * N), rt.param_dtype),
        "w_dt": dense_init(ks[3], r, (r, di), rt.param_dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(~0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], di, (di, d), rt.param_dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, shift_in=None) -> jax.Array:
    """Depthwise causal conv via Kc shifted adds. x (B, S, di), w (Kc, di)."""
    Kc = w.shape[0]
    B, S, di = x.shape
    if shift_in is None:
        shift_in = jnp.zeros((B, Kc - 1, di), x.dtype)
    xp = jnp.concatenate([shift_in, x], axis=1)  # (B, S+Kc-1, di)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(Kc):
        out = out + xp[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _ssm_inputs(p, xz, cfg: ArchConfig, rt: Runtime, *, batch: int,
                conv_state=None):
    """Shared pre-scan computation. xz (B, S, 2*di) -> delta, A, Bx terms."""
    sc, cd = rt.sc, rt.compute_dtype
    di, r, N = cfg.ssm_d_inner, cfg.dt_rank, cfg.ssm_state_dim
    bs = sc.div(batch, sc.dp_axes)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_in, p["conv_w"], conv_state))
    x_c = sc.constrain(x_c, bs, None, sc.div(di, sc.tp_axis))
    xdb = jnp.einsum("bsi,ik->bsk", x_c, p["w_x"].astype(cd))
    dt_r, Bc, Cc = jnp.split(xdb.astype(jnp.float32), [r, r + N], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["w_dt"].astype(jnp.float32))
        + p["dt_bias"])
    delta = sc.constrain(delta, bs, None, sc.div(di, sc.tp_axis))
    A = -jnp.exp(p["A_log"])                                   # (di, N)
    Abar = jnp.exp(delta[..., None] * A[None, None])           # (B,S,di,N)
    Bx = (delta * x_c.astype(jnp.float32))[..., None] * Bc[:, :, None, :]
    return x_c, z, Abar, Bx, Cc, x_in


def mamba(p: dict, x: jax.Array, cfg: ArchConfig, rt: Runtime, *,
          batch: int, return_state: bool = False):
    """Full-sequence selective scan. x (B, S, d)."""
    sc, cd = rt.sc, rt.compute_dtype
    B, S, d = x.shape
    di, N = cfg.ssm_d_inner, cfg.ssm_state_dim
    bs = sc.div(batch, sc.dp_axes)

    xz = jnp.einsum("bsd,dk->bsk", x.astype(cd), p["w_in"].astype(cd))
    xz = sc.constrain(xz, bs, None, sc.div(2 * di, sc.tp_axis))
    x_c, z, Abar, Bx, Cc, x_in = _ssm_inputs(p, xz, cfg, rt, batch=batch)

    if rt.use_pallas and rt.sc.mesh is None and not return_state \
            and S % min(64, S) == 0 and di % min(512, di) == 0:
        from repro.kernels.ssm_scan.ops import selective_scan
        h_dot_c = selective_scan(Abar, Bx, Cc, chunk=min(64, S),
                                 block_d=min(512, di))
        y = h_dot_c + p["D"] * x_c.astype(jnp.float32)
        y = (y.astype(cd) * jax.nn.silu(z))
        return jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(cd))

    Ck = min(rt.ssm_chunk, S)
    if S % Ck != 0:
        Ck = S
    n_chunks = S // Ck

    def chunk_body(h0, inp):
        Abar_c, Bx_c = inp  # (B, Ck, di, N)
        cumA, y = jax.lax.associative_scan(
            lambda a, b: (a[0] * b[0], a[1] * b[0] + b[1]),
            (Abar_c, Bx_c), axis=1)
        h = y + cumA * h0[:, None]
        return h[:, -1], h

    Abar_r = Abar.reshape(B, n_chunks, Ck, di, N).swapaxes(0, 1)
    Bx_r = Bx.reshape(B, n_chunks, Ck, di, N).swapaxes(0, 1)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    h_last, hs = jax.lax.scan(chunk_body, h0, (Abar_r, Bx_r))
    h = hs.swapaxes(0, 1).reshape(B, S, di, N)

    y = jnp.einsum("bsin,bsn->bsi", h, Cc) + p["D"] * x_c.astype(jnp.float32)
    y = (y.astype(cd) * jax.nn.silu(z))
    y = sc.constrain(y, bs, None, sc.div(di, sc.tp_axis))
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(cd))
    if return_state:
        Kc = cfg.ssm_conv_dim
        state = {"conv": x_in[:, S - (Kc - 1):, :], "h": h_last}
        return out, state
    return out


def mamba_with_state(p, x, cfg: ArchConfig, rt: Runtime, *, batch: int):
    return mamba(p, x, cfg, rt, batch=batch, return_state=True)


# --------------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------------- #
def mamba_cache_init(cfg: ArchConfig, rt: Runtime, B: int) -> dict:
    di, N, Kc = cfg.ssm_d_inner, cfg.ssm_state_dim, cfg.ssm_conv_dim
    return {
        "conv": jnp.zeros((B, Kc - 1, di), rt.compute_dtype),
        "h": jnp.zeros((B, di, N), jnp.float32),
    }


def mamba_decode(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig,
                 rt: Runtime) -> Tuple[jax.Array, dict]:
    """One-token step. x (B, 1, d)."""
    cd = rt.compute_dtype
    B = x.shape[0]
    xz = jnp.einsum("bsd,dk->bsk", x.astype(cd), p["w_in"].astype(cd))
    x_c, z, Abar, Bx, Cc, x_in = _ssm_inputs(
        p, xz, cfg, rt, batch=B, conv_state=cache["conv"])
    h = Abar[:, 0] * cache["h"] + Bx[:, 0]              # (B, di, N)
    y = jnp.einsum("bin,bn->bi", h, Cc[:, 0])[:, None]
    y = y + p["D"] * x_c.astype(jnp.float32)
    y = (y.astype(cd) * jax.nn.silu(z))
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(cd))
    new_conv = jnp.concatenate([cache["conv"][:, 1:], x_in], axis=1)
    return out, {"conv": new_conv, "h": h}
