"""xLSTM mixers: chunkwise mLSTM (matrix memory) and recurrent sLSTM.

mLSTM follows the stabilized exponential-gating chunkwise form: within a
chunk, gated attention-like matmuls run on the MXU; across chunks a
(B, nh, dk, dv) matrix memory + normalizer + stabilizer are carried through a
``lax.scan`` — O(S) time, O(1) state, which is what makes xlstm-1.3b runnable
at the 524k-token ``long_500k`` shape.  sLSTM is an inherently sequential
scalar-memory recurrence (per the paper) and is lowered as a ``lax.scan``
over time with block-diagonal per-head recurrent weights.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Runtime, dense_init, rmsnorm
from repro.models.mamba import _causal_conv

_CONV_K = 4


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #
def mlstm_init(key, cfg: ArchConfig, rt: Runtime) -> dict:
    d, di, nh = cfg.d_model, cfg.lstm_d_inner, cfg.lstm_heads
    dh = di // nh
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], d, (d, 2 * di), rt.param_dtype),
        "conv_w": dense_init(ks[1], _CONV_K, (_CONV_K, di), rt.param_dtype),
        "wq": dense_init(ks[2], dh, (nh, dh, dh), rt.param_dtype),
        "wk": dense_init(ks[3], dh, (nh, dh, dh), rt.param_dtype),
        "wv": dense_init(ks[4], dh, (nh, dh, dh), rt.param_dtype),
        "w_gate": dense_init(ks[5], di, (di, 2 * nh), jnp.float32),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((nh,)), jnp.full((nh,), 3.0)]).astype(jnp.float32),
        "out_scale": jnp.ones((di,), rt.param_dtype),
        "w_down": dense_init(ks[6], di, (di, d), rt.param_dtype),
    }


def _mlstm_qkv_gates(p, x, cfg: ArchConfig, rt: Runtime, conv_state=None):
    cd = rt.compute_dtype
    B, S, _ = x.shape
    di, nh = cfg.lstm_d_inner, cfg.lstm_heads
    dh = di // nh
    up = jnp.einsum("bsd,dk->bsk", x.astype(cd), p["w_up"].astype(cd))
    x_m, z = jnp.split(up, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_m, p["conv_w"], conv_state))
    xh = x_c.reshape(B, S, nh, dh)
    q = jnp.einsum("bsnd,nde->bsne", xh, p["wq"].astype(cd))
    k = jnp.einsum("bsnd,nde->bsne", xh, p["wk"].astype(cd)) * (dh ** -0.5)
    v = jnp.einsum("bsnd,nde->bsne", x_m.reshape(B, S, nh, dh),
                   p["wv"].astype(cd))
    gates = (jnp.einsum("bsi,ig->bsg", x_m.astype(jnp.float32), p["w_gate"])
             + p["gate_bias"])
    logi, logf_pre = jnp.split(gates, 2, axis=-1)       # (B, S, nh)
    logf = -jax.nn.softplus(-logf_pre)                  # log sigmoid
    return q, k, v, logi, logf, z, x_m


def mlstm(p: dict, x: jax.Array, cfg: ArchConfig, rt: Runtime, *,
          batch: int, return_state: bool = False):
    sc, cd = rt.sc, rt.compute_dtype
    B, S, d = x.shape
    di, nh = cfg.lstm_d_inner, cfg.lstm_heads
    dh = di // nh
    q, k, v, logi, logf, z, x_m = _mlstm_qkv_gates(p, x, cfg, rt)

    if rt.use_pallas and rt.sc.mesh is None and not return_state \
            and S % min(64, S) == 0:
        from repro.kernels.mlstm_chunk.ops import mlstm_mixer
        h = mlstm_mixer(q.swapaxes(1, 2).astype(jnp.float32),
                        k.swapaxes(1, 2).astype(jnp.float32),
                        v.swapaxes(1, 2).astype(jnp.float32),
                        logi.swapaxes(1, 2), logf.swapaxes(1, 2),
                        chunk=min(64, S))
        h = h.swapaxes(1, 2).reshape(B, S, di).astype(cd)
        h = rmsnorm(h, p["out_scale"]) * jax.nn.silu(z)
        return jnp.einsum("bsi,id->bsd", h, p["w_down"].astype(cd))

    L = min(rt.ssm_chunk, S)
    if S % L != 0:
        L = S
    nC = S // L

    def split(t):  # (B, S, ...) -> (nC, B, L, ...)
        return t.reshape(B, nC, L, *t.shape[2:]).swapaxes(0, 1)

    qs, ks_, vs = split(q), split(k), split(v)
    lis, lfs = split(logi), split(logf)

    stash_dt = jnp.bfloat16 if rt.lstm_bf16_states else jnp.float32

    def chunk(carry, inp):
        C_in, n_in, m_in = carry           # (B,nh,dh,dh), (B,nh,dh), (B,nh)
        qc, kc, vc, li, lf = inp
        qf = qc.astype(jnp.float32).swapaxes(1, 2)   # (B, nh, L, dh)
        kf = kc.astype(jnp.float32).swapaxes(1, 2)
        vf = vc.astype(jnp.float32).swapaxes(1, 2)
        lit = li.swapaxes(1, 2)                       # (B, nh, L)
        b = jnp.cumsum(lf.swapaxes(1, 2), axis=-1)    # (B, nh, L) cum log f
        # D[t, s] = b_t - b_s + i_s (s <= t)
        D = b[..., :, None] - b[..., None, :] + lit[..., None, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)                 # (B, nh, L)
        m_comb = jnp.maximum(m_intra, b + m_in[..., None])
        m_comb = jnp.maximum(m_comb, -1e30)           # guard all -inf rows
        Dn = jnp.exp(D - m_comb[..., None])
        inter_w = jnp.exp(b + m_in[..., None] - m_comb)  # (B, nh, L)
        scores = jnp.einsum("bnld,bnsd->bnls", qf, kf) * Dn
        h_num = (jnp.einsum("bnls,bnsv->bnlv", scores, vf)
                 + inter_w[..., None] * jnp.einsum("bnld,bndv->bnlv", qf, C_in))
        denom = (scores.sum(-1)
                 + inter_w * jnp.einsum("bnld,bnd->bnl", qf, n_in))
        denom = jnp.maximum(jnp.abs(denom), jnp.exp(-m_comb))
        h = h_num / denom[..., None]                  # (B, nh, L, dh)
        # state update to end of chunk
        bL = b[..., -1:]                               # (B, nh, 1)
        dec = bL - b + lit                             # (B, nh, L)
        m_new = jnp.maximum(bL[..., 0] + m_in, jnp.max(dec, axis=-1))
        w_in_state = jnp.exp(bL[..., 0] + m_in - m_new)
        w_tok = jnp.exp(dec - m_new[..., None])        # (B, nh, L)
        C_out = (w_in_state[..., None, None] * C_in
                 + jnp.einsum("bnl,bnld,bnlv->bndv", w_tok, kf, vf))
        n_out = (w_in_state[..., None] * n_in
                 + jnp.einsum("bnl,bnld->bnd", w_tok, kf))
        return (C_out, n_out, m_new), h.swapaxes(1, 2).astype(stash_dt)

    C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, nh, dh), jnp.float32)
    m0 = jnp.full((B, nh), -1e30, jnp.float32)
    (Cf, nf, mf), hs = jax.lax.scan(chunk, (C0, n0, m0),
                                    (qs, ks_, vs, lis, lfs))
    h = hs.swapaxes(0, 1).reshape(B, S, di).astype(cd)
    h = rmsnorm(h, p["out_scale"])
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", h, p["w_down"].astype(cd))
    if return_state:
        state = {"conv": x_m[:, S - (_CONV_K - 1):, :], "C": Cf, "n": nf,
                 "m": mf}
        return out, state
    return out


def mlstm_with_state(p, x, cfg: ArchConfig, rt: Runtime, *, batch: int):
    return mlstm(p, x, cfg, rt, batch=batch, return_state=True)


def mlstm_cache_init(cfg: ArchConfig, rt: Runtime, B: int) -> dict:
    di, nh = cfg.lstm_d_inner, cfg.lstm_heads
    dh = di // nh
    return {
        "conv": jnp.zeros((B, _CONV_K - 1, di), rt.compute_dtype),
        "C": jnp.zeros((B, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((B, nh, dh), jnp.float32),
        "m": jnp.full((B, nh), -1e30, jnp.float32),
    }


def mlstm_decode(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig,
                 rt: Runtime) -> Tuple[jax.Array, dict]:
    cd = rt.compute_dtype
    B = x.shape[0]
    di, nh = cfg.lstm_d_inner, cfg.lstm_heads
    dh = di // nh
    q, k, v, logi, logf, z, x_m = _mlstm_qkv_gates(
        p, x, cfg, rt, conv_state=cache["conv"])
    qf = q[:, 0].astype(jnp.float32)                   # (B, nh, dh)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    li, lf = logi[:, 0], logf[:, 0]                    # (B, nh)
    m_new = jnp.maximum(lf + cache["m"], li)
    fp = jnp.exp(lf + cache["m"] - m_new)
    ip = jnp.exp(li - m_new)
    C = fp[..., None, None] * cache["C"] + ip[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n = fp[..., None] * cache["n"] + ip[..., None] * kf
    num = jnp.einsum("bnd,bndv->bnv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bnd,bnd->bn", qf, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, di).astype(cd)
    h = rmsnorm(h, p["out_scale"]) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", h, p["w_down"].astype(cd))
    new_conv = jnp.concatenate([cache["conv"][:, 1:], x_m], axis=1)
    return out, {"conv": new_conv, "C": C, "n": n, "m": m_new}


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #
def slstm_init(key, cfg: ArchConfig, rt: Runtime) -> dict:
    d, nh = cfg.d_model, cfg.lstm_heads
    dh = d // nh
    ks = jax.random.split(key, 3)
    bias = jnp.concatenate([
        jnp.zeros((d,)), jnp.zeros((d,)),               # z, i
        jnp.full((d,), 3.0), jnp.zeros((d,))])          # f, o
    return {
        "w_in": dense_init(ks[0], d, (d, 4 * d), rt.param_dtype),
        "r": dense_init(ks[1], dh, (nh, dh, 4 * dh), jnp.float32),
        "bias": bias.astype(jnp.float32),
        "norm_scale": jnp.ones((d,), rt.param_dtype),
        "w_down": dense_init(ks[2], d, (d, d), rt.param_dtype),
    }


def _slstm_cell(p, xt, state, cfg: ArchConfig):
    """xt (B, 4d) pre-computed input projection; state (c, n, h, m) (B, d)."""
    d, nh = cfg.d_model, cfg.lstm_heads
    dh = d // nh
    c, n, h, m = state
    B = xt.shape[0]
    rec = jnp.einsum("bnd,ndk->bnk", h.reshape(B, nh, dh), p["r"])
    # per-head (4dh) blocks are [z|i|f|o] slices: regroup to gate-major (4d)
    rec = rec.reshape(B, nh, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    g = xt.astype(jnp.float32) + rec + p["bias"]
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)
    logf = -jax.nn.softplus(-ft)                        # log sigmoid(f)
    m_new = jnp.maximum(logf + m, it)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(it - m_new)
    c_new = fp * c + ip * jnp.tanh(zt)
    n_new = fp * n + ip
    h_new = jax.nn.sigmoid(ot) * (c_new / jnp.maximum(n_new, 1e-6))
    return (c_new, n_new, h_new, m_new)


def slstm(p: dict, x: jax.Array, cfg: ArchConfig, rt: Runtime, *,
          batch: int, return_state: bool = False):
    sc, cd = rt.sc, rt.compute_dtype
    B, S, d = x.shape
    xp = jnp.einsum("bsd,dk->bsk", x.astype(cd), p["w_in"].astype(cd))
    stash_dt = jnp.bfloat16 if rt.lstm_bf16_states else jnp.float32

    # Time-chunked scan: the outer lax.scan steps over chunks of U unrolled
    # cell updates.  This amortizes loop overhead AND — critically — lets the
    # backward pass reduce the recurrent-weight gradient once per chunk
    # instead of once per time step (a 64x cut of the dominant all-reduce
    # traffic at train_4k; see EXPERIMENTS.md §Perf xlstm it3).
    U = max(1, min(64, rt.ssm_chunk, S))
    while S % U != 0:
        U //= 2
    nC = S // U

    def chunk_step(state, x_chunk):  # x_chunk (U, B, 4d)
        hs = []
        for t in range(U):
            state = _slstm_cell(p, x_chunk[t], state, cfg)
            hs.append(state[2].astype(stash_dt))
        return state, jnp.stack(hs)

    z = jnp.zeros((B, d), jnp.float32)
    state0 = (z, z, z, jnp.full((B, d), -1e30, jnp.float32))
    xs = xp.swapaxes(0, 1).reshape(nC, U, B, 4 * d)
    (c, n, hf, m), hs = jax.lax.scan(chunk_step, state0, xs)
    h = hs.reshape(S, B, d).swapaxes(0, 1).astype(cd)  # (B, S, d)
    h = rmsnorm(h, p["norm_scale"])
    out = jnp.einsum("bsd,dk->bsk", h, p["w_down"].astype(cd))
    if return_state:
        return out, {"c": c, "n": n, "h": hf, "m": m}
    return out


def slstm_with_state(p, x, cfg: ArchConfig, rt: Runtime, *, batch: int):
    return slstm(p, x, cfg, rt, batch=batch, return_state=True)


def slstm_cache_init(cfg: ArchConfig, rt: Runtime, B: int) -> dict:
    d = cfg.d_model
    z = jnp.zeros((B, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((B, d), -1e30, jnp.float32)}


def slstm_decode(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig,
                 rt: Runtime) -> Tuple[jax.Array, dict]:
    cd = rt.compute_dtype
    xp = jnp.einsum("bsd,dk->bsk", x.astype(cd), p["w_in"].astype(cd))
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_cell(p, xp[:, 0], state, cfg)
    y = rmsnorm(h[:, None].astype(cd), p["norm_scale"])
    out = jnp.einsum("bsd,dk->bsk", y, p["w_down"].astype(cd))
    return out, {"c": c, "n": n, "h": h, "m": m}
