"""Shared model machinery: sharding context, norms, RoPE, losses, init.

Everything is a pure function over explicit parameter pytrees (no framework).
Sharding is expressed through a ``ShardCtx`` so the same model code runs:
  * un-meshed on CPU for smoke tests (constraints become no-ops),
  * on the (data, model) single-pod mesh,
  * on the (pod, data, model) multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------- #
# Sharding context
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh-aware axis resolution with divisibility fallbacks."""

    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ()     # batch-parallel axes, e.g. ("pod", "data")
    tp_axis: Optional[str] = None     # tensor-parallel axis ("model")
    # parameter-shard axis or tuple of axes ("data" / ("data", "model"))
    fsdp_axis: Optional[object] = None
    seq_parallel: bool = False        # shard activations over seq between blocks
    shard_lstm_r: bool = False        # FSDP-shard sLSTM recurrent weights

    @staticmethod
    def null() -> "ShardCtx":
        return ShardCtx()

    def axis_size(self, axis) -> int:
        if self.mesh is None or axis is None:
            return 1
        if isinstance(axis, (tuple, list)):
            n = 1
            for a in axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[axis]

    @property
    def tp(self) -> int:
        return self.axis_size(self.tp_axis)

    @property
    def dp(self) -> int:
        return self.axis_size(self.dp_axes) if self.dp_axes else 1

    @property
    def fsdp(self) -> int:
        return self.axis_size(self.fsdp_axis)

    def div(self, n: int, axis):
        """Return ``axis`` if dimension ``n`` is divisible by its mesh size."""
        if self.mesh is None or axis is None:
            return None
        return axis if n % self.axis_size(axis) == 0 else None

    def constrain(self, x: jax.Array, *spec) -> jax.Array:
        """Best-effort ``with_sharding_constraint`` (no-op without a mesh)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    # Convenience specs -----------------------------------------------------
    def batch_spec(self, n_batch: int):
        return self.div(n_batch, self.dp_axes)

    def act(self, x: jax.Array, batch_dim_size: int, *rest) -> jax.Array:
        """Constrain an activation whose dim 0 is the (global) batch."""
        return self.constrain(x, self.div(batch_dim_size, self.dp_axes), *rest)


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution-policy knobs threaded through the model functions."""

    sc: ShardCtx = dataclasses.field(default_factory=ShardCtx.null)
    param_dtype: jnp.dtype = jnp.bfloat16
    compute_dtype: jnp.dtype = jnp.bfloat16
    accum_dtype: jnp.dtype = jnp.float32
    attn_dense_threshold: int = 8192   # use single-einsum attention below this
    attn_q_chunk: int = 512            # q-chunk for blockwise attention
    attn_banded: bool = False          # exact-causal banded attention (opt)
    attn_fallback: str = "kvseq"       # heads%TP!=0: "kvseq" | "qseq" shard
    lstm_bf16_states: bool = False     # stash xLSTM scan outputs in bf16
    ce_chunk: int = 512                # seq chunk for cross-entropy
    ssm_chunk: int = 256               # chunk length for SSM / mLSTM scans
    moe_capacity_factor: float = 0.0   # 0 -> use cfg.capacity_factor
    moe_expert_parallel: bool = False  # shard expert axis over TP (EP mode)
    remat_policy: str = "full"         # none | dots | full
    use_pallas: bool = False           # dispatch hot ops to Pallas kernels
    z_loss: float = 1e-4


# --------------------------------------------------------------------------- #
# Norms / activations
# --------------------------------------------------------------------------- #
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale + bias


def norm_apply(kind: str, x: jax.Array, p: dict) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def norm_init(kind: str, d: int, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# --------------------------------------------------------------------------- #
# Init helpers
# --------------------------------------------------------------------------- #
def dense_init(key, fan_in: int, shape: Sequence[int], dtype) -> jax.Array:
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# --------------------------------------------------------------------------- #
# Positions
# --------------------------------------------------------------------------- #
def rope_tables(positions: jax.Array, hd: int, theta: float
                ) -> Tuple[jax.Array, jax.Array]:
    """positions (B, S) -> cos/sin tables (B, S, hd//2) in fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, hd); cos/sin (B, S, hd//2). Interleaved-pair convention."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)


def sinusoidal_position_at(pos: jax.Array, d: int) -> jax.Array:
    """Single sinusoidal position row; pos scalar int32 -> (d,) fp32."""
    inv = 1.0 / (10_000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10_000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (S, d)


# --------------------------------------------------------------------------- #
# Chunked cross-entropy (never materializes (B, S, V) logits)
# --------------------------------------------------------------------------- #
def chunked_cross_entropy(x: jax.Array, w_head: jax.Array, labels: jax.Array,
                          mask: jax.Array, rt: Runtime, vocab_size: int
                          ) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over masked positions + z-loss.

    x: (B, S, d) final hidden; w_head: (d, Vp) (Vp >= vocab_size, padded rows
    are masked to -inf); labels, mask: (B, S).  Scans over S in ``rt.ce_chunk``
    chunks with rematerialization so the backward pass recomputes each chunk's
    logits instead of saving them.
    """
    B, S, d = x.shape
    Vp = w_head.shape[1]
    C = min(rt.ce_chunk, S)
    n_chunks = S // C if S % C == 0 else 1
    if S % C != 0:
        C = S
    sc = rt.sc

    xs = x.reshape(B, n_chunks, C, d).swapaxes(0, 1)  # (n, B, C, d)
    ls = labels.reshape(B, n_chunks, C).swapaxes(0, 1)
    ms = mask.reshape(B, n_chunks, C).swapaxes(0, 1)

    pad_mask = (jnp.arange(Vp) < vocab_size)

    @jax.checkpoint
    def chunk_loss(xc, lc, mc):
        logits = jnp.einsum("bcd,dv->bcv", xc.astype(rt.compute_dtype),
                            w_head.astype(rt.compute_dtype),
                            preferred_element_type=jnp.float32)
        logits = sc.constrain(logits, sc.div(B, sc.dp_axes), None,
                              sc.div(Vp, sc.tp_axis))
        logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)                 # (B, C)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        ce = (lse - ll) * mc
        zl = jnp.square(lse) * mc
        return ce.sum(), zl.sum()

    def body(carry, inp):
        ce_acc, zl_acc = carry
        xc, lc, mc = inp
        ce, zl = chunk_loss(xc, lc, mc)
        return (ce_acc + ce, zl_acc + zl), None

    (ce_sum, zl_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls, ms))
    denom = jnp.maximum(mask.sum().astype(jnp.float32), 1.0)
    loss = ce_sum / denom + rt.z_loss * zl_sum / denom
    return loss, denom


def logits_for(x: jax.Array, w_head: jax.Array, rt: Runtime,
               vocab_size: int) -> jax.Array:
    """Full logits for short sequences (decode / smoke tests)."""
    Vp = w_head.shape[1]
    logits = jnp.einsum("bsd,dv->bsv", x.astype(rt.compute_dtype),
                        w_head.astype(rt.compute_dtype),
                        preferred_element_type=jnp.float32)
    if Vp != vocab_size:
        logits = jnp.where(jnp.arange(Vp)[None, None, :] < vocab_size,
                           logits, -1e30)
    return logits
