"""Mixture-of-Experts with sort-based capacity dispatch.

TPU-native design notes (vs. the GShard one-hot dispatch einsum):
  * dispatch/combine are gathers driven by a per-sequence stable sort of
    expert assignments, so dispatch costs O(S*k log(S*k)) comparisons and
    ZERO matmul FLOPs — expert compute is 2*E*C*d*ff with capacity
    C = ceil(S*k/E * capacity_factor), i.e. active-expert FLOPs x capacity
    factor (the GShard dispatch einsum would add O(S^2) FLOPs).
  * all dispatch work is per batch row: the token axis S is never sharded, so
    routing is collective-free; only the expert matmuls touch sharded weights
    (FSDP all-gather over "data", TP reduce over "model" — or expert-parallel
    when the expert count divides the model axis; both are pure weight
    PartitionSpec choices, see launch/sharding.py).
  * drop policy: tokens beyond capacity are dropped (weight 0), earliest
    tokens win (stable sort) — standard capacity-factor semantics.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Runtime, act_fn, dense_init
from repro.models.mlp import mlp, mlp_init


def moe_capacity(cfg: ArchConfig, rt: Runtime, S: int) -> int:
    cf = rt.moe_capacity_factor or cfg.capacity_factor
    c = int(-(-S * cfg.top_k * cf // cfg.n_experts))  # ceil
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_init(key, cfg: ArchConfig, rt: Runtime) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], d, (d, E), jnp.float32),
        "wg": dense_init(ks[1], d, (E, d, f), rt.param_dtype),
        "wu": dense_init(ks[2], d, (E, d, f), rt.param_dtype),
        "wd": dense_init(ks[3], f, (E, f, d), rt.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, rt,
                               d_ff=cfg.n_shared_experts * cfg.moe_d_ff)
        p["shared_gate"] = dense_init(ks[5], d, (d, 1), rt.param_dtype)
    return p


def moe(p: dict, x: jax.Array, cfg: ArchConfig, rt: Runtime, *,
        batch: int) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    sc = rt.sc
    cd = rt.compute_dtype
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, rt, S)
    N = S * K
    bs = sc.div(batch, sc.dp_axes)

    # ---- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)            # (B, S, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch LB + router z) ----------------------------------
    me = probs.mean(axis=(0, 1))                      # (E,) mean prob
    ce_frac = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (B * S * K))
    lb_loss = E * jnp.sum(me * ce_frac)
    router_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- sort-based dispatch (all per batch row, collective-free) -----------
    fid = top_e.reshape(B, N)                          # expert id per slot
    fw = top_w.reshape(B, N)
    order = jnp.argsort(fid, axis=-1, stable=True)     # (B, N)
    sid = jnp.take_along_axis(fid, order, axis=-1)
    stok = order // K                                  # token position, sorted
    sw = jnp.take_along_axis(fw, order, axis=-1)

    starts = jax.vmap(lambda a: jnp.searchsorted(a, jnp.arange(E)))(sid)
    rank = jnp.arange(N)[None, :] - jnp.take_along_axis(starts, sid, axis=-1)
    keep = rank < C
    slot = jnp.where(keep, sid * C + rank, E * C)      # overflow -> sentinel

    binds = jnp.arange(B)[:, None]
    # slot -> source token (sentinel row gathers token 0 with weight 0)
    slot_tok = jnp.zeros((B, E * C + 1), jnp.int32).at[binds, slot].set(stok)
    xg = x[binds, slot_tok[:, :E * C]]                 # (B, E*C, d)
    xg = xg.reshape(B, E, C, d).astype(cd)
    if rt.moe_expert_parallel:
        xg = sc.constrain(xg, bs, sc.div(E, sc.tp_axis), None, None)

    # ---- expert compute ------------------------------------------------------
    gate = jnp.einsum("becd,edf->becf", xg, p["wg"].astype(cd))
    up = jnp.einsum("becd,edf->becf", xg, p["wu"].astype(cd))
    h = act_fn(cfg.act)(gate) * up
    h = sc.constrain(h, bs, None, None, sc.div(cfg.moe_d_ff, sc.tp_axis))
    yg = jnp.einsum("becf,efd->becd", h, p["wd"].astype(cd))
    yg = yg.reshape(B, E * C, d)

    # ---- combine (gather back, unsort, weighted sum over k) -----------------
    y_sorted = yg[binds, jnp.minimum(slot, E * C - 1)]  # (B, N, d)
    y_sorted = y_sorted * (sw * keep).astype(cd)[..., None]
    inv_order = jnp.argsort(order, axis=-1)
    y_flat = jnp.take_along_axis(y_sorted, inv_order[..., None], axis=1)
    y = y_flat.reshape(B, S, K, d).sum(axis=2)

    if "shared" in p:
        g = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x.astype(cd), p["shared_gate"].astype(cd)))
        y = y + g * mlp(p["shared"], x, cfg, rt, batch=batch)

    aux = {"moe_lb_loss": lb_loss, "moe_router_z": router_z,
           "moe_drop_frac": 1.0 - keep.mean()}
    return y, aux
