"""Dense MLPs: SwiGLU (llama-family) and plain GeLU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Runtime, act_fn, dense_init


def mlp_init(key, cfg: ArchConfig, rt: Runtime, d_ff: int = 0) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], d, (d, ff), rt.param_dtype),
        "w_down": dense_init(ks[1], ff, (ff, d), rt.param_dtype),
    }
    if cfg.act == "silu":
        p["w_gate"] = dense_init(ks[2], d, (d, ff), rt.param_dtype)
    return p


def mlp(p: dict, x: jax.Array, cfg: ArchConfig, rt: Runtime, *,
        batch: int) -> jax.Array:
    sc = rt.sc
    cd = rt.compute_dtype
    bs = sc.div(batch, sc.dp_axes)
    ff = p["w_up"].shape[1]
    up = jnp.einsum("bsd,df->bsf", x.astype(cd), p["w_up"].astype(cd))
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x.astype(cd), p["w_gate"].astype(cd))
        h = act_fn(cfg.act)(gate) * up
    else:
        h = act_fn(cfg.act)(up)
    h = sc.constrain(h, bs, None, sc.div(ff, sc.tp_axis))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cd))
