"""GQA attention: dense, blockwise (long-context), decode, and cross variants.

Sharding strategy (best-effort, per ShardCtx.div):
  * heads divisible by TP  -> shard the head axis of q/scores ("model").
  * heads NOT divisible    -> shard the KV-sequence axis of k/v/scores instead
    (yi-34b 56H, smollm 9H, whisper 20H); softmax over the sharded axis is
    handled by SPMD partial-max/sum all-reduces (small (B,H,Sq) tensors).
  * KV heads are kept replicated over TP when not divisible (GQA kv=8 vs
    TP=16); the repeat-to-H materialization is sliced for free when the head
    axis is sharded.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Runtime, apply_rope, dense_init, rope_tables


def attn_init(key, cfg: ArchConfig, rt: Runtime) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, (d, H * hd), rt.param_dtype),
        "wk": dense_init(ks[1], d, (d, KV * hd), rt.param_dtype),
        "wv": dense_init(ks[2], d, (d, KV * hd), rt.param_dtype),
        "wo": dense_init(ks[3], H * hd, (H * hd, d), rt.param_dtype),
    }


def _project_qkv(p, x, kv_x, cfg: ArchConfig, rt: Runtime):
    B, Sq, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = rt.compute_dtype
    q = jnp.einsum("bsd,dh->bsh", x.astype(cd), p["wq"].astype(cd))
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dh->bsh", src.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("bsd,dh->bsh", src.astype(cd), p["wv"].astype(cd))
    q = q.reshape(B, Sq, H, hd)
    k = k.reshape(B, src.shape[1], KV, hd)
    v = v.reshape(B, src.shape[1], KV, hd)
    return q, k, v


def _expand_kv(k: jax.Array, cfg: ArchConfig) -> jax.Array:
    G = cfg.n_heads // cfg.n_kv_heads
    return k if G == 1 else jnp.repeat(k, G, axis=2)


def _shard_plan(cfg: ArchConfig, rt: Runtime):
    """(head_axis, kvseq_axis, qseq_axis): exactly one is non-None under TP.

    heads %% TP == 0 -> shard heads.  Otherwise fall back to sharding a
    sequence axis of the score tensor: "kvseq" (baseline; softmax reduces
    over the sharded axis -> per-layer ARs) or "qseq" (rows of the score
    matrix; softmax stays local, k/v are gathered once — see §Perf yi-34b).
    """
    sc = rt.sc
    h_axis = sc.div(cfg.n_heads, sc.tp_axis)
    if h_axis is not None:
        return h_axis, None, None
    if rt.attn_fallback == "qseq":
        return None, None, sc.tp_axis
    return None, sc.tp_axis, None


def _sdpa_dense(q, k, v, *, causal: bool, cfg, rt: Runtime, B: int):
    """Single-einsum attention; q (B,Sq,H,hd), k/v already H-expanded."""
    sc = rt.sc
    h_axis, kvseq_axis, qseq_axis = _shard_plan(cfg, rt)
    bs = sc.div(B, sc.dp_axes)
    Sq, Sk = q.shape[1], k.shape[1]
    if kvseq_axis is not None:
        k = sc.constrain(k, bs, sc.div(Sk, kvseq_axis), None, None)
        v = sc.constrain(v, bs, sc.div(Sk, kvseq_axis), None, None)
    if qseq_axis is not None:
        q = sc.constrain(q, bs, sc.div(Sq, qseq_axis), None, None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (cfg.hd ** -0.5)
    scores = sc.constrain(
        scores, bs, h_axis,
        sc.div(Sq, qseq_axis) if qseq_axis else None,
        sc.div(Sk, kvseq_axis) if kvseq_axis else None)
    if causal:
        iq = jax.lax.broadcasted_iota(jnp.int32, (Sq, Sk), 0)
        ik = jax.lax.broadcasted_iota(jnp.int32, (Sq, Sk), 1)
        scores = jnp.where((ik <= iq + (Sk - Sq))[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(rt.compute_dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    return sc.constrain(out, bs, sc.div(Sq, qseq_axis) if qseq_axis
                        else None, h_axis, None)


def _sdpa_blockwise(q, k, v, *, causal: bool, cfg, rt: Runtime, B: int):
    """Scan over q chunks with full-KV online softmax (rematerialized).

    Memory: O(B * H * Cq * Sk) per chunk instead of O(B * H * Sq * Sk).
    FLOPs are counted over the full Sq x Sk rectangle (causal skipping is the
    ``attn_banded`` optimization, see EXPERIMENTS.md §Perf).
    """
    sc = rt.sc
    h_axis, kvseq_axis, qseq_axis = _shard_plan(cfg, rt)
    bs = sc.div(B, sc.dp_axes)
    Sq, Sk = q.shape[1], k.shape[1]
    Cq = min(rt.attn_q_chunk, Sq)
    if Sq % Cq != 0:
        Cq = Sq
    nq = Sq // Cq
    if kvseq_axis is not None:
        k = sc.constrain(k, bs, sc.div(Sk, kvseq_axis), None, None)
        v = sc.constrain(v, bs, sc.div(Sk, kvseq_axis), None, None)

    qs = q.reshape(B, nq, Cq, q.shape[2], q.shape[3]).swapaxes(0, 1)

    @jax.checkpoint
    def chunk(qc, idx):
        if qseq_axis is not None:  # shard the q rows within the chunk
            qc = sc.constrain(qc, bs, sc.div(Cq, qseq_axis), None, None)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qc, k,
                            preferred_element_type=jnp.float32)
        scores = scores * (cfg.hd ** -0.5)
        scores = sc.constrain(
            scores, bs, h_axis,
            sc.div(Cq, qseq_axis) if qseq_axis else None,
            sc.div(Sk, kvseq_axis) if kvseq_axis else None)
        if causal:
            iq = idx * Cq + jax.lax.broadcasted_iota(jnp.int32, (Cq, Sk), 0)
            ik = jax.lax.broadcasted_iota(jnp.int32, (Cq, Sk), 1)
            scores = jnp.where((ik <= iq)[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(rt.compute_dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
        return sc.constrain(out, bs, sc.div(Cq, qseq_axis) if qseq_axis
                            else None, h_axis, None)

    def body(_, inp):
        qc, idx = inp
        return None, chunk(qc, idx)

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nq)))
    return outs.swapaxes(0, 1).reshape(B, Sq, q.shape[2], q.shape[3])


def attention(p: dict, x: jax.Array, cfg: ArchConfig, rt: Runtime, *,
              causal: bool = True, positions: Optional[jax.Array] = None,
              kv_x: Optional[jax.Array] = None, return_kv: bool = False):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    B, Sq, _ = x.shape
    q, k, v = _project_qkv(p, x, kv_x, cfg, rt)
    if cfg.rope and kv_x is None:
        if positions is None:
            positions = jnp.arange(Sq, dtype=jnp.int32)[None, :]
        cos, sin = rope_tables(positions, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    kv = (k, v)  # un-expanded (B, S, KV, hd) for the decode cache
    bq, bk = min(128, q.shape[1]), min(128, k.shape[1])
    divisible = q.shape[1] % bq == 0 and k.shape[1] % bk == 0
    if rt.use_pallas and rt.sc.mesh is None and divisible:
        # single-device hot path: fused flash-attention kernel (GQA-aware;
        # under a mesh the jnp path lowers through SPMD instead)
        from repro.kernels.flash_attention.ops import sdpa as flash_sdpa
        out = flash_sdpa(q, k, v, causal=causal, block_q=bq, block_k=bk)
    else:
        k = _expand_kv(k, cfg)
        v = _expand_kv(v, cfg)
        if k.shape[1] <= rt.attn_dense_threshold:
            out = _sdpa_dense(q, k, v, causal=causal, cfg=cfg, rt=rt, B=B)
        else:
            out = _sdpa_blockwise(q, k, v, causal=causal, cfg=cfg, rt=rt, B=B)
    cd = rt.compute_dtype
    out = out.reshape(B, Sq, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cd))
    if return_kv:
        return out, kv
    return out


def attention_with_kv(p: dict, x: jax.Array, cfg: ArchConfig, rt: Runtime, *,
                      causal: bool = True,
                      positions: Optional[jax.Array] = None,
                      kv_x: Optional[jax.Array] = None):
    return attention(p, x, cfg, rt, causal=causal, positions=positions,
                     kv_x=kv_x, return_kv=True)


# --------------------------------------------------------------------------- #
# Decode (one new token against a KV cache)
# --------------------------------------------------------------------------- #
def attn_cache_init(cfg: ArchConfig, rt: Runtime, B: int, S: int) -> dict:
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((B, S, KV, hd), rt.compute_dtype),
        "v": jnp.zeros((B, S, KV, hd), rt.compute_dtype),
    }


def attn_decode(p: dict, x: jax.Array, cache: dict, cache_len: jax.Array,
                cfg: ArchConfig, rt: Runtime,
                cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None
                ) -> Tuple[jax.Array, dict]:
    """x (B, 1, d); cache k/v (B, S, KV, hd); cache_len scalar int32.

    Writes the new k/v at ``cache_len`` and attends over positions
    [0, cache_len].  With ``cross_kv`` set, attends over the precomputed
    encoder k/v instead (no cache update).
    """
    sc = rt.sc
    B = x.shape[0]
    bs = sc.div(B, sc.dp_axes)
    h_axis, _, _ = _shard_plan(cfg, rt)
    # decode: a 1-token q can't be row-sharded; always kv-seq shard the cache
    seq_axis = sc.tp_axis if h_axis is None else None

    if cross_kv is not None:
        q, _, _ = _project_qkv(p, x, jnp.zeros_like(x), cfg, rt)
        k, v = cross_kv
        new_cache = cache
    else:
        q, k_new, v_new = _project_qkv(p, x, None, cfg, rt)
        if cfg.rope:
            pos = jnp.full((B, 1), cache_len, jnp.int32)
            cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k_new = apply_rope(k_new, cos, sin)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, cache_len, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, cache_len, 0, 0))
        new_cache = {"k": k, "v": v}

    S = k.shape[1]
    k_e = _expand_kv(k, cfg)
    v_e = _expand_kv(v, cfg)
    if seq_axis is not None:
        k_e = sc.constrain(k_e, bs, sc.div(S, seq_axis), None, None)
        v_e = sc.constrain(v_e, bs, sc.div(S, seq_axis), None, None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k_e,
                        preferred_element_type=jnp.float32)
    scores = scores * (cfg.hd ** -0.5)
    if cross_kv is None:
        valid = jnp.arange(S)[None, None, None, :] <= cache_len
        scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(rt.compute_dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v_e)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(rt.compute_dtype))
    return out, new_cache
