"""Model assembly: embeddings -> scan over layer periods -> loss / cache.

Heterogeneous stacks (Jamba, xLSTM) are expressed as a repeating *period* of
LayerSpecs; parameters are stacked with a leading ``n_periods`` axis and the
period body is applied under a single ``lax.scan`` (optionally rematerialized)
— this keeps HLO size and compile time independent of depth.

Three entry points:
  * ``forward_train``   -> (loss, metrics)                  [train_4k]
  * ``forward_prefill`` -> (last-position logits, cache)    [prefill_32k]
  * ``forward_decode``  -> (logits, new cache)              [decode_32k/long_500k]
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (Runtime, chunked_cross_entropy, dense_init,
                                 logits_for, norm_apply, norm_init,
                                 sinusoidal_position_at, sinusoidal_positions)
from repro.models.mlp import mlp, mlp_init
from repro.models.moe import moe, moe_init

AUX_KEYS = ("moe_lb_loss", "moe_router_z", "moe_drop_frac")


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #
def _layer_init(key, spec: LayerSpec, cfg: ArchConfig, rt: Runtime) -> dict:
    ks = iter(jax.random.split(key, 8))
    p: dict = {"mixer_norm": norm_init(cfg.norm, cfg.d_model, rt.param_dtype)}
    if spec.mixer == "attn":
        p["mixer"] = attn_mod.attn_init(next(ks), cfg, rt)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_mod.mamba_init(next(ks), cfg, rt)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm_mod.mlstm_init(next(ks), cfg, rt)
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm_mod.slstm_init(next(ks), cfg, rt)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        p["cross_norm"] = norm_init(cfg.norm, cfg.d_model, rt.param_dtype)
        p["cross"] = attn_mod.attn_init(next(ks), cfg, rt)
    if spec.ffn == "dense":
        p["ffn_norm"] = norm_init(cfg.norm, cfg.d_model, rt.param_dtype)
        p["ffn"] = mlp_init(next(ks), cfg, rt)
    elif spec.ffn == "moe":
        p["ffn_norm"] = norm_init(cfg.norm, cfg.d_model, rt.param_dtype)
        p["ffn"] = moe_init(next(ks), cfg, rt)
    return p


def init_params(key, cfg: ArchConfig, rt: Runtime) -> dict:
    d, Vp = cfg.d_model, cfg.padded_vocab()
    keys = jax.random.split(key, 8)
    params: dict = {
        "embed": dense_init(keys[0], d, (Vp, d), rt.param_dtype),
        "final_norm": norm_init(cfg.norm, d, rt.param_dtype),
        "blocks": {},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], d, (d, Vp), rt.param_dtype)
    for i, spec in enumerate(cfg.period):
        pos_keys = jax.random.split(jax.random.fold_in(keys[2], i),
                                    cfg.n_periods)
        params["blocks"][f"pos{i}"] = jax.vmap(
            lambda k, s=spec: _layer_init(k, s, cfg, rt))(pos_keys)
    if cfg.encoder_layers:
        enc_spec = LayerSpec("attn", "dense")
        enc_keys = jax.random.split(keys[3], cfg.encoder_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: _layer_init(k, enc_spec, cfg, rt))(enc_keys)
        params["enc_norm"] = norm_init(cfg.norm, d, rt.param_dtype)
    return params


# --------------------------------------------------------------------------- #
# Block application
# --------------------------------------------------------------------------- #
def _apply_block(spec: LayerSpec, p: dict, x: jax.Array, cfg: ArchConfig,
                 rt: Runtime, *, batch: int, causal: bool = True,
                 enc_out: Optional[jax.Array] = None,
                 positions: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    aux = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    sc = rt.sc
    if sc.seq_parallel and sc.tp_axis is not None:
        # Megatron-SP: residual stream sharded over seq between blocks
        x = sc.constrain(x, sc.div(batch, sc.dp_axes),
                         sc.div(x.shape[1], sc.tp_axis), None)
    h = norm_apply(cfg.norm, x, p["mixer_norm"])
    if spec.mixer == "attn":
        mixed = attn_mod.attention(p["mixer"], h, cfg, rt, causal=causal,
                                   positions=positions)
    elif spec.mixer == "mamba":
        mixed = mamba_mod.mamba(p["mixer"], h, cfg, rt, batch=batch)
    elif spec.mixer == "mlstm":
        mixed = xlstm_mod.mlstm(p["mixer"], h, cfg, rt, batch=batch)
    else:
        mixed = xlstm_mod.slstm(p["mixer"], h, cfg, rt, batch=batch)
    x = x + mixed
    if spec.cross_attn and enc_out is not None:
        h = norm_apply(cfg.norm, x, p["cross_norm"])
        x = x + attn_mod.attention(p["cross"], h, cfg, rt, causal=False,
                                   kv_x=enc_out)
    if spec.ffn != "none":
        h = norm_apply(cfg.norm, x, p["ffn_norm"])
        if spec.ffn == "dense":
            x = x + mlp(p["ffn"], h, cfg, rt, batch=batch)
        else:
            y, moe_aux = moe(p["ffn"], h, cfg, rt, batch=batch)
            x = x + y
            for k in AUX_KEYS:
                aux[k] = aux[k] + moe_aux[k].astype(jnp.float32)
    return x, aux


def _remat(fn, rt: Runtime):
    if rt.remat_policy == "none":
        return fn
    if rt.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full": save only block inputs


def _scan_periods(params_blocks: dict, x: jax.Array, cfg: ArchConfig,
                  rt: Runtime, *, batch: int, causal: bool = True,
                  enc_out=None, positions=None):
    def body_fn(x, period_params):
        aux_tot = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
        for i, spec in enumerate(cfg.period):
            x, aux = _apply_block(spec, period_params[f"pos{i}"], x, cfg, rt,
                                  batch=batch, causal=causal, enc_out=enc_out,
                                  positions=positions)
            for k in AUX_KEYS:
                aux_tot[k] = aux_tot[k] + aux[k]
        return x, aux_tot

    body = _remat(body_fn, rt)

    def scan_body(carry, period_params):
        x, aux_acc = carry
        x, aux = body(x, period_params)
        return (x, {k: aux_acc[k] + aux[k] for k in AUX_KEYS}), None

    aux0 = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    (x, aux), _ = jax.lax.scan(scan_body, (x, aux0), params_blocks)
    return x, aux


# --------------------------------------------------------------------------- #
# Embedding / head helpers
# --------------------------------------------------------------------------- #
def _embed_tokens(params, tokens: jax.Array, cfg: ArchConfig, rt: Runtime
                  ) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(rt.compute_dtype)
    return rt.sc.act(x, tokens.shape[0], None, None)


def _head_weights(params, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def _add_sinusoidal(x: jax.Array, offset=0) -> jax.Array:
    S, d = x.shape[1], x.shape[2]
    pos = sinusoidal_positions(S + offset, d)[offset:offset + S]
    return x + pos[None].astype(x.dtype)


def encode_audio(params, frames: jax.Array, cfg: ArchConfig, rt: Runtime,
                 *, batch: int) -> jax.Array:
    """Whisper encoder over stubbed post-conv frame embeddings (B, Se, d)."""
    x = _add_sinusoidal(frames.astype(rt.compute_dtype))
    enc_cfg_spec = LayerSpec("attn", "dense")

    def body_fn(x, p):
        x, _ = _apply_block(enc_cfg_spec, p, x, cfg, rt, batch=batch,
                            causal=False)
        return x

    body = _remat(body_fn, rt)
    x, _ = jax.lax.scan(lambda c, p: (body(c, p), None), x,
                        params["enc_blocks"])
    return norm_apply(cfg.norm, x, params["enc_norm"])


# --------------------------------------------------------------------------- #
# Train
# --------------------------------------------------------------------------- #
def forward_train(params: dict, batch: Dict[str, jax.Array], cfg: ArchConfig,
                  rt: Runtime) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    x = _embed_tokens(params, tokens, cfg, rt)
    n_prefix = 0
    enc_out = None
    if cfg.vision_tokens:  # VLM: prepend stubbed patch embeddings
        x = jnp.concatenate(
            [batch["patches"].astype(rt.compute_dtype), x], axis=1)
        n_prefix = cfg.vision_tokens
    if cfg.encoder_layers:  # audio: encode stubbed frame embeddings
        enc_out = encode_audio(params, batch["frames"], cfg, rt, batch=B)
    if not cfg.rope and not cfg.encoder_layers and cfg.family not in (
            "hybrid", "ssm"):
        x = _add_sinusoidal(x)
    elif cfg.encoder_layers:
        x = _add_sinusoidal(x)

    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x, aux = _scan_periods(params["blocks"], x, cfg, rt, batch=B,
                           causal=True, enc_out=enc_out, positions=positions)
    x = norm_apply(cfg.norm, x, params["final_norm"])
    if n_prefix:
        x = x[:, n_prefix:]
    loss_ce, denom = chunked_cross_entropy(
        x, _head_weights(params, cfg), labels, (labels >= 0), rt,
        cfg.vocab_size)
    loss = (loss_ce + 0.01 * aux["moe_lb_loss"] + 0.001 * aux["moe_router_z"])
    metrics = {"loss": loss, "ce": loss_ce, "tokens": denom, **aux}
    return loss, metrics


# --------------------------------------------------------------------------- #
# Prefill / decode (serving)
# --------------------------------------------------------------------------- #
def init_cache(cfg: ArchConfig, rt: Runtime, B: int, S: int) -> dict:
    """Abstract-shape-compatible cache pytree for one decode step."""
    cache: dict = {}
    for i, spec in enumerate(cfg.period):
        if spec.mixer == "attn":
            c = attn_mod.attn_cache_init(cfg, rt, B, S)
        elif spec.mixer == "mamba":
            c = mamba_mod.mamba_cache_init(cfg, rt, B)
        elif spec.mixer == "mlstm":
            c = xlstm_mod.mlstm_cache_init(cfg, rt, B)
        else:
            c = xlstm_mod.slstm_cache_init(cfg, rt, B)
        if spec.cross_attn:
            Se, KV, hd = cfg.encoder_seq, cfg.n_kv_heads, cfg.hd
            c = dict(c)
            c["cross_k"] = jnp.zeros((B, Se, KV, hd), rt.compute_dtype)
            c["cross_v"] = jnp.zeros((B, Se, KV, hd), rt.compute_dtype)
        cache[f"pos{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape), c)
    return cache


def forward_decode(params: dict, tokens: jax.Array, cache: dict,
                   cache_len: jax.Array, cfg: ArchConfig, rt: Runtime
                   ) -> Tuple[jax.Array, dict]:
    """tokens (B, 1); cache from ``init_cache``; cache_len scalar int32."""
    B = tokens.shape[0]
    x = _embed_tokens(params, tokens, cfg, rt)
    if not cfg.rope and cfg.family not in ("hybrid", "ssm"):
        pos_row = sinusoidal_position_at(cache_len, x.shape[-1])
        x = x + pos_row[None, None].astype(x.dtype)

    def scan_body(x, inp):
        period_params, period_cache = inp
        new_cache = {}
        for i, spec in enumerate(cfg.period):
            p = period_params[f"pos{i}"]
            c = period_cache[f"pos{i}"]
            h = norm_apply(cfg.norm, x, p["mixer_norm"])
            if spec.mixer == "attn":
                mixed, nc = attn_mod.attn_decode(
                    p["mixer"], h, {"k": c["k"], "v": c["v"]}, cache_len,
                    cfg, rt)
                nc = {**c, **nc}
            elif spec.mixer == "mamba":
                mixed, nc = mamba_mod.mamba_decode(p["mixer"], h, c, cfg, rt)
            elif spec.mixer == "mlstm":
                mixed, nc = xlstm_mod.mlstm_decode(p["mixer"], h, c, cfg, rt)
            else:
                mixed, nc = xlstm_mod.slstm_decode(p["mixer"], h, c, cfg, rt)
            x = x + mixed
            if spec.cross_attn:
                h = norm_apply(cfg.norm, x, p["cross_norm"])
                y, _ = attn_mod.attn_decode(
                    p["cross"], h, {}, cache_len, cfg, rt,
                    cross_kv=(c["cross_k"], c["cross_v"]))
                x = x + y
            if spec.ffn == "dense":
                h = norm_apply(cfg.norm, x, p["ffn_norm"])
                x = x + mlp(p["ffn"], h, cfg, rt, batch=B)
            elif spec.ffn == "moe":
                h = norm_apply(cfg.norm, x, p["ffn_norm"])
                y, _ = moe(p["ffn"], h, cfg, rt, batch=B)
                x = x + y
            new_cache[f"pos{i}"] = nc
        return x, new_cache

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    x = norm_apply(cfg.norm, x, params["final_norm"])
    logits = logits_for(x, _head_weights(params, cfg), rt, cfg.vocab_size)
    return logits[:, 0], new_cache


def forward_prefill(params: dict, batch: Dict[str, jax.Array],
                    cfg: ArchConfig, rt: Runtime,
                    cache_size: Optional[int] = None
                    ) -> Tuple[jax.Array, dict]:
    """Build a KV cache by scanning the decoder over the prompt.

    For lowering simplicity and exact decode-path parity we run the full
    sequence through the train-style forward to produce last-position logits,
    and (for attention layers) return the cache produced by that pass.  SSM
    states are produced by the chunked scans' final carries.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(params, tokens, cfg, rt)
    enc_out = None
    if cfg.vision_tokens:
        x = jnp.concatenate(
            [batch["patches"].astype(rt.compute_dtype), x], axis=1)
    if cfg.encoder_layers:
        enc_out = encode_audio(params, batch["frames"], cfg, rt, batch=B)
        x = _add_sinusoidal(x)
    elif not cfg.rope and cfg.family not in ("hybrid", "ssm"):
        x = _add_sinusoidal(x)

    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    # the cache covers the full internal sequence (incl. any VLM prefix)
    S_cache = max(cache_size or 0, x.shape[1])
    cache = init_cache(cfg, rt, B, S_cache)

    def _pad_kv(t):
        pad = S_cache - t.shape[1]
        if pad == 0:
            return t
        return jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def scan_body(x, inp):
        period_params, period_cache = inp
        new_cache = {}
        for i, spec in enumerate(cfg.period):
            p = period_params[f"pos{i}"]
            c = period_cache[f"pos{i}"]
            h = norm_apply(cfg.norm, x, p["mixer_norm"])
            nc = c
            if spec.mixer == "attn":
                mixed, kv = attn_mod.attention_with_kv(
                    p["mixer"], h, cfg, rt, positions=positions)
                nc = {**c, "k": _pad_kv(kv[0]), "v": _pad_kv(kv[1])}
            elif spec.mixer == "mamba":
                mixed, st = mamba_mod.mamba_with_state(
                    p["mixer"], h, cfg, rt, batch=B)
                nc = st
            elif spec.mixer == "mlstm":
                mixed, st = xlstm_mod.mlstm_with_state(
                    p["mixer"], h, cfg, rt, batch=B)
                nc = st
            else:
                mixed, st = xlstm_mod.slstm_with_state(
                    p["mixer"], h, cfg, rt, batch=B)
                nc = st
            x = x + mixed
            if spec.cross_attn:
                h = norm_apply(cfg.norm, x, p["cross_norm"])
                y, ckv = attn_mod.attention_with_kv(
                    p["cross"], h, cfg, rt, kv_x=enc_out, causal=False)
                x = x + y
                nc = {**nc, "cross_k": ckv[0], "cross_v": ckv[1]}
            if spec.ffn == "dense":
                hh = norm_apply(cfg.norm, x, p["ffn_norm"])
                x = x + mlp(p["ffn"], hh, cfg, rt, batch=B)
            elif spec.ffn == "moe":
                hh = norm_apply(cfg.norm, x, p["ffn_norm"])
                y, _ = moe(p["ffn"], hh, cfg, rt, batch=B)
                x = x + y
            new_cache[f"pos{i}"] = nc
        return x, new_cache

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    x = norm_apply(cfg.norm, x, params["final_norm"])
    last = x[:, -1:]
    logits = logits_for(last, _head_weights(params, cfg), rt, cfg.vocab_size)
    return logits[:, 0], new_cache
