"""Feature-detection shims for JAX API drift.

The repo targets a range of JAX releases; two APIs moved underneath us:

  * ``jax.experimental.pallas.tpu.CompilerParams`` was called
    ``TPUCompilerParams`` in older releases (and is absent in very old ones).
  * ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)`` only
    exist in newer releases; older ``make_mesh`` takes (shapes, names) only.

Everything here is resolved once at import time so the hot paths pay no
per-call getattr cost.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def _resolve_compiler_params_cls():
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pallas TPU backend not available at all
        return None
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    return None


_COMPILER_PARAMS_CLS = _resolve_compiler_params_cls()


def tpu_compiler_params(**kwargs) -> Optional[object]:
    """Build pallas-TPU compiler params under whichever name this JAX has.

    Returns None (pallas_call's default) when no params class exists, so
    call sites can pass the result straight to ``compiler_params=``.
    """
    if _COMPILER_PARAMS_CLS is None:
        return None
    return _COMPILER_PARAMS_CLS(**kwargs)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # older releases only ship the experimental entry point
    from jax.experimental.shard_map import shard_map  # noqa: F401


_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(shape: Sequence[int], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _AXIS_TYPE is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(_AXIS_TYPE.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)
