"""Feature-detection shims for JAX API drift.

The repo targets a range of JAX releases; two APIs moved underneath us:

  * ``jax.experimental.pallas.tpu.CompilerParams`` was called
    ``TPUCompilerParams`` in older releases (and is absent in very old ones).
  * ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)`` only
    exist in newer releases; older ``make_mesh`` takes (shapes, names) only.

Everything here is resolved once at import time so the hot paths pay no
per-call getattr cost.

This module also pins ``JAX_PLATFORMS=cpu`` when no accelerator is visible
(below, before jax is imported): on accelerator-less CI runners the TPU
plugin otherwise probes the GCP metadata server at device discovery and can
stall for minutes.  Entry points that may run on bare runners
(``launch/dryrun.py``, ``benchmarks/autotune_sharding.py``) import
``repro.compat`` before jax to get this guard; an explicit ``JAX_PLATFORMS``
in the environment always wins.
"""
from __future__ import annotations

import os as _os

from typing import Optional, Sequence, Tuple


def _pin_cpu_if_no_accelerator() -> None:
    if "JAX_PLATFORMS" in _os.environ:
        return  # explicit choice wins
    tpu = (any(_os.path.exists(f"/dev/accel{i}") for i in range(4))
           or _os.path.exists("/dev/vfio")
           or _os.environ.get("TPU_NAME")
           or _os.environ.get("TPU_WORKER_ID"))
    gpu = _os.path.exists("/dev/nvidia0")
    if not tpu and not gpu:
        _os.environ["JAX_PLATFORMS"] = "cpu"


_pin_cpu_if_no_accelerator()

import jax  # noqa: E402  (the platform pin above must precede this)


def _resolve_compiler_params_cls():
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pallas TPU backend not available at all
        return None
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    return None


_COMPILER_PARAMS_CLS = _resolve_compiler_params_cls()


def tpu_compiler_params(**kwargs) -> Optional[object]:
    """Build pallas-TPU compiler params under whichever name this JAX has.

    Returns None (pallas_call's default) when no params class exists, so
    call sites can pass the result straight to ``compiler_params=``.
    """
    if _COMPILER_PARAMS_CLS is None:
        return None
    return _COMPILER_PARAMS_CLS(**kwargs)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # older releases only ship the experimental entry point
    from jax.experimental.shard_map import shard_map  # noqa: F401


_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(shape: Sequence[int], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _AXIS_TYPE is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(_AXIS_TYPE.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)
