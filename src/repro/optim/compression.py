"""Gradient compression: int8 quantization with error feedback.

Distributed-optimization trick for the cross-pod (DCN) gradient reduction:
per-tensor int8 quantization cuts AR wire bytes 4x (vs fp32) with error
feedback (Seide et al. / EF-SGD) carrying the quantization residual into the
next step, which preserves convergence (tested in tests/test_optim.py).

Two layers:
  * ``ef_compress_tree``: numerics transform on the gradient pytree (what the
    train step applies — in SPMD the reduction itself is XLA-inserted, so the
    quantization models the compressed cross-pod collective),
  * ``compressed_psum``: an explicit shard_map int8 all-reduce over a named
    axis, used when the pod axis is manual (demonstrated on the test mesh).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ef_quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (dequantized int8 approximation, new error-feedback buffer)."""
    target = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(target)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, target - deq


def ef_compress_tree(grads, ef_state):
    out = jax.tree.map(ef_quantize, grads, ef_state)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_ef


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire all-reduce over a named (manual) mesh axis.

    Quantize locally, all-gather the int8 payloads + fp32 scales (the wire
    carries 1 byte/element instead of 4), and reduce after dequantization —
    the jax-native equivalent of a compressed DCN all-reduce for the pod
    axis.  Exact to within quantization error (tested).
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    qs = jax.lax.all_gather(q, axis_name)              # int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)          # (g,) fp32 scalars
    return jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,)))
