"""AdamW with fp32 moments over bf16 params, global-norm clip, schedules.

Pure-pytree implementation (no optax offline).  Moment tensors inherit the
parameter PartitionSpecs, so optimizer state is fully sharded (ZeRO-style)
wherever the parameters are.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1
    schedule: str = "cosine"  # cosine | linear | constant


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * (1 - frac)
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def opt_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def opt_update(cfg: AdamWConfig, params, grads, opt_state
               ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule_lr(cfg, step)
    b1c = 1 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    b2c = 1 - cfg.b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        pf = p.astype(jnp.float32)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # no weight decay on norms/biases/scalars
            delta = delta + cfg.weight_decay * pf
        return (pf - lr * delta).astype(p.dtype), m_new, v_new

    flat, treedef = jax.tree.flatten(params)
    gflat = jax.tree.leaves(grads)
    mflat = jax.tree.leaves(opt_state["m"])
    vflat = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat, gflat, mflat, vflat)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
