"""ServiceScheduler: run the existing tuner drivers against a remote
durable tuning service.

The scheduler protocols in this package answer "who *executes* trials";
the durable service answers "who *owns* ask/tell state".  This scheduler
composes the two: trial execution delegates to any inner scheduler
(serial, threads, task queue — whatever the deployment already uses),
while ``make_engine`` hands the driver a ``RemoteOptimizer`` bound to one
named study on the service.  ``Tuner``/``AsyncTuner`` detect the hook and
use the remote engine instead of constructing a local
``AskTellOptimizer`` — the driver loops are unchanged, but every ask and
tell is journaled server-side, so a crashed driver (or service) resumes
from the WAL with bit-identical proposals.

Strategy configuration (optimizer type, seed, fit schedule) lives in the
service's ``service.json``, not the driver config: N drivers against one
study must agree on it, and the journal replays against exactly one
strategy state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.service.client import RemoteOptimizer, ServiceClient


class ServiceScheduler:
    """Scheduler view of one study on a remote tuning service.

    ``inner`` executes trials (defaults to ``SerialScheduler``) and this
    object transparently exposes whichever scheduler protocol the inner
    one implements; ``make_engine`` supplies the remote ask/tell core.
    """

    def __init__(self, base_url: str, study: str, inner=None,
                 client: Optional[ServiceClient] = None,
                 timeout: float = 30.0, retries: int = 3):
        from repro.scheduler.local import SerialScheduler
        self.client = client or ServiceClient(base_url, timeout=timeout,
                                              retries=retries)
        self.study = study
        self.inner = inner if inner is not None else SerialScheduler()

    def make_engine(self, param_space,
                    conf: Optional[Dict[str, Any]] = None
                    ) -> RemoteOptimizer:
        """The driver's ask/tell core: a client for this study.  ``conf``
        is accepted for signature uniformity; strategy settings are
        server-side (see module docstring)."""
        return RemoteOptimizer(self.client, self.study,
                               param_space=param_space)

    # Expose exactly the protocol surface the inner scheduler has:
    # hasattr-based dispatch (``as_async``, the tuners) then sees a batch
    # scheduler, an async one, or both — matching the inner's nature.
    def __getattr__(self, item):
        if item in ("make_objective", "submit", "wait_any", "gather",
                    "as_async", "shutdown", "start", "stats"):
            return getattr(self.inner, item)
        raise AttributeError(item)
