from repro.scheduler.base import (AsyncScheduler, BatchToAsyncAdapter,
                                  Scheduler, TaskHandle, as_async)
from repro.scheduler.distributed import FaultInjection, TaskQueueScheduler
from repro.scheduler.local import (ProcessScheduler, SerialScheduler,
                                   ThreadScheduler)
from repro.scheduler.service import ServiceScheduler

__all__ = ["Scheduler", "AsyncScheduler", "TaskHandle",
           "BatchToAsyncAdapter", "as_async", "FaultInjection",
           "TaskQueueScheduler", "ProcessScheduler", "SerialScheduler",
           "ThreadScheduler", "ServiceScheduler"]
