from repro.scheduler.base import Scheduler
from repro.scheduler.distributed import FaultInjection, TaskQueueScheduler
from repro.scheduler.local import (ProcessScheduler, SerialScheduler,
                                   ThreadScheduler)

__all__ = ["Scheduler", "FaultInjection", "TaskQueueScheduler",
           "ProcessScheduler", "SerialScheduler", "ThreadScheduler"]
