"""Local schedulers: serial (paper Listing 3), thread pool, process pool.

All three implement the batch-objective protocol; ``.as_async()`` (from
``BatchSchedulerBase``) returns the submit/wait_any view so they can also
drive ``AsyncTuner``'s completion-event loop.
"""
from __future__ import annotations

import concurrent.futures as cf
from typing import Any, Dict, List, Optional

from repro.scheduler.base import BatchSchedulerBase, Objective, TrialFn


class SerialScheduler(BatchSchedulerBase):
    """Sequential evaluation; failed trials are dropped (partial results)."""

    def make_objective(self, trial_fn: TrialFn) -> Objective:
        def objective(params_list):
            evals, params = [], []
            for par in params_list:
                try:
                    evals.append(float(trial_fn(par)))
                    params.append(par)
                except Exception:
                    pass  # dropped -> tuner never observes it
            return evals, params

        return objective


class ThreadScheduler(BatchSchedulerBase):
    """Thread-pool evaluation with a per-batch deadline.

    Results that miss the deadline (stragglers) are NOT waited for — the
    batch returns partially, exactly the paper's out-of-order/missing-results
    contract.  Straggler futures are abandoned (daemon threads).
    """

    def __init__(self, n_workers: int = 4, timeout: Optional[float] = None):
        self.n_workers = n_workers
        self.timeout = timeout

    def make_objective(self, trial_fn: TrialFn) -> Objective:
        def objective(params_list):
            evals, params = [], []
            ex = cf.ThreadPoolExecutor(max_workers=self.n_workers)
            futs = {ex.submit(trial_fn, par): par for par in params_list}
            try:
                for fut in cf.as_completed(futs, timeout=self.timeout):
                    par = futs[fut]
                    try:
                        evals.append(float(fut.result()))
                        params.append(par)
                    except Exception:
                        pass
            except cf.TimeoutError:
                pass  # deadline: return what we have
            ex.shutdown(wait=False, cancel_futures=True)
            return evals, params

        return objective


class ProcessScheduler(BatchSchedulerBase):
    """Process-pool evaluation (trial_fn must be picklable)."""

    def __init__(self, n_workers: int = 2, timeout: Optional[float] = None):
        self.n_workers = n_workers
        self.timeout = timeout

    def make_objective(self, trial_fn: TrialFn) -> Objective:
        def objective(params_list):
            evals, params = [], []
            with cf.ProcessPoolExecutor(max_workers=self.n_workers) as ex:
                futs = {ex.submit(trial_fn, par): par for par in params_list}
                try:
                    for fut in cf.as_completed(futs, timeout=self.timeout):
                        par = futs[fut]
                        try:
                            evals.append(float(fut.result()))
                            params.append(par)
                        except Exception:
                            pass
                except cf.TimeoutError:
                    for fut in futs:
                        fut.cancel()
            return evals, params

        return objective
