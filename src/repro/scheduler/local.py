"""Local schedulers: serial (paper Listing 3), thread pool, process pool.

All three implement the batch-objective protocol; ``.as_async()`` (from
``BatchSchedulerBase``) returns the submit/wait_any view so they can also
drive ``AsyncTuner``'s completion-event loop.
"""
from __future__ import annotations

import concurrent.futures as cf
import logging
import threading
import time
from typing import Any, Dict, List, Optional

from repro.scheduler.base import BatchSchedulerBase, Objective, TrialFn

_log = logging.getLogger(__name__)


class SerialScheduler(BatchSchedulerBase):
    """Sequential evaluation; failed trials are dropped (partial results)."""

    def make_objective(self, trial_fn: TrialFn) -> Objective:
        def objective(params_list):
            evals, params = [], []
            for par in params_list:
                try:
                    evals.append(float(trial_fn(par)))
                    params.append(par)
                except Exception as e:
                    # dropped -> tuner never observes it (paper's
                    # fault-tolerance contract), but the drop is visible
                    _log.debug("trial dropped (%s): %r", par, e)
            return evals, params

        return objective


class ThreadScheduler(BatchSchedulerBase):
    """Threaded evaluation with a per-batch deadline.

    Results that miss the deadline (stragglers) are NOT waited for — the
    batch returns partially, exactly the paper's out-of-order/missing-results
    contract.  Trials run on *daemon* threads gated by a semaphore (at most
    ``n_workers`` concurrent), so an abandoned straggler can never block
    interpreter exit.  (``concurrent.futures.ThreadPoolExecutor`` workers
    are non-daemon and joined at interpreter shutdown — one straggler past
    the deadline would stall the whole process for as long as it runs.)
    """

    def __init__(self, n_workers: int = 4, timeout: Optional[float] = None):
        self.n_workers = n_workers
        self.timeout = timeout

    def make_objective(self, trial_fn: TrialFn) -> Objective:
        def objective(params_list):
            cv = threading.Condition()
            gate = threading.BoundedSemaphore(self.n_workers)
            cancelled = threading.Event()
            evals: List[float] = []
            params: List[Dict[str, Any]] = []
            state = {"left": len(params_list)}

            def run(par):
                try:
                    with gate:
                        # deadline already fired while queued behind the
                        # gate: never start the trial (matches the old
                        # executor's cancel_futures semantics — only
                        # already-*running* trials are abandoned mid-air)
                        if cancelled.is_set():
                            return
                        v = float(trial_fn(par))
                    with cv:
                        evals.append(v)
                        params.append(par)
                except Exception as e:
                    # dropped -> tuner never observes it, but visibly
                    _log.debug("trial dropped (%s): %r", par, e)
                finally:
                    with cv:
                        state["left"] -= 1
                        cv.notify_all()

            for par in params_list:
                threading.Thread(target=run, args=(par,), daemon=True,
                                 name="mango-thread-worker").start()
            deadline = (None if self.timeout is None
                        else time.monotonic() + self.timeout)
            with cv:
                while state["left"] > 0:
                    rem = (None if deadline is None
                           else deadline - time.monotonic())
                    if rem is not None and rem <= 0:
                        break  # deadline: return what we have
                    cv.wait(rem)
                # snapshot under the lock: a straggler landing after the
                # deadline appends to the dead lists, not the result
                out = (list(evals), list(params))
            cancelled.set()
            return out

        return objective


class ProcessScheduler(BatchSchedulerBase):
    """Process-pool evaluation (trial_fn must be picklable)."""

    def __init__(self, n_workers: int = 2, timeout: Optional[float] = None):
        self.n_workers = n_workers
        self.timeout = timeout

    def make_objective(self, trial_fn: TrialFn) -> Objective:
        def objective(params_list):
            evals, params = [], []
            with cf.ProcessPoolExecutor(max_workers=self.n_workers) as ex:
                futs = {ex.submit(trial_fn, par): par for par in params_list}
                try:
                    for fut in cf.as_completed(futs, timeout=self.timeout):
                        par = futs[fut]
                        try:
                            evals.append(float(fut.result()))
                            params.append(par)
                        except Exception as e:
                            # dropped -> tuner never observes it
                            _log.debug("trial dropped (%s): %r", par, e)
                except cf.TimeoutError:
                    for fut in futs:
                        fut.cancel()
            return evals, params

        return objective
