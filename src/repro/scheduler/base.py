"""Scheduler abstraction (paper §2.4).

Mango's key design decision: the optimizer never talks to a scheduling
framework.  Two execution protocols drive the same ask/tell core:

  * **Batch** (``Scheduler``): a factory that wraps a per-trial callable
    into the paper's batch objective — takes a list of configurations,
    returns partial ``(evals, params)``.  The synchronous ``Tuner`` loop
    uses this directly.
  * **Async** (``AsyncScheduler``): ``submit(fn, params) -> TaskHandle``
    plus ``wait_any(handles)`` — a completion-event interface the
    ``AsyncTuner`` event loop blocks on.  Implementations signal a
    ``threading.Condition`` when a trial finishes, so the event loop wakes
    exactly then (no polling).

``BatchToAsyncAdapter`` bridges the two: any batch-objective scheduler
(serial, thread pool, process pool, task queue) becomes submittable one
trial at a time, keeping its own fault semantics (a dropped trial surfaces
as a failed handle).  ``as_async`` picks the right view automatically, so
both tuners accept *any* scheduler.

The ``TaskQueueScheduler`` in ``distributed.py`` reproduces the Celery-on-
Kubernetes production setup from the paper (Listing 4) and implements both
protocols natively.
"""
from __future__ import annotations

import threading
import time
import weakref

from repro.analysis.sanitizers import assert_holds
from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

TrialFn = Callable[[Dict[str, Any]], float]
Objective = Callable[[List[Dict[str, Any]]],
                     Tuple[List[float], List[Dict[str, Any]]]]


class Scheduler(Protocol):
    def make_objective(self, trial_fn: TrialFn) -> Objective:
        """Wrap a single-config callable into Mango's batch objective."""
        ...


class TaskHandle:
    """A single in-flight trial: result/error land here, ``done`` is set
    last (and the owning scheduler's condition is notified)."""

    __slots__ = ("params", "result", "error", "done")

    def __init__(self, params: Dict[str, Any]):
        self.params = params
        self.result: Optional[float] = None
        self.error: Optional[BaseException] = None
        self.done = threading.Event()


class AsyncScheduler(Protocol):
    def submit(self, fn: TrialFn, params: Dict[str, Any]) -> TaskHandle:
        """Dispatch one trial; returns immediately with its handle."""
        ...

    def wait_any(self, handles: List[TaskHandle],
                 timeout: Optional[float] = None) -> List[TaskHandle]:
        """Block until at least one handle completes (or timeout); return
        the completed subset."""
        ...


class BatchSchedulerBase:
    """Mixin for batch-objective schedulers: ``as_async()`` returns the
    submit-style view of this scheduler."""

    def make_objective(self, trial_fn: TrialFn) -> Objective:
        raise NotImplementedError

    def as_async(self, coalesce: bool = False) -> "BatchToAsyncAdapter":
        return BatchToAsyncAdapter(self, coalesce=coalesce)


class BatchToAsyncAdapter:
    """Drive a batch-objective ``Scheduler`` one trial at a time.

    Each ``submit`` runs a single-element batch through the wrapped
    scheduler's objective on its own daemon thread (the driver caps
    in-flight trials, so thread count stays bounded; daemon threads mean an
    abandoned straggler can never block interpreter exit), preserving the
    scheduler's fault/deadline semantics: an empty partial result means the
    trial was dropped and surfaces as a failed handle.  Completion signals
    the shared condition variable, so ``wait_any`` wakes exactly when a
    trial lands.

    ``coalesce=True`` batches instead: submits enqueue, and a single
    dispatcher thread drains the whole queue into ONE objective call per
    (objective, drain) group.  Schedulers with per-batch setup cost — a
    ``ProcessScheduler`` builds a fresh process pool per objective call, a
    task-queue scheduler pays a round-trip — amortize that cost over every
    trial queued while the previous dispatch ran, at the price of
    dispatch-granular (not trial-granular) completion.  Fault semantics
    are the batch contract's: results are matched back to handles
    identity-first (the scheduler echoes the params object) then by
    equality, and a submitted trial missing from the partial result
    surfaces as a failed handle.
    """

    def __init__(self, scheduler: Scheduler, coalesce: bool = False):
        self.scheduler = scheduler
        self.coalesce = bool(coalesce)
        self._queue: List[tuple] = []   # (handle, objective, pinned fn)
        self._dispatcher: Optional[threading.Thread] = None
        self._cv = threading.Condition()
        self._outstanding = 0           # submitted, not yet done
        self._closed = False            # shutdown() called: submit refused
        # keyed by the fn object itself, weakly: an ``id(fn)`` key outlives
        # the fn, so a later fn allocated at the recycled address would
        # silently inherit the *old* objective (and every entry would leak
        # for the adapter's lifetime)
        self._objectives: "weakref.WeakKeyDictionary[TrialFn, Objective]" \
            = weakref.WeakKeyDictionary()

    def _objective_for(self, fn: TrialFn) -> Tuple[Objective, TrialFn]:
        """Returns (objective, pin): ``pin`` is the exact fn object the
        cached objective weak-references, and the caller must keep it
        alive for the trial's duration.  Lookups are by equality, so an
        equal-but-distinct callable (a fresh bound-method object) can hit
        an entry wrapping an *earlier* object — pinning the wrapped object
        itself (not the argument) is what makes that reuse safe."""
        try:
            ent = self._objectives.get(fn)
            if ent is not None:
                wrapped = ent[0]()
                if wrapped is not None:
                    return ent[1], wrapped
            # the objective must not hold fn strongly, or the cache entry
            # (value -> fn -> key) could never be collected; the weak
            # indirection is resolved per call, and ``submit`` pins the
            # wrapped fn for each in-flight trial's duration
            fn_ref = weakref.ref(fn)

            def call_fn(par):
                live = fn_ref()
                if live is None:
                    raise RuntimeError(
                        "trial fn was garbage-collected while cached")
                return live(par)

            obj = self.scheduler.make_objective(call_fn)
            self._objectives[fn] = (fn_ref, obj)
            return obj, fn
        except TypeError:
            # unhashable / non-weak-referenceable callables: skip the cache
            return self.scheduler.make_objective(fn), fn

    def submit(self, fn: TrialFn, params: Dict[str, Any]) -> TaskHandle:
        handle = TaskHandle(params)
        objective, pin = self._objective_for(fn)
        with self._cv:
            # closed-check and increment are one critical section:
            # shutdown() flips _closed under this same lock, so a submit
            # racing a drain either lands before _closed (counted in
            # _outstanding, so drained=True waits for it) or raises —
            # never a trial running after shutdown reported drained
            if self._closed:
                raise RuntimeError(
                    "submit() after shutdown(): this adapter is "
                    "draining/stopped and accepts no new trials")
            self._outstanding += 1
            if self.coalesce:
                self._queue.append((handle, objective, pin))
                if self._dispatcher is None:
                    self._dispatcher = threading.Thread(
                        target=self._drain_loop, daemon=True,
                        name="mango-async-coalesce")
                    self._dispatcher.start()
                self._cv.notify_all()
                return handle

        def run(_pin_fn=pin):   # keep the wrapped fn alive for this trial
            try:
                evals, _ = objective([params])
                if evals:
                    handle.result = float(evals[0])
                else:
                    handle.error = RuntimeError(
                        "trial dropped by scheduler (fault/deadline)")
            except Exception as e:  # noqa: BLE001
                handle.error = e
            with self._cv:
                handle.done.set()
                self._outstanding -= 1
                self._cv.notify_all()

        threading.Thread(target=run, daemon=True,
                         name="mango-async-adapter").start()
        return handle

    # ---- coalescing dispatcher -------------------------------------------
    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._queue)
                batch, self._queue = self._queue, []
            # group by cached objective (== by trial fn): one scheduler
            # dispatch per group, preserving submit order across groups
            groups: Dict[int, tuple] = {}
            order: List[int] = []
            for h, obj, pin in batch:
                k = id(obj)
                if k not in groups:
                    groups[k] = (obj, [])
                    order.append(k)
                groups[k][1].append((h, pin))
            for k in order:
                obj, items = groups[k]
                self._dispatch_group(obj, items)

    def _dispatch_group(self, objective: Objective, items: List[tuple]):
        """One batch dispatch; match the partial result back to handles
        (identity first, then equality — the tuner's matching contract)."""
        try:
            evals, params = objective([h.params for h, _ in items])
            remaining = list(items)
            for v, par in zip(evals, params):
                hit = next((i for i, (h, _) in enumerate(remaining)
                            if h.params is par), None)
                if hit is None:
                    hit = next((i for i, (h, _) in enumerate(remaining)
                                if h.params == par), None)
                if hit is None and remaining:
                    hit = 0   # unmatchable result: consume in submit order
                if hit is None:
                    continue  # more results than submitted handles
                remaining.pop(hit)[0].result = float(v)
            for h, _ in remaining:
                h.error = RuntimeError(
                    "trial dropped by scheduler (fault/deadline)")
        except Exception as e:  # noqa: BLE001
            for h, _ in items:
                if h.result is None and h.error is None:
                    h.error = e
        with self._cv:
            for h, _ in items:
                h.done.set()
            self._outstanding -= len(items)
            self._cv.notify_all()

    def wait_any(self, handles: List[TaskHandle],
                 timeout: Optional[float] = None) -> List[TaskHandle]:
        if not handles:
            return []
        with self._cv:
            self._cv.wait_for(
                lambda: any(h.done.is_set() for h in handles), timeout)
            return [h for h in handles if h.done.is_set()]

    # ------------------------------------------------------- graceful drain
    def shutdown(self, timeout: Optional[float] = None) -> bool:
        """Stop accepting submits; with a ``timeout``, block until every
        in-flight trial has completed (drained) or the deadline passes.
        ``timeout=None`` closes immediately without waiting.  Returns
        whether the adapter is fully drained — a service caller snapshots
        only after a ``True`` here, so a stop can't orphan pending trials.
        Safe to call more than once."""
        with self._cv:
            self._closed = True
            if timeout is None:
                return self._drained_locked()
            self._cv.wait_for(self._drained_locked, timeout)
            return self._drained_locked()

    def _drained_locked(self) -> bool:
        """Caller must hold ``_cv`` — ``_outstanding`` is only coherent
        under it (wait_for re-acquires before each predicate call)."""
        assert_holds(self._cv)
        return self._outstanding == 0


class _PollingWaitShim:
    """Wrap a scheduler that has ``submit`` but no ``wait_any`` (third-party
    implementations): fall back to polling the done events."""

    def __init__(self, scheduler, poll: float = 0.01):
        self._sched = scheduler
        self._poll = poll

    def submit(self, fn, params):
        return self._sched.submit(fn, params)

    def wait_any(self, handles, timeout=None):
        if not handles:
            return []
        # monotonic: an NTP wall-clock step must not corrupt the deadline
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            done = [h for h in handles if h.done.is_set()]
            if done or (deadline is not None
                        and time.monotonic() >= deadline):
                return done
            time.sleep(self._poll)


def as_async(scheduler, poll: float = 0.01,
             coalesce: bool = False) -> AsyncScheduler:
    """Return the async (submit/wait_any) view of any scheduler.  ``poll``
    only applies to the shim around submit-only schedulers; everything else
    wakes on a completion condition.  ``coalesce`` batches queued submits
    into one dispatch per drain (batch-objective schedulers only)."""
    if hasattr(scheduler, "submit"):
        if hasattr(scheduler, "wait_any"):
            return scheduler
        return _PollingWaitShim(scheduler, poll=poll)
    if hasattr(scheduler, "make_objective"):
        return BatchToAsyncAdapter(scheduler, coalesce=coalesce)
    raise TypeError(f"{scheduler!r} implements neither the batch nor the "
                    "async scheduler protocol")
