"""Scheduler abstraction (paper §2.4).

Mango's key design decision: the optimizer never talks to a scheduling
framework — it calls a user *objective function* that takes a batch of
configurations and returns partial ``(evals, params)``.  A ``Scheduler``
here is a factory that wraps a per-trial callable into such an objective,
implementing whatever execution/fault semantics the deployment needs.

The ``TaskQueueScheduler`` in ``distributed.py`` reproduces the Celery-on-
Kubernetes production setup from the paper (Listing 4): tasks enqueued to a
worker pool, per-batch deadline, stragglers/failed workers dropped from the
returned lists, optional retries.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Protocol, Tuple

TrialFn = Callable[[Dict[str, Any]], float]
Objective = Callable[[List[Dict[str, Any]]],
                     Tuple[List[float], List[Dict[str, Any]]]]


class Scheduler(Protocol):
    def make_objective(self, trial_fn: TrialFn) -> Objective:
        """Wrap a single-config callable into Mango's batch objective."""
        ...
