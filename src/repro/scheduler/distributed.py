"""Task-queue scheduler reproducing the paper's Celery/Kubernetes deployment.

Semantics modeled on Listing 4 (``train_clf.delay(par)`` + ``process.get()``):

  * tasks are pushed to a queue consumed by a pool of long-lived workers,
  * a per-batch deadline bounds the ``get()`` — stragglers are abandoned,
  * worker failures (injected for testing: ``failure_rate``) surface as
    dropped results, not batch failures,
  * optional ``max_retries`` re-enqueues failed tasks (beyond-paper, matches
    Celery's ``task_acks_late`` production configuration),
  * an async API (``submit`` / ``gather``) used by the asynchronous tuner.

Fault injection exists so the test-suite can drill the tuner's partial-result
contract under worker crashes and stragglers deterministically: each task
carries its own RNG seeded from ``(faults.seed, submit sequence)``, so the
injected failure/straggler set is a pure function of the submission order —
identical across runs regardless of how worker threads race on the queue
(the old shared ``random.Random`` made the dropped set depend on thread
scheduling).
"""
from __future__ import annotations

import dataclasses
import queue
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.analysis.sanitizers import assert_holds
from repro.scheduler.base import Objective, TaskHandle, TrialFn


@dataclasses.dataclass
class FaultInjection:
    failure_rate: float = 0.0       # P(worker raises) per task
    straggler_rate: float = 0.0     # P(task sleeps straggler_delay)
    straggler_delay: float = 1.0    # seconds
    seed: int = 0


class _Task(TaskHandle):
    __slots__ = ("retries", "rng")

    def __init__(self, params, rng: Optional[random.Random] = None):
        super().__init__(params)
        self.retries = 0
        # per-task fault RNG, seeded from (faults.seed, submit sequence):
        # injected failures/stragglers are a pure function of the task, so
        # two runs drop identical task sets no matter how the queue races
        # tasks across worker threads (a shared — or even per-worker — RNG
        # couldn't give that: task -> worker assignment is nondeterministic)
        self.rng = rng


class TaskQueueScheduler:
    """Celery-like distributed task queue with a local worker pool.

    Implements both scheduler protocols natively: the batch objective
    (``make_objective``) and the async submit/wait_any interface — task
    completion signals ``_done_cv``, so ``AsyncTuner`` wakes exactly when a
    trial finishes instead of polling.
    """

    def __init__(self, n_workers: int = 4, timeout: Optional[float] = None,
                 max_retries: int = 0,
                 faults: Optional[FaultInjection] = None):
        self.n_workers = n_workers
        self.timeout = timeout
        self.max_retries = max_retries
        self.faults = faults or FaultInjection()
        self._task_seq = 0              # submit counter seeding task RNGs
        self._q: "queue.Queue[Optional[Tuple[_Task, TrialFn]]]" = queue.Queue()
        self._workers: List[threading.Thread] = []
        self._stop = threading.Event()
        self._draining = threading.Event()
        self._outstanding = 0           # submitted tasks not yet finished
        self._lock = threading.Lock()
        self._done_cv = threading.Condition()
        self._started = False
        self.stats = {"completed": 0, "failed": 0, "retried": 0,
                      "straggled": 0}

    # ------------------------------------------------------------ lifecycle
    def start(self):
        with self._lock:
            if self._started:
                return
            self._started = True
            for i in range(self.n_workers):
                t = threading.Thread(target=self._worker_loop,
                                     name=f"mango-worker-{i}", daemon=True)
                t.start()
                self._workers.append(t)

    def shutdown(self, timeout: Optional[float] = None) -> bool:
        """Stop the worker pool.  ``timeout=None`` keeps the legacy
        semantics: stop immediately, abandoning whatever is in flight.
        With a ``timeout``, first *drain*: new submits are refused while
        every already-queued task runs to completion (retries included),
        then the workers are stopped.  Returns whether the queue was fully
        drained — the durable service checks this before snapshotting so a
        graceful stop can't orphan pending trials."""
        drained = True
        if timeout is not None:
            with self._done_cv:
                # set under the cv: pairs with submit's atomic
                # check+increment, see there
                self._draining.set()
                self._done_cv.wait_for(self._drained_locked, timeout)
                drained = self._drained_locked()
        self._stop.set()
        for _ in self._workers:
            self._q.put(None)
        return drained

    def _worker_loop(self):
        while not self._stop.is_set():
            item = self._q.get()
            if item is None:
                return
            task, fn = item
            try:
                # the task's own RNG decides its fate (no lock needed — one
                # worker holds a task at a time, and retries re-enqueue the
                # same object, drawing the next values of its stream)
                fail = task.rng.random() < self.faults.failure_rate
                straggle = task.rng.random() < self.faults.straggler_rate
                if straggle:
                    self._bump("straggled")
                    time.sleep(self.faults.straggler_delay)
                if fail:
                    raise RuntimeError("injected worker failure")
                task.result = float(fn(task.params))
                self._bump("completed")
                self._finish(task)
            except Exception as e:  # noqa: BLE001
                if task.retries < self.max_retries:
                    task.retries += 1
                    self._bump("retried")
                    self._q.put((task, fn))
                else:
                    task.error = e
                    self._bump("failed")
                    self._finish(task)

    def _bump(self, key: str) -> None:
        # bare ``stats[k] += 1`` is a read-modify-write that loses counts
        # when workers race on the same key
        with self._lock:
            self.stats[key] += 1

    def _finish(self, task: _Task) -> None:
        # notify under the condition lock: wait_any's predicate check and
        # wait are serialized against this, so completions are never missed
        # (a retried task is not finished — it re-enqueues without landing
        # here, so it stays outstanding until its final attempt)
        with self._done_cv:
            task.done.set()
            self._outstanding -= 1
            self._done_cv.notify_all()

    # ------------------------------------------------------------- async API
    def submit(self, fn: TrialFn, params: Dict[str, Any]) -> _Task:
        with self._done_cv:
            # the drain/stop check and the outstanding increment are one
            # critical section (shutdown sets _draining under this same
            # cv), so a submit racing shutdown(timeout) either counts
            # toward the drain or raises — drained=True can't leave a
            # task running behind the caller's back
            if self._stop.is_set() or self._draining.is_set():
                # start() after shutdown() is a no-op (_started stays
                # True), so the task would land in a queue no worker ever
                # drains and wait_any would hang until its timeout; during
                # a drain the whole point is that the in-flight set only
                # shrinks
                raise RuntimeError(
                    "submit() after shutdown(): this scheduler's workers "
                    "have exited or are draining; create a new "
                    "TaskQueueScheduler")
            self._outstanding += 1
        self.start()
        with self._lock:
            seq = self._task_seq
            self._task_seq += 1
        task = _Task(params,
                     rng=random.Random(self.faults.seed * 1_000_003 + seq))
        self._q.put((task, fn))
        return task

    def wait_any(self, handles: List[TaskHandle],
                 timeout: Optional[float] = None) -> List[TaskHandle]:
        """Block until at least one submitted task completes; wakes on the
        completion condition, not a poll loop."""
        if not handles:
            return []
        with self._done_cv:
            self._done_cv.wait_for(
                lambda: any(h.done.is_set() for h in handles), timeout)
            return [h for h in handles if h.done.is_set()]

    def _drained_locked(self) -> bool:
        """Caller must hold ``_done_cv`` — ``_outstanding`` is only
        coherent under it (wait_for re-acquires before each call)."""
        assert_holds(self._done_cv)
        return self._outstanding == 0

    def gather(self, tasks: List[_Task], timeout: Optional[float] = None
               ) -> Tuple[List[float], List[Dict[str, Any]]]:
        # monotonic deadline: a wall-clock (NTP) step must not stretch or
        # collapse the per-batch timeout
        deadline = None if timeout is None else time.monotonic() + timeout
        evals, params = [], []
        for t in tasks:
            remaining = (None if deadline is None
                         else max(0.0, deadline - time.monotonic()))
            if t.done.wait(remaining) and t.error is None:
                evals.append(t.result)
                params.append(t.params)
        return evals, params

    # --------------------------------------------------------- batch objective
    def make_objective(self, trial_fn: TrialFn) -> Objective:
        def objective(params_list):
            tasks = [self.submit(trial_fn, par) for par in params_list]
            return self.gather(tasks, timeout=self.timeout)

        return objective
