"""Production mesh construction and ShardCtx wiring.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The single-pod mesh is
(data=16, model=16) = 256 chips; the multi-pod mesh adds a leading pod axis:
(pod=2, data=16, model=16) = 512 chips, where "pod" is pure data parallelism
across ICI/DCN pod boundaries (parameters are replicated across pods, batch
is sharded over pod x data).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.compat import make_mesh
from repro.models.common import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CI-scale sharding tests (run under forced host devices)."""
    return make_mesh(shape, axes)


def make_shard_ctx(mesh: Optional[jax.sharding.Mesh],
                   seq_parallel: bool = False,
                   flat_dp: bool = False,
                   shard_lstm_r: bool = False) -> ShardCtx:
    """flat_dp: treat the model axis as extra data parallelism (and ZeRO-
    shard parameters over data x model).  The right layout for models too
    small to tensor-parallelize (e.g. xlstm-1.3b on a 256-chip pod), where
    TP would replicate all attention-free compute 16x."""
    if mesh is None:
        return ShardCtx.null()
    axes = mesh.axis_names
    if flat_dp:
        return ShardCtx(
            mesh=mesh,
            dp_axes=tuple(a for a in ("pod", "data", "model") if a in axes),
            tp_axis=None,
            fsdp_axis=tuple(a for a in ("data", "model") if a in axes),
            seq_parallel=False,
            shard_lstm_r=shard_lstm_r,
        )
    dp = tuple(a for a in ("pod", "data") if a in axes)
    return ShardCtx(
        mesh=mesh,
        dp_axes=dp,
        tp_axis="model" if "model" in axes else None,
        fsdp_axis="data" if "data" in axes else None,
        seq_parallel=seq_parallel,
        shard_lstm_r=shard_lstm_r,
    )
