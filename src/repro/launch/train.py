"""End-to-end training driver.

Runs real optimization steps (synthetic Markov LM data) with checkpointing,
resume, and metrics logging.  On this CPU container use ``--reduced`` (or
--arch smollm-135m with small batch/seq overrides); on a TPU fleet the same
driver runs the full configs under ``make_production_mesh()``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 50
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 100 --resume --ckpt-dir /tmp/ckpt       # crash-restart drill
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.common import Runtime
from repro.optim.adamw import AdamWConfig
from repro.train.checkpoint import Checkpointer
from repro.train.step import TrainHyper, init_train_state, make_train_step


def build(args):
    cfg = get_config(args.arch, reduced=args.reduced)
    rt = Runtime(
        param_dtype=jnp.float32 if args.fp32 else jnp.bfloat16,
        compute_dtype=jnp.float32 if args.fp32 else jnp.bfloat16,
        ce_chunk=min(args.seq, 512),
        ssm_chunk=min(args.seq, 256),
        remat_policy=args.remat,
        use_pallas=args.pallas,
    )
    hyper = TrainHyper(
        opt=AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                        total_steps=args.steps,
                        weight_decay=args.weight_decay),
        grad_compression=args.grad_compression,
    )
    return cfg, rt, hyper


def run(args) -> dict:
    cfg, rt, hyper = build(args)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch,
                                  seed=args.data_seed))
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, rt,
                             grad_compression=hyper.grad_compression)
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    start_step = 0
    ckpt = Checkpointer(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(None, state)
        start_step = meta["step"]
        data.restore(meta["data_state"])
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(
        make_train_step(cfg, rt, hyper, n_microbatches=args.micro),
        donate_argnums=0)

    log_path = Path(args.log) if args.log else None
    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        data.step = step + 1
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if log_path:
            with open(log_path, "a") as f:
                f.write(json.dumps(
                    {"step": step, "loss": loss,
                     "ce": float(metrics["ce"]),
                     "grad_norm": float(metrics["grad_norm"]),
                     "lr": float(metrics["lr"])}) + "\n")
        if args.verbose and (step % args.print_every == 0
                             or step == args.steps - 1):
            tok_s = (args.batch * args.seq * (step - start_step + 1)
                     / max(time.time() - t0, 1e-9))
            print(f"step {step:5d} loss {loss:7.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tok_s:,.0f}",
                  flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state,
                      extra={"data_state": data.state(),
                             "arch": args.arch, "loss": loss})
    if ckpt:
        ckpt.save(args.steps, state, extra={"data_state": data.state(),
                                            "arch": args.arch,
                                            "loss": losses[-1]})
        ckpt.wait()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "n_params": n_params,
            "losses": losses,
            "wall_s": time.time() - t0}


def make_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--weight-decay", type=float, default=0.1)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "dots", "full"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--fp32", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=1234)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log", default="")
    ap.add_argument("--print-every", type=int, default=10)
    ap.add_argument("--verbose", action="store_true", default=True)
    return ap


if __name__ == "__main__":
    out = run(make_parser().parse_args())
    print(json.dumps({k: v for k, v in out.items() if k != "losses"}))
