"""Static cost analysis of post-SPMD HLO text with loop multipliers.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
under-reports FLOPs/bytes/collectives for scan-over-layers models by a factor
of n_layers (x microbatches).  This analyzer:

  * splits the HLO module into computations,
  * counts dot FLOPs (2 * prod(result) * prod(lhs contracting dims)),
  * approximates HBM traffic: operand+result bytes of top-level ops, where
      - fusion internals are VMEM-resident (not counted),
      - a fusion operand that is only *sliced* inside the fusion contributes
        the slice bytes, not the full buffer (critical for scan-carried
        stacked parameter/residual buffers),
      - dynamic-update-slice contributes the update bytes (in-place aliasing),
  * counts collective wire bytes with ring factors,
  * resolves the call graph (fusion/call/while/conditional) and multiplies
    while bodies by their trip count (XLA's ``known_trip_count`` annotation,
    falling back to the loop condition's ``compare(_, constant(N)) LT``).

All numbers are per-device (the module is the post-SPMD per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<result>(?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(?P<op>[\w\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_PARAM_RE = re.compile(r"parameter\((\d+)\)")

_SLICE_OPS = ("dynamic-slice", "slice", "gather", "get-tuple-element")
# metadata / zero-traffic ops: tuples and GTEs are SSA bookkeeping, not moves
_ELEMENTWISE_SKIP = ("bitcast", "reshape", "tuple", "get-tuple-element",
                     "parameter", "constant", "after-all", "iota",
                     "optimization-barrier", "copy-done", "partition-id",
                     "replica-id")


def _tok_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(text: str) -> float:
    return sum(_tok_elems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_TOK.findall(text))


def _shape_elems(text: str) -> int:
    return sum(_tok_elems(dims) for _, dims in _SHAPE_TOK.findall(text))


@dataclasses.dataclass
class OpRec:
    name: str
    op: str
    result: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: {k: {"count": 0.0, "wire_bytes": 0.0}
                                 for k in _COLL_KINDS})
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        m = _HEADER_RE.match(line)
        if m and "=" not in line.split("(", 1)[0]:
            cur = m.group(1)
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None and s:
            comps[cur].append(line)
    return comps


def _split_top_level(args: str) -> List[str]:
    """Split on commas outside [] / {} — shape dims and layout annotations
    (``f32[512,2048]{1,0}``) contain commas of their own."""
    parts, cur, depth = [], [], 0
    for ch in args:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _operand_names(line: str, op: str) -> List[str]:
    # anchor on "<op>(" rather than the first "name(" — tiled layout
    # annotations like f32[128,128]{1,0:T(8,128)} put a paren group in the
    # result type before the call
    i = line.find(op + "(")
    if i < 0:
        return []
    j = i + len(op) + 1
    depth, k = 1, j
    while k < len(line) and depth:
        if line[k] == "(":
            depth += 1
        elif line[k] == ")":
            depth -= 1
        k += 1
    names = []
    for arg in _split_top_level(line[j:k - 1]):
        mm = re.search(r"%?([\w.\-]+)\s*$", arg.strip())
        if mm:
            names.append(mm.group(1))
    return names


def _parse_ops(lines: List[str]) -> List[OpRec]:
    out = []
    for line in lines:
        m = _OP_RE.match(line)
        if m:
            out.append(OpRec(m.group("name"), m.group("op"),
                             m.group("result"),
                             _operand_names(line, m.group("op")), line))
    return out


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _wire(kind: str, out_bytes: float, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)
    if kind == "all-reduce":
        return out_bytes * 2 * (g - 1) / g
    if kind == "all-to-all":
        return out_bytes * (g - 1) / g
    return out_bytes  # collective-permute


def analyze_module(hlo: str, n_devices: int) -> Dict:
    comps_lines = _split_computations(hlo)
    comps: Dict[str, List[OpRec]] = {n: _parse_ops(ls)
                                     for n, ls in comps_lines.items()}

    shapes: Dict[str, str] = {}
    consts: Dict[str, int] = {}
    for ops in comps.values():
        for o in ops:
            shapes[o.name] = o.result
            cm = re.search(r"constant\((\d+)\)", o.line) \
                if o.op == "constant" else None
            if cm and "[]" in o.result:
                consts[o.name] = int(cm.group(1))

    # Per-computation, per-parameter "effective bytes" when used only through
    # slicing ops (a scan reading one layer's slice of a stacked buffer), and
    # in-place handling for fusions whose root is a dynamic-update-slice of a
    # parameter (a scan *writing* one step's slice into a stacked buffer —
    # only the updated slice moves, the buffer aliases in place).
    param_eff: Dict[str, Dict[int, float]] = {}
    fusion_result_eff: Dict[str, float] = {}
    for name, ops in comps.items():
        params: Dict[str, int] = {}
        for o in ops:
            if o.op == "parameter":
                pm = _PARAM_RE.search(o.line)
                if pm:
                    params[o.name] = int(pm.group(1))
        eff: Dict[int, float] = {}
        dus_targets: Dict[str, float] = {}  # param name -> update bytes
        for o in ops:
            if o.op == "dynamic-update-slice" and len(o.operands) > 1:
                upd_bytes = _shape_bytes(shapes.get(o.operands[1], ""))
                tgt = o.operands[0]
                if tgt in params and (_shape_bytes(shapes.get(tgt, ""))
                                      == _shape_bytes(o.result)):
                    dus_targets[tgt] = upd_bytes
                    fusion_result_eff[name] = min(
                        fusion_result_eff.get(name, float("inf")), upd_bytes)
        for pname, idx in params.items():
            if pname in dus_targets:
                eff[idx] = dus_targets[pname]  # RMW of the slice only
                continue
            uses = [o for o in ops if pname in o.operands]
            if uses and all(u.op in _SLICE_OPS for u in uses):
                eff[idx] = sum(_shape_bytes(u.result) for u in uses)
            else:
                eff[idx] = _shape_bytes(shapes.get(pname, ""))
        param_eff[name] = eff

    costs: Dict[str, CompCost] = {}
    fusion_comps = set()
    for name, ops in comps.items():
        c = CompCost()
        for o in ops:
            op, result, line = o.op, o.result, o.line
            if op == "dot":
                res_elems = _shape_elems(result)
                contract = 1
                cm = _CONTRACT_RE.search(line)
                if cm and o.operands:
                    toks = _SHAPE_TOK.findall(shapes.get(o.operands[0], ""))
                    if toks:
                        dims = [int(x) for x in toks[0][1].split(",") if x]
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                contract *= dims[int(idx)]
                c.flops += 2.0 * res_elems * contract
                c.bytes += _shape_bytes(result) + sum(
                    _shape_bytes(shapes.get(x, "")) for x in o.operands)
            elif any(op == k or op == k + "-start" for k in _COLL_KINDS):
                kind = op.replace("-start", "")
                ob = _shape_bytes(result)
                g = _group_size(line, n_devices)
                c.coll[kind]["count"] += 1
                c.coll[kind]["wire_bytes"] += _wire(kind, ob, g)
                c.bytes += ob
            elif op == "fusion":
                cm = _CALLS_RE.search(line)
                callee = cm.group(1) if cm else None
                if callee:
                    fusion_comps.add(callee)
                    c.calls.append((callee, 1.0))
                if callee in fusion_result_eff:  # in-place DUS fusion
                    c.bytes += fusion_result_eff[callee]
                else:
                    c.bytes += _shape_bytes(result)
                eff = param_eff.get(callee, {})
                for i, x in enumerate(o.operands):
                    full = _shape_bytes(shapes.get(x, ""))
                    c.bytes += min(full, eff.get(i, full)) if eff else full
            elif op == "dynamic-update-slice":
                # in-place: only the update (operand 1) moves
                upd = (shapes.get(o.operands[1], "")
                       if len(o.operands) > 1 else result)
                c.bytes += 2 * _shape_bytes(upd)
            elif op == "while":
                bm = _BODY_RE.search(line)
                cm = _COND_RE.search(line)
                if bm:
                    trip = 1.0
                    tm = _TRIP_RE.search(line)
                    if tm:
                        trip = float(tm.group(1))
                    elif cm and cm.group(1) in comps_lines:
                        trip = _cond_trip(comps[cm.group(1)], consts)
                    c.calls.append((bm.group(1), trip))
            elif op == "conditional":
                bm = _BRANCHES_RE.search(line)
                if bm:
                    for b in bm.group(1).split(","):
                        c.calls.append((b.strip().lstrip("%"), 1.0))
            elif op in ("call", "custom-call", "map", "reduce", "sort",
                        "scatter", "reduce-window", "select-and-scatter"):
                cm = _CALLS_RE.search(line)
                if cm:
                    c.calls.append((cm.group(1), 1.0))
                c.bytes += _shape_bytes(result) + sum(
                    _shape_bytes(shapes.get(x, "")) for x in o.operands)
            elif op in _ELEMENTWISE_SKIP:
                pass
            else:
                # top-level unfused op: result + operands touch HBM
                c.bytes += _shape_bytes(result) + sum(
                    _shape_bytes(shapes.get(x, "")) for x in o.operands)
        costs[name] = c

    # fusion computations: internals are VMEM-resident; zero their own bytes
    # and keep only dot FLOPs / collectives / nested calls.
    for fname in fusion_comps:
        if fname in costs:
            costs[fname].bytes = 0.0

    memo: Dict[str, Dict] = {}

    def total(name: str, depth=0) -> Dict:
        if name in memo:
            return memo[name]
        if name not in costs or depth > 128:
            return {"flops": 0.0, "bytes": 0.0,
                    "coll": {k: {"count": 0.0, "wire_bytes": 0.0}
                             for k in _COLL_KINDS}}
        c = costs[name]
        agg = {"flops": c.flops, "bytes": c.bytes,
               "coll": {k: dict(v) for k, v in c.coll.items()}}
        for callee, mult in c.calls:
            sub = total(callee, depth + 1)
            agg["flops"] += mult * sub["flops"]
            agg["bytes"] += mult * sub["bytes"]
            for k in _COLL_KINDS:
                agg["coll"][k]["count"] += mult * sub["coll"][k]["count"]
                agg["coll"][k]["wire_bytes"] += (
                    mult * sub["coll"][k]["wire_bytes"])
        memo[name] = agg
        return agg

    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    entry = m.group(1) if m else None
    if entry not in comps:
        called = {cl for cc in costs.values() for cl, _ in cc.calls}
        roots = [n for n in comps if n not in called and n not in fusion_comps]
        entry = roots[0] if roots else next(iter(comps))
    out = total(entry)
    out["entry"] = entry
    out["n_computations"] = len(comps)

    # effective loop multiplier per computation (for the breakdown)
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        for callee, m_ in costs.get(name, CompCost()).calls:
            mult[callee] = mult.get(callee, 0.0) + mult[name] * m_
            if callee not in order:
                order.append(callee)
    breakdown = []
    for name, c in costs.items():
        w = mult.get(name, 0.0)
        if w == 0:
            continue
        fl = c.flops * w
        by = c.bytes * w
        wire = sum(v["wire_bytes"] for v in c.coll.values()) * w
        if fl > 0 or by > 0 or wire > 0:
            breakdown.append({"comp": name, "mult": w, "flops": fl,
                              "bytes": by, "wire": wire})
    out["breakdown"] = sorted(breakdown, key=lambda r: -max(
        r["flops"] / 197e12, r["bytes"] / 819e9, r["wire"] / 50e9))[:12]
    return out


# ---------------------------------------------------------------------------
# Analytic plan estimator (no compile).
#
# The HLO analyzer above needs a compiled module — minutes per (arch, plan)
# cell.  ``estimate_plan`` prices a distribution plan for a registry cell in
# microseconds from the same roofline model (hlo_analysis constants + the
# ring wire formulas above), which is what makes sharding-plan search a
# *cheap objective* for the tuner: thousands of plans per second, with the
# compile-and-measure path kept as the validation step for the winners.
# ---------------------------------------------------------------------------

# extra forward passes paid to rematerialize activations in the backward
_REMAT_FLOP_MULT = {"none": 1.0, "dots": 7.0 / 6.0, "full": 8.0 / 6.0}
# HBM-traffic factor for activations (reads+writes per token*d_model*layer)
_REMAT_ACT_TRAFFIC = {"none": 18.0, "dots": 12.0, "full": 8.0}
# activations *stored* until the backward (drives the memory model)
_REMAT_ACT_STORED = {"none": 8.0, "dots": 4.0, "full": 1.5}

HBM_PER_CHIP_BYTES = 16e9  # TPU v5e


def estimate_plan(cfg, shape, plan: Dict, n_devices: int = 256) -> Dict:
    """Analytic roofline estimate of one training/serving step under a plan.

    ``plan`` knobs (all optional):
      tp (int, default 1)            tensor-parallel group size
      zero ("zero1" | "zero3")       grad sync: one all-reduce per step vs
                                     per-microbatch param regather + RS
      remat ("none"|"dots"|"full")   recompute policy
      micro (int, default 1)         gradient-accumulation microbatches
      seq_parallel (bool)            AG+RS instead of AR on the TP axis
      ep (bool)                      MoE expert parallelism (all-to-all)
      capacity_factor (float)        MoE token capacity

    Returns roofline terms plus ``t_step_s`` (the scalar objective),
    ``hbm_gb`` and ``fits`` (the memory constraint) — deterministic,
    microseconds per call, no compile.
    """
    from repro.launch import hlo_analysis

    tp = max(int(plan.get("tp", 1)), 1)
    zero = plan.get("zero", "zero1")
    remat = plan.get("remat", "full")
    micro = max(int(plan.get("micro", 1)), 1)
    seq_parallel = bool(plan.get("seq_parallel", False))
    ep = bool(plan.get("ep", False))
    cf = float(plan.get("capacity_factor", 0.0)) or cfg.capacity_factor

    if n_devices % tp:
        return {"feasible": False, "reason": f"tp={tp} !| {n_devices}",
                "t_step_s": float("inf"), "fits": False}
    dp = n_devices // tp
    train = shape.kind == "train"

    P = float(cfg.param_count()["total"])
    tokens = float(shape.global_batch) * (shape.seq_len if train or
                                          shape.kind == "prefill" else 1)
    tokens_chip = tokens / n_devices
    d, L = float(cfg.d_model), float(cfg.n_layers)

    # -- compute ------------------------------------------------------------
    flops_chip = (hlo_analysis.model_flops(cfg, shape)
                  * (_REMAT_FLOP_MULT[remat] if train else 1.0) / n_devices)

    # -- HBM traffic per chip ----------------------------------------------
    act_traffic = _REMAT_ACT_TRAFFIC[remat] if train else 6.0
    bytes_act = 2.0 * tokens_chip * d * L * act_traffic
    passes = (2.0 + 2.0 * (_REMAT_FLOP_MULT[remat] - 1.0)) if train else 1.0
    bytes_weights = 2.0 * (P / tp) * passes * (micro if train else 1.0)
    # optimizer update: fp32 m/v read+write + master-param update, sharded
    # over dp either way (zero1 shards moments too — same traffic term)
    bytes_opt = (P / (dp * tp)) * (4 * 4 + 4 * 2) if train else 0.0
    hbm_bytes = bytes_act + bytes_weights + bytes_opt

    # -- wire per chip ------------------------------------------------------
    grad_bytes = 2.0 * P / tp
    wire = 0.0
    if train and dp > 1:
        if zero == "zero3":
            # per-microbatch bf16 param all-gather + grad reduce-scatter
            wire += micro * (_wire("all-gather", grad_bytes, dp)
                             + _wire("reduce-scatter", grad_bytes / dp, dp))
        else:
            wire += _wire("all-reduce", grad_bytes, dp)
    if tp > 1:
        # Megatron TP: 2 collectives per layer per pass over the sharded
        # activations; seq-parallel swaps AR for AG+RS (~0.75x wire)
        act_layer = 2.0 * (tokens / dp) * d
        n_coll = 2.0 * (3.0 if train else 1.0)
        wire += L * n_coll * _wire("all-reduce", act_layer, tp) * (
            0.75 if seq_parallel else 1.0)
    n_moe = sum(1 for s in cfg.period if s.ffn == "moe") * (
        cfg.n_periods if cfg.n_experts else 0)
    if ep and n_moe:
        a2a = 2.0 * tokens_chip * d * max(cf, 1.0) * max(cfg.top_k, 1)
        g = min(cfg.n_experts, n_devices)
        wire += n_moe * 2.0 * _wire("all-to-all", a2a, g)

    terms = hlo_analysis.roofline_terms(flops_chip, hbm_bytes, wire)
    # compute and HBM overlap on the MXU/VMEM pipeline; collectives only
    # partially hide behind compute — charge them serially (pessimistic)
    t_step = max(terms["t_compute_s"], terms["t_memory_s"]) + terms[
        "t_collective_s"]

    # -- memory model -------------------------------------------------------
    params_res = 2.0 * P / tp / (dp if (train and zero == "zero3") else 1.0)
    opt_res = (12.0 * P / (dp * tp)) if train else 0.0
    act_res = (2.0 * (tokens_chip / micro) * d * L
               * _REMAT_ACT_STORED[remat]) if train else (
        2.0 * tokens_chip * d * L * 0.5)
    hbm_gb = (params_res + opt_res + act_res) / 1e9
    return {
        "feasible": True,
        "t_step_s": t_step,
        "t_compute_s": terms["t_compute_s"],
        "t_memory_s": terms["t_memory_s"],
        "t_collective_s": terms["t_collective_s"],
        "dominant": terms["dominant"],
        "hbm_gb": hbm_gb,
        "fits": hbm_gb * 1e9 <= HBM_PER_CHIP_BYTES,
        "plan": {"tp": tp, "zero": zero, "remat": remat, "micro": micro,
                 "seq_parallel": seq_parallel, "ep": ep,
                 "capacity_factor": cf},
    }


def _cond_trip(cond_ops: List[OpRec], consts: Dict[str, int]) -> float:
    for o in cond_ops:
        if o.op == "compare" and "direction=LT" in o.line:
            for x in reversed(o.operands):
                if x in consts:
                    return float(consts[x])
    vals = [consts[o.name] for o in cond_ops if o.name in consts]
    return float(max(vals)) if vals else 1.0
