"""Parse compiled HLO for collective traffic + roofline terms.

``compiled.as_text()`` is the post-SPMD, per-device program: tensor shapes in
it are LOCAL shards.  For each collective we derive per-chip bytes-on-wire
with standard ring factors:

  all-gather        out * (g-1)/g        (out = gathered, local)
  reduce-scatter    out * (g-1)          (out = scattered piece)
  all-reduce        out * 2(g-1)/g
  all-to-all        out * (g-1)/g
  collective-permute out * 1

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(?P<result>[^=]+?)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(result: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(result):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)  # replica_groups=[8,64] -> 8 groups of 64
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, wire_bytes (per chip), raw_bytes}."""
    stats = {k: {"count": 0, "wire_bytes": 0.0, "raw_bytes": 0.0}
             for k in _COLL_KINDS}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        # async pairs: count the -start, skip the -done
        if "-done(" in line:
            continue
        kind = m.group("kind")
        out_bytes = _shape_bytes(m.group("result"))
        g = _group_size(line)
        if kind == "all-gather":
            wire = out_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = out_bytes * (g - 1)
        elif kind == "all-reduce":
            wire = out_bytes * 2 * (g - 1) / g
        elif kind == "all-to-all":
            wire = out_bytes * (g - 1) / g
        else:  # collective-permute
            wire = out_bytes
        s = stats[kind]
        s["count"] += 1
        s["wire_bytes"] += wire
        s["raw_bytes"] += out_bytes
    return stats


def model_flops(cfg, shape) -> float:
    """Theoretically-useful FLOPs for this (arch, shape) cell.

    6*N_active*D (train) / 2*N_active*D (prefill) / 2*N_active*B (decode)
    plus exact-causal attention score/value FLOPs (which 6ND ignores and
    which dominate small-d archs at long S).
    """
    pc = cfg.param_count()
    B, S = shape.global_batch, shape.seq_len
    n_attn = sum(1 for s in cfg.period if s.mixer == "attn") * cfg.n_periods
    Hhd = cfg.n_heads * cfg.hd
    if shape.kind == "train":
        base = 6 * pc["active"] * B * S
        attn = 3 * n_attn * 2 * B * S * S * Hhd  # causal: 0.5 * 4BS^2
        if cfg.encoder_layers:
            Se = cfg.encoder_seq
            attn += 3 * cfg.encoder_layers * 4 * B * Se * Se * Hhd  # bidir
            attn += 3 * n_attn * 4 * B * S * Se * Hhd               # cross
        return base + attn
    if shape.kind == "prefill":
        base = 2 * pc["active"] * B * S
        attn = n_attn * 2 * B * S * S * Hhd
        if cfg.encoder_layers:
            Se = cfg.encoder_seq
            attn += cfg.encoder_layers * 4 * B * Se * Se * Hhd
            attn += n_attn * 4 * B * S * Se * Hhd
        return base + attn
    # decode: one token against an S-long cache
    base = 2 * pc["active"] * B
    attn = n_attn * 4 * B * S * Hhd
    if cfg.encoder_layers:
        attn += n_attn * 4 * B * cfg.encoder_seq * Hhd
    return base + attn


def roofline_terms(flops_per_chip: float, hbm_bytes_per_chip: float,
                   wire_bytes_per_chip: float) -> Dict[str, float]:
    t_compute = flops_per_chip / PEAK_FLOPS
    t_memory = hbm_bytes_per_chip / HBM_BW
    t_coll = wire_bytes_per_chip / LINK_BW
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom
    terms["roofline_fraction"] = t_compute / bound if bound > 0 else 0.0
    return terms
