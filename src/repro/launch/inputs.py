"""ShapeDtypeStruct stand-ins for every model input (no device allocation)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.common import Runtime
from repro.models.transformer import init_cache

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                      rt: Runtime) -> Dict[str, SDS]:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
    }
    if cfg.vision_tokens:
        batch["patches"] = SDS((B, cfg.vision_tokens, cfg.d_model),
                               rt.compute_dtype)
    if cfg.encoder_layers:
        batch["frames"] = SDS((B, cfg.encoder_seq, cfg.d_model),
                              rt.compute_dtype)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                        rt: Runtime) -> Dict[str, SDS]:
    batch = train_batch_specs(cfg, shape, rt)
    del batch["labels"]
    return batch


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig, rt: Runtime
                       ) -> Tuple[SDS, Dict, SDS]:
    """(tokens, cache, cache_len) stand-ins for one decode step."""
    B, S = shape.global_batch, shape.seq_len
    tokens = SDS((B, 1), jnp.int32)
    cache = jax.eval_shape(lambda: init_cache(cfg, rt, B, S))
    cache_len = SDS((), jnp.int32)
    return tokens, cache, cache_len


def input_specs(cfg: ArchConfig, shape: ShapeConfig, rt: Runtime):
    """Public entry: the abstract inputs for the step this shape lowers."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape, rt)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape, rt)}
    tokens, cache, cache_len = decode_input_specs(cfg, shape, rt)
    return {"tokens": tokens, "cache": cache, "cache_len": cache_len}
