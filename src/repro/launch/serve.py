"""Batched serving driver: prefill a prompt batch, then greedy decode.

On the CPU container run reduced configs; on TPU the same driver runs under
``make_production_mesh()`` with the serving param layout (TP-sharded weights
replicated over the data axis — see launch/sharding.py + EXPERIMENTS §Perf).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.common import Runtime
from repro.models.transformer import init_params
from repro.train.step import make_decode_step, make_prefill_step


def run(args) -> dict:
    cfg = get_config(args.arch, reduced=args.reduced)
    rt = Runtime(param_dtype=jnp.float32 if args.fp32 else jnp.bfloat16,
                 compute_dtype=jnp.float32 if args.fp32 else jnp.bfloat16,
                 use_pallas=args.pallas)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg, rt)

    B, P = args.batch, args.prompt_len
    total = P + args.gen
    batch = {"tokens": jax.random.randint(key, (B, P), 0, cfg.vocab_size,
                                          jnp.int32)}
    if cfg.vision_tokens:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), rt.compute_dtype)
        total += cfg.vision_tokens
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), rt.compute_dtype)

    prefill = jax.jit(make_prefill_step(cfg, rt, cache_size=total))
    decode = jax.jit(make_decode_step(cfg, rt), donate_argnums=2)

    t0 = time.time()
    tok, cache = prefill(params, batch)
    tok.block_until_ready()
    t_prefill = time.time() - t0

    out_tokens = [np.asarray(tok)]
    pos0 = P + (cfg.vision_tokens or 0)
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, cache = decode(params, tok[:, None], cache,
                            jnp.int32(pos0 + i))
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    result = {
        "arch": args.arch,
        "prefill_s": round(t_prefill, 4),
        "decode_s": round(t_decode, 4),
        "decode_tok_s": round(B * (args.gen - 1) / max(t_decode, 1e-9), 1),
        "generated_shape": list(gen.shape),
        "sample": gen[0, :10].tolist(),
    }
    return result


def make_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--fp32", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    return ap


if __name__ == "__main__":
    print(json.dumps(run(make_parser().parse_args()), indent=2))
