"""Best-effort parameter / cache / batch PartitionSpec rules.

Every rule checks divisibility against the live mesh (via ShardCtx.div) and
falls back to replication on that tensor axis, so *every* (arch x mesh) cell
lowers and compiles — the fallbacks are recorded in the dry-run artifact.

Naming convention: rules dispatch on the leaf's key name (wq, w_up, ...) and
the mixer kind of the enclosing layer position (attention wq is (d, H*hd)
while mLSTM wq is (nh, dh, dh)).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import ShardCtx


def _path_names(path) -> list:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
    return names


def _leaf_spec(names: list, shape: tuple, cfg: ArchConfig, sc: ShardCtx) -> P:
    tp, fs = sc.tp_axis, sc.fsdp_axis
    d = lambda n, a: sc.div(n, a)  # axis if divisible else None
    name = names[-1]
    stacked = names[0] in ("blocks", "enc_blocks")
    base = shape[1:] if stacked else shape
    mixer_kind = "attn"
    if names[0] == "blocks":
        pos = int(re.match(r"pos(\d+)", names[1]).group(1))
        mixer_kind = cfg.period[pos].mixer
    lstm_like = mixer_kind in ("mlstm", "slstm") and "mixer" in names

    def out(*spec):
        spec = tuple(s if i < len(base) else None for i, s in enumerate(spec))
        return P(*(((None,) + spec) if stacked else spec))

    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    if name == "embed":
        return P(d(base[0], tp), d(base[1], fs))
    if name == "lm_head":
        return P(d(base[0], fs), d(base[1], tp))
    if len(base) == 0 or all(s == 1 for s in base):
        return out()

    if lstm_like:
        # xLSTM blocks: FSDP-only (activations replicated over TP; see DESIGN).
        if name in ("wq", "wk", "wv"):          # (nh, dh, dh)
            return out(None, d(base[1], fs), None)
        if name == "r":                          # (nh, dh, 4dh)
            # sLSTM recurrent weights live INSIDE the sequential time scan.
            # Replicated by default (~4M params); with the 64-step-chunked
            # scan they can be FSDP-sharded again — one gather/reduce per
            # chunk instead of per step (§Perf xlstm it5).
            if sc.shard_lstm_r:
                return out(None, d(base[1], fs), None)
            return out(None, None, None)
        if name in ("w_up", "w_in"):             # (d, k)
            return out(d(base[0], fs), None)
        if name == "w_down":                     # (di, d)
            return out(None, d(base[1], fs))
        if name == "w_gate":                     # (di, 2nh)
            return out(d(base[0], fs), None)
        return out(*(None,) * len(base))

    if name == "wq":                             # (d, H*hd)
        return out(d(base[0], fs), tp if sc.div(H, tp) else None)
    if name in ("wk", "wv"):                     # (d, KV*hd)
        return out(d(base[0], fs), tp if sc.div(KV, tp) else None)
    if name == "wo":                             # (H*hd, d)
        return out(tp if sc.div(H, tp) else None, d(base[1], fs))
    if name in ("w_gate", "w_up"):               # (d, ff)
        return out(d(base[0], fs), d(base[1], tp))
    if name == "w_down":                         # (ff, d)
        return out(d(base[0], tp), d(base[1], fs))
    if name == "router":                         # (d, E)
        return out(d(base[0], fs), None)
    if name in ("wg", "wu"):                     # (E, d, f) MoE experts
        return out(None, d(base[1], fs), d(base[2], tp))
    if name == "wd":                             # (E, f, d)
        return out(None, d(base[1], tp), d(base[2], fs))
    if name == "shared_gate":                    # (d, 1)
        return out(d(base[0], fs), None)
    # --- mamba ---
    if name == "w_in":                           # (d, 2di)
        return out(d(base[0], fs), d(base[1], tp))
    if name == "conv_w":                         # (Kc, di)
        return out(None, d(base[1], tp))
    if name == "w_x":                            # (di, r+2N)
        return out(d(base[0], tp), None)
    if name == "w_dt":                           # (r, di)
        return out(None, d(base[1], tp))
    if name == "A_log":                          # (di, N)
        return out(d(base[0], tp), None)
    if name in ("dt_bias", "D"):                 # (di,)
        return out(d(base[0], tp))
    if name == "w_out":                          # (di, d)
        return out(d(base[0], tp), d(base[1], fs))
    if name == "out_scale":
        return out(None)
    # norms / biases / gates: replicate
    return out(*(None,) * len(base))


def expert_parallel_overrides(specs, cfg: ArchConfig, sc: ShardCtx):
    """EP mode: shard the expert axis of MoE weights over TP instead of ff."""
    tp = sc.tp_axis

    def fix(path, spec):
        names = _path_names(path)
        if names and names[-1] in ("wg", "wu", "wd") and len(names) > 1 \
                and names[0] == "blocks":
            if sc.div(cfg.n_experts, tp):
                stacked = (None,)
                if names[-1] in ("wg", "wu"):
                    return P(*stacked, tp, sc.div(cfg.d_model, sc.fsdp_axis),
                             None)
                return P(*stacked, tp, None,
                         sc.div(cfg.d_model, sc.fsdp_axis))
        return spec

    return jax.tree_util.tree_map_with_path(fix, specs)


def param_specs(params_tree, cfg: ArchConfig, sc: ShardCtx,
                expert_parallel: bool = False):
    specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(_path_names(path), leaf.shape, cfg, sc),
        params_tree)
    if expert_parallel:
        specs = expert_parallel_overrides(specs, cfg, sc)
    return specs


def cache_specs(cache_tree, cfg: ArchConfig, sc: ShardCtx, batch: int):
    """Decode-cache specs: batch over DP; KV or S of attention caches over TP."""
    tp = sc.tp_axis
    bspec = sc.div(batch, sc.dp_axes)

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shape = leaf.shape  # leading axis = n_periods
        m = re.match(r"pos(\d+)", names[0]) if names else None
        if m and cfg.period[int(m.group(1))].mixer in ("mlstm", "slstm"):
            return P(*((None, bspec) + (None,) * (len(shape) - 2)))
        if name in ("k", "v", "cross_k", "cross_v"):  # (P, B, S, KV, hd)
            if sc.div(cfg.n_kv_heads, tp):
                return P(None, bspec, None, tp, None)
            return P(None, bspec, sc.div(shape[2], tp), None, None)
        if name == "conv":                            # (P, B, Kc-1, di)
            return P(None, bspec, None, sc.div(shape[3], tp))
        if name == "h" and len(shape) == 4:           # mamba (P, B, di, N)
            return P(None, bspec, sc.div(shape[2], tp), None)
        # xLSTM states & misc: batch-sharded only
        return P(*((None, bspec) + (None,) * (len(shape) - 2)))

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def batch_specs(batch_tree, sc: ShardCtx, batch: int):
    bspec = sc.div(batch, sc.dp_axes)

    def spec_for(leaf):
        return P(*((bspec,) + (None,) * (len(leaf.shape) - 1)))

    return jax.tree.map(spec_for, batch_tree)


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
