import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * jax.jit(step, in_shardings=..., out_shardings=...).lower(**abstract)
  * .compile() under the production mesh (16x16 single-pod / 2x16x16 multi-pod)
  * memory_analysis() -> fits-per-device evidence
  * cost_analysis() + HLO collective parse -> roofline terms (§Roofline)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k \
      --mesh single [--seq-parallel] [--remat full] [--micro 0] [--ep] \
      [--banded] [--tag baseline]
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import repro.compat  # noqa: F401  (pins JAX_PLATFORMS=cpu on bare runners)
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape, cells
from repro.launch import hlo_analysis, hlo_cost
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh, make_shard_ctx
from repro.launch.sharding import (batch_specs, cache_specs, param_specs,
                                   to_shardings)
from repro.models.common import Runtime
from repro.train.step import (TrainHyper, auto_microbatches, init_train_state,
                              make_decode_step, make_prefill_step,
                              make_train_step)

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def make_runtime(cfg, mesh, args) -> Runtime:
    sc = make_shard_ctx(mesh, seq_parallel=args.seq_parallel,
                        flat_dp=getattr(args, "flat_dp", False),
                        shard_lstm_r=getattr(args, "shard_r", False))
    return Runtime(
        sc=sc,
        attn_q_chunk=args.attn_q_chunk,
        attn_banded=args.banded,
        attn_fallback=getattr(args, "attn_fallback", "kvseq"),
        lstm_bf16_states=getattr(args, "lstm_bf16", False),
        remat_policy=args.remat,
        moe_expert_parallel=args.ep,
        moe_capacity_factor=args.capacity_factor,
        ssm_chunk=args.ssm_chunk,
        ce_chunk=args.ce_chunk,
    )


def lower_cell(arch: str, shape_id: str, mesh_kind: str, args):
    cfg = get_config(arch)
    shape = get_shape(shape_id)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rt = make_runtime(cfg, mesh, args)
    sc = rt.sc
    B = shape.global_batch

    state_sds = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg, rt))
    sc_params = sc
    if getattr(args, "serve_tp", False) and shape.kind != "train":
        # serving layout: TP-shard weights, replicate over data/pod — no
        # per-step FSDP all-gathers (there is no optimizer state to shard)
        sc_params = dataclasses.replace(sc, fsdp_axis=None)
    p_specs = param_specs(state_sds["params"], cfg, sc_params,
                          expert_parallel=args.ep)
    p_sh = to_shardings(p_specs, mesh)
    ins = input_specs(cfg, shape, rt)
    meta = {"arch": arch, "shape": shape_id, "mesh": mesh_kind,
            "n_devices": mesh.devices.size,
            "config": {k: v for k, v in vars(args).items()
                       if k in ("seq_parallel", "remat", "micro", "ep",
                                "banded", "attn_q_chunk", "capacity_factor",
                                "ssm_chunk", "ce_chunk", "tag", "flat_dp",
                                "attn_fallback", "lstm_bf16", "serve_tp", "zero1")}}

    if shape.kind == "train":
        n_micro = args.micro or auto_microbatches(cfg, shape, rt)
        meta["n_microbatches"] = n_micro
        hyper = TrainHyper()
        step = make_train_step(cfg, rt, hyper, n_microbatches=n_micro)
        if getattr(args, "zero1", False):
            # ZeRO-1: bf16 params replicated over data (no per-microbatch
            # weight regathers); only fp32 optimizer moments are FSDP-sharded
            sc_repl = dataclasses.replace(sc, fsdp_axis=None)
            p_specs = param_specs(state_sds["params"], cfg, sc_repl,
                                  expert_parallel=args.ep)
            p_sh = to_shardings(p_specs, mesh)
            m_specs = param_specs(state_sds["params"], cfg, sc,
                                  expert_parallel=args.ep)
            msh = to_shardings(m_specs, mesh)
            opt_sh = {"m": msh, "v": msh, "step": NamedSharding(mesh, P())}
        else:
            opt_sh = {"m": p_sh, "v": p_sh,
                      "step": NamedSharding(mesh, P())}
        state_sh = {"params": p_sh, "opt": opt_sh}
        b_sh = to_shardings(batch_specs(ins["batch"], sc, B), mesh)
        metric_keys = ("loss", "ce", "tokens", "moe_lb_loss", "moe_router_z",
                       "moe_drop_frac", "grad_norm", "lr")
        m_sh = {k: NamedSharding(mesh, P()) for k in metric_keys}
        jitted = jax.jit(step, in_shardings=(state_sh, b_sh),
                         out_shardings=(state_sh, m_sh), donate_argnums=0)
        lower_args = (state_sds, ins["batch"])
    elif shape.kind == "prefill":
        from repro.models.transformer import init_cache
        step = make_prefill_step(cfg, rt, cache_size=shape.seq_len)
        cache_sds = jax.eval_shape(
            lambda: init_cache(cfg, rt, B, shape.seq_len))
        c_sh = to_shardings(cache_specs(cache_sds, cfg, sc, B), mesh)
        b_sh = to_shardings(batch_specs(ins["batch"], sc, B), mesh)
        tok_sh = NamedSharding(mesh, P(sc.div(B, sc.dp_axes)))
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(tok_sh, c_sh))
        lower_args = (state_sds["params"], ins["batch"])
    else:  # decode
        step = make_decode_step(cfg, rt)
        c_specs = cache_specs(ins["cache"], cfg, sc, B)
        c_sh = to_shardings(c_specs, mesh)
        bspec = sc.div(B, sc.dp_axes)
        tok_in_sh = NamedSharding(mesh, P(bspec, None))
        tok_sh = NamedSharding(mesh, P(bspec))
        len_sh = NamedSharding(mesh, P())
        jitted = jax.jit(step, in_shardings=(p_sh, tok_in_sh, c_sh, len_sh),
                         out_shardings=(tok_sh, c_sh), donate_argnums=2)
        lower_args = (state_sds["params"], ins["tokens"], ins["cache"],
                      ins["cache_len"])

    t0 = time.time()
    with mesh:
        lowered = jitted.lower(*lower_args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    meta.update(t_lower_s=round(t_lower, 2), t_compile_s=round(t_compile, 2))
    return cfg, shape, mesh, compiled, meta


def analyze(cfg, shape, mesh, compiled, meta) -> dict:
    n_dev = mesh.devices.size
    ca = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}

    hlo = compiled.as_text()
    if meta.get("save_hlo"):
        import gzip
        p = Path(meta["save_hlo"])
        p.parent.mkdir(parents=True, exist_ok=True)
        with gzip.open(p, "wt") as f:
            f.write(hlo)
    # Loop-aware static analysis (XLA cost_analysis counts scan bodies once).
    mod = hlo_cost.analyze_module(hlo, n_dev)
    flops = mod["flops"]
    hbm_bytes = mod["bytes"]
    coll = mod["coll"]
    wire = sum(s["wire_bytes"] for s in coll.values())
    terms = hlo_analysis.roofline_terms(flops, hbm_bytes, wire)

    # useful-FLOPs ratio
    model_flops = hlo_analysis.model_flops(cfg, shape)
    meta.update(
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbm_bytes,
        wire_bytes_per_chip=wire,
        collectives={k: v for k, v in coll.items() if v["count"]},
        memory_analysis=mem_info,
        model_flops=model_flops,
        hlo_flops_global=flops * n_dev,
        useful_flops_ratio=(model_flops / (flops * n_dev)
                           if flops else None),
        xla_cost_analysis={"flops_body_once": float(ca.get("flops", 0.0)),
                           "bytes_body_once": float(
                               ca.get("bytes accessed", 0.0))},
        roofline=terms,
        breakdown=mod.get("breakdown", []),
        hlo_text_bytes=len(hlo),
    )
    return meta


def run_cell(arch, shape_id, mesh_kind, args) -> dict:
    cfg, shape, mesh, compiled, meta = lower_cell(arch, shape_id, mesh_kind,
                                                  args)
    if getattr(args, "save_hlo", False):
        meta["save_hlo"] = str(
            Path(args.out) / "hlo"
            / f"{arch}__{shape_id}__{mesh_kind}__{args.tag}.hlo.gz")
    meta = analyze(cfg, shape, mesh, compiled, meta)
    print(compiled.memory_analysis())
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=["none", "dots", "full"])
    ap.add_argument("--micro", type=int, default=0)
    ap.add_argument("--ep", action="store_true")
    ap.add_argument("--banded", action="store_true")
    ap.add_argument("--flat-dp", action="store_true",
                    help="model axis becomes extra DP + ZeRO (small archs)")
    ap.add_argument("--attn-fallback", default="kvseq",
                    choices=["kvseq", "qseq"])
    ap.add_argument("--lstm-bf16", action="store_true",
                    help="stash xLSTM scan outputs in bf16")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--serve-tp", action="store_true",
                    help="serving layout: replicate params over data axes")
    ap.add_argument("--zero1", action="store_true",
                    help="replicate bf16 params over data; shard only moments")
    ap.add_argument("--shard-r", action="store_true",
                    help="FSDP-shard sLSTM recurrent weights (chunked scan)")
    ap.add_argument("--attn-q-chunk", type=int, default=512)
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--ssm-chunk", type=int, default=256)
    ap.add_argument("--ce-chunk", type=int, default=512)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        todo = [(a, s) for a, s, ok, _ in cells(include_skips=False)]
    else:
        todo = [(args.arch, args.shape)]

    failures = 0
    for arch, shape_id in todo:
        for mesh_kind in meshes:
            name = f"{arch}__{shape_id}__{mesh_kind}__{args.tag}"
            path = outdir / f"{name}.json"
            try:
                t0 = time.time()
                meta = run_cell(arch, shape_id, mesh_kind, args)
                meta["t_total_s"] = round(time.time() - t0, 2)
                path.write_text(json.dumps(meta, indent=2, default=str))
                r = meta["roofline"]
                print(f"OK   {name}: compute={r['t_compute_s']:.4f}s "
                      f"mem={r['t_memory_s']:.4f}s coll={r['t_collective_s']:.4f}s "
                      f"dominant={r['dominant']} "
                      f"frac={r['roofline_fraction']:.3f}", flush=True)
            except Exception as e:
                failures += 1
                path.with_suffix(".err").write_text(
                    f"{e}\n{traceback.format_exc()}")
                print(f"FAIL {name}: {type(e).__name__}: {e}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
