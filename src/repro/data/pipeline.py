"""Deterministic, resumable synthetic token pipeline.

Real training needs a data substrate with: determinism under restart,
shard-awareness (each DP rank reads its slice), and O(1) resume state.  We
generate an order-2 Markov token stream from a seed-derived transition table
— it has learnable structure (CE drops well below ln(V) within a few hundred
steps on a small model) while requiring no files.

Resume state is just ``(seed, step)``: batch ``i`` is a pure function of
them, so a restarted job continues byte-identically (tested).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    branch: int = 4  # Markov branching factor (lower = more learnable)


class SyntheticLM:
    """Order-1 Markov stream with a deterministic per-(seed,step) batch."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # each token has `branch` likely successors
        self.succ = rng.integers(0, V, size=(V, cfg.branch), dtype=np.int32)
        self.step = 0

    def state(self) -> Dict:
        return {"seed": self.cfg.seed, "step": self.step}

    def restore(self, state: Dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch"
        self.step = int(state["step"])

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, V, size=B)
        choices = rng.integers(0, cfg.branch, size=(B, S))
        noise = rng.random((B, S)) < 0.05  # 5% uniform noise
        noise_tok = rng.integers(0, V, size=(B, S), dtype=np.int32)
        for t in range(S):
            nxt = self.succ[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], noise_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b


def host_shard(batch: Dict[str, np.ndarray], rank: int, world: int
               ) -> Dict[str, np.ndarray]:
    """Slice the global batch for one data-parallel host (multi-host I/O)."""
    def s(a):
        per = a.shape[0] // world
        return a[rank * per:(rank + 1) * per]
    return {k: s(v) for k, v in batch.items()}
