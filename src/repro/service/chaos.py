"""Chaos harness: SIGKILL the tuning service at seeded points, restart,
and prove recovery is *exact*.

The harness runs the service as a subprocess and drives a deterministic
multi-study ask/tell workload against it over HTTP.  For each of
``--kills`` phases it arms one crash point (``REPRO_SERVICE_CRASH``,
derived from ``random.Random(seed * 1_000_003 + phase)`` — the same
per-task seeding idiom as ``scheduler.distributed.FaultInjection``, so
the kill schedule is a pure function of the seed).  When the process dies
mid-call, the harness restarts it and *re-issues the interrupted request
verbatim* — same ``req_id``, same trial id — exercising every recovery
guarantee at once: torn-tail truncation, WAL suffix replay over the
snapshot, ask dedup, tell dedup.

After the workload (plus one final crash-free restart, proving recovery
is idempotent), an uninterrupted in-process oracle runs the identical
script in a second data dir, and the harness asserts:

  * ``op_seq`` equal — no journaled op was lost or double-counted;
  * every study's full trial ledger (ids, params, status, values) is
    JSON-equal — no tell double-applied, no proposal re-drawn;
  * the *next* proposals from both services are bit-equal — the
    recovered optimizer state (RNG streams, GP fit schedule) is exact,
    not merely consistent.

Exit code 0 = all phases passed; on failure the data dirs (WAL +
snapshots) are left in place as artifacts.
"""
from __future__ import annotations

import argparse
import json
import os
import random
import signal
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.service.client import ServiceClient, ServiceDown, ServiceError
from repro.service.wal import atomic_write_text

# tags eligible for a seeded kill; indices stay small so every spec fires
# within one phase's slice of the workload
KILL_TAGS = [
    ("ask.mid_journal", 2),        # (tag, index upper bound)
    ("ask.after_journal", 2),
    ("tell.mid_journal", 3),
    ("tell.after_journal", 3),
    ("tell.after_apply", 3),
    ("tell_failed.after_journal", 1),
    ("compact.before_snapshot", 1),
    ("compact.after_snapshot", 1),
    ("compact.after_truncate", 1),
    ("compact.background", 1),     # dies inside the compactor daemon
]

DEFAULT_CONFIG = {
    "space": {"x": {"uniform": [-2.0, 4.0]},
              "lr": {"loguniform": [1e-4, 1e-1]}},
    "max_studies": 8,
    "optimizer": "bayesian",
    "seed": 0,
    "mc_samples": 32,
    "fit_steps": 4,
    "refit_every": 4,
    "compact_every_ops": 10,       # arms the background compactor
}

# the heterogeneous fleet the workload provisions: one bank serves all
# three families, sub-batched inside each ask_all
STRATEGY_CYCLE = ["bayesian", "tpe", "clustering"]


def kill_specs(seed: int, kills: int) -> List[str]:
    """One ``tag:index`` spec per phase, a pure function of the seed."""
    specs = []
    for i in range(kills):
        rng = random.Random(seed * 1_000_003 + i)
        tag, bound = KILL_TAGS[rng.randrange(len(KILL_TAGS))]
        specs.append(f"{tag}:{rng.randrange(bound)}")
    return specs


# --------------------------------------------------------------- workload
class Workload:
    """Deterministic script of service calls.  ``run_step`` executes one
    step against any executor (HTTP client or in-process service) and
    keeps per-study trial bookkeeping, so the oracle and the chaos run
    issue byte-identical request sequences."""

    def __init__(self, seed: int, studies: int, rounds: int, batch: int):
        self.seed = seed
        self.names = [f"s{i}" for i in range(studies)]
        self.rounds = rounds
        self.batch = batch
        self._value_seq = 0

    def _value(self) -> float:
        v = random.Random(self.seed * 1_000_003
                          + 7_777_777 + self._value_seq).uniform(-2.0, 2.0)
        self._value_seq += 1
        return v

    def steps(self):
        """Yields (kind, name, payload) tuples.  Tell steps reference ask
        replies positionally: trial ids are minted sequentially per study,
        so id = round*batch + slot deterministically."""
        for i, name in enumerate(self.names):
            yield ("create", name,
                   {"sign": -1.0 if i % 2 else 1.0,
                    "optimizer": STRATEGY_CYCLE[i % len(STRATEGY_CYCLE)]})
        for r in range(self.rounds):
            for s, name in enumerate(self.names):
                yield ("ask", name, {"n": self.batch,
                                     "req_id": f"r{r}s{s}"})
                for slot in range(self.batch):
                    tid = r * self.batch + slot
                    # every 7th resolution is a failure (deterministic)
                    if (r * self.batch + slot + s) % 7 == 3:
                        yield ("tell_failed", name, {"trial_id": tid})
                    else:
                        yield ("tell", name, {"trial_id": tid,
                                              "value": self._value()})
                yield ("trace", name, {})
            yield ("compact", None, {})


def exec_step(ex, step: Tuple[str, Optional[str], Dict[str, Any]]):
    kind, name, p = step
    if kind == "create":
        return ex.create_study(name, sign=p["sign"],
                               optimizer=p.get("optimizer"))
    if kind == "ask":
        return ex.ask(name, n=p["n"], req_id=p["req_id"])
    if kind == "tell":
        return ex.tell(name, p["trial_id"], p["value"])
    if kind == "tell_failed":
        return ex.tell_failed(name, p["trial_id"])
    if kind == "trace":
        return ex.trace(name)
    if kind == "compact":
        return ex.compact()
    raise ValueError(kind)


# ------------------------------------------------------------- subprocess
class ServerProc:
    def __init__(self, data_dir: str, config_path: Optional[str],
                 crash_spec: str = ""):
        env = dict(os.environ)
        env.setdefault("JAX_PLATFORMS", "cpu")
        if crash_spec:
            env["REPRO_SERVICE_CRASH"] = crash_spec
        else:
            env.pop("REPRO_SERVICE_CRASH", None)
        cmd = [sys.executable, "-m", "repro.service.server",
               "--data-dir", data_dir, "--port", "0"]
        if config_path:
            cmd += ["--config", config_path]
        self.proc = subprocess.Popen(cmd, env=env,
                                     stdout=subprocess.PIPE,
                                     stderr=subprocess.STDOUT, text=True)
        self.base_url = self._await_serving()

    def _await_serving(self, timeout: float = 180.0) -> str:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            line = self.proc.stdout.readline()
            if not line:
                raise RuntimeError(
                    f"server exited during startup "
                    f"(rc={self.proc.poll()})")
            if line.startswith("SERVING "):
                _, host, port = line.split()[:3]
                return f"http://{host}:{port}"
        raise RuntimeError("server did not print SERVING in time")

    def alive(self) -> bool:
        return self.proc.poll() is None

    def wait_dead(self, timeout: float = 10.0) -> bool:
        try:
            self.proc.wait(timeout)
            return True
        except subprocess.TimeoutExpired:
            return False

    def kill(self) -> None:
        if self.alive():
            self.proc.send_signal(signal.SIGKILL)
            self.proc.wait()


# ----------------------------------------------------------------- oracle
class OracleExec:
    """In-process uninterrupted run of the same workload (the ground
    truth the chaos run must be bit-equal to)."""

    def __init__(self, data_dir: str, config: Dict[str, Any]):
        from repro.service.server import CrashPoints, TuningService
        # explicit empty spec: the oracle must never inherit the harness
        # environment's crash points
        self.svc = TuningService(data_dir, config=config,
                                 crash=CrashPoints(""))

    def __getattr__(self, item):
        if item in ("create_study", "ask", "tell", "tell_failed", "trace",
                    "compact", "best", "results", "trials", "health"):
            return getattr(self.svc, item)
        raise AttributeError(item)


# ------------------------------------------------------------------ main
def run(data_dir: str, kills: int = 5, seed: int = 0, studies: int = 3,
        rounds: int = 6, batch: int = 2,
        config: Optional[Dict[str, Any]] = None,
        verbose: bool = True) -> Dict[str, Any]:
    cfg = dict(config or DEFAULT_CONFIG)
    cfg["seed"] = seed
    os.makedirs(data_dir, exist_ok=True)
    svc_dir = os.path.join(data_dir, "service")
    oracle_dir = os.path.join(data_dir, "oracle")
    cfg_path = os.path.join(data_dir, "config.json")
    atomic_write_text(cfg_path, json.dumps(cfg))

    def say(msg):
        if verbose:
            print(msg, flush=True)

    specs = kill_specs(seed, kills)
    say(f"chaos: kill schedule {specs}")

    steps = list(Workload(seed, studies, rounds, batch).steps())
    fired: List[str] = []
    pos = 0
    phase = 0
    server = ServerProc(svc_dir, cfg_path,
                        specs[phase] if phase < len(specs) else "")
    client = ServiceClient(server.base_url, timeout=60.0, retries=0)
    while pos < len(steps):
        step = steps[pos]
        try:
            exec_step(client, step)
            pos += 1
        except ServiceDown:
            if not server.wait_dead(timeout=15.0):
                server.kill()
                raise RuntimeError(
                    f"call failed but server still alive at step {pos} "
                    f"({step[0]}) — not a crash-point death")
            say(f"chaos: killed at step {pos} ({step[0]}) by "
                f"{specs[phase]}; restarting")
            fired.append(specs[phase])
            phase += 1
            server = ServerProc(
                svc_dir, None, specs[phase] if phase < len(specs) else "")
            client = ServiceClient(server.base_url, timeout=60.0, retries=0)
            # re-issue the interrupted step verbatim: dedup must absorb it
    # a spec may not fire if the workload ran out first — report, and the
    # bit-equality checks below still hold for however many fired
    if phase < len(specs):
        say(f"chaos: {len(specs) - phase} spec(s) never fired: "
            f"{specs[phase:]}")
    server.kill()

    # final crash-free restart: recovery must be idempotent (replaying an
    # already-recovered dir changes nothing)
    server = ServerProc(svc_dir, None, "")
    client = ServiceClient(server.base_url, timeout=60.0, retries=2)

    say("chaos: running uninterrupted oracle")
    oracle = OracleExec(oracle_dir, cfg)
    for step in list(Workload(seed, studies, rounds, batch).steps()):
        exec_step(oracle, step)

    # ---------------------------------------------------------- compare
    failures: List[str] = []
    h_svc, h_orc = client.health(), oracle.health()
    if h_svc["op_seq"] != h_orc["op_seq"]:
        failures.append(f"op_seq diverged: service {h_svc['op_seq']} "
                        f"vs oracle {h_orc['op_seq']}")
    names = [f"s{i}" for i in range(studies)]
    for name in names:
        t_svc = client.trials(name)["trials"]
        t_orc = oracle.trials(name)["trials"]
        if t_svc != t_orc:
            failures.append(f"{name}: trial ledger diverged "
                            f"(dedup violated or replay drifted)")
            for a, b in zip(t_svc, t_orc):
                if a != b:
                    failures.append(f"  first diff: {a!r} != {b!r}")
                    break
        # remaining proposals must be bit-equal: the recovered RNG/GP
        # state, not just the ledger, is exact
        p_svc = client.ask(name, n=2 * batch)["trials"]
        p_orc = oracle.ask(name, n=2 * batch)["trials"]
        if p_svc != p_orc:
            failures.append(f"{name}: post-recovery proposals diverged")
            failures.append(f"  service: {p_svc!r}")
            failures.append(f"  oracle:  {p_orc!r}")
    server.kill()
    oracle.svc.close()

    report = {"kills_requested": kills, "kills_fired": len(fired),
              "fired": fired, "steps": len(steps), "failures": failures}
    say(f"chaos: {len(fired)}/{kills} kills fired over {len(steps)} steps; "
        f"{'PASS' if not failures else 'FAIL'}")
    for f in failures:
        say(f"  {f}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="SIGKILL chaos harness for the durable tuning service")
    ap.add_argument("--data-dir", required=True,
                    help="work dir; service/ and oracle/ land here and are "
                         "left as artifacts on failure")
    ap.add_argument("--kills", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--studies", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args(argv)
    report = run(args.data_dir, kills=args.kills, seed=args.seed,
                 studies=args.studies, rounds=args.rounds, batch=args.batch)
    return 1 if report["failures"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
