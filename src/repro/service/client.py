"""Client for the durable tuning service (stdlib ``urllib`` only).

Two layers:

  * ``ServiceClient`` — thin JSON-over-HTTP wrapper, one method per
    endpoint, with bounded retries on connection errors.  Retries are
    safe by construction: every mutating endpoint is idempotent (create
    by name, tell by trial id, ask/observe/trace by ``req_id`` — minted
    here per logical call, before the retry loop, so every resend
    carries the same id), so a request whose response was lost to a
    crash can be resent verbatim and lands exactly once.
  * ``RemoteOptimizer`` — duck-types the ``AskTellOptimizer`` surface the
    tuner drivers use (``ask``/``tell``/``tell_failed``/
    ``observe_params``/``snapshot_trace``/``results``/counters), backed
    by one named study on the service.  ``ServiceScheduler.make_engine``
    hands this to ``Tuner``/``AsyncTuner``, so the existing driver loops
    run against a remote service unchanged.
"""
from __future__ import annotations

import http.client
import json
import time
import uuid
from typing import Any, Dict, List, Optional
from urllib.error import HTTPError, URLError
from urllib.parse import quote
from urllib.request import Request, urlopen


class ServiceError(Exception):
    """An error with an HTTP status.  The server core raises it to name
    the reply code (the handler maps it to a JSON error body); the client
    re-raises it for any non-2xx response, so callers on either side of
    the wire catch the same type."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status


class ServiceDown(Exception):
    """Could not reach the service at all (refused/reset/timeout)."""


class ServiceClient:
    def __init__(self, base_url: str, timeout: float = 10.0,
                 retries: int = 3, retry_wait: float = 0.1):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout
        self.retries = retries
        self.retry_wait = retry_wait

    # ------------------------------------------------------------- plumbing
    def _request(self, method: str, path: str,
                 body: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        data = None if body is None else json.dumps(body).encode()
        last: Optional[Exception] = None
        for attempt in range(self.retries + 1):
            req = Request(self.base_url + path, data=data, method=method,
                          headers={"Content-Type": "application/json"})
            try:
                with urlopen(req, timeout=self.timeout) as resp:
                    return json.loads(resp.read().decode())
            except HTTPError as e:
                # the server answered: no retry, surface its error
                try:
                    msg = json.loads(e.read().decode()).get("error", str(e))
                except Exception:  # noqa: BLE001
                    msg = str(e)
                raise ServiceError(e.code, msg) from None
            except (URLError, ConnectionError, TimeoutError, OSError,
                    http.client.HTTPException) as e:
                # HTTPException covers a SIGKILL mid-response
                # (RemoteDisconnected / IncompleteRead)
                last = e
                if attempt < self.retries:
                    time.sleep(self.retry_wait * (attempt + 1))
        raise ServiceDown(f"{method} {path}: {last}") from last

    @staticmethod
    def _study_path(name: str, verb: str) -> str:
        return f"/studies/{quote(name, safe='')}/{verb}"

    # ------------------------------------------------------------ endpoints
    def create_study(self, name: str, sign: float = 1.0,
                     optimizer: Optional[str] = None) -> Dict[str, Any]:
        body = {"name": name, "sign": sign}
        if optimizer is not None:
            body["optimizer"] = optimizer
        return self._request("POST", "/studies", body)

    def ask(self, name: str, n: int = 1,
            req_id: Optional[str] = None) -> Dict[str, Any]:
        return self._request("POST", self._study_path(name, "ask"),
                             {"n": n,
                              "req_id": req_id or uuid.uuid4().hex})

    def tell(self, name: str, trial_id: int, value: float) -> Dict[str, Any]:
        return self._request("POST", self._study_path(name, "tell"),
                             {"trial_id": trial_id, "value": value})

    def tell_failed(self, name: str, trial_id: int) -> Dict[str, Any]:
        return self._request("POST", self._study_path(name, "tell_failed"),
                             {"trial_id": trial_id})

    def observe(self, name: str, params: Dict[str, Any], value: float,
                req_id: Optional[str] = None) -> Dict[str, Any]:
        return self._request("POST", self._study_path(name, "observe"),
                             {"params": params, "value": value,
                              "req_id": req_id or uuid.uuid4().hex})

    def trace(self, name: str,
              req_id: Optional[str] = None) -> Dict[str, Any]:
        return self._request("POST", self._study_path(name, "trace"),
                             {"req_id": req_id or uuid.uuid4().hex})

    def best(self, name: str) -> Dict[str, Any]:
        return self._request("GET", self._study_path(name, "best"))

    def results(self, name: str) -> Dict[str, Any]:
        return self._request("GET", self._study_path(name, "results"))

    def trials(self, name: str) -> Dict[str, Any]:
        return self._request("GET", self._study_path(name, "trials"))

    def studies(self) -> Dict[str, Any]:
        return self._request("GET", "/studies")

    def health(self) -> Dict[str, Any]:
        return self._request("GET", "/health")

    def compact(self) -> Dict[str, Any]:
        return self._request("POST", "/admin/compact", {})


class RemoteTrial:
    """Client-side view of a service trial (duck-types ``Trial`` for the
    driver loops: ``id``/``params``/``status``/``value``; ``params`` is
    rebindable like the ledger-backed original)."""

    __slots__ = ("id", "params", "status", "value")

    def __init__(self, d: Dict[str, Any]):
        self.id = int(d["id"])
        self.params = dict(d["params"])
        self.status = d["status"]
        self.value = d["value"]


class RemoteOptimizer:
    """``AskTellOptimizer`` surface over one named remote study."""

    def __init__(self, client: ServiceClient, study: str,
                 param_space=None, sign: float = 1.0):
        from repro.core.spaces import ParamSpace
        self.client = client
        self.study = study
        if param_space is None or isinstance(param_space, ParamSpace):
            self.space = param_space
        else:
            self.space = ParamSpace(param_space)
        self._sign = float(sign)
        self._created = False

    # sign assignment is how the drivers select maximize/minimize; the
    # study direction lives server-side, so propagate it (create is
    # idempotent by name — a same-sign repeat is a no-op)
    @property
    def sign(self) -> float:
        return self._sign

    @sign.setter
    def sign(self, v: float) -> None:
        self._sign = float(v)
        self.client.create_study(self.study, sign=self._sign)
        self._created = True

    def _ensure(self) -> None:
        if not self._created:
            self.client.create_study(self.study, sign=self._sign)
            self._created = True

    # ----------------------------------------------------------- ask/tell
    def ask(self, n: int = 1) -> List[RemoteTrial]:
        self._ensure()
        # a fresh req_id per logical ask: a lost response is retried with
        # the SAME id, so the service re-serves the cached proposals
        # instead of minting (and journaling) a second draw
        out = self.client.ask(self.study, n=n, req_id=uuid.uuid4().hex)
        return [RemoteTrial(t) for t in out["trials"]]

    def tell(self, trial_id: int, value: float) -> RemoteTrial:
        return RemoteTrial(self.client.tell(self.study, trial_id,
                                            float(value)))

    def tell_failed(self, trial_id: int) -> RemoteTrial:
        return RemoteTrial(self.client.tell_failed(self.study, trial_id))

    def observe_params(self, params: Dict[str, Any],
                       value: float) -> RemoteTrial:
        from repro.core.optimizer import _to_jsonable
        self._ensure()
        return RemoteTrial(self.client.observe(
            self.study, _to_jsonable(dict(params)), float(value)))

    def snapshot_trace(self) -> None:
        self.client.trace(self.study)

    def pending_trials(self) -> List[RemoteTrial]:
        out = self.client.trials(self.study)
        return [RemoteTrial(t) for t in out["trials"]
                if t["status"] == "pending"]

    # ------------------------------------------------------------ counters
    def _best(self) -> Dict[str, Any]:
        self._ensure()
        return self.client.best(self.study)

    @property
    def num_trials(self) -> int:
        return int(self._best()["num_trials"])

    @property
    def n_observed(self) -> int:
        return int(self._best()["n_observed"])

    @property
    def n_failed(self) -> int:
        return int(self._best()["n_failed"])

    # ------------------------------------------------------------- results
    def results(self, iterations: Optional[int] = None, wall: float = 0.0):
        from repro.core.tuner import TunerResults
        r = self.client.results(self.study)
        return TunerResults(
            best_objective=r["best_objective"],
            best_params=r["best_params"],
            params_tried=r["params_tried"],
            objective_values=r["objective_values"],
            best_trace=r["best_trace"],
            iterations=(len(r["objective_values"]) if iterations is None
                        else iterations),
            n_failed=r["n_failed"],
            wall_time_s=wall)

    # the service journals every mutation — driver-side checkpointing is
    # redundant, so the hooks are accepted and ignored
    def save(self, path, iteration: int = 0) -> None:
        pass

    def load(self, path) -> int:
        return 0
