"""Durable ask/tell tuning service over HTTP (stdlib only).

One process hosts many named studies backed by a single ``StudyBank``.
Every state-mutating request — create / ask / tell / tell_failed /
observe / trace — is assigned a monotonic ``seq``, journaled to the
CRC-framed WAL (``repro.service.wal``) with an fsync, and only *then*
applied to the bank, all under one lock so journal order equals apply
order.  Crash recovery (``repro.service.recovery``) loads the latest
fleet snapshot and replays the WAL suffix; because every proposal is a
pure function of bank state and the per-study RNG streams, a replayed
``ask`` mints bit-identical trial ids and configurations, which is what
lets an interrupted ask be *re-served* rather than re-drawn.

Exactly-once effect on at-least-once delivery:

  * tells are deduped by trial id — a pending trial is resolved once,
    a repeat (client retry, or a WAL suffix overlapping the snapshot)
    is a no-op reply with ``applied: false``;
  * asks, observes and traces are deduped by client ``req_id`` — a
    retried request returns the cached reply instead of journaling a
    second op; the reply cache rides in the snapshot's ``extra`` block
    so it survives compaction;
  * creates are idempotent by study name.

Journal-then-apply requires apply to be infallible once journaled, so
every op is validated against the bank (``StudyBank.validate_op``)
*before* the WAL append — a malformed request (``ask`` with ``n<1``, an
``observe`` whose params don't encode) is rejected with 4xx and never
reaches the log, where it would poison every future replay.

Degradation: if the WAL volume errors, the service stays up read-only —
``best``/``results``/``studies`` keep serving, mutations get 503.

``REPRO_SERVICE_CRASH`` (``tag:index`` specs, comma-separated — e.g.
``tell.after_journal:3``) arms deterministic SIGKILL points for the
chaos harness; unset in production.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import threading
from collections import OrderedDict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, List, Optional
from urllib.parse import unquote, urlparse

from repro.analysis.sanitizers import assert_holds
from repro.service.client import ServiceError
from repro.service.recovery import CONFIG, SNAPSHOT, WAL_FILE, recover
from repro.service.wal import WriteAheadLog, atomic_write_text

REPLY_CACHE_CAP = 128   # retained req_id replies per study


def space_from_spec(spec: Dict[str, Any]) -> Dict[str, Any]:
    """Build a ``ParamSpace``-ready dict from a JSON space spec.

    Each entry is a one-key tagged dict::

        {"lr": {"loguniform": [1e-4, 1e-1]},
         "x":  {"uniform": [-1.0, 2.0]},        # [loc, scale]
         "n":  {"range": [16, 256, 16]},        # start, stop, step
         "act": {"choice": ["relu", "gelu"]},
         "tile": {"int": [1, 16]},              # inclusive bounds
         "bq":  {"logint": [32, 512]},
         "tag": {"const": "v1"}}

    Conditional subspaces nest one level of the same grammar under
    ``cond`` (core.spaces.Choice)::

        {"plan": {"cond": {"dp":  {"zero": {"choice": ["z1", "z3"]}},
                           "tp8": {"sp": {"choice": [0, 1]}}}}}
    """
    from scipy.stats import loguniform, uniform

    from repro.core.spaces import Choice, Int, LogInt

    def one(name: str, s: Any, nested: bool = False) -> Any:
        if not isinstance(s, dict) or len(s) != 1:
            raise ServiceError(400, f"bad spec for param {name!r}: {s!r}")
        kind, arg = next(iter(s.items()))
        if kind == "uniform":
            return uniform(float(arg[0]), float(arg[1]))
        if kind == "loguniform":
            return loguniform(float(arg[0]), float(arg[1]))
        if kind == "range":
            return range(*[int(a) for a in arg])
        if kind == "choice":
            return list(arg)
        if kind == "int":
            return Int(int(arg[0]), int(arg[1]))
        if kind == "logint":
            return LogInt(int(arg[0]), int(arg[1]))
        if kind == "const":
            return arg
        if kind == "cond" and not nested:
            if not isinstance(arg, dict) or not arg:
                raise ServiceError(
                    400, f"cond spec for {name!r} wants a branch dict")
            return Choice({
                bname: {cn: one(f"{name}.{bname}.{cn}", cs, nested=True)
                        for cn, cs in sub.items()}
                for bname, sub in arg.items()})
        raise ServiceError(400, f"unknown spec kind {kind!r} "
                                f"for param {name!r}")

    return {name: one(name, s) for name, s in spec.items()}


class CrashPoints:
    """Deterministic SIGKILL injection for the chaos harness.

    ``REPRO_SERVICE_CRASH="ask.mid_journal:2,compact.after_snapshot:0"``
    kills the process at the 3rd hit of the first tag or the 1st of the
    second (0-based hit index per tag).  Mutations are serialized under
    the service lock, so hit counts are a pure function of the op stream
    — the same workload always dies at the same byte.
    """

    def __init__(self, spec: Optional[str] = None):
        spec = (os.environ.get("REPRO_SERVICE_CRASH", "")
                if spec is None else spec)
        self._armed: Dict[str, int] = {}
        self._hits: Dict[str, int] = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            tag, idx = part.rsplit(":", 1)
            self._armed[tag] = int(idx)

    def check(self, tag: str) -> None:
        if tag not in self._armed:
            return
        hit = self._hits.get(tag, 0)
        self._hits[tag] = hit + 1
        if hit == self._armed[tag]:
            os.kill(os.getpid(), signal.SIGKILL)

    def hook(self, tag: str) -> Optional[Callable[[], None]]:
        """A callable for WAL ``mid_hook`` — only when the tag is armed,
        so production appends stay single-write."""
        if tag not in self._armed:
            return None
        return lambda: self.check(tag)


class TuningService:
    """The service core: bank + WAL + side tables, HTTP-agnostic."""

    def __init__(self, data_dir, config: Optional[Dict[str, Any]] = None,
                 crash: Optional[CrashPoints] = None):
        from repro.core.studybank import StudyBank
        self.data_dir = str(data_dir)
        os.makedirs(self.data_dir, exist_ok=True)
        cfg_path = os.path.join(self.data_dir, CONFIG)
        if config is not None and not os.path.exists(cfg_path):
            atomic_write_text(cfg_path, json.dumps(config, indent=1))
        if not os.path.exists(cfg_path):
            raise ServiceError(500, f"no {CONFIG} in {self.data_dir}; pass "
                                    "config= on first start")
        with open(cfg_path) as fh:
            self.config = json.load(fh)
        cfg = self.config
        self.bank = StudyBank(
            space_from_spec(cfg["space"]),
            n_studies=int(cfg.get("max_studies", 16)),
            optimizer=cfg.get("optimizer", "bayesian"),
            seed=int(cfg.get("seed", 0)),
            mc_samples=cfg.get("mc_samples"),
            fit_steps=int(cfg.get("fit_steps", 40)),
            refit_every=int(cfg.get("refit_every", 8)),
            use_pallas=bool(cfg.get("use_pallas", False)),
            strategy_kwargs=cfg.get("strategy_kwargs"))
        self.compact_every_ops = int(cfg.get("compact_every_ops", 0))
        self.compact_interval_s = float(cfg.get("compact_interval_s", 0.0))
        self.crash = crash or CrashPoints()
        self._lock = threading.RLock()
        self._names: Dict[str, int] = {}
        # per-study req_id -> trial-id list: asks cache their proposal ids,
        # observes the single registered id, traces an empty list (the
        # reply is rebuilt from the live trials, so status stays current)
        self._reply_cache: Dict[int, "OrderedDict[str, List[int]]"] = {}
        self.wal_error: Optional[str] = None
        self._ops_since_snapshot = 0
        self._snap_path = os.path.join(self.data_dir, SNAPSHOT)
        self.recovery = recover(
            self.data_dir, self.bank, self._apply_record,
            on_snapshot=lambda: self._restore_extra(self.bank.extra))
        self.wal = WriteAheadLog(os.path.join(self.data_dir, WAL_FILE))
        # background compaction: the request path only *signals* (an Event
        # set is nanoseconds); the snapshot+truncate stall moves off the
        # serving threads onto this timer-driven daemon
        self._compact_wake = threading.Event()
        self._stop = threading.Event()
        self._compact_thread: Optional[threading.Thread] = None
        if self.compact_every_ops or self.compact_interval_s:
            self._compact_thread = threading.Thread(
                target=self._compact_loop, name="wal-compactor", daemon=True)
            self._compact_thread.start()

    # ------------------------------------------------------- side tables
    def _restore_extra(self, extra) -> None:
        if not extra:
            return
        self._names = dict(extra.get("names", {}))
        self._reply_cache = {
            int(b): OrderedDict((rid, list(ids)) for rid, ids in entries)
            for b, entries in extra.get("reply_cache", {}).items()}

    def _extra_meta(self) -> Dict[str, Any]:
        return {"names": self._names,
                "reply_cache": {str(b): [[rid, ids]
                                         for rid, ids in od.items()]
                                for b, od in self._reply_cache.items()}}

    def _row(self, name: str) -> int:
        b = self._names.get(name)
        if b is None:
            raise ServiceError(404, f"unknown study {name!r}")
        return b

    def _check_writable(self) -> None:
        if self.wal_error is not None:
            raise ServiceError(
                503, f"journal volume failed ({self.wal_error}); service "
                     "is read-only until restarted on healthy storage")

    # -------------------------------------------------- journal-then-apply
    def _apply_record(self, op: Dict[str, Any]):
        """Apply one journal op to bank + side tables.  This is the ONE
        mutation path — live serving and crash replay both land here, so
        the name table and ask cache can never diverge from the bank."""
        kind = op["op"]
        b = int(op["study"])
        if kind == "create":
            self._names[op["name"]] = b
        result = self.bank.apply_op(op)
        if op.get("req_id") is not None:
            payload = {"ask": lambda: [t.id for t in result],
                       "observe": lambda: [result.id],
                       "trace": lambda: []}.get(kind)
            if payload is not None:
                od = self._reply_cache.setdefault(b, OrderedDict())
                od[op["req_id"]] = payload()
                while len(od) > REPLY_CACHE_CAP:
                    od.popitem(last=False)
        return result

    def _commit(self, op: Dict[str, Any]):
        """Validate, assign the next seq, journal (fsync), then apply.
        Caller must hold the lock — WAL order must equal apply order for
        replay to be exact.  Validation comes first: once a record is
        fsync'd it WILL be replayed on every restart, so nothing that
        can't apply may reach the log."""
        assert_holds(self._lock)
        op = dict(op)
        self.bank.validate_op(op)
        op["seq"] = self.bank.next_op_seq()
        kind = op["op"]
        self.crash.check(f"{kind}.before_journal")
        try:
            self.wal.append(op, mid_hook=self.crash.hook(
                f"{kind}.mid_journal"))
        except OSError as e:
            self.wal_error = f"{type(e).__name__}: {e}"
            self._check_writable()
        self.crash.check(f"{kind}.after_journal")
        result = self._apply_record(op)
        self.crash.check(f"{kind}.after_apply")
        self._ops_since_snapshot += 1
        if (self.compact_every_ops
                and self._ops_since_snapshot >= self.compact_every_ops):
            # wake the compactor instead of snapshotting inline: the old
            # synchronous path stalled whichever unlucky request crossed
            # the threshold for the whole snapshot+fsync
            self._compact_wake.set()
        return result

    # ------------------------------------------------------------- public
    def create_study(self, name: str, sign: float = 1.0,
                     optimizer: Optional[str] = None) -> Dict[str, Any]:
        """Create (or idempotently re-create) a named study.  ``optimizer``
        picks the per-study strategy — one bank serves a heterogeneous
        GP+TPE+clustering fleet, sub-batched per family inside a single
        ``ask_all`` — and defaults to the bank-wide config strategy."""
        sign = float(sign)
        with self._lock:
            if name in self._names:
                b = self._names[name]
                view = self.bank.studies[b]
                cur = self.bank.strategy_names[b]
                if sign == view.sign and optimizer in (None, cur):
                    return {"study": b, "name": name, "optimizer": cur,
                            "created": False}
                if view.num_trials > 0:
                    raise ServiceError(
                        409, f"study {name!r} already has trials with "
                             f"sign {view.sign} / strategy {cur!r}")
            else:
                b = len(self._names)
                if b >= self.bank.n_studies:
                    raise ServiceError(
                        507, f"bank capacity {self.bank.n_studies} "
                             "exhausted (raise max_studies)")
            self._check_writable()
            op = {"op": "create", "study": b, "name": name, "sign": sign}
            if optimizer is not None:
                op["optimizer"] = str(optimizer)
            self._commit(op)
            return {"study": b, "name": name,
                    "optimizer": self.bank.strategy_names[b],
                    "created": True}

    def ask(self, name: str, n: int = 1,
            req_id: Optional[str] = None) -> Dict[str, Any]:
        with self._lock:
            b = self._row(name)
            view = self.bank.studies[b]
            if req_id is not None:
                cached = self._reply_cache.get(b, {}).get(req_id)
                if cached is not None:
                    return {"trials": [self._trial_json(view._trials[i])
                                       for i in cached], "cached": True}
            self._check_writable()
            trials = self._commit({"op": "ask", "study": b, "n": int(n),
                                   "req_id": req_id})
            return {"trials": [self._trial_json(t) for t in trials],
                    "cached": False}

    def tell(self, name: str, trial_id: int, value: float) -> Dict[str, Any]:
        return self._resolve(name, trial_id, "tell", value=float(value))

    def tell_failed(self, name: str, trial_id: int) -> Dict[str, Any]:
        return self._resolve(name, trial_id, "tell_failed")

    def _resolve(self, name: str, trial_id: int, kind: str,
                 **extra) -> Dict[str, Any]:
        with self._lock:
            b = self._row(name)
            view = self.bank.studies[b]
            t = view._trials.get(int(trial_id))
            if t is None:
                raise ServiceError(404, f"study {name!r} has no trial "
                                        f"{trial_id} (tell before ask?)")
            from repro.core.optimizer import PENDING
            if t.status != PENDING:
                # duplicate delivery: reply, don't journal — retries must
                # not grow the WAL
                return {**self._trial_json(t), "applied": False}
            self._check_writable()
            t, applied = self._commit({"op": kind, "study": b,
                                       "trial_id": int(trial_id), **extra})
            return {**self._trial_json(t), "applied": applied}

    def observe(self, name: str, params: Dict[str, Any], value: float,
                req_id: Optional[str] = None) -> Dict[str, Any]:
        from repro.core.optimizer import _to_jsonable
        with self._lock:
            b = self._row(name)
            if req_id is not None:
                cached = self._reply_cache.get(b, {}).get(req_id)
                if cached is not None:
                    view = self.bank.studies[b]
                    return {**self._trial_json(view._trials[cached[0]]),
                            "cached": True}
            self._check_writable()
            t = self._commit({"op": "observe", "study": b,
                              "params": _to_jsonable(dict(params)),
                              "value": float(value), "req_id": req_id})
            return {**self._trial_json(t), "cached": False}

    def trace(self, name: str,
              req_id: Optional[str] = None) -> Dict[str, Any]:
        with self._lock:
            b = self._row(name)
            if req_id is not None \
                    and req_id in self._reply_cache.get(b, {}):
                return {"ok": True, "cached": True}
            self._check_writable()
            self._commit({"op": "trace", "study": b, "req_id": req_id})
            return {"ok": True, "cached": False}

    def best(self, name: str) -> Dict[str, Any]:
        from repro.core.optimizer import _to_jsonable
        with self._lock:
            view = self.bank.studies[self._row(name)]
            res = view.results()
            return {"best_objective": res.best_objective,
                    "best_params": _to_jsonable(res.best_params),
                    "num_trials": view.num_trials,
                    "n_observed": view.n_observed,
                    "n_failed": view.n_failed}

    def results(self, name: str) -> Dict[str, Any]:
        from repro.core.optimizer import _to_jsonable
        with self._lock:
            view = self.bank.studies[self._row(name)]
            res = view.results()
            return {"best_objective": res.best_objective,
                    "best_params": _to_jsonable(res.best_params),
                    "params_tried": [_to_jsonable(p)
                                     for p in res.params_tried],
                    "objective_values": res.objective_values,
                    "best_trace": res.best_trace,
                    "n_failed": res.n_failed}

    def trials(self, name: str) -> Dict[str, Any]:
        with self._lock:
            view = self.bank.studies[self._row(name)]
            return {"trials": [self._trial_json(t)
                               for t in view._trials.values()]}

    def studies(self) -> Dict[str, Any]:
        with self._lock:
            out = []
            for name, b in sorted(self._names.items(), key=lambda kv: kv[1]):
                v = self.bank.studies[b]
                out.append({"name": name, "study": b, "sign": v.sign,
                            "num_trials": v.num_trials,
                            "n_observed": v.n_observed,
                            "n_failed": v.n_failed})
            return {"studies": out}

    def health(self) -> Dict[str, Any]:
        return {"status": "degraded" if self.wal_error else "ok",
                "op_seq": self.bank.op_seq,
                "n_studies": len(self._names),
                "wal_error": self.wal_error}

    # --------------------------------------------------------- compaction
    def compact(self) -> Dict[str, Any]:
        with self._lock:
            self._check_writable()
            return self._compact_locked()

    def _compact_loop(self) -> None:
        """Daemon compactor: sleeps until the op-count threshold signal
        (``_commit``) or the ``compact_interval_s`` timer, then takes the
        service lock and snapshots.  Compaction never changes bank state
        (replay skips ``seq <= snapshot op_seq``), so running it off the
        request path is crash-equivalent to the old inline call — the
        chaos harness's ``compact.background`` point proves it."""
        while not self._stop.is_set():
            self._compact_wake.wait(self.compact_interval_s or None)
            if self._stop.is_set():
                return
            self._compact_wake.clear()
            with self._lock:
                if self.wal_error is not None \
                        or self._ops_since_snapshot == 0:
                    continue
                self.crash.check("compact.background")
                try:
                    self._compact_locked()
                except ServiceError:
                    continue    # degraded -> read-only; nothing to drain

    def _compact_locked(self) -> Dict[str, Any]:
        assert_holds(self._lock)  # caller-must-hold: snapshot vs. commits
        self.crash.check("compact.before_snapshot")
        try:
            # the snapshot carries op_seq + side tables; the replace is
            # atomic, and the truncate below need not be coupled to it —
            # replay skips seq <= snapshot op_seq
            self.bank.save(self._snap_path, iteration=self.bank.op_seq,
                           extra=self._extra_meta())
            self.crash.check("compact.after_snapshot")
            self.wal.reset()
        except OSError as e:
            self.wal_error = f"{type(e).__name__}: {e}"
            self._check_writable()
        self.crash.check("compact.after_truncate")
        self._ops_since_snapshot = 0
        return {"op_seq": self.bank.op_seq}

    # ------------------------------------------------------------ helpers
    @staticmethod
    def _trial_json(t) -> Dict[str, Any]:
        from repro.core.optimizer import _to_jsonable
        return {"id": t.id, "params": _to_jsonable(t.params),
                "status": t.status, "value": t.value}

    def shutdown(self, timeout: Optional[float] = None) -> None:
        """Stop the background compactor (joining it for up to
        ``timeout`` seconds — an in-flight snapshot finishes first) and
        close the WAL.  Idempotent."""
        self._stop.set()
        self._compact_wake.set()
        if self._compact_thread is not None:
            self._compact_thread.join(timeout)
            self._compact_thread = None
        self.wal.close()

    def close(self) -> None:
        self.shutdown(timeout=10.0)


# ---------------------------------------------------------------- HTTP layer
class _Handler(BaseHTTPRequestHandler):
    service: TuningService = None   # set by serve()
    protocol_version = "HTTP/1.1"

    def log_message(self, *a):       # quiet: chaos restarts spam otherwise
        pass

    def _reply(self, status: int, payload: Dict[str, Any]) -> None:
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> Dict[str, Any]:
        n = int(self.headers.get("Content-Length") or 0)
        if not n:
            return {}
        try:
            return json.loads(self.rfile.read(n).decode())
        except (UnicodeDecodeError, json.JSONDecodeError):
            raise ServiceError(400, "request body is not valid JSON")

    def _route(self, method: str) -> None:
        svc = self.service
        parts = [unquote(p) for p in
                 urlparse(self.path).path.strip("/").split("/") if p]
        try:
            if method == "GET":
                if parts == ["health"]:
                    return self._reply(200, svc.health())
                if parts == ["studies"]:
                    return self._reply(200, svc.studies())
                if len(parts) == 3 and parts[0] == "studies":
                    name, verb = parts[1], parts[2]
                    if verb == "best":
                        return self._reply(200, svc.best(name))
                    if verb == "results":
                        return self._reply(200, svc.results(name))
                    if verb == "trials":
                        return self._reply(200, svc.trials(name))
            else:  # POST
                body = self._body()
                if parts == ["studies"]:
                    return self._reply(200, svc.create_study(
                        body["name"], body.get("sign", 1.0),
                        body.get("optimizer")))
                if parts == ["admin", "compact"]:
                    return self._reply(200, svc.compact())
                if len(parts) == 3 and parts[0] == "studies":
                    name, verb = parts[1], parts[2]
                    if verb == "ask":
                        return self._reply(200, svc.ask(
                            name, body.get("n", 1), body.get("req_id")))
                    if verb == "tell":
                        return self._reply(200, svc.tell(
                            name, body["trial_id"], body["value"]))
                    if verb == "tell_failed":
                        return self._reply(200, svc.tell_failed(
                            name, body["trial_id"]))
                    if verb == "observe":
                        return self._reply(200, svc.observe(
                            name, body["params"], body["value"],
                            body.get("req_id")))
                    if verb == "trace":
                        return self._reply(200, svc.trace(
                            name, body.get("req_id")))
            raise ServiceError(404, f"no route {method} {self.path}")
        except ServiceError as e:
            self._reply(e.status, {"error": str(e)})
        except (KeyError, TypeError, ValueError) as e:
            self._reply(400, {"error": f"{type(e).__name__}: {e}"})
        except Exception as e:  # noqa: BLE001 — the service must stay up
            self._reply(500, {"error": f"{type(e).__name__}: {e}"})

    def do_GET(self):
        self._route("GET")

    def do_POST(self):
        self._route("POST")


def serve(data_dir, host: str = "127.0.0.1", port: int = 0,
          config: Optional[Dict[str, Any]] = None):
    """Build the service and a threaded HTTP server bound to ``port``
    (0 = ephemeral).  Returns ``(httpd, service)``; caller runs
    ``httpd.serve_forever()``."""
    service = TuningService(data_dir, config=config)
    handler = type("BoundHandler", (_Handler,), {"service": service})
    httpd = ThreadingHTTPServer((host, port), handler)
    httpd.daemon_threads = True
    return httpd, service


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="durable tuning service")
    ap.add_argument("--data-dir", required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--config", default=None,
                    help="JSON config file (first start only)")
    args = ap.parse_args(argv)
    config = None
    if args.config:
        with open(args.config) as fh:
            config = json.load(fh)
    httpd, service = serve(args.data_dir, args.host, args.port,
                           config=config)
    # the chaos harness parses this line to learn the bound port
    print(f"SERVING {httpd.server_address[0]} {httpd.server_address[1]} "
          f"op_seq={service.bank.op_seq}", flush=True)
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        service.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
