"""CRC-framed write-ahead log for the durable tuning service.

Every state-mutating request is journaled here *before* it is applied to
the ``StudyBank`` (journal-then-apply), so a crash between the fsync and
the in-memory mutation loses nothing: recovery replays the record and the
bank's deterministic ask/tell core reproduces the exact same state.

Frame format (little-endian)::

    +--------+--------+--------+----------------+
    | magic  | length | crc32  | payload        |
    | uint32 | uint32 | uint32 | `length` bytes |
    +--------+--------+--------+----------------+

The payload is a UTF-8 JSON object (one journal op).  ``read_records``
validates each frame in order and stops at the first bad one — a short
header, short payload, wrong magic, or CRC mismatch all mean the tail was
torn by a crash mid-write; everything before it is intact (frames are
appended with a single ``write`` + ``fsync``, so a torn frame can only be
the last one).  Recovery truncates the file back to the good prefix so
the next append extends a clean log.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

MAGIC = 0x57414C31                 # "WAL1"
_HEADER = struct.Struct("<III")    # magic, payload length, payload crc32
MAX_RECORD = 64 * 1024 * 1024      # sanity bound: a longer frame is garbage


def encode_frame(record: Dict[str, Any]) -> bytes:
    payload = json.dumps(record, separators=(",", ":"),
                         sort_keys=True).encode()
    return _HEADER.pack(MAGIC, len(payload),
                        zlib.crc32(payload) & 0xFFFFFFFF) + payload


def read_records(path) -> Tuple[List[Dict[str, Any]], int, int]:
    """Scan a WAL file; returns ``(records, good_bytes, total_bytes)``.

    ``good_bytes`` is the offset just past the last valid frame; anything
    between it and ``total_bytes`` is a torn tail (or corruption) and must
    be truncated before the log is appended to again.  A missing file is
    an empty log.
    """
    if not os.path.exists(path):
        return [], 0, 0
    with open(path, "rb") as fh:
        buf = fh.read()
    records: List[Dict[str, Any]] = []
    off = 0
    total = len(buf)
    while off + _HEADER.size <= total:
        magic, length, crc = _HEADER.unpack_from(buf, off)
        if magic != MAGIC or length > MAX_RECORD:
            break
        start = off + _HEADER.size
        end = start + length
        if end > total:
            break                              # torn mid-payload
        payload = buf[start:end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break                              # bit rot / torn rewrite
        try:
            records.append(json.loads(payload.decode()))
        except (UnicodeDecodeError, json.JSONDecodeError):
            break
        off = end
    return records, off, total


def truncate_to(path, good_bytes: int) -> None:
    """Cut a torn tail off the log (crash recovery's first step)."""
    with open(path, "r+b") as fh:
        fh.truncate(good_bytes)
        fh.flush()
        os.fsync(fh.fileno())


class WriteAheadLog:
    """Append-only fsync'd journal.  One ``append`` = one durable frame.

    ``append``'s ``mid_hook`` exists for the chaos harness: it is invoked
    after the first half of the frame has been written *and flushed* but
    before the rest, so a SIGKILL inside the hook leaves a genuine torn
    frame on disk at a deterministic point.
    """

    def __init__(self, path):
        self.path = path
        self._fh = open(path, "ab")

    def append(self, record: Dict[str, Any],
               mid_hook: Optional[Callable[[], None]] = None) -> None:
        frame = encode_frame(record)
        if mid_hook is not None:
            half = max(1, len(frame) // 2)
            self._fh.write(frame[:half])
            self._fh.flush()
            os.fsync(self._fh.fileno())
            mid_hook()
            self._fh.write(frame[half:])
        else:
            self._fh.write(frame)
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def reset(self) -> None:
        """Truncate the log to empty (after a snapshot made it redundant).
        Not atomic with the snapshot write — it doesn't need to be: every
        journal op carries a monotonic ``seq`` and the snapshot stores the
        last applied one, so replay skips records the snapshot already
        contains if the crash lands between the two steps."""
        self._fh.truncate(0)
        self._fh.seek(0)
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        try:
            self._fh.close()
        except OSError:
            pass


def atomic_write_text(path, text: str) -> None:
    """Durable, atomic file publish: write-tmp -> flush -> fsync ->
    os.replace.  A crash at any byte leaves either the old file or the
    new one, never a torn hybrid — the config/snapshot counterpart of the
    WAL's own fsync'd append discipline (REPRO-W302)."""
    p = str(path)
    tmp = p + ".tmp"
    with open(tmp, "w") as fh:
        fh.write(text)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, p)
