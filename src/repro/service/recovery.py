"""Crash recovery: latest snapshot + WAL suffix replay.

The recovery contract (proved end-to-end by the chaos harness in
``repro.service.chaos``):

  * the snapshot (``StudyBank.save``'s atomic ``.npz``) stores ``op_seq``,
    the sequence number of the last journal op it contains;
  * the WAL holds every op since *some* earlier point — possibly
    overlapping the snapshot (compaction truncates the log *after* the
    snapshot replace, so a crash between the two leaves both);
  * replay truncates the torn tail, then applies every record with
    ``seq > op_seq`` in order.  Asks re-execute ``view.ask(n)`` against
    bit-identical RNG/GP state, so they mint the *same* trial ids and
    configurations the pre-crash service handed out; tells go through the
    idempotent ``tell_once`` path, so an at-least-once journal can't
    double-apply an observation.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List

from repro.service.wal import read_records, truncate_to

SNAPSHOT = "snapshot.npz"
WAL_FILE = "wal.log"
CONFIG = "service.json"


@dataclasses.dataclass
class RecoveryReport:
    snapshot_loaded: bool = False
    snapshot_iteration: int = 0
    wal_records: int = 0          # valid frames found in the log
    replayed: int = 0             # applied (seq > snapshot op_seq)
    skipped: int = 0              # already contained in the snapshot
    truncated_bytes: int = 0      # torn tail cut off the log
    poisoned: int = 0             # consumed their seq but failed to apply


def recover(data_dir, bank,
            apply_record: Callable[[Dict[str, Any]], Any],
            on_snapshot: Callable[[], None] = None) -> RecoveryReport:
    """Restore ``bank`` (and the caller's side tables, via
    ``apply_record``) from ``data_dir``.  ``apply_record`` must route each
    journal op through ``bank.apply_op`` — the service passes its own
    wrapper so name tables and ask-dedup caches are rebuilt by the same
    code path that maintains them live.  ``on_snapshot`` fires after the
    snapshot load (before replay) so the caller can restore side tables
    from ``bank.extra`` first."""
    rep = RecoveryReport()
    snap = os.path.join(data_dir, SNAPSHOT)
    if os.path.exists(snap):
        rep.snapshot_iteration = bank.load(snap)
        rep.snapshot_loaded = True
        if on_snapshot is not None:
            on_snapshot()
    wal_path = os.path.join(data_dir, WAL_FILE)
    records, good, total = read_records(wal_path)
    rep.wal_records = len(records)
    if good < total:
        rep.truncated_bytes = total - good
        truncate_to(wal_path, good)
    for rec in records:
        if int(rec["seq"]) <= bank.op_seq:
            rep.skipped += 1
            continue
        prev = bank.op_seq
        try:
            apply_record(rec)
        except Exception:
            # ops are validated before journaling, so this is defense in
            # depth.  apply_op consumes the seq even when the apply raises;
            # if op_seq advanced, the record is a poison frame — live
            # serving skipped it the same way, so skipping here preserves
            # bit-exact replay.  op_seq NOT advancing means a structural
            # journal error (seq gap/reorder): abort rather than silently
            # drop the whole suffix.
            if bank.op_seq == prev:
                raise
            rep.poisoned += 1
        else:
            rep.replayed += 1
    return rep


def wal_suffix(data_dir) -> List[Dict[str, Any]]:
    """The valid records currently in the log (diagnostics / tests)."""
    return read_records(os.path.join(data_dir, WAL_FILE))[0]
