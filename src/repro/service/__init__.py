"""Durable ask/tell tuning service: WAL + exact-replay crash recovery.

See ``repro.service.server`` for the write path (journal-then-apply),
``repro.service.recovery`` for the restart path (snapshot + WAL suffix
replay), ``repro.service.client`` for the driver-facing client, and
``repro.service.chaos`` for the SIGKILL harness that proves the
bit-equal recovery contract.
"""
from repro.service.client import (RemoteOptimizer, RemoteTrial,
                                  ServiceClient, ServiceDown)
from repro.service.recovery import RecoveryReport, recover
from repro.service.server import (CrashPoints, ServiceError, TuningService,
                                  serve, space_from_spec)
from repro.service.wal import WriteAheadLog, read_records, truncate_to

__all__ = [
    "RemoteOptimizer", "RemoteTrial", "ServiceClient", "ServiceDown",
    "RecoveryReport", "recover", "CrashPoints", "ServiceError",
    "TuningService", "serve", "space_from_spec", "WriteAheadLog",
    "read_records", "truncate_to",
]
