"""CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 = clean (every finding suppressed or baselined),
1 = unbaselined findings (or unparsable files), 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.baseline import Baseline
from repro.analysis.lint import lint_paths
from repro.analysis.rules import all_rules


def _list_rules() -> str:
    lines = []
    for r in all_rules():
        lines.append(f"{r.id}  [{r.family}]  scopes={','.join(r.scopes)}")
        lines.append(f"    {r.description}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: repo-specific static analysis")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files/directories to lint (default: src/)")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="justified-findings baseline file; matching "
                         "findings are suppressed")
    ap.add_argument("--write-baseline", metavar="PATH", default=None,
                    help="write every current finding as a baseline "
                         "entry (note=TODO) and exit 0")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0
    paths = args.paths or ["src/"]
    baseline = None
    if args.baseline:
        try:
            baseline = Baseline.load(args.baseline)
        except FileNotFoundError:
            print(f"baseline {args.baseline} not found", file=sys.stderr)
            return 2
        except ValueError as e:
            print(str(e), file=sys.stderr)
            return 2
    res = lint_paths(paths, baseline=baseline)

    if args.write_baseline:
        Baseline.from_findings(res.findings).save(args.write_baseline)
        print(f"wrote {len(res.findings)} finding(s) as baseline entries "
              f"to {args.write_baseline} — justify each note before "
              "committing")
        return 0

    if args.format == "json":
        print(json.dumps({
            "unbaselined": [vars(f) for f in res.unbaselined],
            "baselined": [vars(f) for f in res.baselined],
            "stale_baseline_entries": res.stale,
            "errors": res.errors,
        }, indent=1))
    else:
        for f in res.unbaselined:
            print(f.format())
        for e in res.errors:
            print(f"error: {e}", file=sys.stderr)
        for e in res.stale:
            print(f"warning: stale baseline entry (nothing matches): "
                  f"{e['rule']} {e['path']} {e['content']!r}",
                  file=sys.stderr)
        print(f"repro-lint: {len(res.unbaselined)} finding(s), "
              f"{len(res.baselined)} baselined, {len(res.stale)} stale "
              f"baseline entr{'y' if len(res.stale) == 1 else 'ies'}")
    return 1 if (res.unbaselined or res.errors) else 0


if __name__ == "__main__":
    sys.exit(main())
