"""Runtime sanitizers: enforce device-hygiene invariants while code runs.

Three guards, each wrapping an invariant that is CI-gated elsewhere in
this repo:

  * ``no_retrace()`` — generalizes the jit-cache-delta audit that
    ``benchmarks/multi_study.py`` pioneered for the PR 6 zero-retrace
    contract into a reusable context manager over any mapping of named
    jitted entry points.
  * ``no_transfer()`` — wraps ``jax.transfer_guard_*`` for steady-state
    ask paths.  By default only *implicit device->host* transfers are
    disallowed: those are the hidden syncs (``.item()``, ``float()``,
    ``np.asarray`` on a device value) that stall the dispatch pipeline,
    while the candidate upload each ask is a designed host->device
    transfer (4 per ask, measured in PR 4).  Explicit
    ``jax.device_get()`` stays allowed — it marks the one deliberate
    exit point.
  * ``assert_holds(lock)`` — debug-mode lock-ownership assertion for
    caller-must-hold functions (the PR 3/7 bug class).  Free when
    disabled; enable with ``REPRO_DEBUG_LOCKS=1`` or ``set_debug_locks``.
"""
from __future__ import annotations

import contextlib
import os
import threading
from typing import Dict, Mapping, Optional


class RetraceError(AssertionError):
    """A jitted entry point compiled more often than its budget."""


class RetraceReport:
    """Mutable report yielded by ``no_retrace``.

    ``expected`` maps entry-point name -> compiles the audited region is
    *allowed* (default 0 for every name: pure steady state).  Callers
    that legitimately cross shape buckets (the multi-study growth sweep)
    fill it in before the block exits.  After exit, ``deltas`` holds the
    per-entry-point new-cache-entry counts and ``violations`` the summed
    excess ``max(0, delta - expected)``.
    """

    def __init__(self, jits: Mapping[str, object],
                 expected: Optional[Mapping[str, int]] = None):
        self.jits = dict(jits)
        self.expected: Dict[str, int] = dict(expected or {})
        self.base: Dict[str, int] = {}
        self.deltas: Dict[str, int] = {}
        self.violations: int = 0
        self._finished = False

    def _snapshot(self) -> Dict[str, int]:
        return {name: int(f._cache_size())
                for name, f in self.jits.items()}

    def finish(self) -> None:
        now = self._snapshot()
        self.deltas = {k: now[k] - self.base[k] for k in self.jits}
        self.violations = sum(
            max(0, self.deltas[k] - int(self.expected.get(k, 0)))
            for k in self.jits)
        self._finished = True

    def detail(self) -> str:
        """`name=delta/expected` for every mismatching entry point."""
        return ",".join(
            f"{k}={self.deltas[k]}/{int(self.expected.get(k, 0))}"
            for k in sorted(self.jits)
            if self.deltas.get(k, 0) != int(self.expected.get(k, 0)))


@contextlib.contextmanager
def no_retrace(jits: Optional[Mapping[str, object]] = None,
               expected: Optional[Mapping[str, int]] = None,
               raise_on_violation: bool = True):
    """Audit the jit caches of ``jits`` (name -> jitted callable) across
    the block: every entry point may add at most ``expected[name]``
    (default 0) cache entries, i.e. compile at most that many times.

    ``jits=None`` audits the bank serving pipeline (``gp.BANK_JITS``) —
    the PR 6 zero-retrace contract.  Yields a ``RetraceReport``; with
    ``raise_on_violation=False`` the caller inspects
    ``report.violations`` itself (the benchmark gate turns it into a
    nonzero exit code instead of a traceback).
    """
    if jits is None:
        from repro.core import gp as gp_lib
        jits = gp_lib.BANK_JITS
    rep = RetraceReport(jits, expected)
    rep.base = rep._snapshot()
    try:
        yield rep
    finally:
        rep.finish()
    if raise_on_violation and rep.violations:
        raise RetraceError(
            f"{rep.violations} unexpected jit compile(s) in audited "
            f"region: {rep.detail()} (name=new_entries/expected) — a "
            "retrace leaked into the steady state")


@contextlib.contextmanager
def no_transfer(device_to_host: Optional[str] = "disallow",
                host_to_device: Optional[str] = None,
                device_to_device: Optional[str] = None):
    """Transfer-guard the block.  Levels per direction: None (leave
    unchanged), "allow", "log", "disallow", "log_explicit",
    "disallow_explicit" — see ``jax.transfer_guard``.

    The default guards only implicit device->host transfers: that is the
    hidden-sync class (REPRO-J101) the fused ask paths must never pay,
    while candidate uploads are designed host->device traffic and
    ``jax.device_get`` remains the sanctioned exit.  Pass
    ``host_to_device="disallow"`` too when auditing a fully
    device-resident region.

    Backend caveat: on the CPU backend device buffers live in host
    memory, so device->host reads are zero-copy and the d2h guard can
    never fire — it becomes load-bearing on accelerator backends.  The
    host->device direction enforces on every backend (the sanitizer
    tests pin the implicit-raises / explicit-allowed split there).
    """
    import jax
    with contextlib.ExitStack() as stack:
        if device_to_host is not None:
            stack.enter_context(
                jax.transfer_guard_device_to_host(device_to_host))
        if host_to_device is not None:
            stack.enter_context(
                jax.transfer_guard_host_to_device(host_to_device))
        if device_to_device is not None:
            stack.enter_context(
                jax.transfer_guard_device_to_device(device_to_device))
        yield


# --------------------------------------------------------------------- locks
_DEBUG_LOCKS = os.environ.get("REPRO_DEBUG_LOCKS", "") not in ("", "0")


def set_debug_locks(enabled: bool) -> bool:
    """Toggle ``assert_holds`` enforcement; returns the previous value."""
    global _DEBUG_LOCKS
    prev, _DEBUG_LOCKS = _DEBUG_LOCKS, bool(enabled)
    return prev


def debug_locks_enabled() -> bool:
    return _DEBUG_LOCKS


def assert_holds(lock) -> None:
    """Assert the calling thread holds ``lock``.

    A no-op unless debug mode is on (``REPRO_DEBUG_LOCKS=1`` or
    ``set_debug_locks(True)``), so caller-must-hold contracts — the
    commit path of the service, the drain predicates of the schedulers —
    can declare themselves at zero steady-state cost.  RLock/Condition
    check true ownership (``_is_owned``); a plain ``threading.Lock``
    has no owner, so only held-by-someone (``locked()``) is checkable.
    The lint rule REPRO-C201 treats a declared ``assert_holds(self.X)``
    as lock-held evidence for the whole function.
    """
    if not _DEBUG_LOCKS:
        return
    owned = getattr(lock, "_is_owned", None)
    if owned is not None:
        if not owned():
            raise AssertionError(
                f"assert_holds: {lock!r} is not held by "
                f"{threading.current_thread().name}")
        return
    locked = getattr(lock, "locked", None)
    if locked is not None and not locked():
        raise AssertionError(
            f"assert_holds: {lock!r} is not held (plain Lock: ownership "
            "is unverifiable, only held-by-someone)")
