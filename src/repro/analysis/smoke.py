"""Sanitizer smoke: steady-state bank serving under both runtime guards.

``python -m repro.analysis.smoke`` warms a small StudyBank into its
shape bucket, then drives ask/tell rounds with

  * ``no_transfer()`` — any implicit device->host read raises, and
  * ``no_retrace()`` — any jit compile of a ``gp.BANK_JITS`` entry point
    raises,

so the CI smoke job proves the PR 4/6 steady-state contract (zero hidden
syncs, zero retraces per warm ask) end to end, not just via unit tests.
Exit 0 prints PASS; any violation raises and exits nonzero.
"""
from __future__ import annotations

import sys


def run(n_studies: int = 4, warm_rounds: int = 3, rounds: int = 6,
        verbose: bool = True) -> int:
    from scipy import stats

    from repro.analysis.sanitizers import no_retrace, no_transfer
    from repro.core import StudyBank

    space = {"x": stats.uniform(0, 1), "y": stats.uniform(-1, 2)}
    bank = StudyBank(space, n_studies, optimizer="bayesian", seed=0,
                     mc_samples=32)

    def objective(p):
        return -(p["x"] - 0.3) ** 2 - (p["y"] - 0.5) ** 2

    def drive(n_rounds):
        for _ in range(n_rounds):
            for b, ts in enumerate(bank.ask_all(1)):
                for t in ts:
                    bank.tell(b, t.id, objective(t.params))

    # warmup: the GP pipeline first dispatches once a study has >= 2
    # observations (round 3), compiling the bucket's programs and running
    # the first hyper fit
    drive(warm_rounds)
    # audited steady state: stay inside the na=16 bucket (observations
    # stay well under 16 - pend_cap - n), so not a single compile — and
    # not one implicit device->host transfer — is allowed
    with no_transfer(), no_retrace():
        drive(rounds)
    if verbose:
        print(f"sanitizer smoke PASS: {rounds} steady-state ask_all "
              f"rounds x {n_studies} studies under no_transfer() + "
              "no_retrace()")
    return 0


if __name__ == "__main__":
    sys.exit(run())
