"""Justified-findings baseline for repro-lint.

A baseline entry acknowledges ONE deliberate violation with a one-line
justification, e.g. the tuner's user-facing wall-clock result timing
(REPRO-D001 is about deadlines, not reporting).  Entries match findings
by ``(rule, path, stripped source line)`` — never by line *number* — so
unrelated edits above a justified line can't invalidate the baseline,
while editing the offending line itself (the thing the justification was
written about) correctly turns the entry stale and the finding live.

File format (``.repro-lint-baseline`` at the repo root): JSON,
hand-editable, stable key order::

    {"version": 1,
     "entries": [{"rule": "REPRO-D001",
                  "path": "src/repro/core/tuner.py",
                  "content": "t0 = time.time()",
                  "note": "user-facing wall-clock result timing"}]}

Workflow: ``python -m repro.analysis src/ --write-baseline PATH`` emits
entries (note = TODO) for every current finding; justify each, commit
the file, and the CI lint job passes while any NEW finding still fails.
Stale entries (matching nothing) are reported as warnings so dead
justifications get pruned, but never fail the run.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.analysis.lint import Finding

VERSION = 1


class Baseline:
    def __init__(self, entries: Optional[List[Dict[str, Any]]] = None):
        self.entries: List[Dict[str, Any]] = entries or []

    # ------------------------------------------------------------- io
    @classmethod
    def load(cls, path) -> "Baseline":
        data = json.loads(Path(path).read_text())
        if data.get("version") != VERSION:
            raise ValueError(
                f"baseline {path}: unsupported version "
                f"{data.get('version')!r} (expected {VERSION})")
        entries = data.get("entries", [])
        for e in entries:
            for key in ("rule", "path", "content"):
                if key not in e:
                    raise ValueError(
                        f"baseline {path}: entry missing {key!r}: {e}")
        return cls(entries)

    def save(self, path) -> None:
        data = {"version": VERSION, "entries": self.entries}
        Path(path).write_text(json.dumps(data, indent=1) + "\n")

    # ------------------------------------------------------- matching
    @staticmethod
    def _same_file(entry_path: str, finding_path: str) -> bool:
        """Suffix-tolerant path equality: the committed baseline stores
        repo-relative paths (``src/repro/...``) but the engine may be
        handed absolute paths (tests, editors) — same file either way."""
        if entry_path == finding_path:
            return True
        return (finding_path.endswith("/" + entry_path)
                or entry_path.endswith("/" + finding_path))

    def match(self, f: Finding) -> Optional[int]:
        """Index of the first entry covering ``f``, or None.  An entry
        covers any number of identical offending lines in its file (a
        pattern duplicated in two branches needs one justification)."""
        for i, e in enumerate(self.entries):
            if (e["rule"] == f.rule and self._same_file(e["path"], f.path)
                    and e["content"] == f.content):
                return i
        return None

    @classmethod
    def from_findings(cls, findings, note: str = "TODO: justify"
                      ) -> "Baseline":
        seen = set()
        entries = []
        for f in findings:
            key = (f.rule, f.path, f.content)
            if key in seen:
                continue
            seen.add(key)
            entries.append({"rule": f.rule, "path": f.path,
                            "content": f.content, "note": note})
        return cls(entries)
