"""repro.analysis: repo-specific static analysis + runtime sanitizers.

  * ``python -m repro.analysis src/ --baseline .repro-lint-baseline`` —
    the blocking CI lint gate (stdlib-only, no jax import).
  * ``repro.analysis.sanitizers`` — ``no_retrace``/``no_transfer``/
    ``assert_holds`` runtime guards (imported lazily; they need jax).

See ``docs/analysis.md`` for the rule catalog and workflow.
"""
from repro.analysis.baseline import Baseline
from repro.analysis.lint import Finding, LintResult, Module, Rule, lint_paths

__all__ = ["Baseline", "Finding", "LintResult", "Module", "Rule",
           "lint_paths"]
