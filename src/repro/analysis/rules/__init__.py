"""repro-lint rule plugins.

Each submodule holds one rule *family*; a rule registers itself with the
``@register`` decorator.  ``all_rules()`` imports every family module
and returns one instance per registered rule class — the engine, the
CLI, and the meta-test ("every shipped rule has a firing bad fixture")
all enumerate rules through it, so a rule that isn't registered simply
does not exist.
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Type

from repro.analysis.lint import Rule

_FAMILY_MODULES = ("determinism", "device", "concurrency", "durability")
_REGISTRY: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {cls.id}")
    _REGISTRY[cls.id] = cls
    return cls


def _load() -> None:
    for name in _FAMILY_MODULES:
        importlib.import_module(f"{__name__}.{name}")


def all_rules() -> List[Rule]:
    _load()
    return [cls() for _, cls in sorted(_REGISTRY.items())]


def rule_ids() -> List[str]:
    _load()
    return sorted(_REGISTRY)
