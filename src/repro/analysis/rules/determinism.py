"""Determinism rules: wall clocks and entropy where replay must be pure.

The durable service's whole recovery contract (PR 7) is that replaying
the WAL reproduces proposals bit-identically; the scheduler fault
semantics (PR 3) depend on deadlines that NTP steps can't stretch.  Both
die quietly to a stray ``time.time()`` or an OS-entropy RNG.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import Finding, Module, Rule, call_name
from repro.analysis.rules import register

# np.random module-level (global-state) draws — every one bypasses the
# seed plumbing that makes kill->resume replay exact
_GLOBAL_NP_RANDOM = {
    "rand", "randn", "randint", "random", "random_sample", "uniform",
    "normal", "choice", "shuffle", "permutation", "seed",
}
_GLOBAL_STDLIB_RANDOM = {
    "random", "randint", "uniform", "choice", "shuffle", "seed", "gauss",
    "normalvariate", "randrange", "sample",
}


def _imported_bare_time(mod: Module) -> bool:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            if any(a.name == "time" for a in node.names):
                return True
    return False


@register
class WallClockRule(Rule):
    id = "REPRO-D001"
    family = "determinism"
    scopes = ("core", "scheduler", "service")
    description = ("time.time() in core/scheduler/service — deadlines, "
                   "retries and replayable state must use "
                   "time.monotonic()")
    rationale = ("PR 3 fixed deadline arithmetic that an NTP wall-clock "
                 "step could stretch or collapse; PR 7's WAL replay must "
                 "be a pure function of the journal.  Wall clocks belong "
                 "only in user-facing reporting — baseline those.")

    def check(self, mod: Module) -> Iterable[Finding]:
        bare = _imported_bare_time(mod)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            hit = (name == "time.time"
                   or (bare and name == "time")
                   or name in ("datetime.now", "datetime.datetime.now",
                               "datetime.utcnow",
                               "datetime.datetime.utcnow"))
            if hit:
                yield self.finding(
                    mod, node,
                    "wall-clock read — use time.monotonic() for "
                    "durations/deadlines (NTP steps corrupt wall-clock "
                    "arithmetic); baseline only user-facing timing")


@register
class UnseededRngRule(Rule):
    id = "REPRO-D002"
    family = "determinism"
    scopes = ("core", "scheduler", "service")
    description = ("unseeded RNG construction / global-state random draws "
                   "outside explicit seed plumbing")
    rationale = ("Kill->resume replays bit-identical proposals only "
                 "because every RNG stream is seeded and serialized "
                 "(PR 2/6/7).  An OS-entropy generator or a global "
                 "np.random/random draw silently breaks that contract.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if (name in ("np.random.default_rng",
                         "numpy.random.default_rng",
                         "random.Random")
                    and not node.args and not node.keywords):
                yield self.finding(
                    mod, node,
                    f"unseeded {name}() draws OS entropy — construct from "
                    "an explicit seed (or restore a serialized state via "
                    "a seeded placeholder)")
            elif name.startswith(("np.random.", "numpy.random.")):
                leaf = name.rsplit(".", 1)[1]
                if leaf in _GLOBAL_NP_RANDOM:
                    yield self.finding(
                        mod, node,
                        f"global-state {name}() — thread a seeded "
                        "np.random.Generator through instead")
            elif name.startswith("random.") and name.count(".") == 1:
                leaf = name.rsplit(".", 1)[1]
                if leaf in _GLOBAL_STDLIB_RANDOM:
                    yield self.finding(
                        mod, node,
                        f"global-state {name}() — use a per-purpose "
                        "seeded random.Random(seed)")


# function-name fragments that mark a journaled / replayed mutation path:
# everything reachable from WAL replay must be a pure function of the
# journal record + prior state
_REPLAY_MARKERS = ("apply_op", "apply_record", "_apply", "replay",
                   "recover", "_commit")

_IMPURE_CALLS = ("time.time", "datetime.now", "datetime.datetime.now",
                 "np.random.default_rng", "numpy.random.default_rng",
                 "random.Random")


@register
class ReplayPurityRule(Rule):
    id = "REPRO-D003"
    family = "determinism"
    scopes = ("service", "studybank.py")
    description = ("wall-clock or RNG reads inside journaled/replayed "
                   "mutation paths")
    rationale = ("Recovery = snapshot + WAL suffix replay (PR 7).  A "
                 "clock or entropy read inside apply/replay/commit code "
                 "makes the replayed state diverge from the live state "
                 "it must reproduce bit-identically.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not any(m in fn.name for m in _REPLAY_MARKERS):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                impure = (name in _IMPURE_CALLS
                          or (name.startswith(("np.random.",
                                               "numpy.random."))
                              and name.rsplit(".", 1)[1]
                              in _GLOBAL_NP_RANDOM)
                          or (name.startswith("random.")
                              and name.count(".") == 1
                              and name.rsplit(".", 1)[1]
                              in _GLOBAL_STDLIB_RANDOM))
                if impure:
                    yield self.finding(
                        mod, node,
                        f"{name}() inside replayed mutation path "
                        f"{fn.name}() — replay must be a pure function "
                        "of the WAL record and prior state")
