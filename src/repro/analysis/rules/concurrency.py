"""Concurrency rules: lock-guard inference, thread hygiene, silent drops.

PRs 3 and 7 both fixed, by hand, the same class of bug: an attribute
protected by a lock in one method and mutated bare in another
(scheduler stats, adapter outstanding counts, drain flags).  The
lock-guard rule infers the protected set from the code itself, so the
*next* unguarded mutation is a lint finding, not a flaky race.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.lint import (Finding, Module, Rule, call_name,
                                 dotted_name, terminal_name)
from repro.analysis.rules import register

_LOCKISH = re.compile(r"(lock|mutex|cv|cond)", re.IGNORECASE)

# self.<attr>.<method>(...) calls that mutate the attr in place
_MUTATING_METHODS = {
    "append", "extend", "insert", "add", "remove", "discard", "pop",
    "popitem", "clear", "update", "setdefault",
}


def _with_lock_attr(item: ast.withitem) -> Optional[str]:
    """``with self._lock:`` / ``with self._cv:`` -> the attr name."""
    expr = item.context_expr
    if (isinstance(expr, ast.Attribute)
            and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"
            and _LOCKISH.search(expr.attr)):
        return expr.attr
    return None


def _self_attr_of_target(t) -> Optional[str]:
    """The ``X`` of a mutation targeting ``self.X``, ``self.X[...]`` or
    ``self.X.Y``."""
    while isinstance(t, (ast.Subscript, ast.Attribute)):
        if (isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name)
                and t.value.id == "self"):
            return t.attr
        t = t.value
    return None


def _mutations(node) -> List[Tuple[str, ast.AST]]:
    """(attr, node) for every ``self.X`` mutation in ``node``'s subtree."""
    out: List[Tuple[str, ast.AST]] = []
    for n in ast.walk(node):
        if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (n.targets if isinstance(n, ast.Assign)
                       else [n.target])
            for t in targets:
                for el in ([t] if not isinstance(t, (ast.Tuple, ast.List))
                           else t.elts):
                    attr = _self_attr_of_target(el)
                    if attr is not None:
                        out.append((attr, n))
        elif isinstance(n, ast.Call):
            f = n.func
            if (isinstance(f, ast.Attribute)
                    and f.attr in _MUTATING_METHODS):
                attr = _self_attr_of_target(f.value)
                if attr is not None:
                    out.append((attr, n))
    return out


@register
class LockGuardRule(Rule):
    id = "REPRO-C201"
    family = "concurrency"
    scopes = ("scheduler", "service", "core")
    description = ("attribute mutated under `with self.<lock>` in one "
                   "method must be lock-held at every other mutation "
                   "site in the class")
    rationale = ("Exactly the bug class fixed by hand in PR 3 (scheduler "
                 "stats, submit-after-shutdown) and PR 7 (drain/submit "
                 "races): one bare mutation off the lock loses updates "
                 "under thread races.  `sanitizers.assert_holds(self.X)` "
                 "at the top of a caller-must-hold function counts as "
                 "held.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            guarded: Dict[str, Set[str]] = {}   # attr -> {locks seen}
            # pass 1: attrs mutated under a with-self-lock block
            for w in ast.walk(cls):
                if not isinstance(w, ast.With):
                    continue
                locks = [a for a in map(_with_lock_attr, w.items)
                         if a is not None]
                if not locks:
                    continue
                for attr, _ in _mutations(w):
                    guarded.setdefault(attr, set()).update(locks)
            if not guarded:
                continue
            # pass 2: mutations of guarded attrs outside any such block
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if meth.name == "__init__":
                    continue   # construction is single-threaded
                asserted = self._asserted_locks(meth)
                for attr, node in _mutations(meth):
                    if attr not in guarded:
                        continue
                    if guarded[attr] & asserted:
                        continue   # assert_holds() declares the contract
                    if self._under_lock(mod, node, guarded[attr]):
                        continue
                    locks = "/".join(sorted(guarded[attr]))
                    yield self.finding(
                        mod, node,
                        f"self.{attr} is mutated under self.{locks} "
                        f"elsewhere in {cls.name} but not here — hold "
                        "the lock or declare the contract with "
                        f"assert_holds(self.{sorted(guarded[attr])[0]})")

    @staticmethod
    def _asserted_locks(meth) -> Set[str]:
        out: Set[str] = set()
        for n in ast.walk(meth):
            if (isinstance(n, ast.Call)
                    and terminal_name(n) == "assert_holds" and n.args):
                a = n.args[0]
                if (isinstance(a, ast.Attribute)
                        and isinstance(a.value, ast.Name)
                        and a.value.id == "self"):
                    out.add(a.attr)
        return out

    @staticmethod
    def _under_lock(mod: Module, node: ast.AST, locks: Set[str]) -> bool:
        cur = mod.parents.get(node)
        while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if isinstance(cur, ast.With):
                held = {a for a in map(_with_lock_attr, cur.items)
                        if a is not None}
                if held & locks:
                    return True
            cur = mod.parents.get(cur)
        return False


@register
class DaemonThreadRule(Rule):
    id = "REPRO-C202"
    family = "concurrency"
    scopes = ("scheduler", "service", "train")
    description = ("threading.Thread without daemon=True in scheduler/"
                   "service code")
    rationale = ("PR 3: a non-daemon worker abandoned past its deadline "
                 "blocks interpreter exit for as long as the straggler "
                 "runs.  Every fan-out thread here must be a daemon; "
                 "threads that must complete should be joined "
                 "explicitly, not left to interpreter shutdown.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if call_name(node) not in ("threading.Thread", "Thread"):
                continue
            daemon = next((kw for kw in node.keywords
                           if kw.arg == "daemon"), None)
            ok = (daemon is not None
                  and isinstance(daemon.value, ast.Constant)
                  and daemon.value.value is True)
            if not ok:
                yield self.finding(
                    mod, node,
                    "threading.Thread without daemon=True — a straggler "
                    "on this thread blocks interpreter exit (PR 3 "
                    "deadline-cancel contract)")


@register
class SilentExceptRule(Rule):
    id = "REPRO-C203"
    family = "concurrency"
    scopes = ("core", "scheduler", "service")
    description = ("`except Exception` that swallows without re-raise, "
                   "log, counter, or fallback assignment")
    rationale = ("Dropped-trial semantics are deliberate (the paper's "
                 "partial-result contract), but an *invisible* drop is "
                 "undiagnosable in production.  Every broad handler "
                 "must leave a trace: re-raise, log, bump a counter, or "
                 "assign a fallback.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not self._broad(node):
                continue
            if self._has_evidence(node):
                continue
            yield self.finding(
                mod, node,
                "broad except swallows silently — re-raise, log the "
                "drop, bump a stats counter, or assign a fallback")

    @staticmethod
    def _broad(h: ast.ExceptHandler) -> bool:
        if h.type is None:
            return True
        names = []
        if isinstance(h.type, ast.Tuple):
            names = [dotted_name(e) for e in h.type.elts]
        else:
            names = [dotted_name(h.type)]
        return any(n in ("Exception", "BaseException") for n in names)

    @staticmethod
    def _has_evidence(h: ast.ExceptHandler) -> bool:
        bound = h.name
        for n in ast.walk(h):
            if isinstance(n, ast.Raise):
                return True
            if (bound and isinstance(n, ast.Name) and n.id == bound
                    and isinstance(n.ctx, ast.Load)):
                return True
            if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                return True
            if isinstance(n, ast.Call):
                name = call_name(n).lower()
                if any(tok in name for tok in ("log", "warn", "print",
                                               "bump", "count", "record",
                                               "stat")):
                    return True
            if isinstance(n, ast.Return) and n.value is not None:
                return True
        return False
