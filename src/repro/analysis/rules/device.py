"""Device-hygiene rules: hidden syncs, retrace hazards, jit closures.

The fused proposal paths (PR 1/3/4/6) are one device program per ask;
their perf claims are CI-gated.  A stray ``.item()`` or ``np.asarray``
on a JAX value is a hidden blocking device->host sync; a ``jnp`` call
under an eager Python loop is a per-iteration dispatch (and a retrace
hazard when shapes vary); a jitted entry point closing over mutable
Python state silently bakes a stale value into the compiled program.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set

from repro.analysis.lint import (Finding, Module, Rule, call_name,
                                 terminal_name)
from repro.analysis.rules import register

# fused-path files: where device values flow and host syncs hide
_DEVICE_FILES = ("gp.py", "acquisition.py", "tpe.py", "scoring.py",
                 "studybank.py", "kmeans.py", "kernels")

# call-name shapes that produce device (JAX) values in this repo
_DEVICE_TERMINAL_PREFIXES = ("bank_", "fused_", "fit_hypers", "_dispatch")
_HOST_TERMINALS = {"device_get"}        # jax.device_get returns numpy


def _is_device_call(call: ast.Call) -> bool:
    name = call_name(call)
    root = name.split(".", 1)[0]
    term = terminal_name(call)
    if term in _HOST_TERMINALS:
        return False
    if root in ("jnp", "jax", "lax"):
        return True
    return any(term.startswith(p) for p in _DEVICE_TERMINAL_PREFIXES)


def _assign_targets(node) -> List[str]:
    out: List[str] = []

    def collect(t):
        if isinstance(t, ast.Name):
            out.append(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                collect(e)

    if isinstance(node, ast.Assign):
        for t in node.targets:
            collect(t)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        collect(node.target)
    return out


def _walk_scope(scope: ast.AST, module_level: bool):
    """Walk ``scope`` without descending into *other* function bodies:
    taint is per innermost function, so a name assigned from a device
    call in one function can't flag an unrelated same-named host value
    elsewhere in the module."""
    stack = [scope]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if (isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and (module_level or child is not scope)):
                continue
            stack.append(child)


def _device_names(scope: ast.AST, module_level: bool = False) -> Set[str]:
    """Names in ``scope`` assigned (directly or via tuple unpack) from a
    device-producing call.  Two passes so a name defined later in source
    order still taints earlier textual uses in loops."""
    tainted: Set[str] = set()
    for _ in range(2):
        for node in _walk_scope(scope, module_level):
            if not isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)):
                continue
            value = node.value
            if value is None:
                continue
            if isinstance(value, ast.Call) and (
                    call_name(value) in ("float", "int", "bool", "len")
                    or terminal_name(value) in ("device_get", "item",
                                                "tolist")):
                # host extraction: the result is a Python/numpy host
                # value, so the assignment *clears* any earlier taint
                for t in _assign_targets(node):
                    tainted.discard(t)
                continue
            feeds = any(
                (isinstance(n, ast.Call) and _is_device_call(n))
                or (isinstance(n, ast.Name) and n.id in tainted
                    and isinstance(n.ctx, ast.Load))
                for n in ast.walk(value))
            if feeds:
                tainted.update(_assign_targets(node))
    return tainted


def _jit_decorated(fn) -> bool:
    for dec in fn.decorator_list:
        name = dotted = ""
        if isinstance(dec, ast.Call):
            dotted = call_name(dec)
            if dotted in ("functools.partial", "partial") and dec.args:
                first = dec.args[0]
                name = (call_name(first) if isinstance(first, ast.Call)
                        else (first.attr if isinstance(first, ast.Attribute)
                              else getattr(first, "id", "")))
                if isinstance(first, ast.Attribute):
                    name = f"{getattr(first.value, 'id', '')}.{first.attr}"
            else:
                name = dotted
        elif isinstance(dec, ast.Attribute):
            name = f"{getattr(dec.value, 'id', '')}.{dec.attr}"
        elif isinstance(dec, ast.Name):
            name = dec.id
        if name in ("jax.jit", "jit"):
            return True
    return False


@register
class HostSyncRule(Rule):
    id = "REPRO-J101"
    family = "device-hygiene"
    scopes = _DEVICE_FILES
    description = (".item()/float()/np.asarray on a JAX value in a fused "
                   "proposal path — each is a hidden blocking device sync")
    rationale = ("The bank serving steady state is transfer-audited "
                 "(sanitizers.no_transfer); an implicit device->host "
                 "read stalls the dispatch pipeline.  Use "
                 "jax.device_get() at the one deliberate exit point, or "
                 "keep the value on device.")

    def check(self, mod: Module) -> Iterable[Finding]:
        taint_cache: dict = {}

        def tainted_for(node: ast.AST) -> Set[str]:
            fn = mod.enclosing_function(node)
            key = fn if fn is not None else mod.tree
            if key not in taint_cache:
                taint_cache[key] = _device_names(
                    key, module_level=fn is None)
            return taint_cache[key]

        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            term = terminal_name(node)
            name = call_name(node)
            msg = None
            if term == "item" and isinstance(node.func, ast.Attribute):
                msg = (".item() forces a device sync — use "
                       "jax.device_get() at the designed exit point")
            elif name in ("np.asarray", "np.array", "numpy.asarray",
                          "numpy.array", "float") and node.args:
                arg = node.args[0]
                dev = ((isinstance(arg, ast.Name)
                        and arg.id in tainted_for(node))
                       or (isinstance(arg, ast.Call)
                           and _is_device_call(arg)))
                np_call = (name == "float"
                           and isinstance(arg, ast.Call)
                           and call_name(arg).split(".", 1)[0]
                           in ("np", "numpy", "jnp"))
                if dev:
                    msg = (f"{name}() on a device value is an "
                           "implicit device->host transfer — use "
                           "jax.device_get()")
                elif np_call:
                    msg = ("float() over an array-API call in a "
                           "fused-path file — hidden sync if the "
                           "value is a JAX array; baseline if "
                           "provably host")
            if msg is not None:
                yield self.finding(mod, node, msg)


@register
class EagerLoopDispatchRule(Rule):
    id = "REPRO-J102"
    family = "device-hygiene"
    scopes = _DEVICE_FILES
    description = ("jnp/jax call under an eager Python for/while/"
                   "comprehension — per-iteration dispatch and retrace "
                   "hazard")
    rationale = ("PR 6 replaced every per-study Python loop with one "
                 "vmap'd program (74.6x at B=256).  Loops *inside* "
                 "jax.jit unroll at trace time and are exempt; eager "
                 "loops dispatch (and may retrace) every iteration.")

    def check(self, mod: Module) -> Iterable[Finding]:
        loops = [n for n in ast.walk(mod.tree)
                 if isinstance(n, (ast.For, ast.While, ast.ListComp,
                                   ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp))]
        for loop in loops:
            fn = mod.enclosing_function(loop)
            if fn is not None and (_jit_decorated(fn)
                                   or "kernel" in fn.name):
                # jit bodies and Pallas kernel bodies trace once: their
                # Python loops unroll at trace time, not eager dispatch
                continue
            for node in ast.walk(loop):
                if (isinstance(node, ast.Attribute)
                        and isinstance(node.value, ast.Name)
                        and node.value.id in ("jnp", "jax", "lax")):
                    yield self.finding(
                        mod, loop,
                        f"{node.value.id}.{node.attr} inside an eager "
                        "Python loop — one device dispatch per "
                        "iteration; batch/vmap it or hoist out")
                    break   # one finding per loop, not per op


@register
class JitClosureRule(Rule):
    id = "REPRO-J103"
    family = "device-hygiene"
    scopes = _DEVICE_FILES
    description = ("jax.jit entry point closing over enclosing-function "
                   "locals — non-static Python state baked in at trace "
                   "time")
    rationale = ("A jitted function that closes over a mutable local "
                 "keeps serving the value captured at first trace; "
                 "rebinding the local silently does nothing.  Pass such "
                 "values as (static) arguments instead.  ALL_CAPS "
                 "constants are exempt.")

    def check(self, mod: Module) -> Iterable[Finding]:
        module_names = self._module_bindings(mod.tree)
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _jit_decorated(fn):
                continue
            outer = mod.enclosing_function(fn)
            if outer is None:
                continue    # module-level entry point: no function closure
            enclosing_locals: Set[str] = set()
            cur = outer
            while cur is not None:
                enclosing_locals |= self._local_bindings(cur)
                cur = mod.enclosing_function(cur)
            own = self._local_bindings(fn) | {
                a.arg for a in (fn.args.args + fn.args.kwonlyargs
                                + fn.args.posonlyargs)}
            if fn.args.vararg:
                own.add(fn.args.vararg.arg)
            if fn.args.kwarg:
                own.add(fn.args.kwarg.arg)
            seen: Set[str] = set()
            for node in ast.walk(fn):
                if (isinstance(node, ast.Name)
                        and isinstance(node.ctx, ast.Load)
                        and node.id not in own
                        and node.id not in module_names
                        and node.id in enclosing_locals
                        and not node.id.isupper()
                        and node.id not in seen
                        and node.id not in _builtin_names()):
                    seen.add(node.id)
                    yield self.finding(
                        mod, node,
                        f"jitted {fn.name}() closes over enclosing-"
                        f"function local {node.id!r} — captured once at "
                        "trace time; pass it as a (static) argument")

    @staticmethod
    def _local_bindings(fn) -> Set[str]:
        out: Set[str] = set()
        for a in (fn.args.args + fn.args.kwonlyargs + fn.args.posonlyargs):
            out.add(a.arg)
        if fn.args.vararg:
            out.add(fn.args.vararg.arg)
        if fn.args.kwarg:
            out.add(fn.args.kwarg.arg)
        for node in ast.walk(fn):
            out.update(_assign_targets(node))
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)) and node is not fn:
                out.add(node.name)
            elif isinstance(node, ast.For):
                out.update(_assign_targets_of(node.target))
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for al in node.names:
                    out.add((al.asname or al.name).split(".")[0])
            elif isinstance(node, ast.withitem) and node.optional_vars:
                out.update(_assign_targets_of(node.optional_vars))
            elif isinstance(node, ast.comprehension):
                out.update(_assign_targets_of(node.target))
        return out

    @staticmethod
    def _module_bindings(tree) -> Set[str]:
        out: Set[str] = set()
        for node in tree.body:
            out.update(_assign_targets(node))
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                out.add(node.name)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for al in node.names:
                    out.add((al.asname or al.name).split(".")[0])
        return out


def _assign_targets_of(t) -> Set[str]:
    out: Set[str] = set()
    if isinstance(t, ast.Name):
        out.add(t.id)
    elif isinstance(t, (ast.Tuple, ast.List)):
        for e in t.elts:
            out |= _assign_targets_of(e)
    return out


def _builtin_names() -> Set[str]:
    import builtins
    return set(dir(builtins))
