"""Durability rules: journal-before-apply and atomic checkpoint writes.

The service's crash contract (PR 7): every mutation is fsync'd to the
WAL *before* it applies, and every checkpoint publish is
write-tmp -> flush -> fsync -> os.replace, so a crash at any byte leaves
either the old file or the new one — never a torn hybrid.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from repro.analysis.lint import (Finding, Module, Rule, call_name,
                                 terminal_name)
from repro.analysis.rules import register

# evidence that a function journals: any call through an attr chain
# containing "wal"/"journal" (self.wal.append, wal.append, log.journal)
_JOURNAL_TOKENS = ("wal", "journal")


def _is_journal_call(call: ast.Call) -> bool:
    name = call_name(call).lower()
    return any(tok in name.split(".") for tok in _JOURNAL_TOKENS)


@register
class WalBeforeApplyRule(Rule):
    id = "REPRO-W301"
    family = "durability"
    scopes = ("service",)
    description = ("apply_op() must be dominated by a WAL append in the "
                   "same function (journal-then-apply)")
    rationale = ("PR 7's recovery contract: an op that applied but was "
                 "never journaled is lost on crash and replay diverges "
                 "from live state.  The shared live/replay apply path "
                 "is the one legitimate exception — baseline it with "
                 "the call-graph justification.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            journaled_lines: List[int] = []
            applies: List[ast.Call] = []
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                if mod.enclosing_function(node) is not fn:
                    continue    # nested functions audit themselves
                if _is_journal_call(node):
                    journaled_lines.append(node.lineno)
                elif terminal_name(node) == "apply_op":
                    applies.append(node)
            for call in applies:
                if not any(ln <= call.lineno for ln in journaled_lines):
                    yield self.finding(
                        mod, call,
                        f"apply_op() in {fn.name}() without a preceding "
                        "WAL append — journal-then-apply, or baseline "
                        "the shared replay path with its justification")


# write sites that must be atomic+durable in checkpoint/journal code
_WRITE_TERMINALS = {"savez", "savez_compressed", "dump", "write_text",
                    "write_bytes"}


def _open_mode(call: ast.Call) -> Optional[str]:
    if call_name(call) != "open":
        return None
    mode = None
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
        mode = call.args[1].value
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = kw.value.value
    return mode if isinstance(mode, str) else None


@register
class AtomicWriteRule(Rule):
    id = "REPRO-W302"
    family = "durability"
    scopes = ("service", "studybank.py", "checkpoint.py", "optimizer.py")
    description = ("checkpoint/journal file writes must go through "
                   "flush + fsync + os.replace (atomic rename)")
    rationale = ("A crash mid-write without the tmp/fsync/replace idiom "
                 "leaves a torn file that recovery then trusts.  The "
                 "WAL's torn-tail truncation only protects the journal "
                 "itself; snapshots and configs must be "
                 "all-or-nothing.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            has_fsync = has_replace = delegates = False
            sites: List[ast.Call] = []
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                if mod.enclosing_function(node) is not fn:
                    continue    # nested functions audit themselves
                term = terminal_name(node)
                name = call_name(node)
                if term == "fsync":
                    has_fsync = True
                elif term == "replace" or name == "os.replace":
                    has_replace = True
                elif "atomic" in term.lower():
                    delegates = True    # routed through an atomic helper
                mode = _open_mode(node)
                if mode in ("w", "wb", "w+", "wb+"):
                    sites.append(node)
                elif (term in _WRITE_TERMINALS
                      and name.split(".", 1)[0] in ("np", "numpy", "json")
                      and term != "write_text"):
                    sites.append(node)
                elif term in ("write_text", "write_bytes"):
                    sites.append(node)
            if delegates or not sites:
                continue
            if has_fsync and has_replace:
                continue
            missing = [w for w, ok in
                       (("fsync", has_fsync), ("os.replace", has_replace))
                       if not ok]
            for site in sites:
                yield self.finding(
                    mod, site,
                    f"durable write without {' + '.join(missing)} — use "
                    "write-tmp -> flush -> fsync -> os.replace so a "
                    "crash never publishes a torn file")
