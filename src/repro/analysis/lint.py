"""repro-lint: AST-driven, repo-specific static analysis.

The repo's strongest properties are *invariants*, not features —
bit-identical crash replay (PR 7), zero-retrace steady-state serving
(PR 6), monotonic-deadline fault semantics (PR 3).  Each rule in
``repro.analysis.rules`` encodes one of those invariants at the line
level, so a regression is flagged on the push that introduces it instead
of surfacing as a flaky CI failure months later.

Engine pieces (stdlib-only — the lint CI job needs no jax/numpy):

  * ``Module``: one parsed source file + parent links + per-line noqa.
  * ``Rule``: plugin base class; subclasses register via
    ``rules.register`` and scope themselves to directory/file tokens.
  * suppressions: ``# repro: noqa RULE-ID[,RULE-ID]`` on the offending
    line (bare ``# repro: noqa`` suppresses every rule on that line).
  * baseline: a JSON file of *justified* findings (see ``baseline.py``)
    matched by (rule, path, stripped source line) so line-number churn
    never invalidates an entry.

Exit contract of the CLI (``python -m repro.analysis``): 0 when every
finding is suppressed or baselined, 1 otherwise — the CI ``lint`` job
blocks on it.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

NOQA_RE = re.compile(r"#\s*repro:\s*noqa\b[:\s]*([A-Z0-9\-, ]*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str           # as passed to the engine (posix separators)
    line: int
    col: int
    message: str
    content: str        # stripped source line, the baseline match key

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


class Module:
    """One parsed file: tree + parent links + noqa table."""

    def __init__(self, path: str, src: str):
        self.path = str(Path(path).as_posix())
        self.src = src
        self.lines = src.splitlines()
        self.tree = ast.parse(src, filename=path)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        # line -> set of suppressed rule ids ({"*"} = all)
        self.noqa: Dict[int, set] = {}
        for i, text in enumerate(self.lines, start=1):
            m = NOQA_RE.search(text)
            if m:
                ids = {s.strip() for s in m.group(1).replace(",", " ").split()
                       if s.strip()}
                self.noqa[i] = ids or {"*"}

    # ----------------------------------------------------------- helpers
    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def suppressed(self, rule_id: str, lineno: int) -> bool:
        ids = self.noqa.get(lineno)
        return bool(ids) and ("*" in ids or rule_id in ids)

    def enclosing(self, node: ast.AST, kinds) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, kinds):
                return cur
            cur = self.parents.get(cur)
        return None

    def enclosing_function(self, node: ast.AST):
        return self.enclosing(
            node, (ast.FunctionDef, ast.AsyncFunctionDef))


def dotted_name(node: ast.AST) -> str:
    """``np.random.default_rng`` for the func of a Call (best effort)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    elif isinstance(node, ast.Call):
        parts.append(dotted_name(node.func) + "()")
    return ".".join(reversed(parts))


def call_name(call: ast.Call) -> str:
    return dotted_name(call.func)


def terminal_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


class Rule:
    """Base class for lint rules.  Subclasses set the class attrs and
    implement ``check``; ``scopes`` holds directory tokens (``"core"``,
    ``"service"``) and/or file names (``"studybank.py"``) — a rule only
    runs on files under a matching directory or with a matching name, so
    fixtures under ``tmp/core/x.py`` exercise the same scoping as the
    real tree."""

    id: str = ""
    family: str = ""
    scopes: Tuple[str, ...] = ()
    description: str = ""
    rationale: str = ""

    def applies(self, path: str) -> bool:
        if not self.scopes:
            return True
        parts = Path(path).parts
        name = Path(path).name
        return any(tok in parts or tok == name for tok in self.scopes)

    def check(self, mod: Module) -> Iterable[Finding]:
        raise NotImplementedError

    # ----------------------------------------------------------- helper
    def finding(self, mod: Module, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(self.id, mod.path, line, col, message,
                       mod.line_text(line))


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]          # all, after noqa suppression
    unbaselined: List[Finding]       # findings with no baseline entry
    baselined: List[Finding]
    stale: List[dict]                # baseline entries matching nothing
    errors: List[str]                # unparsable files

    @property
    def ok(self) -> bool:
        return not self.unbaselined and not self.errors


def iter_py_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        pp = Path(p)
        if pp.is_dir():
            out.extend(str(f.as_posix()) for f in sorted(pp.rglob("*.py")))
        elif pp.suffix == ".py":
            out.append(str(pp.as_posix()))
    return out


def lint_paths(paths: Sequence[str], rules: Optional[Sequence[Rule]] = None,
               baseline=None) -> LintResult:
    """Run ``rules`` (default: every registered rule) over ``paths``.

    ``baseline`` is a ``repro.analysis.baseline.Baseline`` (or None).
    """
    if rules is None:
        from repro.analysis.rules import all_rules
        rules = all_rules()
    findings: List[Finding] = []
    errors: List[str] = []
    for fpath in iter_py_files(paths):
        try:
            mod = Module(fpath, Path(fpath).read_text())
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            errors.append(f"{fpath}: {type(e).__name__}: {e}")
            continue
        for rule in rules:
            if not rule.applies(fpath):
                continue
            for f in rule.check(mod):
                if not mod.suppressed(f.rule, f.line):
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    if baseline is None:
        return LintResult(findings, list(findings), [], [], errors)
    kept, suppressed = [], []
    used = set()
    for f in findings:
        idx = baseline.match(f)
        if idx is None:
            kept.append(f)
        else:
            suppressed.append(f)
            used.add(idx)
    stale = [e for i, e in enumerate(baseline.entries) if i not in used]
    return LintResult(findings, kept, suppressed, stale, errors)
