"""Unified Mango-vs-TPE convergence harness (paper Figs. 2 and 3).

One entry point for the paper's two evaluation figures, both running
through the same ask/tell core (``run_algorithms`` -> ``Tuner``): Fig. 2 is
the GBM-on-wine classifier tuning task (maximize CV accuracy), Fig. 3 the
modified mixed-variable Branin (minimize).  Each figure's paper claims are
checked against the run and emitted as ``# CLAIM`` lines; ``--json`` writes
the per-algorithm best-so-far traces plus the claim verdicts so the CI
``figures`` job can archive the convergence trajectory per commit
(``BENCH_paper_figures.json``), the same pattern as the proposal-latency
bench.

``--quick`` selects a grid sized for CI (a few minutes on one CPU);
the default grid matches ``benchmarks/run.py``'s moderate configuration and
``--full`` the paper-scale one.
"""
from __future__ import annotations

import argparse
import json
import time


def run_fig2(n_iters=15, repeats=3, parallel_batch=5):
    from benchmarks import fig2_classifier
    return fig2_classifier.run(n_iters=n_iters, repeats=repeats,
                               parallel_batch=parallel_batch)


def run_fig3(n_iters=15, repeats=5, parallel_batch=5):
    from benchmarks import fig3_branin
    return fig3_branin.run(n_iters=n_iters, repeats=repeats,
                           parallel_batch=parallel_batch)


def _final(traces, name):
    return float(traces[name][:, -1].mean())


def claims_fig2(tr):
    """The paper's Fig. 2 statements -> [(claim, detail, passed)]."""
    ms, ts = _final(tr, "mango-serial"), _final(tr, "tpe-serial")
    mp = _final(tr, "mango-parallel")
    mc = _final(tr, "mango-clustering")
    tp = _final(tr, "tpe-parallel")
    rnd = _final(tr, "random-parallel")
    bo_min = min(ms, mp, mc, tp)
    return [
        ("fig2 'all BO >= random (within noise)'",
         f"min(BO)={bo_min:.4f} vs random={rnd:.4f}", bo_min >= rnd - 0.01),
        ("fig2 'Mango serial slightly better than Hyperopt serial'",
         f"{ms:.4f} vs {ts:.4f}", ms >= ts - 0.005),
        ("fig2 'Mango parallel >= Hyperopt parallel (<=40 iters)'",
         f"{max(mp, mc):.4f} vs {tp:.4f}", max(mp, mc) >= tp - 0.005),
    ]


def claims_fig3(tr):
    """The paper's Fig. 3 statements (minimization: lower is better)."""
    ms, ts = _final(tr, "mango-serial"), _final(tr, "tpe-serial")
    mp, tp = _final(tr, "mango-parallel"), _final(tr, "tpe-parallel")
    rs = _final(tr, "random-serial")
    return [
        ("fig3 'Mango outperforms Hyperopt in serial'",
         f"{ms:.3f} <= {ts:.3f}", ms <= ts + 0.05),
        ("fig3 'Mango outperforms Hyperopt in parallel'",
         f"{mp:.3f} <= {tp:.3f}", mp <= tp + 0.05),
        ("fig3 'BO beats random'", f"{ms:.3f} <= {rs:.3f}",
         ms <= rs + 1e-9),
    ]


FIGURES = {
    # name -> (runner, claims, emit-prefix, derived-key)
    "fig2": (run_fig2, claims_fig2, "fig2_wine", "best_acc"),
    "fig3": (run_fig3, claims_fig3, "fig3_branin", "best_final"),
}

# (n_iters, repeats, parallel_batch) per figure and grid size
GRIDS = {
    "quick": {"fig2": (6, 2, 3), "fig3": (10, 3, 5)},
    "default": {"fig2": (15, 3, 5), "fig3": (15, 5, 5)},
    "full": {"fig2": (40, 10, 5), "fig3": (30, 10, 5)},
}


def run_figures(figs, grid="default", json_path=None):
    """Run the selected figures, print CSV rows + claim lines, and return
    the JSON-able result document."""
    doc = {"benchmark": "paper_figures", "grid": grid, "figures": {}}
    for fig in figs:
        runner, claims_fn, prefix, key = FIGURES[fig]
        n_iters, repeats, pb = GRIDS[grid][fig]
        print(f"# === {fig}: n_iters={n_iters} repeats={repeats} "
              f"batch={pb} ===")
        t0 = time.time()
        traces = runner(n_iters=n_iters, repeats=repeats, parallel_batch=pb)
        wall = time.time() - t0
        algos = {}
        for name, trace in traces.items():
            final = float(trace[:, -1].mean())
            # per-algorithm per-repeat wall share: same us_per_call metric
            # the old run.py emitted, so the CSV trajectory stays
            # comparable across commits
            us = wall / max(len(traces), 1) * 1e6 / max(repeats, 1)
            print(f"{prefix}_{name},{us:.1f},{key}={final:.4f}", flush=True)
            algos[name] = {"final_mean": final,
                           "trace_mean": trace.mean(axis=0).tolist()}
        claims = []
        for claim, detail, passed in claims_fn(traces):
            print(f"# CLAIM {claim}: {detail} -> "
                  f"{'PASS' if passed else 'FAIL'}")
            claims.append({"claim": claim, "detail": detail,
                           "passed": bool(passed)})
        doc["figures"][fig] = {"n_iters": n_iters, "repeats": repeats,
                               "parallel_batch": pb, "wall_s": round(wall, 1),
                               "algos": algos, "claims": claims}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_path}")
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig", choices=["2", "3", "all"], default="all")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized grid (a few minutes on one CPU)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale repeats/iterations (slow on 1 CPU)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write traces + claim verdicts as JSON")
    args = ap.parse_args()
    grid = "quick" if args.quick else ("full" if args.full else "default")
    figs = ["fig2", "fig3"] if args.fig == "all" else [f"fig{args.fig}"]
    run_figures(figs, grid=grid, json_path=args.json)


if __name__ == "__main__":
    main()
