"""Shared benchmark harness: run (algorithm x repeats) and collect traces."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import Tuner


def best_so_far(values: List[float], per_iter: int, n_iters: int,
                maximize: bool) -> np.ndarray:
    """Collapse the flat eval list into a best-so-far-per-iteration trace."""
    out = []
    best = -np.inf if maximize else np.inf
    vals = list(values)
    # initial-random evals count as iteration 0
    for it in range(n_iters):
        lo = it * per_iter
        hi = min((it + 1) * per_iter, len(vals))
        for v in vals[lo:hi]:
            best = max(best, v) if maximize else min(best, v)
        out.append(best)
    return np.array(out)


def run_algorithms(space: dict, objective_of: Callable[[], Callable],
                   algos: Dict[str, dict], n_iters: int, repeats: int,
                   maximize: bool = True, mc_samples: int = 1200,
                   fit_steps: int = 12) -> Dict[str, np.ndarray]:
    """algos: name -> dict(optimizer=..., batch_size=...).

    Returns name -> (repeats, n_iters) best-so-far traces.
    """
    traces = {}
    for name, conf in algos.items():
        rows = []
        t0 = time.time()
        for rep in range(repeats):
            tuner = Tuner(space, objective_of(), dict(
                num_iteration=n_iters, initial_random=2, seed=1000 + rep,
                mc_samples=mc_samples, fit_steps=fit_steps, **conf))
            res = tuner.maximize() if maximize else tuner.minimize()
            # skip the 2 initial-random evals, then chunk by batch
            vals = res.objective_values
            init, rest = vals[:2], vals[2:]
            best0 = max(init) if maximize else min(init)
            trace = best_so_far(rest, conf.get("batch_size", 1), n_iters,
                                maximize)
            trace = (np.maximum if maximize else np.minimum)(trace, best0)
            rows.append(trace)
        traces[name] = np.stack(rows)
        print(f"#   {name:28s} mean_final={traces[name][:, -1].mean():.4f} "
              f"({time.time() - t0:.0f}s)", flush=True)
    return traces
