"""XGBoost-on-wine stand-in: a real numpy gradient-boosted-trees classifier.

xgboost/sklearn are not installable offline, so Fig. 2's tuning target is
reproduced with an equivalent-in-kind objective: a from-scratch multiclass
GBM (vector-leaf regression trees on softmax residuals, plus a "gblinear"
booster and a DART-style tree-dropout booster) trained on a deterministic
wine-like dataset (178 samples, 13 features, 3 classes — the UCI wine shape)
and scored by 3-fold CV accuracy.  The hyperparameter space mirrors the
paper's Listing 1.
"""
from __future__ import annotations

import numpy as np


def make_wine(seed: int = 7):
    """Deterministic 3-class, 13-feature dataset with UCI-wine geometry.

    Class structure is partly nonlinear (two features carry class-dependent
    quadratic interactions) and overlapping, so CV accuracy is hyperparameter
    sensitive (~0.80 for weak configs, ~0.95 for tuned ones) and no single
    booster trivially saturates.
    """
    rng = np.random.default_rng(seed)
    n_per = (59, 71, 48)  # UCI wine class sizes
    means = rng.normal(0, 1.05, size=(3, 13))
    mix = rng.normal(0, 0.35, size=(13, 13))  # shared feature correlations
    X, y = [], []
    for c, n in enumerate(n_per):
        z = rng.normal(size=(n, 13))
        f = z @ mix + means[c] + rng.normal(0, 0.55, size=(n, 13))
        # nonlinear class signal: XOR-ish quadratic interactions
        f[:, 3] = 0.8 * z[:, 0] * z[:, 1] * (1 if c != 1 else -1) \
            + 0.4 * f[:, 3]
        f[:, 7] = 0.8 * (z[:, 2] ** 2 - 1.0) * (1 if c != 2 else -1) \
            + 0.4 * f[:, 7]
        X.append(f)
        y.append(np.full(n, c))
    X = np.concatenate(X)
    y = np.concatenate(y)
    # 3% label noise keeps perfect accuracy out of reach
    flip = rng.random(len(y)) < 0.03
    y[flip] = rng.integers(0, 3, flip.sum())
    perm = rng.permutation(len(y))
    return X[perm].astype(np.float32), y[perm].astype(np.int32)


class _Tree:
    """Depth-limited regression tree with vector (K-class) leaves."""

    __slots__ = ("feat", "thr", "left", "right", "leaf")

    def __init__(self, X, G, depth, min_gain, rng):
        n, d = X.shape
        self.leaf = G.mean(axis=0)
        self.feat = None
        if depth == 0 or n < 8:
            return
        base = np.sum(G.mean(axis=0) ** 2) * n
        best_gain, best = min_gain, None
        for f in rng.choice(d, size=min(d, 8), replace=False):
            col = X[:, f]
            for thr in np.quantile(col, (0.25, 0.5, 0.75)):
                m = col <= thr
                nl = int(m.sum())
                if nl == 0 or nl == n:
                    continue
                gl = G[m].mean(axis=0)
                gr = G[~m].mean(axis=0)
                gain = (np.sum(gl ** 2) * nl + np.sum(gr ** 2) * (n - nl)
                        - base)
                if gain > best_gain:
                    best_gain, best = gain, (f, thr, m)
        if best is None:
            return
        f, thr, m = best
        self.feat, self.thr = f, thr
        self.left = _Tree(X[m], G[m], depth - 1, min_gain, rng)
        self.right = _Tree(X[~m], G[~m], depth - 1, min_gain, rng)

    def predict(self, X):
        if self.feat is None:
            return np.broadcast_to(self.leaf, (len(X), len(self.leaf)))
        m = X[:, self.feat] <= self.thr
        out = np.empty((len(X), len(self.leaf)))
        out[m] = self.left.predict(X[m])
        out[~m] = self.right.predict(X[~m])
        return out


def _softmax(F):
    e = np.exp(F - F.max(axis=1, keepdims=True))
    return e / e.sum(axis=1, keepdims=True)


class GBMClassifier:
    """Multiclass gradient boosting: gbtree / dart / gblinear boosters."""

    def __init__(self, learning_rate=0.3, gamma=0.0, max_depth=3,
                 n_estimators=50, booster="gbtree", seed=0):
        self.lr = max(float(learning_rate), 1e-3)
        self.min_gain = float(gamma) * 0.08
        self.depth = int(max_depth)
        self.n_est = int(n_estimators)
        self.booster = booster
        self.seed = seed

    def fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        K = int(y.max()) + 1
        Y = np.eye(K)[y]
        if self.booster == "gblinear":
            Xb = np.concatenate([X, np.ones((len(X), 1))], axis=1)
            W = np.zeros((Xb.shape[1], K))
            for _ in range(min(self.n_est * 4, 400)):
                P = _softmax(Xb @ W)
                W += self.lr * 0.1 * (Xb.T @ (Y - P) / len(X)
                                      - 1e-3 * W)
            self.W = W
            return self
        self.trees = []
        preds = []  # cached per-tree train predictions (DART re-weighting)
        F = np.zeros((len(X), K))
        for i in range(min(self.n_est, 150)):
            if self.booster == "dart" and preds:
                drop = rng.random(len(preds)) < 0.1
                Fd = F - sum(p for p, d in zip(preds, drop) if d)
            else:
                Fd = F
            G = Y - _softmax(Fd)
            t = _Tree(X, G, self.depth, self.min_gain, rng)
            self.trees.append(t)
            preds.append(self.lr * t.predict(X))
            F = F + preds[-1]
        return self

    def predict(self, X):
        if self.booster == "gblinear":
            Xb = np.concatenate([X, np.ones((len(X), 1))], axis=1)
            return np.argmax(Xb @ self.W, axis=1)
        F = sum(self.lr * t.predict(X) for t in self.trees)
        return np.argmax(F, axis=1)


def cv_accuracy(params: dict, X, y, folds: int = 3) -> float:
    n = len(y)
    idx = np.arange(n)
    accs = []
    for f in range(folds):
        test = idx[f::folds]
        train = np.setdiff1d(idx, test)
        clf = GBMClassifier(**params, seed=f).fit(X[train], y[train])
        accs.append(float((clf.predict(X[test]) == y[test]).mean()))
    return float(np.mean(accs))
