"""Diff two ``BENCH_*.json`` perf-trajectory files row by row.

The CI ``bench`` job restores the previous push's JSON from the actions
cache, runs the quick grid, and pipes this tool's markdown table into
``$GITHUB_STEP_SUMMARY`` — a per-row regression view on every consecutive
push to a branch.

    python benchmarks/bench_delta.py OLD.json NEW.json \
        [--threshold 1.15] [--gate 'pallas_rescore_*:1.25' ...]

Rows are matched by ``name``.  Two de-noising mechanisms make the deltas
meaningful on shared CI runners:

  * the benchmark itself times paired paths with *interleaved* median-of-N
    reps (``proposal_latency._interleaved_medians``), so CPU-share
    throttling bursts hit both paths of a pair equally within one run;
  * rows with a same-run baseline partner (``*_fused`` -> ``*_host``/
    ``*_seed``, ``*_downdate`` -> ``*_full``, ``kinv_f64_*`` ->
    ``kinv_f32_*``, ``refit_warm`` -> ``refit_cold``) are compared as
    *ratios to that baseline* rather than absolute microseconds — a run
    that is globally 2x slower (noisy neighbor) moves numerator and
    denominator together and produces no false flag.  Such rows are marked
    ``rel`` in the table; rows without a partner fall back to the raw
    comparison.

A row is flagged as a regression when its (normalized) new/old ratio
exceeds ``--threshold`` (default +15%) and as an improvement below the
inverse.  Added/removed rows are listed, not flagged.

``--gate GLOB:RATIO`` (repeatable) promotes matching rows to *blocking*:
if any gated row regresses beyond its own ratio, the table is still
printed but the exit code is 2.  Rows serving as someone's normalization
denominator are exempt from gating (their comparison is raw microseconds
— the very noise the normalization cancels), so in practice the CI
``pallas_rescore_*:1.25`` gate blocks on the *downdate-vs-full ratio*
regressing >25%; a uniform slowdown of both kernels stays advisory.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import sys

# derived row prefix -> same-run baseline row prefix (first match wins)
BASELINES = [
    ("proposal_fused", "proposal_seed"),
    ("pallas_pending_fused", "pallas_pending_host"),
    ("pallas_rescore_downdate", "pallas_rescore_full"),
    ("clustering_fused", "clustering_host"),
    ("tpe_fused", "tpe_host"),
    ("tpe_pallas", "tpe_host"),
    ("kinv_f64_schur", "kinv_f32_schur"),
    ("refit_warm", "refit_cold"),
    ("single_study_asks", "single_study_random"),
    ("studies_per_sec", "multi_study_loop"),
    ("autotune_ask_gp", "autotune_ask_random"),
]


def baseline_name(name):
    """The same-run row this row normalizes against, or None."""
    for derived, base in BASELINES:
        if name.startswith(derived):
            return base + name[len(derived):]
    return None


def load_rows(path):
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", [])}


def _ratio(old, new, name):
    """(new/old ratio, normalized?) — relative to the same-run baseline
    row when both runs carry it."""
    base = baseline_name(name)
    if base and base in old and base in new and old[base] > 0 \
            and new[base] > 0:
        o = old[name] / old[base]
        n = new[name] / new[base]
        if o > 0:
            return n / o, True
    o, n = old[name], new[name]
    if o <= 0:
        # 0-valued counter rows (e.g. steady_state_retrace) are equal-or-
        # better when the new run is also 0 — not an infinite regression.
        return (1.0 if n <= 0 else float("inf")), False
    return n / o, False


def delta_table(old, new, threshold=1.15, gates=()):
    """(markdown lines, gated-regression row names)."""
    lines = ["| row | old (us) | new (us) | delta | |",
             "|---|---:|---:|---:|---|"]
    n_reg = 0
    gated = []
    # rows serving as someone's normalization denominator are never gated:
    # their comparison is raw microseconds, which is exactly the shared-
    # runner noise the normalization exists to cancel (they stay visible
    # with the advisory flag)
    denominators = {baseline_name(n) for n in new} - {None}
    for name in new:
        if name not in old:
            continue
        ratio, normalized = _ratio(old, new, name)
        flag = ""
        if ratio > threshold:
            flag = "REGRESSION"
            n_reg += 1
        elif ratio < 1.0 / threshold:
            flag = "improved"
        if name not in denominators:
            for pat, gate_ratio in gates:
                if fnmatch.fnmatch(name, pat) and ratio > gate_ratio:
                    flag = "REGRESSION (blocking)"
                    gated.append(name)
                    break
        rel = " rel" if normalized else ""
        lines.append(f"| `{name}` | {old[name]:.1f} | {new[name]:.1f} | "
                     f"{(ratio - 1.0) * 100:+.1f}%{rel} | {flag} |")
    added = sorted(set(new) - set(old))
    removed = sorted(set(old) - set(new))
    if added:
        lines.append("")
        lines.append("New rows: " + ", ".join(f"`{a}`" for a in added))
    if removed:
        lines.append("")
        lines.append("Removed rows: " + ", ".join(f"`{r}`"
                                                  for r in removed))
    header = (f"### Bench delta vs previous push — "
              f"{n_reg} row(s) over the +{(threshold - 1) * 100:.0f}% "
              f"threshold"
              + (f", {len(gated)} BLOCKING" if gated else ""))
    return [header, ""] + lines, gated


def parse_gate(spec):
    pat, _, ratio = spec.rpartition(":")
    if not pat:
        raise argparse.ArgumentTypeError(
            f"--gate wants GLOB:RATIO, got {spec!r}")
    return pat, float(ratio)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=1.15,
                    help="advisory regression flag at (normalized) "
                         "new/old above this ratio")
    ap.add_argument("--gate", type=parse_gate, action="append", default=[],
                    metavar="GLOB:RATIO",
                    help="blocking gate: exit 2 if a row matching GLOB "
                         "regresses beyond RATIO (repeatable)")
    args = ap.parse_args()
    try:
        old = load_rows(args.old)
        new = load_rows(args.new)
    except (OSError, ValueError, KeyError) as e:
        print(f"bench_delta: unreadable input: {e}", file=sys.stderr)
        return 1
    lines, gated = delta_table(old, new, args.threshold, args.gate)
    print("\n".join(lines))
    if gated:
        print(f"bench_delta: blocking regression on {', '.join(gated)}",
              file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
