"""Diff two ``BENCH_*.json`` perf-trajectory files row by row.

The CI ``bench`` job restores the previous push's JSON from the actions
cache, runs the quick grid, and pipes this tool's markdown table into
``$GITHUB_STEP_SUMMARY`` — a per-row regression view on every consecutive
push to a branch, without gating merges on noisy CI timings (the job stays
non-blocking; this tool always exits 0 unless inputs are unreadable).

    python benchmarks/bench_delta.py OLD.json NEW.json [--threshold 1.15]

Rows are matched by ``name``.  A row is flagged as a regression when
``new/old > threshold`` (default +15%, roughly the noise floor of shared CI
runners for these microbenchmarks) and as an improvement below the inverse.
Added/removed rows are listed, not flagged.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path):
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", [])}


def delta_table(old, new, threshold=1.15):
    """Markdown lines comparing two {name: us_per_call} dicts."""
    lines = ["| row | old (us) | new (us) | delta | |",
             "|---|---:|---:|---:|---|"]
    n_reg = 0
    for name in new:
        if name not in old:
            continue
        o, n = old[name], new[name]
        ratio = n / o if o > 0 else float("inf")
        flag = ""
        if ratio > threshold:
            flag = "REGRESSION"
            n_reg += 1
        elif ratio < 1.0 / threshold:
            flag = "improved"
        lines.append(f"| `{name}` | {o:.1f} | {n:.1f} | "
                     f"{(ratio - 1.0) * 100:+.1f}% | {flag} |")
    added = sorted(set(new) - set(old))
    removed = sorted(set(old) - set(new))
    if added:
        lines.append("")
        lines.append("New rows: " + ", ".join(f"`{a}`" for a in added))
    if removed:
        lines.append("")
        lines.append("Removed rows: " + ", ".join(f"`{r}`"
                                                  for r in removed))
    header = (f"### Bench delta vs previous push — "
              f"{n_reg} row(s) over the +{(threshold - 1) * 100:.0f}% "
              f"threshold")
    return [header, ""] + lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=1.15,
                    help="regression flag at new/old above this ratio")
    args = ap.parse_args()
    try:
        old = load_rows(args.old)
        new = load_rows(args.new)
    except (OSError, ValueError, KeyError) as e:
        print(f"bench_delta: unreadable input: {e}", file=sys.stderr)
        return 1
    print("\n".join(delta_table(old, new, args.threshold)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
