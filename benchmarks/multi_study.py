"""Multi-study throughput: the vmap'd StudyBank ask vs a Python loop.

The tentpole claim (ISSUE 6): N concurrent studies cost ONE device
dispatch, not N.  Two arms per fleet size B:

  * ``multi_study_loop_{B}``: B independent ``AskTellOptimizer`` objects
    asked one after another — the pre-bank serving pattern.  Every study
    pays its own jit dispatch, candidate draw, and host round-trip.
  * ``studies_per_sec_{B}``: one ``StudyBank`` of B studies served by a
    single ``ask_all`` — one columnar candidate draw, one shape-bucketed
    gather, one vmap'd fused program.

Both arms run the same strategy, the same ``mc_samples``, and identically
pre-seeded studies (~20 observations, past the random phase).  The
default candidate budget is small (``n_mc=32``) because this row measures
*serving overhead amortization* — dispatch, gather, host round-trips —
which is what the bank actually batches away; both arms always get the
identical budget, and larger budgets shift both arms toward the same
elementwise-scoring floor.  The timed op is the steady-state ask: each
rep's proposals are told *failed* in the untimed setup slot, so
observation counts — and therefore every device shape and the fit
schedule — stay frozen across reps.  Rows are timed
interleaved (same convention as ``proposal_latency``) so CPU-share
throttling hits both arms equally; ``bench_delta`` normalizes the
``studies_per_sec`` rows against the same-run loop row, which is what the
CI gate (``studies_per_sec_256:1.25``) blocks on.  Acceptance target:
bank >= 50x the loop at B=256.

``steady_state_retrace``: the zero-retrace proof for the shape-bucket
schedule.  One bank grows 64 -> 1024 observations, asking at every bucket
edge (edge-1 / edge / edge+1) and at interior points; each staged jitted
bank entry point (``gp.BANK_JITS``: factors, prescales, dist, exp, pick,
absorb, fit) should compile exactly once per power-of-2 bucket it is
dispatched at and never again.  The row's value is ``new_cache_entries -
expected_compiles`` summed over entry points — nonzero means a retrace
leaked into the steady state, and the script exits 1 (the CI bench job
fails).

``--json PATH`` writes the rows for the CI perf-trajectory archive.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

ROWS = []   # every emitted row, for --json


def _emit(name, us, derived):
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


def _interleaved_medians(calls, reps=3, setups=None):
    """Median seconds per call, calls interleaved within each rep (see
    ``proposal_latency._interleaved_medians`` — same throttle-resistant
    convention).  ``setups[i]`` runs untimed before each timed call."""
    samples = [[] for _ in calls]
    for i, c in enumerate(calls):        # warmup: compile the timed path
        if setups is not None and setups[i] is not None:
            setups[i]()
        c()
    for _ in range(reps):
        for i, c in enumerate(calls):
            if setups is not None and setups[i] is not None:
                setups[i]()
            t0 = time.perf_counter()
            c()
            samples[i].append(time.perf_counter() - t0)
    return [float(np.median(s)) for s in samples]


def _space():
    from scipy import stats
    return {"x": stats.uniform(0, 1), "y": stats.uniform(-1, 2),
            "z": stats.uniform(0, 3)}


def _seed_study(opt, k, rng):
    for _ in range(k):
        p = {"x": float(rng.uniform(0, 1)), "y": float(rng.uniform(-1, 1)),
             "z": float(rng.uniform(0, 3))}
        opt.observe_params(p, float(rng.normal()))


def run_throughput(fleet_sizes=(16, 64, 256), n_obs=20, n_mc=32, reps=3,
                   seed=0):
    """studies/sec, bank vs loop, across fleet size."""
    from repro.core import AskTellOptimizer, StudyBank

    results = []
    for B in fleet_sizes:
        rng = np.random.default_rng(seed)
        opts = [AskTellOptimizer(_space(), optimizer="bayesian",
                                 seed=seed + 1 + i, mc_samples=n_mc)
                for i in range(B)]
        for o in opts:
            _seed_study(o, n_obs, rng)
        rng = np.random.default_rng(seed)
        bank = StudyBank(_space(), B, optimizer="bayesian", seed=seed,
                         mc_samples=n_mc)
        for b in range(B):
            _seed_study(bank.study(b), n_obs, rng)

        loop_asked, bank_asked = [], []

        def loop_setup():
            # failed tells keep n_obs (and every device shape) frozen
            for o, t in loop_asked:
                o.tell_failed(t.id)
            loop_asked.clear()

        def loop_call():
            for o in opts:
                loop_asked.append((o, o.ask(1)[0]))

        def bank_setup():
            for b, ts in enumerate(bank_asked):
                for t in ts:
                    bank.tell_failed(b, t.id)
            bank_asked.clear()

        def bank_call():
            bank_asked.extend(bank.ask_all(1))

        t_loop, t_bank = _interleaved_medians(
            [loop_call, bank_call], reps=reps,
            setups=[loop_setup, bank_setup])
        sps_loop = B / max(t_loop, 1e-12)
        sps_bank = B / max(t_bank, 1e-12)
        speedup = t_loop / max(t_bank, 1e-12)
        _emit(f"multi_study_loop_{B}", t_loop * 1e6,
              f"speedup=1.0x,studies_per_sec={sps_loop:.1f}")
        _emit(f"studies_per_sec_{B}", t_bank * 1e6,
              f"speedup={speedup:.1f}x,studies_per_sec={sps_bank:.1f}")
        results.append((B, speedup))
    return results


def run_retrace_sweep(max_obs=1024, n_mc=64, n_studies=2, seed=0):
    """Grow one bank 64 -> ``max_obs`` observations, asking at every
    bucket edge and at interior points; count jit cache entries beyond
    the one compile each entry point owes per bucket shape."""
    from repro.analysis.sanitizers import no_retrace
    from repro.core import StudyBank
    from repro.core.studybank import _pow2

    bank = StudyBank(_space(), n_studies, optimizer="bayesian", seed=seed,
                     mc_samples=n_mc)
    led = bank.ledger
    rng = np.random.default_rng(seed)

    # n_obs targets: for each bucket edge E (na jumps at n_obs = E where
    # _pow2(E + pend_cap + 1) doubles), visit E-1, E, E+1, plus a mid-bucket
    # point — the within-bucket asks are where a retrace would hide.
    pend_cap, n = 4, 1
    targets = []
    na, k = 64, 59                       # first edge: _pow2(59+5) = 64
    while na <= max_obs:
        edge = na - pend_cap - n         # last n_obs still inside bucket na
        targets += [edge - 1, edge, edge + 1, edge + (edge // 2)]
        na *= 2
    targets = sorted(t for t in set(targets) if 58 <= t <= max_obs - 5)

    propose_buckets, fit_buckets = set(), set()
    # audit the whole sweep with the shared sanitizer (jits=None ->
    # gp.BANK_JITS; base snapshot absorbs the throughput phase that ran
    # in this process); the benchmark turns violations into exit 1
    # itself, so no raise here
    with no_retrace(raise_on_violation=False) as rep:
        for k in targets:
            for b in range(n_studies):
                add = k - int(led.n_observed()[b])
                _seed_study(bank.study(b), add, rng)
            na = _pow2(max(16, k + pend_cap + n))
            propose_buckets.add(na)
            due = ((led.have_fit == 0) |
                   (led.n_observed() - led.n_fit >= bank.refit_every))
            if due.any():
                fit_buckets.add(na)
            # two asks per target: the first may compile (bucket boundary),
            # the second must be a pure cache hit
            for _ in range(2):
                asked = bank.ask_all(n)
                for b, ts in enumerate(asked):
                    for t in ts:
                        bank.tell_failed(b, t.id)
        # expected compiles per staged entry point: one per na bucket it is
        # dispatched at.  prescale_C's shape depends only on mc_samples (one
        # bucket for the whole sweep); absorb never runs (no trial is in
        # flight at ask time); the fit program runs only at fit-due targets.
        nb = len(propose_buckets)
        rep.expected = {"bank_factors": nb, "bank_prescale_X": nb,
                        "bank_prescale_C": 1, "bank_absorb": 0,
                        "bank_dist": nb, "bank_exp": nb, "bank_pick": nb,
                        "fit_hypers_bank": len(fit_buckets)}
    retraces = rep.violations
    detail = rep.detail() or "all=expected"
    _emit("steady_state_retrace", float(retraces),
          f"retraces={retraces},boundaries={nb},{detail}")
    return retraces


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="small grid for smoke runs (retrace sweep stops "
                         "at 256 observations)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write every emitted row as JSON (the CI "
                         "tier-2 job uploads this as BENCH_*.json)")
    args = ap.parse_args()
    results = run_throughput(reps=args.reps)
    retraces = run_retrace_sweep(max_obs=256 if args.quick else 1024)
    target = [s for B, s in results if B == 256]
    if target:
        print(f"# CLAIM issue6 'bank ask >= 50x the Python loop at 256 "
              f"studies': {target[0]:.1f}x -> "
              f"{'PASS' if target[0] >= 50.0 else 'FAIL'}")
    print(f"# CLAIM issue6 'zero steady-state retraces across the growth "
          f"sweep': {retraces} -> {'PASS' if retraces == 0 else 'FAIL'}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "multi_study", "rows": ROWS}, f,
                      indent=1)
        print(f"# wrote {len(ROWS)} rows to {args.json}")
    if retraces:
        sys.exit(1)


if __name__ == "__main__":
    main()
