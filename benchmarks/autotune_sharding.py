"""Mango autotunes the framework's OWN distribution config (beyond-paper).

The paper's batched-GP-bandit search applied to a systems surface: each
trial spawns a dry-run subprocess (lower + compile + roofline analysis) for
one (arch x shape) cell with a candidate configuration of

    microbatches x remat policy x MoE capacity factor x CE chunk x
    attention q-chunk x sequence parallelism x attention fallback,

and the objective is the negated bottleneck (max of the three roofline
terms).  Trials that fail to compile return nothing — the scheduler-style
partial-result contract in its natural systems habitat.

  PYTHONPATH=src python -m benchmarks.autotune_sharding \
      --arch qwen2-moe-a2.7b --shape train_4k --iterations 4 --batch 2
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

from repro.core import Tuner

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "autotune"


def make_trial(arch: str, shape: str, mesh: str):
    def trial(par) -> float:
        tag = f"at{abs(hash(tuple(sorted(par.items())))) % 10 ** 8}"
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--tag", tag, "--out", str(OUT),
               "--micro", str(int(par["micro"])),
               "--remat", par["remat"],
               "--capacity-factor", str(par["capacity_factor"]),
               "--ce-chunk", str(int(par["ce_chunk"])),
               "--attn-q-chunk", str(int(par["attn_q_chunk"]))]
        if par["seq_parallel"] == "on":
            cmd.append("--seq-parallel")
        if par["zero"] == "zero1":
            cmd.append("--zero1")
        p = subprocess.run(cmd, capture_output=True, text=True, timeout=1500,
                           env={"PYTHONPATH": str(ROOT / "src"),
                                "PATH": "/usr/bin:/bin"},
                           cwd=str(ROOT))
        art = OUT / f"{arch}__{shape}__{mesh}__{tag}.json"
        if p.returncode != 0 or not art.exists():
            raise RuntimeError(f"compile failed: {p.stdout[-300:]}")
        d = json.loads(art.read_text())
        r = d["roofline"]
        bottleneck = max(r["t_compute_s"], r["t_memory_s"],
                         r["t_collective_s"])
        print(f"#   trial {par} -> bottleneck {bottleneck:.2f}s "
              f"(dominant {r['dominant']})", flush=True)
        return -bottleneck

    return trial


SPACE = {
    "micro": [1, 2, 4, 8, 16],
    "remat": ["none", "dots", "full"],
    "capacity_factor": [1.0, 1.25, 1.5],
    "ce_chunk": [256, 512, 1024],
    "attn_q_chunk": [256, 512, 1024],
    "seq_parallel": ["off", "on"],
    "zero": ["zero3", "zero1"],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--iterations", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)

    trial = make_trial(args.arch, args.shape, args.mesh)

    def objective(params_list):
        evals, params = [], []
        for par in params_list:
            try:
                evals.append(trial(par))
                params.append(par)
            except Exception as e:  # failed compile -> dropped result
                print(f"#   trial failed: {e}", flush=True)
        return evals, params

    t0 = time.time()
    res = Tuner(SPACE, objective, dict(
        optimizer="bayesian", batch_size=args.batch,
        num_iteration=args.iterations, initial_random=2, seed=0,
        mc_samples=2000, fit_steps=15,
        checkpoint_path=str(OUT / "tuner_state.json"))).maximize()
    print(json.dumps({
        "cell": f"{args.arch}/{args.shape}/{args.mesh}",
        "best_bottleneck_s": -res.best_objective,
        "best_config": res.best_params,
        "trials_observed": len(res.objective_values),
        "trials_failed": res.n_failed,
        "wall_min": round((time.time() - t0) / 60, 1),
    }, indent=2, default=str))


if __name__ == "__main__":
    main()
