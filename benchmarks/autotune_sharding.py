"""Mango tunes the framework's OWN stack (beyond-paper, ROADMAP scenario).

Two searches over the repo's real workload surface, both driven through the
production ``Tuner`` on *conditional* spaces (``Choice`` / ``Int`` /
``LogInt`` / constraint predicates — core/spaces.py):

  1. **Sharding-plan search** — for one config-registry cell
     (arch x shape x mesh size), a conditional space over the
     parallelism layout: the ``parallel`` root picks dp / tp4 / tp8
     (/ ep for MoE archs) and only that branch's knobs exist (``zero``
     matters only under pure-dp; ``capacity_factor`` only under expert
     parallelism).  The objective is ``hlo_cost.estimate_plan`` — the
     analytic roofline estimator (microseconds per plan, no compile) —
     and a constraint predicate rejects plans whose resident HBM
     exceeds the chip.  ``--validate`` re-scores the winner with the
     real lower+compile dry-run pipeline.

  2. **Pallas kernel tile search** — flash_attention (block_q, block_k)
     and ssm_scan (block_d, chunk) tile knobs with a *measured-runtime*
     objective (jit + interpret on CPU; real kernels on TPU), the
     classic block-size autotune shaped as an ask/tell study.

Emits the repo's ``name,us_per_call,derived`` rows (``--json`` for the CI
trajectory):

  autotune_ask_gp        us per GP ask/tell iteration on the conditional
                         space (gated in CI as a ratio to the random row)
  autotune_ask_random    same loop, random search — the same-run
                         normalization denominator (throttling-immune)
  autotune_objective     us per estimate_plan call

  PYTHONPATH=src python benchmarks/autotune_sharding.py --quick --json out.json
  PYTHONPATH=src python benchmarks/autotune_sharding.py --full --validate
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import repro.compat  # noqa: F401  (pins JAX_PLATFORMS=cpu on bare runners)
import numpy as np

from repro.configs import get_config, get_shape
from repro.core import Tuner, ParamSpace, Choice, LogInt, CHOICE_KEY
from repro.launch.hlo_cost import estimate_plan

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "autotune"

ROWS = []


def _emit(name, us, derived):
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


# --------------------------------------------------------------------------
# scenario 1: conditional sharding-plan search on the analytic cost model
# --------------------------------------------------------------------------

def sharding_space(cfg, shape, n_devices):
    """(ParamSpace knobs, config->plan mapping, constraints)."""
    branches = {
        "dp": {"zero": ["zero1", "zero3"]},
        "tp4": {"seq_parallel": [0, 1]},
        "tp8": {"seq_parallel": [0, 1]},
    }
    if cfg.n_experts:
        branches["ep"] = {"capacity_factor": [1.0, 1.25, 1.5]}
    space = {
        "parallel": Choice(branches),
        "remat": ["none", "dots", "full"],
        "micro": LogInt(1, 16),
    }

    def plan_of(c):
        p = c["parallel"]
        br = p[CHOICE_KEY]
        plan = {"remat": c["remat"], "micro": int(c["micro"]),
                "zero": p.get("zero", "zero3"),
                "tp": {"dp": 1, "tp4": 4, "tp8": 8, "ep": 1}[br],
                "seq_parallel": bool(p.get("seq_parallel", 0)),
                "ep": br == "ep"}
        if "capacity_factor" in p:
            plan["capacity_factor"] = float(p["capacity_factor"])
        return plan

    constraints = [lambda c: estimate_plan(cfg, shape, plan_of(c),
                                           n_devices)["fits"]]
    return space, plan_of, constraints


def run_sharding(args):
    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    n_dev = args.devices
    space, plan_of, cons = sharding_space(cfg, shape, n_dev)

    def objective(params_list):
        evals, params = [], []
        for par in params_list:
            est = estimate_plan(cfg, shape, plan_of(par), n_dev)
            if est["feasible"]:
                evals.append(-est["t_step_s"])
                params.append(par)
        return evals, params

    iters = 6 if args.quick else 20
    conf = dict(optimizer="bayesian", batch_size=2, num_iteration=iters,
                initial_random=2, seed=args.seed,
                mc_samples=2000 if args.quick else 5000,
                fit_steps=15 if args.quick else 40)

    # timed GP loop (the gated row) + random-search loop (its same-run
    # denominator: runner throttling moves both, the ratio stays clean)
    t0 = time.perf_counter()
    res = Tuner(ParamSpace(space, constraints=cons), objective, conf).maximize()
    t_gp = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_rand = Tuner(ParamSpace(space, constraints=cons), objective,
                     {**conf, "optimizer": "random"}).maximize()
    t_rand = time.perf_counter() - t0

    best_plan = plan_of(res.best_params)
    best = estimate_plan(cfg, shape, best_plan, n_dev)
    _emit("autotune_ask_gp", t_gp / iters * 1e6,
          f"best_step={-res.best_objective:.4f}s")
    _emit("autotune_ask_random", t_rand / iters * 1e6,
          f"best_step={-res_rand.best_objective:.4f}s")

    # objective latency row (cheapness claim: thousands of plans/second)
    reps = 200
    t0 = time.perf_counter()
    for _ in range(reps):
        estimate_plan(cfg, shape, best_plan, n_dev)
    _emit("autotune_objective", (time.perf_counter() - t0) / reps * 1e6,
          f"cell={args.arch}/{args.shape}/n{n_dev}")

    summary = {
        "cell": f"{args.arch}/{args.shape}/n{n_dev}",
        "best_plan": best_plan,
        "best_step_s": -res.best_objective,
        "best_hbm_gb": round(best["hbm_gb"], 2),
        "dominant": best["dominant"],
        "random_best_step_s": -res_rand.best_objective,
        "trials": len(res.objective_values),
        "gp_vs_random_gain": (
            (-res_rand.best_objective) / max(-res.best_objective, 1e-12)),
    }
    if args.validate:
        summary["dryrun"] = validate_with_dryrun(args, best_plan)
    return summary


def validate_with_dryrun(args, plan):
    """Re-score the winner through the real lower+compile pipeline.

    The subprocess inherits the parent environment (plus a defaulted
    JAX_PLATFORMS) — a scrubbed env used to drop JAX_PLATFORMS, which let
    the TPU plugin stall on GCP metadata lookups on bare CI runners.
    """
    tag = "autotune-best"
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", args.arch, "--shape", args.shape, "--mesh", "single",
           "--tag", tag, "--out", str(OUT),
           "--micro", str(plan["micro"]), "--remat", plan["remat"]]
    if plan.get("seq_parallel"):
        cmd.append("--seq-parallel")
    if plan.get("zero") == "zero1":
        cmd.append("--zero1")
    if plan.get("ep"):
        cmd += ["--ep", "--capacity-factor",
                str(plan.get("capacity_factor", 1.25))]
    p = subprocess.run(cmd, capture_output=True, text=True, timeout=1800,
                       env=env, cwd=str(ROOT))
    art = OUT / f"{args.arch}__{args.shape}__single__{tag}.json"
    if p.returncode != 0 or not art.exists():
        return {"error": (p.stdout + p.stderr)[-400:]}
    d = json.loads(art.read_text())
    return {"roofline": d["roofline"], "t_compile_s": d.get("t_compile_s")}


# --------------------------------------------------------------------------
# scenario 2: Pallas kernel tile search, measured-runtime objective
# --------------------------------------------------------------------------

def _measure(make_fn, reps=3):
    """Median seconds/call of a jitted thunk, compile excluded."""
    import jax
    fn = make_fn()
    jax.block_until_ready(fn())  # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run_kernels(args):
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import sdpa
    from repro.kernels.ssm_scan.ops import selective_scan

    S = 256 if args.quick else 512
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, S, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, 2, 64), jnp.float32)

    Ssm, di, N = (128, 128, 8) if args.quick else (256, 256, 16)
    A = jax.random.uniform(ks[0], (1, Ssm, di, N), jnp.float32, 0.5, 0.999)
    Bx = jax.random.normal(ks[1], (1, Ssm, di, N), jnp.float32) * 0.1
    Cc = jax.random.normal(ks[2], (1, Ssm, N), jnp.float32)

    # one conditional study over both kernels: the Choice root selects the
    # kernel, each branch carries that kernel's tile knobs, and the
    # objective measures the *active* kernel normalized to its own
    # default-tile runtime (so branches are comparable and the argmax is
    # "which kernel gains most from retiling, and with which tiles")
    t_flash0 = _measure(lambda: (lambda: sdpa(q, k, v, causal=True,
                                              interpret=True,
                                              block_q=128, block_k=128)))
    t_ssm0 = _measure(lambda: (lambda: selective_scan(
        A, Bx, Cc, block_d=min(512, di), chunk=64)))

    space = {"kernel": Choice({
        "flash_attention": {"block_q": [32, 64, 128, 256],
                            "block_k": [32, 64, 128, 256]},
        "ssm_scan": {"block_d": [32, 64, 128],
                     "chunk": [16, 32, 64]},
    })}
    cons = [lambda c: (c["kernel"].get("block_q", 1) <= S
                       and c["kernel"].get("block_k", 1) <= S
                       and di % c["kernel"].get("block_d", 1) == 0
                       and Ssm % c["kernel"].get("chunk", 1) == 0)]

    measured = {}

    def objective(params_list):
        evals, params = [], []
        for par in params_list:
            kc = par["kernel"]
            if kc[CHOICE_KEY] == "flash_attention":
                bq, bk = kc["block_q"], kc["block_k"]
                t = _measure(lambda: (lambda: sdpa(
                    q, k, v, causal=True, interpret=True,
                    block_q=bq, block_k=bk)))
                rel = t / t_flash0
            else:
                bd, ck = kc["block_d"], kc["chunk"]
                t = _measure(lambda: (lambda: selective_scan(
                    A, Bx, Cc, block_d=bd, chunk=ck)))
                rel = t / t_ssm0
            measured[json.dumps(kc, sort_keys=True)] = t
            evals.append(-rel)
            params.append(par)
        return evals, params

    iters = 4 if args.quick else 12
    res = Tuner(ParamSpace(space, constraints=cons), objective,
                dict(optimizer="bayesian", batch_size=1,
                     num_iteration=iters, initial_random=2, seed=args.seed,
                     mc_samples=2000, fit_steps=10)).maximize()
    best = res.best_params["kernel"]
    return {
        "flash_default_s": t_flash0, "ssm_default_s": t_ssm0,
        "best_kernel_config": best,
        "best_rel_runtime": -res.best_objective,
        "trials": len(res.objective_values),
        "measured": {k: round(v, 5) for k, v in measured.items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json")
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true",
                    help="re-score the sharding winner via the real "
                         "lower+compile dry-run (minutes)")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()
    if not args.full:
        args.quick = True
    OUT.mkdir(parents=True, exist_ok=True)

    t0 = time.time()
    doc = {"sharding": run_sharding(args)}
    if not args.skip_kernels:
        doc["kernels"] = run_kernels(args)
    doc["rows"] = ROWS
    doc["wall_s"] = round(time.time() - t0, 1)
    print(json.dumps({k: v for k, v in doc.items() if k != "rows"},
                     indent=2, default=str))
    if args.json:
        Path(args.json).write_text(json.dumps(doc, indent=2, default=str))


if __name__ == "__main__":
    main()
