"""Batch-size scaling (paper §2.4: batch_size is the per-job parallelism).

Measures iterations-to-target on the mixed Branin as batch size grows —
the parallel-efficiency view of the hallucination strategy: bigger batches
cost more evals but fewer synchronous rounds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.fig3_branin import SPACE, _objective_factory
from repro.core import Tuner

TARGET = 2.0  # minimize: reach f <= 2.0


def run(repeats=3, n_iters=25):
    rows = []
    for batch in (1, 2, 5, 10):
        iters_needed, evals_needed = [], []
        for rep in range(repeats):
            res = Tuner(SPACE, _objective_factory(), dict(
                optimizer="bayesian", batch_size=batch,
                num_iteration=n_iters, initial_random=2, seed=2000 + rep,
                mc_samples=1200, fit_steps=12)).minimize()
            vals = res.objective_values
            best = np.inf
            hit_eval = None
            for i, v in enumerate(vals):
                best = min(best, v)
                if best <= TARGET:
                    hit_eval = i + 1
                    break
            hit_iter = (np.ceil((hit_eval - 2) / batch)
                        if hit_eval and hit_eval > 2 else 1) \
                if hit_eval else n_iters
            iters_needed.append(float(hit_iter))
            evals_needed.append(float(hit_eval or len(vals)))
        rows.append((batch, float(np.mean(iters_needed)),
                     float(np.mean(evals_needed))))
    return rows
