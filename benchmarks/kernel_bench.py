"""Kernel micro-benchmarks.

On this CPU container, wall-times of the Pallas kernels are measured in
interpret mode (a correctness path, NOT TPU performance) — reported alongside
the jit'd jnp-oracle timing at the same shape, plus the analytic FLOPs so a
GFLOP/s "derived" column exists.  TPU numbers come from running the same
entry points with interpret=False on hardware.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: B2 H8 KV2 S1024 hd64
    from repro.kernels.flash_attention.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, H, KV, S, hd = 2, 8, 2, 1024, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    flops = 4 * B * H * S * S * hd * 0.5
    us_ref = _time(jax.jit(lambda q, k, v: attention_ref(q, k, v)), q, k, v)
    rows.append(("flash_attention_oracle_b2h8s1024", us_ref,
                 f"{flops / us_ref / 1e3:.1f}GFLOPs_cpu"))
    us_pal = _time(lambda q, k, v: flash_attention(q, k, v, block_q=128,
                                                   block_k=128), q, k, v)
    rows.append(("flash_attention_interpret", us_pal, "correctness_path"))

    # ssm scan: B2 S2048 di256 N16
    from repro.kernels.ssm_scan.ref import ssm_scan_ref
    from repro.kernels.ssm_scan.ssm_scan import ssm_scan
    B, S, di, N = 2, 2048, 256, 16
    A = jax.random.uniform(ks[0], (B, S, di, N), jnp.float32, 0.8, 0.999)
    Bx = jax.random.normal(ks[1], (B, S, di, N), jnp.float32) * 0.1
    C = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    us_ref = _time(jax.jit(ssm_scan_ref), A, Bx, C)
    elems = B * S * di * N * 3
    rows.append(("ssm_scan_oracle_b2s2048", us_ref,
                 f"{elems / us_ref / 1e3:.1f}GElem_cpu"))
    us_pal = _time(lambda a, b, c: ssm_scan(a, b, c, block_d=128, chunk=128),
                   A, Bx, C)
    rows.append(("ssm_scan_interpret", us_pal, "correctness_path"))

    # mlstm chunk: B1 NH4 S1024 dh128
    from repro.kernels.mlstm_chunk.mlstm_chunk import mlstm_chunk
    from repro.kernels.mlstm_chunk.ref import mlstm_ref
    B, NH, S, dh = 1, 4, 1024, 128
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, NH, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, NH, S, dh), jnp.float32) * dh ** -0.5
    v = jax.random.normal(ks[2], (B, NH, S, dh), jnp.float32)
    li = jax.random.normal(ks[3], (B, NH, S), jnp.float32)
    lf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, NH, S)) - 1.0)
    us_ref = _time(jax.jit(mlstm_ref), q, k, v, li, lf)
    rows.append(("mlstm_recurrent_oracle_s1024", us_ref, "sequential_ref"))
    us_pal = _time(lambda *a: mlstm_chunk(*a, chunk=128), q, k, v, li, lf)
    rows.append(("mlstm_chunk_interpret", us_pal, "correctness_path"))

    # gp acquisition: S=8192 candidates, n=256 train, d=8
    from repro.kernels.gp_acquisition.ref import matern52, ucb_scores_ref
    rng = np.random.default_rng(0)
    n, d, Sc = 256, 8, 8192
    X = jnp.asarray(rng.uniform(size=(n, d)), jnp.float32)
    mask = jnp.ones((n,), jnp.float32)
    Km = matern52(X * 2.0, X * 2.0, 1.0, 1.0) + 0.01 * jnp.eye(n)
    Kinv = jnp.linalg.inv(Km)
    alpha = Kinv @ jnp.asarray(rng.normal(size=n), jnp.float32)
    Cands = jnp.asarray(rng.uniform(size=(Sc, d)), jnp.float32)
    f = jax.jit(lambda c: ucb_scores_ref(c * 2.0, X * 2.0, mask, Kinv,
                                         alpha, 1.0, 1.0, 0.01, 4.0))
    us_ref = _time(f, Cands)
    flops = 2 * Sc * n * (d + n + 1)
    rows.append(("gp_acquisition_oracle_s8192n256", us_ref,
                 f"{flops / us_ref / 1e3:.1f}GFLOPs_cpu"))
    return rows
