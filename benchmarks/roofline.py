"""Roofline table generator: reads dry-run artifacts -> CSV / markdown."""
from __future__ import annotations

import glob
import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(tag="baseline"):
    rows = []
    for f in sorted(glob.glob(str(ARTIFACTS / f"*__{tag}.json"))):
        d = json.load(open(f))
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "tag": tag,
            "compute_s": r["t_compute_s"], "memory_s": r["t_memory_s"],
            "collective_s": r["t_collective_s"],
            "dominant": r["dominant"].replace("t_", "").replace("_s", ""),
            "fraction": r["roofline_fraction"],
            "useful_ratio": d.get("useful_flops_ratio") or 0.0,
            "model_flops": d.get("model_flops", 0),
            "hlo_flops_global": d.get("hlo_flops_global", 0),
            "n_micro": d.get("n_microbatches"),
        })
    return rows


def csv_rows(tag="baseline"):
    out = []
    for r in load(tag):
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        derived = (f"dom={r['dominant']};frac={r['fraction']:.3f};"
                   f"useful={r['useful_ratio']:.2f}")
        out.append((name, us, derived))
    return out


def markdown(tag="baseline") -> str:
    rows = load(tag)
    lines = ["| arch | shape | mesh | compute(s) | memory(s) | collective(s)"
             " | dominant | roofline frac | useful FLOPs ratio |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {r['fraction']:.3f} | {r['useful_ratio']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown())
