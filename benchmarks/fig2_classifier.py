"""Fig. 2 analogue: tuning a boosted-trees classifier on the wine-like task.

Space mirrors the paper's Listing 1 (XGBClassifier).  Compared algorithms:
  serial:   mango-bayesian(b=1), tpe(b=1), random(b=1)
  parallel: mango-bayesian(b=5), mango-clustering(b=5), tpe(b=5), random(b=5)

Paper claims reproduced (checked by run.py):
  C1: every BO strategy beats random search,
  C2: Mango serial >= TPE serial (slightly better),
  C3: Mango parallel >= TPE parallel at <= 40 iterations.
"""
from __future__ import annotations

from scipy.stats import uniform

from benchmarks.optimizers import run_algorithms
from benchmarks.surrogate import cv_accuracy, make_wine

SPACE = {
    "learning_rate": uniform(0, 1),
    "gamma": uniform(0, 5),
    "max_depth": range(1, 11),
    "n_estimators": range(1, 300),
    "booster": ["gbtree", "gblinear", "dart"],
}


def _objective_factory():
    X, y = make_wine()

    def objective(params_list):
        evals, params = [], []
        for p in params_list:
            try:
                evals.append(cv_accuracy(p, X, y))
                params.append(p)
            except Exception:
                pass
        return evals, params

    return objective


def run(n_iters=20, repeats=3, parallel_batch=5):
    serial = {
        "mango-serial": dict(optimizer="bayesian", batch_size=1),
        "tpe-serial": dict(optimizer="tpe", batch_size=1),
        "random-serial": dict(optimizer="random", batch_size=1),
    }
    par = {
        "mango-parallel": dict(optimizer="bayesian",
                               batch_size=parallel_batch),
        "mango-clustering": dict(optimizer="clustering",
                                 batch_size=parallel_batch),
        "tpe-parallel": dict(optimizer="tpe", batch_size=parallel_batch),
        "random-parallel": dict(optimizer="random",
                                batch_size=parallel_batch),
    }
    traces = run_algorithms(SPACE, _objective_factory, {**serial, **par},
                            n_iters, repeats, maximize=True)
    return traces
