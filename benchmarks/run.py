"""Benchmark entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the repo contract) plus a
claims-check section validating the paper's Fig. 2/3 statements against this
run.  ``--full`` uses paper-scale repeats/iterations (slow on 1 CPU);
the default is a moderate configuration sized for this container.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-fig2", action="store_true")
    args = ap.parse_args()

    repeats2, iters2 = (10, 40) if args.full else (3, 15)
    repeats3, iters3 = (10, 30) if args.full else (5, 15)

    print("# === Fig 3: modified mixed-variable Branin (minimize) ===")
    from benchmarks import fig3_branin
    t0 = time.time()
    tr3 = fig3_branin.run(n_iters=iters3, repeats=repeats3)
    for name, trace in tr3.items():
        final = trace[:, -1].mean()
        emit(f"fig3_branin_{name}", (time.time() - t0) / max(len(tr3), 1)
             * 1e6 / repeats3, f"best_final={final:.3f}")
    m_s = tr3["mango-serial"][:, -1].mean()
    t_s = tr3["tpe-serial"][:, -1].mean()
    m_p = tr3["mango-parallel"][:, -1].mean()
    t_p = tr3["tpe-parallel"][:, -1].mean()
    r_s = tr3["random-serial"][:, -1].mean()
    print(f"# CLAIM fig3 'Mango outperforms Hyperopt in serial': "
          f"{m_s:.3f} <= {t_s:.3f} -> {'PASS' if m_s <= t_s + 0.05 else 'FAIL'}")
    print(f"# CLAIM fig3 'Mango outperforms Hyperopt in parallel': "
          f"{m_p:.3f} <= {t_p:.3f} -> {'PASS' if m_p <= t_p + 0.05 else 'FAIL'}")
    print(f"# CLAIM fig3 'BO beats random': {m_s:.3f} <= {r_s:.3f} -> "
          f"{'PASS' if m_s <= r_s + 1e-9 else 'FAIL'}")

    if not args.skip_fig2:
        print("# === Fig 2: GBM-on-wine classifier tuning (maximize) ===")
        from benchmarks import fig2_classifier
        t0 = time.time()
        tr2 = fig2_classifier.run(n_iters=iters2, repeats=repeats2)
        for name, trace in tr2.items():
            emit(f"fig2_wine_{name}", (time.time() - t0) / max(len(tr2), 1)
                 * 1e6 / repeats2, f"best_acc={trace[:, -1].mean():.4f}")
        ms = tr2["mango-serial"][:, -1].mean()
        ts = tr2["tpe-serial"][:, -1].mean()
        mp = tr2["mango-parallel"][:, -1].mean()
        mc = tr2["mango-clustering"][:, -1].mean()
        tp = tr2["tpe-parallel"][:, -1].mean()
        rnd = tr2["random-parallel"][:, -1].mean()
        print(f"# CLAIM fig2 'all BO >= random (within noise)': "
              f"min(BO)={min(ms, mp, mc, tp):.4f} vs random={rnd:.4f} -> "
              f"{'PASS' if min(ms, mp, mc, tp) >= rnd - 0.01 else 'FAIL'}")
        print(f"# CLAIM fig2 'Mango serial slightly better than Hyperopt "
              f"serial': {ms:.4f} vs {ts:.4f} -> "
              f"{'PASS' if ms >= ts - 0.005 else 'FAIL'}")
        print(f"# CLAIM fig2 'Mango parallel >= Hyperopt parallel "
              f"(<=40 iters)': {max(mp, mc):.4f} vs {tp:.4f} -> "
              f"{'PASS' if max(mp, mc) >= tp - 0.005 else 'FAIL'}")

    print("# === Batch-size scaling (hallucination strategy) ===")
    from benchmarks import batch_scaling
    for batch, iters, evals in batch_scaling.run(
            repeats=3 if not args.full else 5):
        emit(f"batch_scaling_b{batch}", iters * 1e6,
             f"iters_to_target={iters:.1f};evals={evals:.1f}")

    print("# === Kernel micro-benchmarks ===")
    from benchmarks import kernel_bench
    for name, us, derived in kernel_bench.run():
        emit(name, us, derived)

    print("# === Roofline (from dry-run artifacts) ===")
    from benchmarks import roofline
    for name, us, derived in roofline.csv_rows("baseline"):
        emit(name, us, derived)


if __name__ == "__main__":
    main()
