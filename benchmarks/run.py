"""Benchmark entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the repo contract) plus a
claims-check section validating the paper's Fig. 2/3 statements against this
run.  ``--full`` uses paper-scale repeats/iterations (slow on 1 CPU);
the default is a moderate configuration sized for this container.
"""
from __future__ import annotations

import argparse


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-fig2", action="store_true")
    args = ap.parse_args()

    # Figs. 2/3 now run through the unified Mango-vs-TPE harness
    # (benchmarks/paper_figures.py) — claims logic lives there only.
    from benchmarks.paper_figures import run_figures
    grid = "full" if args.full else "default"
    figs = ["fig3"] if args.skip_fig2 else ["fig3", "fig2"]
    run_figures(figs, grid=grid)

    print("# === Batch-size scaling (hallucination strategy) ===")
    from benchmarks import batch_scaling
    for batch, iters, evals in batch_scaling.run(
            repeats=3 if not args.full else 5):
        emit(f"batch_scaling_b{batch}", iters * 1e6,
             f"iters_to_target={iters:.1f};evals={evals:.1f}")

    print("# === Kernel micro-benchmarks ===")
    from benchmarks import kernel_bench
    for name, us, derived in kernel_bench.run():
        emit(name, us, derived)

    print("# === Roofline (from dry-run artifacts) ===")
    from benchmarks import roofline
    for name, us, derived in roofline.csv_rows("baseline"):
        emit(name, us, derived)


if __name__ == "__main__":
    main()
