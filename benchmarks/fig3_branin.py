"""Fig. 3 analogue: modified mixed-variable Branin (Halstrup 2016 flavor).

x1 is continuous on [-5, 10]; x2 is *discretized* to the 16 integer levels
of [0, 15]; a categorical switch adds a constant shelf to one branch.  The
global minimum stays at the classic Branin basins (f* ~= 0.4 at the discrete
x2 resolution).  Minimization; serial and batch-5 parallel regimes.
"""
from __future__ import annotations

import math

from scipy.stats import uniform

from benchmarks.optimizers import run_algorithms


def branin(x1: float, x2: float) -> float:
    a, b, c = 1.0, 5.1 / (4 * math.pi ** 2), 5 / math.pi
    r, s, t = 6.0, 10.0, 1 / (8 * math.pi)
    return (a * (x2 - b * x1 ** 2 + c * x1 - r) ** 2
            + s * (1 - t) * math.cos(x1) + s)


def modified_branin(p: dict) -> float:
    shelf = {"low": 0.0, "high": 12.0}[p["mode"]]
    return branin(p["x1"], float(p["x2"])) + shelf


SPACE = {
    "x1": uniform(-5, 15),      # [-5, 10]
    "x2": range(0, 16),         # discretized
    "mode": ["low", "high"],    # categorical shelf
}


def _objective_factory():
    def objective(params_list):
        return [modified_branin(p) for p in params_list], list(params_list)

    return objective


def run(n_iters=20, repeats=10, parallel_batch=5):
    algos = {
        "mango-serial": dict(optimizer="bayesian", batch_size=1),
        "tpe-serial": dict(optimizer="tpe", batch_size=1),
        "random-serial": dict(optimizer="random", batch_size=1),
        "mango-parallel": dict(optimizer="bayesian",
                               batch_size=parallel_batch),
        "tpe-parallel": dict(optimizer="tpe", batch_size=parallel_batch),
    }
    return run_algorithms(SPACE, _objective_factory, algos, n_iters,
                          repeats, maximize=False)
