"""Durable-service serving overhead: HTTP ask/tell vs the in-process bank.

What the durability layer costs per operation.  Three arms, same strategy
and study state:

  * ``inproc_ask``: ``StudyBank`` bank-of-one ``view.ask(1)`` — the raw
    engine, no journal, no HTTP.
  * ``service_ask``: ``TuningService.ask`` called in-process — adds the
    journal-then-apply write path (JSON frame, CRC, fsync) and dedup
    bookkeeping, but no network.
  * ``http_ask``: the same ask through ``ServiceClient`` against a
    ``ThreadingHTTPServer`` on localhost — the full deployment path.

Tell rows mirror the same three arms.  Asks are steady-state: proposals
are resolved (told failed) between timed reps so observation counts and
device shapes stay frozen.  The fsync dominates the service arm by
design — that is the durability price, reported, not hidden.

``--json PATH`` writes rows for the CI perf-trajectory archive.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
import threading
import time

ROWS = []


def _emit(name, us, note=""):
    ROWS.append({"name": name, "us_per_call": round(us, 1), "note": note})
    print(f"{name},{us:.1f},{note}", flush=True)


def _median_us(fn, reps=5, calls=20, setup=None):
    samples = []
    for _ in range(reps):
        if setup:
            setup()
        t0 = time.perf_counter()
        for _ in range(calls):
            fn()
        samples.append((time.perf_counter() - t0) / calls)
    samples.sort()
    return samples[len(samples) // 2] * 1e6


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--calls", type=int, default=20)
    args = ap.parse_args(argv)

    from repro.core.studybank import StudyBank
    from repro.service.client import ServiceClient
    from repro.service.server import (CrashPoints, TuningService, serve,
                                      space_from_spec)

    CFG = {"space": {"x": {"uniform": [-1.0, 2.0]},
                     "lr": {"loguniform": [1e-4, 1e-1]}},
           "max_studies": 2, "optimizer": "bayesian", "seed": 0,
           "mc_samples": 64, "fit_steps": 8}
    work = tempfile.mkdtemp(prefix="svc_bench_")

    def seed_study(ask, tell, n=12):
        for i in range(n):
            for t in ask():
                tell(t, 0.1 * i)

    # ---- in-process bank (no journal) ---------------------------------
    bank = StudyBank(space_from_spec(CFG["space"]), n_studies=1, seed=0,
                     mc_samples=CFG["mc_samples"],
                     fit_steps=CFG["fit_steps"])
    view = bank.studies[0]
    seed_study(lambda: view.ask(1), lambda t, v: view.tell(t.id, v))
    pend = []

    def inproc_ask():
        pend.extend(view.ask(1))

    def inproc_settle():
        while pend:
            view.tell_failed(pend.pop().id)

    us = _median_us(inproc_ask, calls=args.calls, setup=inproc_settle)
    _emit("service_inproc_ask", us, "bank view, no WAL")

    # ---- service core (WAL fsync, no HTTP) ----------------------------
    svc = TuningService(f"{work}/core", config=CFG, crash=CrashPoints(""))
    svc.create_study("s")
    seed_study(lambda: [type("T", (), t) for t in
                        svc.ask("s", 1)["trials"]],
               lambda t, v: svc.tell("s", t.id, v))
    sp = []

    def svc_ask():
        sp.extend(t["id"] for t in svc.ask("s", 1)["trials"])

    def svc_settle():
        while sp:
            svc.tell_failed("s", sp.pop())

    us = _median_us(svc_ask, calls=args.calls, setup=svc_settle)
    _emit("service_wal_ask", us, "journal-then-apply, fsync")
    ids = [t["id"] for t in svc.ask("s", args.calls)["trials"]]
    t0 = time.perf_counter()
    for tid in ids:
        svc.tell("s", tid, 1.0)
    _emit("service_wal_tell",
          (time.perf_counter() - t0) / len(ids) * 1e6, "fsync per tell")

    # ---- full HTTP path ----------------------------------------------
    httpd, hsvc = serve(f"{work}/http", port=0, config=CFG)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    cl = ServiceClient(f"http://127.0.0.1:{httpd.server_address[1]}")
    cl.create_study("s")
    seed_study(lambda: [type("T", (), t) for t in
                        cl.ask("s", 1)["trials"]],
               lambda t, v: cl.tell("s", t.id, v))
    hp = []

    def http_ask():
        hp.extend(t["id"] for t in cl.ask("s", 1)["trials"])

    def http_settle():
        while hp:
            cl.tell_failed("s", hp.pop())

    us = _median_us(http_ask, calls=args.calls, setup=http_settle)
    _emit("service_http_ask", us, "localhost HTTP round trip")
    ids = [t["id"] for t in cl.ask("s", args.calls)["trials"]]
    t0 = time.perf_counter()
    for tid in ids:
        cl.tell("s", tid, 1.0)
    _emit("service_http_tell",
          (time.perf_counter() - t0) / len(ids) * 1e6, "HTTP + fsync")

    httpd.shutdown()
    hsvc.close()
    svc.close()
    shutil.rmtree(work, ignore_errors=True)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(ROWS, fh, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
