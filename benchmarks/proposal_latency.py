"""Batch-proposal latency: seed Python-loop GP-BUCB vs the fused path.

Measures one steady-state tuner iteration of ``propose`` — exactly what the
tuner hot loop pays per iteration:

  * ``seed``: ``HallucinationStrategy`` — full O(fit_steps * n^3)
    hyperparameter refit, then a host-roundtripping Python loop over batch
    slots (posterior -> numpy UCB -> hallucinate) per proposal call.
  * ``fused``: ``FusedHallucinationStrategy`` — O(n^2) incremental Cholesky
    appends for the new observations plus one jit'd ``lax.fori_loop`` device
    program for the whole batch.

Grid: batch_size in {1, 4, 16} x n_obs in {16, 64, 256, 512}.  Emits the
repo's ``name,us_per_call,derived`` CSV rows: the fused row's headline
number is the steady-state propose call (the seed refits inside every
propose; the fused path doesn't — that *is* the optimization), and the
``amortized=`` field adds the periodic refit's share under the default
``refit_every=8`` schedule for the whole-loop view.  Acceptance target
(ISSUE 1): fused propose >= 3x at batch_size=4, n_obs=256.

Per n_obs it also emits ``refit_cold_n{n}`` vs ``refit_warm_n{n}``: the
refit-boundary hyperparameter re-tune from scratch vs warm-started from the
previous fit's log-params (ISSUE 2 — the warm path runs a short Adam polish,
``warm_fit_steps``, instead of the full ``fit_steps`` schedule); the
amortized number uses the warm cost, since that is what a steady-state
tuner loop pays.

ISSUE-3 sections (the finished on-device proposal stack):

  * ``pallas_pending_{host,fused}``: async replacement pick on the Pallas
    scorer with in-flight trials — host absorb loop (one device program per
    pending trial) vs the single fused program whose absorb phase tracks
    K^{-1} via in-program Schur appends.
  * ``pallas_rescore_{full,downdate}``: the per-slot rescore op across
    training-set size n — full scoring kernel (O(n^2 S)) vs the in-kernel
    rank-1 variance downdate (O(n S)); the growth across n rows is the
    point.
  * ``clustering_{host,fused}``: clustering batch proposal, host pipeline
    (acquisition surface + top-slice + k-means on host) vs the one-program
    device pipeline (wash on CPU; on accelerators it removes the (n_mc,)
    device->host transfer per ask).

ISSUE-5 section (the conditioning-hardened shared scoring core):

  * ``kinv_f32_schur_{n}`` vs ``kinv_f64_schur_{n}``: one per-slot rescore
    op (rank-1 system extension + variance downdate) on the legacy float32
    K^{-1} Schur path vs the hardened factor path (float64 Schur
    accumulation when x64 is enabled, one iterative-refinement step on
    float32-only backends).  Acceptance: hardened <10% over f32 at n=1024.

ISSUE-10 sections (bank-of-one: every single-study strategy now serves
asks through the bucketed ``StudyBank`` pipeline):

  * ``single_study_ask_{gp,tpe,clustering}``: one steady-state
    ``AskTellOptimizer.ask`` per strategy — the whole serving path the
    refactor unified (columnar candidate draw -> bucketed gather ->
    staged vmap'd device program -> one exit sync).  ``single_study_asks``
    is the mean of the three; it is the CI-gated row
    (``single_study_asks:1.25``), normalized by ``bench_delta`` against
    the same-run ``single_study_random`` row (a random-strategy ask —
    pure host work, so runner throttling moves both and the gate blocks
    only on the bank serving overhead itself regressing).
  * ``time_to_1000_asks``: measured ask+tell_failed rounds on the
    bank-of-one GP path, extrapolated to 1000 asks — the steady-state
    serving headline.
  * ``single_study_retrace``: the single-study zero-retrace proof.  Each
    of GP / TPE / clustering grows 64 -> 1024 observations through
    ``AskTellOptimizer``; every bank entry point (``gp.BANK_JITS`` +
    ``fused_tpe_propose_bank``) may compile once per power-of-2 bucket it
    is dispatched at, and the row's value is the summed excess jit-cache
    growth (nonzero exits 1 — the CI bench job fails).

All paired rows are timed with *interleaved* reps (``_interleaved_medians``)
so this container's bursty CPU-share throttling hits every path equally;
``bench_delta.py`` additionally normalizes derived rows against the same
run's baseline row before flagging regressions.

``--json PATH`` additionally writes every emitted row as JSON so CI can
archive the perf trajectory (``BENCH_*.json``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

ROWS = []   # every emitted row, for --json


def _emit(name, us, derived):
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


def _interleaved_medians(calls, reps=3, setups=None):
    """Median seconds per call, with the calls *interleaved within each
    rep*: this container's CPU shares are throttled in bursts, so timing
    each path in its own contiguous window skews the *ratio* between paths
    — interleaving exposes every path to the same bursts (and the CI
    bench-delta job then normalizes derived rows against the same-run
    baseline row, so the trajectory comparison sees throttle-free ratios).
    ``setups[i]`` runs untimed before each timed ``calls[i]``.

    One untimed setup+call round runs first: the steady-state op sequence
    can differ from the caller's own warmup (e.g. the incremental GP's
    append programs only compile on the first post-reset call), and a
    stray compile inside a timed rep poisons small-reps medians.
    """
    samples = [[] for _ in calls]
    for i, c in enumerate(calls):        # warmup: compile the timed path
        if setups is not None and setups[i] is not None:
            setups[i]()
        c()
    for _ in range(reps):
        for i, c in enumerate(calls):
            if setups is not None and setups[i] is not None:
                setups[i]()
            t0 = time.perf_counter()
            c()
            samples[i].append(time.perf_counter() - t0)
    return [float(np.median(s)) for s in samples]


def _time_full_fit(strategy, X, y, reps=3):
    """Median seconds for a full from-scratch observe (hyperparameter tune)."""
    import jax

    times = []
    for _ in range(reps):
        strategy.gp.state = None
        strategy.gp.n_fit = 0
        strategy.gp._fit_params = None    # cold: default Adam init
        t0 = time.perf_counter()
        st = strategy.gp.observe(X, y)
        jax.block_until_ready((st.L, st.ls, st.var, st.noise))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _time_warm_refit(strategy, X, y, reps=3):
    """Median seconds for a refit-boundary re-tune: Adam warm-started from
    the previous fit's log-params (short polish run) instead of the full
    from-scratch schedule."""
    import jax

    n = len(y)
    times = []
    for _ in range(reps):
        strategy.gp.state = None
        strategy.gp.n_fit = 0
        strategy.gp._fit_params = None
        st = strategy.gp.fit(X[: n - 8], y[: n - 8])   # previous fit
        jax.block_until_ready((st.L, st.ls, st.var, st.noise))
        t0 = time.perf_counter()
        st = strategy.gp.fit(X, y)                     # warm refit
        jax.block_until_ready((st.L, st.ls, st.var, st.noise))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


DEFAULT_REFIT_EVERY = 8   # the Tuner default the amortized number models


def run_pallas_pending(n_obs_grid=(64, 256), n_pend=8, bs=4, n_cand=2000,
                       dim=4, fit_steps=40, reps=3, seed=0):
    """Async replacement pick on the Pallas scorer with in-flight trials.

    ``pallas_pending_host``: the seed path — one host round-trip
    (posterior + K^{-1} Schur append programs) per pending trial before the
    fused pick can even start.  ``pallas_pending_fused``: the absorb phase
    runs inside the one jit'd program (``fused_propose_pallas_pending``),
    and per-slot rescoring uses the in-kernel rank-1 variance downdate
    (O(n S) per slot, not O(n^2 S)).
    """
    from repro.core.strategies import FusedHallucinationStrategy

    rng = np.random.default_rng(seed)
    for n in n_obs_grid:
        X = rng.uniform(size=(n, dim)).astype(np.float32)
        y = np.sum(-(X - 0.5) ** 2, axis=-1).astype(np.float32)
        y += 0.05 * rng.normal(size=n).astype(np.float32)
        C = rng.uniform(size=(n_cand, dim)).astype(np.float32)
        P = rng.uniform(size=(n_pend, dim)).astype(np.float32)

        host = FusedHallucinationStrategy(dim, 1e6, fit_steps=fit_steps,
                                          refit_every=10 ** 9,
                                          use_pallas=True)
        fused = FusedHallucinationStrategy(dim, 1e6, fit_steps=fit_steps,
                                           refit_every=10 ** 9,
                                           use_pallas=True)

        def host_call():
            st = host.gp.observe(X, y)           # steady state: no-op pass
            st = host.gp.ensure_capacity(st, n_pend + bs)
            st = host._absorb_pending(st, P)     # one program per pending
            return host.pick_from_state(st, C, bs)

        def fused_call():
            return fused.propose(X, y, C, bs, pending=P)

        host_call()      # warm jit caches (and take the one-time GP fit)
        fused_call()
        t_host, t_fused = _interleaved_medians([host_call, fused_call],
                                               reps=reps)
        _emit(f"pallas_pending_host_bs{bs}_p{n_pend}_n{n}", t_host * 1e6,
              "speedup=1.0x")
        _emit(f"pallas_pending_fused_bs{bs}_p{n_pend}_n{n}", t_fused * 1e6,
              f"speedup={t_host / max(t_fused, 1e-12):.1f}x")


def run_perslot_rescore(n_grid=(64, 256, 1024), n_cand=2000, dim=4, reps=5,
                        seed=0):
    """The per-slot rescore op itself, old vs new, across training-set size.

    ``pallas_rescore_full``: re-run the full factor scoring kernel per slot
    (``t = k @ L^{-T}``: O(n^2 S)).  ``pallas_rescore_downdate``: the
    in-kernel rank-1 variance downdate (matvec against the cached cross-
    covariance block: O(n S)).  The *ratio across n rows* is the point:
    full rescoring grows ~quadratically with n, the downdate ~linearly.
    (The legacy K^{-1} UCB kernel these rows originally baselined was
    deleted with the K^{-1} path; the baseline is now the factor scorer.)
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.gp_acquisition.gp_acquisition import (
        score_cov_pallas, var_downdate_pallas)
    from repro.kernels.gp_acquisition.ref import matern52

    rng = np.random.default_rng(seed)
    dp = 8
    for n in n_grid:
        X = rng.uniform(size=(n, dim)).astype(np.float32) * 2.0
        Xs = np.zeros((n, dp), np.float32)
        Xs[:, :dim] = X
        mask = np.ones(n, np.float32)
        var, noise = 1.0, 0.05
        K = np.array(matern52(jnp.asarray(Xs), jnp.asarray(Xs), 1.0, var))
        K[np.diag_indices(n)] = var + noise
        import scipy.linalg as sla
        L = np.linalg.cholesky(K).astype(np.float32)
        Linv = sla.solve_triangular(L, np.eye(n, dtype=np.float32),
                                    lower=True).astype(np.float32)
        y = rng.normal(size=n).astype(np.float32)
        alpha = (Linv.T @ (Linv @ y)).astype(np.float32)
        Cs = np.zeros((n_cand, dp), np.float32)
        Cs[:, :dim] = rng.uniform(size=(n_cand, dim)).astype(np.float32) * 2

        args = (jnp.asarray(Cs), jnp.asarray(Xs), jnp.asarray(mask),
                jnp.asarray(Linv), jnp.asarray(alpha), jnp.float32(var),
                jnp.float32(noise))
        _, sig2, Kc = jax.block_until_ready(score_cov_pallas(*args))
        star = 7
        k_star = Kc[star]
        u = jnp.asarray(np.linalg.solve(K, np.asarray(k_star))
                        .astype(np.float32))
        schur = jnp.float32(var + noise) - k_star @ u

        def full_call():
            return jax.block_until_ready(score_cov_pallas(*args))

        def downdate_call():
            return jax.block_until_ready(var_downdate_pallas(
                jnp.asarray(Cs), jnp.asarray(Cs[star]), Kc, u, schur,
                sig2, jnp.float32(var)))

        full_call()
        downdate_call()
        t_full, t_dd = _interleaved_medians([full_call, downdate_call],
                                            reps=reps)
        _emit(f"pallas_rescore_full_n{n}", t_full * 1e6, "speedup=1.0x")
        _emit(f"pallas_rescore_downdate_n{n}", t_dd * 1e6,
              f"speedup={t_full / max(t_dd, 1e-12):.1f}x")


def run_kinv_hardening(n_grid=(256, 1024), n_cand=2000, dim=4, reps=5,
                       seed=0):
    """ISSUE-5 rows: the conditioning hardening's cost on the rescore path.

    One per-slot rescore op = rank-1 system extension + variance downdate
    against the cached cross-covariance block:

      * ``kinv_f32_schur_n{n}``: the legacy float32 K^{-1} Schur append
        (``gp._append_core_uv`` — triangular solves + full-matrix
        block-inverse rewrite) + the downdate kernel.  This is the PR-3
        path whose picks flipped on near-noiseless fits.
      * ``kinv_f64_schur_n{n}``: the hardened ``scoring.factor_append``
        (rank-1 (L, L^{-1}) extension; Schur solves accumulate in float64
        when the backend has x64 enabled, and carry one float32
        iterative-refinement step otherwise — the configuration measured
        here is whatever the running backend resolves to) + the same
        downdate kernel.

    Acceptance (ISSUE 5): the hardening costs <10% vs the float32 Schur
    path at n=1024.
    """
    import jax
    import jax.numpy as jnp
    import scipy.linalg as sla

    from repro.core import scoring
    from repro.core.gp import _append_core_uv
    from repro.kernels.gp_acquisition.gp_acquisition import (
        score_cov_pallas, var_downdate_pallas)
    from repro.kernels.gp_acquisition.ref import matern52

    rng = np.random.default_rng(seed)
    dp = 8
    out = []
    for n in n_grid:
        Xs = np.zeros((n, dp), np.float32)
        Xs[:, :dim] = rng.uniform(size=(n, dim)).astype(np.float32) * 2.0
        # last padded slot stays inactive: it is the slot both appends
        # extend into (identity row in L / Linv, zero in the mask)
        mask = np.ones(n, np.float32)
        mask[n - 1] = 0.0
        var, noise = 1.0, 0.05
        K = np.array(matern52(jnp.asarray(Xs), jnp.asarray(Xs), 1.0, var))
        K = K * mask[:, None] * mask[None, :]
        K[np.diag_indices(n)] = np.where(mask > 0, var + noise, 1.0)
        L = np.linalg.cholesky(K).astype(np.float32)
        Linv = sla.solve_triangular(L, np.eye(n, dtype=np.float32),
                                    lower=True).astype(np.float32)
        Kinv = np.linalg.inv(K).astype(np.float32)
        y = (rng.normal(size=n) * mask).astype(np.float32)
        alpha = (Linv.T @ (Linv @ y)).astype(np.float32)
        Cs = np.zeros((n_cand, dp), np.float32)
        Cs[:, :dim] = rng.uniform(size=(n_cand, dim)).astype(np.float32) * 2

        _, sig2, Kc = jax.block_until_ready(score_cov_pallas(
            jnp.asarray(Cs), jnp.asarray(Xs), jnp.asarray(mask),
            jnp.asarray(Linv), jnp.asarray(alpha), jnp.float32(var),
            jnp.float32(noise)))
        star = 7
        idx = jnp.int32(n - 1)   # extend into the inactive slot
        k_vec = Kc[star]         # masked cross-covariance row (zero at idx)

        @jax.jit
        def legacy_step(L, Kinv, Kc, sig2):
            L2, Kinv2, u, schur = _append_core_uv(L, Kinv, idx, k_vec,
                                                  jnp.float32(var),
                                                  jnp.float32(noise))
            sig2b, _ = var_downdate_pallas(jnp.asarray(Cs),
                                           jnp.asarray(Cs[star]), Kc, u,
                                           schur, sig2, jnp.float32(var))
            return L2, Kinv2, sig2b

        @jax.jit
        def hardened_step(L, Linv, Kc, sig2):
            L2, Linv2, u, schur = scoring.factor_append(
                L, Linv, idx, k_vec, jnp.float32(var), jnp.float32(noise))
            sig2b, _ = var_downdate_pallas(jnp.asarray(Cs),
                                           jnp.asarray(Cs[star]), Kc, u,
                                           schur, sig2, jnp.float32(var))
            return L2, Linv2, sig2b

        Lj, Linvj, Kinvj = (jnp.asarray(L), jnp.asarray(Linv),
                            jnp.asarray(Kinv))

        def legacy_call():
            return jax.block_until_ready(legacy_step(Lj, Kinvj, Kc, sig2))

        def hardened_call():
            return jax.block_until_ready(hardened_step(Lj, Linvj, Kc,
                                                       sig2))

        legacy_call()
        hardened_call()
        t_f32, t_hard = _interleaved_medians([legacy_call, hardened_call],
                                             reps=reps)
        overhead = (t_hard - t_f32) / t_f32 * 100.0
        _emit(f"kinv_f32_schur_n{n}", t_f32 * 1e6, "overhead=+0.0%")
        _emit(f"kinv_f64_schur_n{n}", t_hard * 1e6,
              f"overhead={overhead:+.1f}%")
        out.append((n, overhead))
    return out


def run_clustering(n_obs_grid=(64, 256), bs=4, n_cand=2000, dim=4,
                   fit_steps=40, reps=3, seed=0):
    """Clustering batch proposal: host pipeline (acquisition surface +
    top-slice + k-means all round-tripping through numpy) vs the fused
    device program (``fused_cluster_propose`` — only the ``(batch_size,)``
    indices leave the device)."""
    from repro.core.strategies import ClusteringStrategy

    rng = np.random.default_rng(seed)
    for n in n_obs_grid:
        X = rng.uniform(size=(n, dim)).astype(np.float32)
        y = np.sum(-(X - 0.5) ** 2, axis=-1).astype(np.float32)
        y += 0.05 * rng.normal(size=n).astype(np.float32)
        C = rng.uniform(size=(n_cand, dim)).astype(np.float32)

        host = ClusteringStrategy(dim, 1e6, fit_steps=fit_steps,
                                  refit_every=10 ** 9)
        fused = ClusteringStrategy(dim, 1e6, fit_steps=fit_steps,
                                   refit_every=10 ** 9)
        host.propose_host(X, y, C, bs, seed=0)   # warm jit + one-time fit
        fused.propose(X, y, C, bs, seed=0)
        t_host, t_fused = _interleaved_medians(
            [lambda: host.propose_host(X, y, C, bs, seed=0),
             lambda: fused.propose(X, y, C, bs, seed=0)], reps=reps)
        _emit(f"clustering_host_bs{bs}_n{n}", t_host * 1e6, "speedup=1.0x")
        _emit(f"clustering_fused_bs{bs}_n{n}", t_fused * 1e6,
              f"speedup={t_host / max(t_fused, 1e-12):.1f}x")


def run_tpe(n_cand_grid=(2048, 8192), n_obs_grid=(64, 256), bs=4, dim=4,
            reps=5, seed=0):
    """ISSUE-4 rows: the TPE baseline, host numpy vs device-resident.

    ``tpe_host``: the seed path — numpy good/bad split + the O(m n d)
    product-Parzen KDE materializing an (m, n, d) temporary per split, per
    propose call.  ``tpe_fused``: ``fused_tpe_propose`` — masked split,
    jnp KDE scoring and ``lax.top_k`` in one jit'd device program.
    ``tpe_pallas``: the same program scoring through the ``tpe_kde`` Pallas
    kernel (interpret mode on CPU — the correctness path; the row tracks
    the one-program contract, the CPU win belongs to ``tpe_fused``).

    The candidate grid starts at S=2048 because ``ParamSpace.mc_samples``
    floors at 2000 — a real ask never scores fewer; below ~1k candidates
    both paths sit in the ~2 ms dispatch/allocator-noise regime of this
    throttled 2-core container and the comparison measures the scheduler,
    not the algorithm.  Acceptance (ISSUE 4): fused >= 2x over host on
    every row with n_candidates >= 512.
    """
    from repro.core.tpe import TPEStrategy

    rng = np.random.default_rng(seed)
    out = []
    for n in n_obs_grid:
        X = rng.uniform(size=(n, dim)).astype(np.float32)
        y = np.sum(-(X - 0.5) ** 2, axis=-1).astype(np.float32)
        y += 0.05 * rng.normal(size=n).astype(np.float32)
        for S in n_cand_grid:
            C = rng.uniform(size=(S, dim)).astype(np.float32)
            host = TPEStrategy(dim, 1e6)
            fused = TPEStrategy(dim, 1e6)
            pallas = TPEStrategy(dim, 1e6, use_pallas=True)
            calls = [lambda: host.propose_host(X, y, C, bs),
                     lambda: fused.propose(X, y, C, bs),
                     lambda: pallas.propose(X, y, C, bs)]
            for c in calls:     # warm numpy allocator / jit caches
                c()
            t_host, t_fused, t_pal = _interleaved_medians(calls, reps=reps)
            _emit(f"tpe_host_bs{bs}_n{n}_S{S}", t_host * 1e6,
                  "speedup=1.0x")
            speedup = t_host / max(t_fused, 1e-12)
            _emit(f"tpe_fused_bs{bs}_n{n}_S{S}", t_fused * 1e6,
                  f"speedup={speedup:.1f}x")
            _emit(f"tpe_pallas_bs{bs}_n{n}_S{S}", t_pal * 1e6,
                  f"speedup={t_host / max(t_pal, 1e-12):.1f}x")
            out.append((n, S, speedup))
    return out


def _ask_space():
    from scipy import stats
    return {"x": stats.uniform(0, 1), "y": stats.uniform(-1, 2),
            "z": stats.uniform(0, 3)}


def _grow(opt, k, rng):
    for _ in range(k):
        p = {"x": float(rng.uniform(0, 1)), "y": float(rng.uniform(-1, 1)),
             "z": float(rng.uniform(0, 3))}
        opt.observe_params(p, float(rng.normal()))


def run_bank_of_one(n_obs=256, n_mc=64, reps=5, seed=0):
    """ISSUE-10 rows: the unified single-study serving path.

    Every bank-served strategy is timed on one steady-state
    ``AskTellOptimizer.ask`` (each rep's proposal is told *failed* in the
    untimed setup slot, so observation counts and every bucket shape stay
    frozen).  ``single_study_random`` — a random-strategy ask, pure host
    candidate draw with no device program — is the same-run normalization
    denominator for the gated ``single_study_asks`` mean:
    ``bench_delta`` compares the *ratio*, so shared-runner throttling
    (which moves host work and dispatch overhead together) stays
    advisory and the gate blocks only on the bank serving overhead
    itself regressing >25%.
    """
    from repro.core import AskTellOptimizer

    rng = np.random.default_rng(seed)
    names = [("random", "random"), ("gp", "bayesian"), ("tpe", "tpe"),
             ("clustering", "clustering")]
    opts, asked = {}, {}
    for label, strat in names:
        o = AskTellOptimizer(_ask_space(), optimizer=strat,
                             seed=seed + 1, mc_samples=n_mc)
        _grow(o, n_obs, rng)
        opts[label], asked[label] = o, []

    def setup(label):
        for t in asked[label]:
            opts[label].tell_failed(t.id)
        asked[label].clear()

    def call(label):
        asked[label].append(opts[label].ask(1)[0])

    import functools
    labels = [lb for lb, _ in names]
    meds = _interleaved_medians(
        [functools.partial(call, lb) for lb in labels], reps=reps,
        setups=[functools.partial(setup, lb) for lb in labels])
    t_rand = meds[0]
    _emit("single_study_random", t_rand * 1e6,
          f"baseline=1.0x,n_obs={n_obs}")
    for lb, t in zip(labels[1:], meds[1:]):
        _emit(f"single_study_ask_{lb}", t * 1e6,
              f"n_obs={n_obs},vs_random={t / max(t_rand, 1e-12):.1f}x")
    t_mean = float(np.mean(meds[1:]))
    _emit("single_study_asks", t_mean * 1e6,
          f"mean_of=gp/tpe/clustering,n_obs={n_obs},"
          f"vs_random={t_mean / max(t_rand, 1e-12):.1f}x")

    # time_to_1000_asks: real ask+tell_failed rounds (the tell is part of
    # what a serving loop pays), extrapolated from a measured burst
    gp_opt = opts["gp"]
    setup("gp")
    rounds = 20
    t = gp_opt.ask(1)[0]                # untimed settle round
    gp_opt.tell_failed(t.id)
    t0 = time.perf_counter()
    for _ in range(rounds):
        t = gp_opt.ask(1)[0]
        gp_opt.tell_failed(t.id)
    per_round = (time.perf_counter() - t0) / rounds
    _emit("time_to_1000_asks", per_round * 1000.0 * 1e6,
          f"per_round={per_round * 1e6:.0f}us,rounds_measured={rounds},"
          f"strategy=bayesian,n_obs={n_obs}")
    return t_mean


def run_single_study_retrace(max_obs=1024, n_mc=64, seed=0):
    """The single-study zero-retrace proof, one strategy at a time.

    The multi-study growth sweep (``multi_study.run_retrace_sweep``)
    pins the bucket schedule for ``ask_all``; this one pins the
    bank-of-one path those same programs now serve: three
    ``AskTellOptimizer`` instances (GP, clustering, TPE) each grow
    64 -> ``max_obs`` observations, asking twice at every bucket edge
    (edge-1 / edge / edge+1) and at interior points.  Each audited entry
    point — ``gp.BANK_JITS`` plus the TPE bank program — may compile
    once per power-of-2 bucket it is dispatched at; GP and clustering
    share the obs-stage programs (identical shapes -> cache hits for the
    second family), clustering adds only its pick head, TPE only its one
    fused program.  Emits the summed excess as ``single_study_retrace``
    and returns it (``main`` exits 1 when nonzero).
    """
    from repro.analysis.sanitizers import no_retrace
    from repro.core import AskTellOptimizer
    from repro.core import gp as gp_lib
    from repro.core import tpe as tpe_lib
    from repro.core.studybank import _pow2

    jits = dict(gp_lib.BANK_JITS)
    jits["fused_tpe_propose_bank"] = tpe_lib.fused_tpe_propose_bank

    rng = np.random.default_rng(seed)
    opts = {lb: AskTellOptimizer(_ask_space(), optimizer=strat,
                                 seed=seed + 1, mc_samples=n_mc)
            for lb, strat in [("gp", "bayesian"),
                              ("clustering", "clustering"),
                              ("tpe", "tpe")]}

    # same bucket-edge targets as the multi-study sweep: for each edge E
    # (na doubles at n_obs = E), visit E-1, E, E+1, plus mid-bucket
    pend_cap, n = 4, 1
    targets, na = [], 64
    while na <= max_obs:
        edge = na - pend_cap - n
        targets += [edge - 1, edge, edge + 1, edge + (edge // 2)]
        na *= 2
    targets = sorted(t for t in set(targets) if 58 <= t <= max_obs - 5)

    buckets, fit_buckets = set(), set()
    with no_retrace(jits=jits, raise_on_violation=False) as rep:
        for lb, opt in opts.items():
            for k in targets:
                _grow(opt, k - opt.n_observed, rng)
                na = _pow2(max(16, k + pend_cap + n))
                buckets.add(na)
                if lb != "tpe":
                    led = opt._led
                    if (led.have_fit[0] == 0
                            or k - int(led.n_fit[0]) >= opt.refit_every):
                        fit_buckets.add(na)
                # two asks per target: the first may compile (bucket
                # boundary), the second must be a pure cache hit
                for _ in range(2):
                    t = opt.ask(1)[0]
                    opt.tell_failed(t.id)
        nb = len(buckets)
        # one compile per bucket a program is dispatched at; prescale_C
        # depends only on mc_samples; absorb never runs (every trial is
        # told failed before the next ask)
        rep.expected = {
            "bank_factors": nb, "bank_prescale_X": nb,
            "bank_prescale_C": 1, "bank_absorb": 0, "bank_dist": nb,
            "bank_exp": nb, "bank_pick": nb, "bank_cluster_pick": nb,
            "fit_hypers_bank": len(fit_buckets),
            "fused_tpe_propose_bank": nb,
        }
    retraces = rep.violations
    detail = rep.detail() or "all=expected"
    _emit("single_study_retrace", float(retraces),
          f"retraces={retraces},boundaries={nb},strategies=3,{detail}")
    return retraces


def run(batch_sizes=(1, 4, 16), n_obs_grid=(16, 64, 256, 512),
        n_cand=2000, dim=4, fit_steps=40, reps=3, seed=0):
    from repro.core.strategies import (FusedHallucinationStrategy,
                                       HallucinationStrategy)

    rng = np.random.default_rng(seed)
    rows = []
    for n in n_obs_grid:
        X = rng.uniform(size=(n, dim)).astype(np.float32)
        y = np.sum(-(X - 0.5) ** 2, axis=-1).astype(np.float32)
        C = rng.uniform(size=(n_cand, dim)).astype(np.float32)
        # refit-boundary cost: cold (from-scratch Adam) vs warm-started
        warm_probe = FusedHallucinationStrategy(dim, 1e6,
                                                fit_steps=fit_steps,
                                                refit_every=10 ** 9)
        warm_probe.gp.fit(X, y)            # warm the jit caches (both step
        warm_probe.gp.fit(X, y)            # counts compile out-of-band)
        t_cold = _time_full_fit(warm_probe, X, y, reps=reps)
        t_warm = _time_warm_refit(warm_probe, X, y, reps=reps)
        _emit(f"refit_cold_n{n}", t_cold * 1e6, "speedup=1.0x")
        _emit(f"refit_warm_n{n}", t_warm * 1e6,
              f"speedup={t_cold / max(t_warm, 1e-12):.1f}x")
        for bs in batch_sizes:
            ref = HallucinationStrategy(dim, 1e6, fit_steps=fit_steps)
            # huge refit_every so the timed steady-state window never
            # crosses a refit boundary (with the default 8, appending
            # bs >= 8 rows would pull the full refit into the window)
            fused = FusedHallucinationStrategy(dim, 1e6,
                                               fit_steps=fit_steps,
                                               refit_every=10 ** 9)
            # warm the jit caches out-of-band
            ref.propose(X, y, C, bs)
            fused.propose(X, y, C, bs)

            # per-rep setups reset strategy state untimed; the fused path
            # pre-observes n - bs rows (synced) so the timed call pays one
            # steady-state tuner iteration, not the first-call full fit
            import jax

            def setup_ref():
                ref.gp.state = None
                ref.gp.n_fit = 0

            def setup_fused():
                fused.gp.state = None
                fused.gp.n_fit = 0
                pfx = max(1, n - bs)
                st = fused.gp.observe(X[:pfx], y[:pfx])
                jax.block_until_ready((st.L, st.ls, st.var, st.noise))

            t_ref, t_fused = _interleaved_medians(
                [lambda: ref.propose(X, y, C, bs),
                 lambda: fused.propose(X, y, C, bs)],
                reps=reps, setups=[setup_ref, setup_fused])
            # amortized whole-loop cost under the default schedule: each
            # iteration appends bs rows, so a refit runs every
            # ceil(refit_every / bs) iterations -> min(1, bs/refit_every)
            # refits per iteration — and steady-state refits are *warm*
            t_amort = t_fused + t_warm * min(1.0, bs / DEFAULT_REFIT_EVERY)
            speedup = t_ref / max(t_fused, 1e-12)
            rows.append((bs, n, t_ref, t_fused, speedup))
            _emit(f"proposal_seed_bs{bs}_n{n}", t_ref * 1e6, "speedup=1.0x")
            _emit(f"proposal_fused_bs{bs}_n{n}", t_fused * 1e6,
                  f"amortized={t_amort * 1e6:.0f}us,speedup={speedup:.1f}x")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="small grid for smoke runs")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write every emitted row as JSON (the CI "
                         "tier-2 job uploads this as BENCH_*.json)")
    args = ap.parse_args()
    if args.quick:
        rows = run(batch_sizes=(4,), n_obs_grid=(64, 256), reps=args.reps)
        run_pallas_pending(n_obs_grid=(64,), reps=args.reps)
        run_perslot_rescore(n_grid=(64, 256), reps=args.reps)
        run_clustering(n_obs_grid=(64,), reps=args.reps)
        kinv_rows = run_kinv_hardening(n_grid=(256,), reps=args.reps)
        tpe_rows = run_tpe(n_cand_grid=(2048,), n_obs_grid=(64, 256),
                           reps=args.reps)
        run_bank_of_one(reps=args.reps)
        retraces = run_single_study_retrace(max_obs=256)
    else:
        rows = run(reps=args.reps)
        run_pallas_pending(reps=args.reps)
        run_perslot_rescore(reps=args.reps)
        run_clustering(reps=args.reps)
        kinv_rows = run_kinv_hardening(reps=args.reps)
        tpe_rows = run_tpe(reps=args.reps)
        run_bank_of_one(reps=args.reps)
        retraces = run_single_study_retrace(max_obs=1024)
    target = [r for r in rows if r[0] == 4 and r[1] == 256]
    if target:
        bs, n, t_ref, t_fused, speedup = target[0]
        print(f"# CLAIM issue1 'fused >= 3x at batch_size=4, n_obs=256': "
              f"{speedup:.1f}x -> {'PASS' if speedup >= 3.0 else 'FAIL'}")
    tpe_target = [s for n, S, s in tpe_rows if S >= 512]
    if tpe_target:
        worst = min(tpe_target)
        print(f"# CLAIM issue4 'tpe fused >= 2x over host at "
              f"n_candidates >= 512': worst {worst:.1f}x -> "
              f"{'PASS' if worst >= 2.0 else 'FAIL'}")
    kinv_target = [o for nn, o in kinv_rows if nn == 1024]
    if kinv_target:
        print(f"# CLAIM issue5 'conditioning hardening <10% over the f32 "
              f"Schur rescore path at n=1024': {kinv_target[0]:+.1f}% -> "
              f"{'PASS' if kinv_target[0] < 10.0 else 'FAIL'}")
    print(f"# CLAIM issue10 'zero steady-state retraces across "
          f"single-study growth (gp/tpe/clustering)': {retraces} -> "
          f"{'PASS' if retraces == 0 else 'FAIL'}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "proposal_latency", "rows": ROWS}, f,
                      indent=2)
        print(f"# wrote {len(ROWS)} rows to {args.json}")
    if retraces:
        sys.exit(1)


if __name__ == "__main__":
    main()
