"""Batch-proposal latency: seed Python-loop GP-BUCB vs the fused path.

Measures one steady-state tuner iteration of ``propose`` — exactly what the
tuner hot loop pays per iteration:

  * ``seed``: ``HallucinationStrategy`` — full O(fit_steps * n^3)
    hyperparameter refit, then a host-roundtripping Python loop over batch
    slots (posterior -> numpy UCB -> hallucinate) per proposal call.
  * ``fused``: ``FusedHallucinationStrategy`` — O(n^2) incremental Cholesky
    appends for the new observations plus one jit'd ``lax.fori_loop`` device
    program for the whole batch.

Grid: batch_size in {1, 4, 16} x n_obs in {16, 64, 256, 512}.  Emits the
repo's ``name,us_per_call,derived`` CSV rows: the fused row's headline
number is the steady-state propose call (the seed refits inside every
propose; the fused path doesn't — that *is* the optimization), and the
``amortized=`` field adds the periodic refit's share under the default
``refit_every=8`` schedule for the whole-loop view.  Acceptance target
(ISSUE 1): fused propose >= 3x at batch_size=4, n_obs=256.

Per n_obs it also emits ``refit_cold_n{n}`` vs ``refit_warm_n{n}``: the
refit-boundary hyperparameter re-tune from scratch vs warm-started from the
previous fit's log-params (ISSUE 2 — the warm path runs a short Adam polish,
``warm_fit_steps``, instead of the full ``fit_steps`` schedule); the
amortized number uses the warm cost, since that is what a steady-state
tuner loop pays.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _time_propose(strategy, X, y, C, bs, *, steady_prefix=None, reps=3):
    """Median seconds for one propose call on (X, y).

    ``steady_prefix``: for the incremental strategy, pre-observe the first
    n - bs rows so the timed call pays what a mid-run tuner iteration pays
    (bs appends + the fused batch program), not the first-call full fit.
    The pre-observed state is synced before the timer starts — JAX dispatch
    is async, so an unsynced fit would silently bleed into the window.
    """
    import jax

    times = []
    for _ in range(reps):
        if hasattr(strategy, "gp"):
            strategy.gp.state = None          # reset stateful caches
            strategy.gp.n_fit = 0
        if steady_prefix is not None:
            st = strategy.gp.observe(X[:steady_prefix], y[:steady_prefix])
            jax.block_until_ready((st.L, st.ls, st.var, st.noise))
        t0 = time.perf_counter()
        picks = strategy.propose(X, y, C, bs)   # host-read picks = synced
        times.append(time.perf_counter() - t0)
        assert len(picks) == bs
    return float(np.median(times))


def _time_full_fit(strategy, X, y, reps=3):
    """Median seconds for a full from-scratch observe (hyperparameter tune)."""
    import jax

    times = []
    for _ in range(reps):
        strategy.gp.state = None
        strategy.gp.n_fit = 0
        strategy.gp._fit_params = None    # cold: default Adam init
        t0 = time.perf_counter()
        st = strategy.gp.observe(X, y)
        jax.block_until_ready((st.L, st.ls, st.var, st.noise))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _time_warm_refit(strategy, X, y, reps=3):
    """Median seconds for a refit-boundary re-tune: Adam warm-started from
    the previous fit's log-params (short polish run) instead of the full
    from-scratch schedule."""
    import jax

    n = len(y)
    times = []
    for _ in range(reps):
        strategy.gp.state = None
        strategy.gp.n_fit = 0
        strategy.gp._fit_params = None
        st = strategy.gp.fit(X[: n - 8], y[: n - 8])   # previous fit
        jax.block_until_ready((st.L, st.ls, st.var, st.noise))
        t0 = time.perf_counter()
        st = strategy.gp.fit(X, y)                     # warm refit
        jax.block_until_ready((st.L, st.ls, st.var, st.noise))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


DEFAULT_REFIT_EVERY = 8   # the Tuner default the amortized number models


def run(batch_sizes=(1, 4, 16), n_obs_grid=(16, 64, 256, 512),
        n_cand=2000, dim=4, fit_steps=40, reps=3, seed=0):
    from repro.core.strategies import (FusedHallucinationStrategy,
                                       HallucinationStrategy)

    rng = np.random.default_rng(seed)
    rows = []
    for n in n_obs_grid:
        X = rng.uniform(size=(n, dim)).astype(np.float32)
        y = np.sum(-(X - 0.5) ** 2, axis=-1).astype(np.float32)
        C = rng.uniform(size=(n_cand, dim)).astype(np.float32)
        # refit-boundary cost: cold (from-scratch Adam) vs warm-started
        warm_probe = FusedHallucinationStrategy(dim, 1e6,
                                                fit_steps=fit_steps,
                                                refit_every=10 ** 9)
        warm_probe.gp.fit(X, y)            # warm the jit caches (both step
        warm_probe.gp.fit(X, y)            # counts compile out-of-band)
        t_cold = _time_full_fit(warm_probe, X, y, reps=reps)
        t_warm = _time_warm_refit(warm_probe, X, y, reps=reps)
        _emit(f"refit_cold_n{n}", t_cold * 1e6, "speedup=1.0x")
        _emit(f"refit_warm_n{n}", t_warm * 1e6,
              f"speedup={t_cold / max(t_warm, 1e-12):.1f}x")
        for bs in batch_sizes:
            ref = HallucinationStrategy(dim, 1e6, fit_steps=fit_steps)
            # huge refit_every so the timed steady-state window never
            # crosses a refit boundary (with the default 8, appending
            # bs >= 8 rows would pull the full refit into the window)
            fused = FusedHallucinationStrategy(dim, 1e6,
                                               fit_steps=fit_steps,
                                               refit_every=10 ** 9)
            # warm the jit caches out-of-band
            ref.propose(X, y, C, bs)
            fused.propose(X, y, C, bs)
            t_ref = _time_propose(ref, X, y, C, bs, reps=reps)
            t_fused = _time_propose(fused, X, y, C, bs,
                                    steady_prefix=max(1, n - bs), reps=reps)
            # amortized whole-loop cost under the default schedule: each
            # iteration appends bs rows, so a refit runs every
            # ceil(refit_every / bs) iterations -> min(1, bs/refit_every)
            # refits per iteration — and steady-state refits are *warm*
            t_amort = t_fused + t_warm * min(1.0, bs / DEFAULT_REFIT_EVERY)
            speedup = t_ref / max(t_fused, 1e-12)
            rows.append((bs, n, t_ref, t_fused, speedup))
            _emit(f"proposal_seed_bs{bs}_n{n}", t_ref * 1e6, "speedup=1.0x")
            _emit(f"proposal_fused_bs{bs}_n{n}", t_fused * 1e6,
                  f"amortized={t_amort * 1e6:.0f}us,speedup={speedup:.1f}x")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="small grid for smoke runs")
    args = ap.parse_args()
    if args.quick:
        rows = run(batch_sizes=(4,), n_obs_grid=(64, 256), reps=args.reps)
    else:
        rows = run(reps=args.reps)
    target = [r for r in rows if r[0] == 4 and r[1] == 256]
    if target:
        bs, n, t_ref, t_fused, speedup = target[0]
        print(f"# CLAIM issue1 'fused >= 3x at batch_size=4, n_obs=256': "
              f"{speedup:.1f}x -> {'PASS' if speedup >= 3.0 else 'FAIL'}")


if __name__ == "__main__":
    main()
